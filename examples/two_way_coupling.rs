//! Two-way rigid⇄cloth coupling (paper Fig. 5a / Fig. 11): a bunny and an
//! armadillo stand on a cloth; the cloth's corners are hoisted and the
//! figurines are lifted. Writes OBJ snapshots to /tmp for inspection.
//!
//! Run: `cargo run --release --example two_way_coupling`

use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::obj::save_obj;
use diffsim::mesh::primitives::{armadillo, bunny, cloth_grid};
use diffsim::mesh::TriMesh;

fn main() -> anyhow::Result<()> {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(cloth_grid(12, 12, 2.4, 2.4), 0.4, 6000.0, 3.0, 2.0);
    let corners = [0usize, 12, 12 * 13, 13 * 13 - 1];
    for &c in &corners {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(bunny(0.22, 1), 0.6).with_position(Vec3::new(-0.35, 0.3, 0.0)),
    );
    sys.add_rigid(
        RigidBody::from_mesh(armadillo(0.22, 1), 0.6).with_position(Vec3::new(0.35, 0.3, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 400.0, ..Default::default() });

    println!("settling...");
    sim.run(150);
    let y0: Vec<f64> = sim.sys.rigids.iter().map(|b| b.translation().y).collect();

    println!("hoisting the cloth corners...");
    for step in 0..600 {
        for &c in &corners {
            sim.sys.cloths[0].x[c].y += 0.0008;
        }
        sim.step();
        if step % 150 == 0 {
            println!(
                "  step {step:4}: bunny y={:.3} armadillo y={:.3} cloth-min={:.3}",
                sim.sys.rigids[0].translation().y,
                sim.sys.rigids[1].translation().y,
                sim.sys.cloths[0].x.iter().map(|p| p.y).fold(f64::MAX, f64::min),
            );
        }
    }
    for (i, b) in sim.sys.rigids.iter().enumerate() {
        let lift = b.translation().y - y0[i];
        println!("figurine {i} lifted by {lift:+.3} m");
        assert!(lift > 0.1, "figurine {i} was not lifted");
    }
    // Snapshot meshes.
    let cloth_mesh = TriMesh {
        verts: sim.sys.cloths[0].x.clone(),
        faces: sim.sys.cloths[0].faces.clone(),
    };
    save_obj(std::path::Path::new("/tmp/coupling_cloth.obj"), &cloth_mesh)?;
    for (i, b) in sim.sys.rigids.iter().enumerate() {
        let world = TriMesh { verts: b.world_verts(), faces: b.mesh0.faces.clone() };
        save_obj(std::path::Path::new(&format!("/tmp/coupling_body{i}.obj")), &world)?;
    }
    println!("wrote /tmp/coupling_*.obj\ntwo_way_coupling OK");
    Ok(())
}
