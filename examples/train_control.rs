//! Learning control (paper Fig. 8a): train the paper's MLP controller by
//! backpropagating through the simulator, and compare with DDPG on the
//! same budget. Logs the two loss curves.
//!
//! Run: `cargo run --release --example train_control [episodes]`

use diffsim::experiments::control::{train_ddpg_sticks, train_ours_sticks};

fn main() {
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    println!("training ours (BPTT through simulator), {episodes} episodes...");
    let ours = train_ours_sticks(episodes, 11);
    println!("training DDPG baseline, {episodes} episodes...");
    let ddpg = train_ddpg_sticks(episodes, 11);
    println!("\n episode    ours-loss    ddpg-loss");
    for i in 0..episodes {
        println!("{i:8}    {:9.4}    {:9.4}", ours[i], ddpg[i]);
    }
    let tail = |v: &[f64]| v.iter().rev().take(5).sum::<f64>() / 5.0;
    println!("\ntail-5 mean: ours {:.4} vs DDPG {:.4}", tail(&ours), tail(&ddpg));
    println!("train_control OK");
}
