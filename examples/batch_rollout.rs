//! Batched rollouts + vectorized gradients: N variants of one scene run
//! in parallel on a `SceneBatch`, and per-scene ∂loss/∂θ comes back from
//! one batched backward — the population workload behind the paper's
//! inverse/control/estimation loops (Figs. 7–9).
//!
//! Run: `cargo run --release --example batch_rollout`

use diffsim::batch::SceneBatch;
use diffsim::bodies::{RigidBody, System};
use diffsim::engine::backward::LossGrad;
use diffsim::engine::SimConfig;
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::ml::adam::Adam;
use diffsim::util::pool::Pool;

fn main() {
    // Scene: a cube sliding on the ground; per-scene parameter θ_i is
    // its initial speed, loss_i = (x_T − target)².
    let n = 8;
    let target = 1.0;
    let steps = 40;
    let thetas: Vec<f64> = (0..n).map(|i| 0.5 + 0.25 * i as f64).collect();
    let mut base = System::new();
    base.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    base.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.502, 0.0)));

    let workers = Pool::machine_workers();
    let cfg = SimConfig { record_tape: true, dt: 1.0 / 100.0, workers, ..Default::default() };
    let thetas_ref = &thetas;
    let mut batch = SceneBatch::from_scene(&base, &cfg, n, |i, sys| {
        sys.rigids[1] = RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.502, 0.0))
            .with_velocity(Vec3::new(thetas_ref[i], 0.0, 0.0));
    });

    // One call: N taped rollouts + N backwards, batched. The lockstep
    // forward pools every fail-safe pass's zone solves across all
    // scenes (one Coordinator::zone_solve_batch call per pass level
    // when a shared coordinator is installed); with the native solver,
    // as here, trajectories are bitwise-identical to the scene-parallel
    // rollout_grad.
    let res = batch.rollout_grad_lockstep(
        steps,
        |_| (),
        |_, _, _, _| {},
        |_, sim, _| {
            let x = sim.sys.rigids[1].translation().x;
            let mut seed = LossGrad::zeros(sim);
            seed.rigid_q[1][3] = 2.0 * (x - target);
            ((x - target) * (x - target), seed)
        },
    );

    println!("scene  theta   final x   loss      dL/dtheta");
    for i in 0..n {
        let x = batch.sim(i).sys.rigids[1].translation().x;
        println!(
            "{i:5}  {:5.2}  {x:8.4}  {:8.5}  {:+9.5}",
            thetas[i],
            res.losses[i],
            res.grads[i].rigid_v0[1][3]
        );
    }

    // Per-scene ∂L/∂θ gathered into ONE contiguous buffer (scene-major),
    // ready for a single optimizer step over the whole population.
    let flat = res.gather_param_grads(1, |_i, g, out| out[0] = g.rigid_v0[1][3]);
    let mut params = thetas.clone();
    let mut opt = Adam::new(n, 0.05);
    opt.step(&mut params, &flat);
    println!("\nmean loss {:.5}; one Adam step over the gathered buffer:", res.mean_loss());
    println!("  theta  {thetas:.2?}");
    println!("  theta' {params:.2?}");

    // Sanity: gradients point every scene toward the target.
    for i in 0..n {
        let x = batch.sim(i).sys.rigids[1].translation().x;
        let g = res.grads[i].rigid_v0[1][3];
        assert!(
            (x < target && g <= 0.0) || (x >= target && g >= 0.0),
            "scene {i}: x={x}, grad={g} points away from the target"
        );
    }

    // The batch shares one cross-scene BatchArena: after a rollout the
    // per-step contact/solver buffers have been checked out and reused
    // instead of allocated per scene per step.
    let a = batch.arena().stats();
    println!(
        "\narena: {} takes, {} reused ({:.0}% hit rate), {} retained",
        a.takes,
        a.hits,
        100.0 * a.hit_rate(),
        diffsim::util::memory::fmt_bytes(a.retained_bytes)
    );
    assert!(a.takes > 0, "pooled batch must route buffers through the arena");
    println!("\nbatch_rollout OK");
}
