//! END-TO-END DRIVER: proves the full three-layer stack composes on a
//! real workload.
//!
//!   L1 (Pallas kernel) → L2 (JAX graph) → `make artifacts` (HLO text)
//!   → rust PJRT runtime → coordinator batching → engine forward +
//!   taped backward → gradient-based optimization of a contact-rich
//!   inverse problem — with the zone backward running through the AOT
//!   PJRT executables, cross-checked against the native path.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use diffsim::bodies::{RigidBody, System};
use diffsim::coordinator::Coordinator;
use diffsim::engine::backward::{backward, LossGrad};
use diffsim::engine::{DiffMode, SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::runtime::Runtime;
use diffsim::util::timer::Timer;
use std::sync::Arc;

const STEPS: usize = 40;

fn episode(force: &[f64], coord: Option<Arc<Coordinator>>) -> (f64, Vec<f64>) {
    // Scene: cube on the ground must be pushed to the target x = 1.2.
    let target = 1.2;
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.502, 0.0)));
    let mut sim = Simulation::new(
        sys,
        SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() },
    );
    if let Some(c) = coord {
        sim.coordinator = Some(c);
        sim.cfg.diff_mode = DiffMode::Pjrt;
    }
    for s in 0..STEPS {
        sim.sys.rigids[1].ext_force = Vec3::new(force[s], 0.0, 0.0);
        sim.step();
    }
    let x = sim.sys.rigids[1].translation().x;
    let loss = (x - target) * (x - target);
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[1][3] = 2.0 * (x - target);
    let g = backward(&sim, &seed);
    (loss, (0..STEPS).map(|s| g.rigid_force[s][1].x).collect())
}

fn main() -> anyhow::Result<()> {
    println!("=== end-to-end: L1 Pallas → L2 JAX → HLO → rust PJRT → gradients ===\n");
    let rt = Arc::new(Runtime::load_default().map_err(|e| {
        anyhow::anyhow!("{e:#}\n  → run `make artifacts` first")
    })?);
    println!("artifacts loaded: {:?}\n", rt.artifact_names());
    let coord = Arc::new(Coordinator::new(rt.clone()));

    // 1. Cross-check: one episode, PJRT gradients vs native gradients.
    let probe = vec![1.0; STEPS];
    let (_, g_native) = episode(&probe, None);
    let (_, g_pjrt) = episode(&probe, Some(coord.clone()));
    let max_rel = g_native
        .iter()
        .zip(&g_pjrt)
        .map(|(a, b)| (a - b).abs() / (1.0 + a.abs()))
        .fold(0.0f64, f64::max);
    println!("PJRT vs native gradient agreement: max rel err = {max_rel:.2e}");
    assert!(max_rel < 5e-3, "PJRT gradients diverge from native");

    // 2. Optimize the force schedule THROUGH the PJRT-backed backward.
    println!("\noptimizing force schedule (gradient descent, PJRT backward):");
    let mut force = vec![0.0; STEPS];
    let t = Timer::start();
    let mut last_loss = f64::MAX;
    for it in 0..20 {
        let (loss, grad) = episode(&force, Some(coord.clone()));
        println!("  iter {it:2}: loss = {loss:.5}");
        for (f, g) in force.iter_mut().zip(&grad) {
            *f -= 500.0 * g;
        }
        last_loss = loss;
    }
    println!("optimized in {:.1}s; final loss {last_loss:.5}", t.seconds());
    assert!(last_loss < 1e-2, "optimization did not converge");

    // 3. Coordinator telemetry: the batching the L3 layer did.
    let m = coord.metrics.lock().unwrap();
    println!("\ncoordinator metrics:\n{}", m.to_json().pretty());
    assert!(m.zone_pjrt_calls > 0, "no zone batches went through PJRT");
    println!("\nend_to_end OK — all three layers compose.");
    Ok(())
}
