//! Quickstart: build a scene (ground + falling bodies + cloth), simulate,
//! and read back state — the 5-minute tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, icosphere, unit_box};

fn main() {
    // 1. Assemble a system: a frozen ground plane, two rigid bodies, and
    //    a pinned cloth.
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 1.5, 0.0)));
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.3, 2), 2.0)
            .with_position(Vec3::new(1.5, 1.0, 0.0))
            .with_velocity(Vec3::new(-1.0, 0.0, 0.0)),
    );
    let mut cloth = Cloth::from_grid(
        cloth_grid(10, 10, 2.0, 2.0).translated(Vec3::new(-2.5, 1.2, 0.0)),
        0.3,
        2000.0,
        2.0,
        1.0,
    );
    cloth.pin(0);
    cloth.pin(10);
    sys.add_cloth(cloth);

    // 2. Configure and run.
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 200.0, ..Default::default() });
    for step in 0..400 {
        sim.step();
        if step % 80 == 0 {
            let s = &sim.last_stats;
            println!(
                "step {step:4}: cube y={:.3}  ball x={:.3}  impacts={}  zones={}  KE={:.3}",
                sim.sys.rigids[1].translation().y,
                sim.sys.rigids[2].translation().x,
                s.impacts,
                s.zones,
                sim.sys.kinetic_energy()
            );
        }
    }

    // 3. Inspect final state.
    println!("\nfinal state:");
    for (i, b) in sim.sys.rigids.iter().enumerate().skip(1) {
        println!("  rigid {i}: pos {:?}", b.translation());
    }
    let lowest = sim.sys.cloths[0].x.iter().map(|p| p.y).fold(f64::MAX, f64::min);
    println!("  cloth lowest node: y = {lowest:.3}");
    assert!((sim.sys.rigids[1].translation().y - 0.5).abs() < 0.05, "cube should rest on ground");
    println!("\nquickstart OK");
}
