//! Repo automation tasks, invoked as `cargo xtask <command>`.
//!
//! The only command today is `lint`: a tree-wide invariant pass over
//! `rust/src` that enforces the correctness rules catalogued in
//! ARCHITECTURE.md §"Correctness & static analysis". It is a CI hard
//! gate; run it locally before pushing:
//!
//! ```text
//! cargo xtask lint            # check the tree (exit 1 on violations)
//! cargo xtask lint --list     # print the rule catalog
//! cargo xtask lint --root DIR # lint DIR/rust/src instead of the repo
//! ```
//!
//! The pass is deliberately line-level lexing (comments and string
//! literals stripped, `#[cfg(test)]` regions tracked) rather than a
//! full parse: zero dependencies, so it builds offline and cannot
//! rot the main crate's dependency graph.

mod lint;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint_cmd(&args[1..]),
        None | Some("--help") | Some("-h") | Some("help") => {
            print_help();
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("xtask: unknown command `{other}`\n");
            print_help();
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    eprintln!("usage: cargo xtask <command>\n");
    eprintln!("commands:");
    eprintln!("  lint [--root DIR] [--list]   invariant pass over rust/src (CI hard gate)");
}

fn lint_cmd(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut list = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--list" => list = true,
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(dir) => root = Some(PathBuf::from(dir)),
                    None => {
                        eprintln!("xtask lint: --root needs a directory argument");
                        return ExitCode::FAILURE;
                    }
                }
            }
            other => {
                eprintln!("xtask lint: unknown flag `{other}`");
                return ExitCode::FAILURE;
            }
        }
        i += 1;
    }

    if list {
        for rule in lint::RULES {
            println!("{:<16} {}", rule.name, rule.desc);
        }
        return ExitCode::SUCCESS;
    }

    let root = root.unwrap_or_else(default_root);
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        eprintln!("xtask lint: no rust/src under {}", root.display());
        return ExitCode::FAILURE;
    }

    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();

    let mut violations = Vec::new();
    for path in &files {
        let source = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("xtask lint: reading {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        };
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        violations.extend(lint::check_file(&rel, &source));
    }

    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        println!("xtask lint: {} files checked, 0 violations", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "xtask lint: {} violation(s) in {} files checked",
            violations.len(),
            files.len()
        );
        ExitCode::FAILURE
    }
}

/// The xtask crate sits directly under the repo root, so the tree to
/// lint is the manifest dir's parent. `--root` overrides for tests.
fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask/ sits under the repo root")
        .to_path_buf()
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}
