//! The invariant pass: named, individually-suppressable rules over
//! `rust/src`, matched line-by-line on a lexed view of each file
//! (string literals and comments blanked out, `#[cfg(test)]` regions
//! tracked so test-only code is exempt from the determinism rules).
//!
//! Suppression syntax (checked against the *raw* line, so it lives in
//! a comment on the flagged line or the line directly above):
//!
//! ```text
//! // lint:allow(rule-name: justification)          — this line / next line
//! // lint:allow-file(rule-name: justification)     — whole file
//! ```
//!
//! Several rules may be named, comma-separated, before the colon.
//! A justification is required by convention (reviewed, not parsed).

use std::fmt;

/// A named invariant. The catalog is documented in ARCHITECTURE.md
/// §"Correctness & static analysis"; keep the two in sync.
pub struct Rule {
    pub name: &'static str,
    pub desc: &'static str,
}

pub const RULES: &[Rule] = &[
    Rule {
        name: "float-ord",
        desc: "no raw float `partial_cmp` — use `f64::total_cmp` (NaN-total, deterministic)",
    },
    Rule {
        name: "hash-iter",
        desc: "no HashMap/HashSet in dispatch/solver/collision paths — iteration order is \
               nondeterministic; use BTreeMap or sorted keys",
    },
    Rule {
        name: "thread-spawn",
        desc: "no thread spawning outside util/pool.rs — all parallelism goes through Pool",
    },
    Rule {
        name: "wallclock",
        desc: "no Instant/SystemTime in numeric paths — wall-clock reads belong in \
               util/timer.rs and util/telemetry.rs",
    },
    Rule {
        name: "safety-comment",
        desc: "every `unsafe` block/fn/impl needs a `// SAFETY:` comment within 5 lines above",
    },
    Rule {
        name: "static-mut",
        desc: "no `static mut` — use atomics, OnceLock, or thread-locals",
    },
    Rule {
        name: "no-bare-unwrap",
        desc: "no bare `.unwrap()`/`.expect(...)` in engine/solver/batch non-test code — \
               these paths feed per-scene fault containment; return a typed error \
               (`SceneError`) or justify the invariant with a lint:allow",
    },
];

/// Directories where HashMap/HashSet *presence* is flagged (the PR-2
/// bug class: hash-ordered iteration feeding dispatch or contact
/// ordering). Elsewhere hash containers are fine.
const HASH_SCOPED_DIRS: &[&str] =
    &["/collision/", "/solver/", "/coordinator/", "/engine/", "/batch/"];

/// Directories where bare `.unwrap()`/`.expect(` is flagged: the fault
/// containment layer (engine step, solvers, batch orchestration) must
/// surface failures as typed `SceneError`s, not process aborts — a
/// panic in one scene otherwise escapes per-scene isolation unless a
/// `catch_unwind` happens to be in the way.
const UNWRAP_SCOPED_DIRS: &[&str] = &["/engine/", "/solver/", "/batch/"];

/// Files allowed to read wall clocks: the observability layer itself.
const WALLCLOCK_EXEMPT: &[&str] = &["util/timer.rs", "util/telemetry.rs"];

/// The one file allowed to spawn threads.
const SPAWN_EXEMPT: &[&str] = &["util/pool.rs"];

#[derive(Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} (suppress: `// lint:allow({}: why)`)",
            self.file, self.line, self.rule, self.msg, self.rule
        )
    }
}

/// Run every rule over one file. `rel` is the repo-relative path with
/// forward slashes (used for the per-directory rule scoping).
pub fn check_file(rel: &str, source: &str) -> Vec<Violation> {
    let raw: Vec<&str> = source.lines().collect();
    let code = strip_comments_and_strings(source);
    let in_test = test_regions(&raw, &code);
    let file_allows = collect_allows(&raw, "lint:allow-file(");

    let mut out = Vec::new();
    let mut push = |rule: &'static str, line_idx: usize, msg: String| {
        out.push(Violation { file: rel.to_string(), line: line_idx + 1, rule, msg });
    };

    for i in 0..raw.len() {
        let line = code.get(i).map(String::as_str).unwrap_or("");
        let allowed = |rule: &str| {
            file_allows.iter().any(|a| a == rule)
                || line_allows(&raw, i).iter().any(|a| a == rule)
        };
        let test_line = in_test[i];

        if !test_line && !allowed("float-ord") && word_hit(line, "partial_cmp") {
            push("float-ord", i, "raw float `partial_cmp`; use `f64::total_cmp`".into());
        }

        if !test_line
            && !allowed("hash-iter")
            && HASH_SCOPED_DIRS.iter().any(|d| rel.contains(d))
            && (word_hit(line, "HashMap") || word_hit(line, "HashSet"))
        {
            push(
                "hash-iter",
                i,
                "hash container in an ordering-sensitive path; use BTreeMap/sorted keys".into(),
            );
        }

        if !test_line
            && !allowed("thread-spawn")
            && !SPAWN_EXEMPT.iter().any(|f| rel.ends_with(f))
            && (line.contains("thread::spawn")
                || line.contains("thread::scope")
                || line.contains("thread::Builder"))
        {
            push("thread-spawn", i, "thread spawn outside util/pool.rs; use Pool".into());
        }

        if !test_line
            && !allowed("wallclock")
            && !WALLCLOCK_EXEMPT.iter().any(|f| rel.ends_with(f))
            && (word_hit(line, "Instant") || word_hit(line, "SystemTime"))
        {
            push("wallclock", i, "wall-clock read in a numeric path".into());
        }

        if !allowed("safety-comment") && unsafe_site(line) && !has_safety_nearby(&raw, i) {
            push(
                "safety-comment",
                i,
                "`unsafe` without a `// SAFETY:` comment within 5 lines above".into(),
            );
        }

        if !allowed("static-mut") && static_mut_hit(line) {
            push("static-mut", i, "`static mut` is banned; use atomics or OnceLock".into());
        }

        if !test_line
            && !allowed("no-bare-unwrap")
            && UNWRAP_SCOPED_DIRS.iter().any(|d| rel.contains(d))
            && (line.contains(".unwrap()") || line.contains(".expect("))
        {
            push(
                "no-bare-unwrap",
                i,
                "bare unwrap/expect in a fault-contained path; return a typed error \
                 (`SceneError`) or justify the invariant"
                    .into(),
            );
        }
    }
    out
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Substring match with identifier-boundary checks on both ends, so
/// `Instant` does not hit `InstantaneousFoo` and `partial_cmp` does
/// not hit `my_partial_cmp_wrapper`.
fn word_hit(code: &str, needle: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(p) = code[start..].find(needle) {
        let p = start + p;
        let before_ok = p == 0 || !is_ident(bytes[p - 1]);
        let end = p + needle.len();
        let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

/// `static` immediately followed by the `mut` keyword.
fn static_mut_hit(code: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(p) = code[start..].find("static") {
        let p = start + p;
        let before_ok = p == 0 || !is_ident(bytes[p - 1]);
        let tail = code[p + "static".len()..].trim_start();
        let mut_kw = tail.strip_prefix("mut").is_some_and(|rest| {
            rest.is_empty() || !is_ident(rest.as_bytes()[0])
        });
        if before_ok && mut_kw {
            return true;
        }
        start = p + 1;
    }
    false
}

/// Does this (stripped) line open an `unsafe` block, fn, impl, trait,
/// or extern block? `unsafe` as a bare fn-pointer type (`unsafe
/// fn(usize)`) is not a site; neither is the word inside strings or
/// comments (already blanked).
fn unsafe_site(code: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(p) = code[start..].find("unsafe") {
        let p = start + p;
        let before_ok = p == 0 || !is_ident(bytes[p - 1]);
        let after = &code[p + "unsafe".len()..];
        let after_ok = after.is_empty() || !is_ident(after.as_bytes()[0]);
        if before_ok && after_ok {
            let t = after.trim_start();
            let opens_block = t.starts_with('{') || t.is_empty();
            let declares = t.strip_prefix("fn ").is_some()
                || t == "impl"
                || t.starts_with("impl ")
                || t.starts_with("impl<")
                || t == "trait"
                || t.starts_with("trait ")
                || t.starts_with("extern ")
                || t.starts_with("extern\"");
            if opens_block || declares {
                return true;
            }
        }
        start = p + 1;
    }
    false
}

/// A `SAFETY:` marker anywhere on the flagged raw line or the 5 raw
/// lines above it (doc comments count: `/// SAFETY:` on an `unsafe
/// fn` states the caller contract).
fn has_safety_nearby(raw: &[&str], i: usize) -> bool {
    (0..=5).any(|d| i >= d && raw[i - d].contains("SAFETY:"))
}

/// Names listed in `marker(name, name: justification)` occurrences on
/// one raw line.
fn marker_names(raw_line: &str, marker: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = raw_line;
    while let Some(p) = rest.find(marker) {
        let after = &rest[p + marker.len()..];
        let Some(close) = after.find(')') else { break };
        let inside = &after[..close];
        let names = inside.split(':').next().unwrap_or("");
        out.extend(names.split(',').map(|n| n.trim().to_string()));
        rest = &after[close + 1..];
    }
    out
}

fn collect_allows(raw: &[&str], marker: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in raw {
        out.extend(marker_names(line, marker));
    }
    out
}

/// Rules suppressed for line `i`: `lint:allow(...)` on the line
/// itself or on the line directly above.
fn line_allows(raw: &[&str], i: usize) -> Vec<String> {
    let mut out = marker_names(raw[i], "lint:allow(");
    if i > 0 {
        out.extend(marker_names(raw[i - 1], "lint:allow("));
    }
    out
}

/// Blank out comments and string/char literals, preserving the line
/// structure and column positions (stripped chars become spaces), so
/// downstream rules only ever see real code tokens.
fn strip_comments_and_strings(src: &str) -> Vec<String> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
    }
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            out.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    cur.push(' ');
                    cur.push(' ');
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(1);
                    cur.push(' ');
                    cur.push(' ');
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.push(' ');
                    i += 1;
                } else if c == 'r'
                    && (i == 0 || !chars[i - 1].is_alphanumeric() && chars[i - 1] != '_')
                {
                    // Possible raw string: r"..." or r#"..."# (any #s).
                    let mut j = i + 1;
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        st = St::RawStr(hashes);
                        for _ in i..=j {
                            cur.push(' ');
                        }
                        i = j + 1;
                    } else {
                        cur.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    if chars.get(i + 1) == Some(&'\\') {
                        // Quote, backslash, escaped char consumed; then
                        // scan to the closing quote (covers \x41, \u{..}).
                        let mut j = i + 3;
                        while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        for _ in i..=j.min(chars.len().saturating_sub(1)) {
                            cur.push(' ');
                        }
                        i = j + 1;
                    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                        cur.push_str("   ");
                        i += 3;
                    } else {
                        // Lifetime (or stray quote): keep, it is code.
                        cur.push(c);
                        i += 1;
                    }
                } else {
                    cur.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.push(' ');
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    cur.push(' ');
                    cur.push(' ');
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(depth + 1);
                    cur.push(' ');
                    cur.push(' ');
                    i += 2;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    cur.push(' ');
                    if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                        cur.push(' ');
                        i += 1;
                    }
                    i += 1;
                } else if c == '"' {
                    st = St::Code;
                    cur.push(' ');
                    i += 1;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        for _ in 0..=hashes as usize {
                            cur.push(' ');
                        }
                        i += 1 + hashes as usize;
                        st = St::Code;
                        continue;
                    }
                }
                cur.push(' ');
                i += 1;
            }
        }
    }
    out.push(cur);
    out
}

/// Mark the line ranges of `#[cfg(test)] mod ...` (and any cfg
/// attribute naming `test`, e.g. `#[cfg(all(loom, test))]`) by brace
/// tracking on the stripped view. Only attributes followed by a `mod`
/// within 3 lines open a region; `#[cfg(test)]` on a lone item (a
/// `use`, a single fn) exempts just the lines up to the item's close.
fn test_regions(raw: &[&str], code: &[String]) -> Vec<bool> {
    let n = raw.len();
    let mut in_test = vec![false; n];
    let mut i = 0;
    while i < n {
        let t = raw[i].trim_start();
        let is_test_cfg = t.starts_with("#[cfg(") && t.contains("test");
        if !is_test_cfg {
            i += 1;
            continue;
        }
        // Find the start of the gated item: skip pure attribute lines
        // (an attribute sharing its line with the item — `#[cfg(test)]
        // mod t {` — counts as the item line, spotted by its brace).
        let mut item = i;
        while item < n {
            let tt = raw[item].trim_start();
            let cl = code.get(item).map(String::as_str).unwrap_or("");
            if tt.starts_with("#[") && !cl.contains('{') {
                item += 1;
            } else {
                break;
            }
        }
        if item >= n || item > i + 3 {
            i += 1;
            continue;
        }
        // Brace-track from the item line to its closing brace (or to
        // the `;` for brace-less items like `use`).
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = item;
        while j < n {
            in_test[j] = true;
            let line = code.get(j).map(String::as_str).unwrap_or("");
            for ch in line.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            if !opened && line.contains(';') {
                break;
            }
            j += 1;
        }
        for k in i..item {
            in_test[k] = true;
        }
        i = j + 1;
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<&'static str> {
        check_file(rel, src).into_iter().map(|v| v.rule).collect()
    }

    /// Join snippet lines into a source string (keeps these tests
    /// inside the repo's own line-length budget).
    fn src(lines: &[&str]) -> String {
        let mut s = lines.join("\n");
        s.push('\n');
        s
    }

    #[test]
    fn float_ord_fires_on_partial_cmp() {
        let bad = src(&[
            "fn f(xs: &mut Vec<f64>) {",
            "    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());",
            "}",
        ]);
        assert_eq!(rules_fired("rust/src/ml/opt.rs", &bad), vec!["float-ord"]);
        let good = "fn f(xs: &mut Vec<f64>) {\n    xs.sort_by(|a, b| a.total_cmp(b));\n}\n";
        assert!(rules_fired("rust/src/ml/opt.rs", good).is_empty());
    }

    #[test]
    fn float_ord_ignores_comments_and_strings() {
        let src = "// partial_cmp is banned\nfn f() { let _ = \"partial_cmp\"; }\n";
        assert!(rules_fired("rust/src/ml/opt.rs", src).is_empty());
    }

    #[test]
    fn hash_iter_fires_only_in_scoped_dirs() {
        let bad = src(&[
            "use std::collections::HashMap;",
            "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }",
        ]);
        let fired = rules_fired("rust/src/collision/foo.rs", &bad);
        assert!(fired.iter().all(|r| *r == "hash-iter") && !fired.is_empty());
        // Same code outside the scoped dirs is fine.
        assert!(rules_fired("rust/src/util/foo.rs", &bad).is_empty());
    }

    #[test]
    fn thread_spawn_fires_outside_pool() {
        let bad = "fn f() { std::thread::spawn(|| {}); }\n";
        assert_eq!(rules_fired("rust/src/solver/lcp.rs", bad), vec!["thread-spawn"]);
        assert!(rules_fired("rust/src/util/pool.rs", bad).is_empty());
    }

    #[test]
    fn wallclock_fires_outside_telemetry() {
        let bad = "use std::time::Instant;\nfn f() { let t = Instant::now(); }\n";
        let fired = rules_fired("rust/src/solver/lcp.rs", bad);
        assert_eq!(fired, vec!["wallclock", "wallclock"]);
        assert!(rules_fired("rust/src/util/timer.rs", bad).is_empty());
        assert!(rules_fired("rust/src/util/telemetry.rs", bad).is_empty());
    }

    #[test]
    fn safety_comment_fires_on_bare_unsafe() {
        let bad = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_fired("rust/src/util/pool.rs", bad), vec!["safety-comment"]);
        let good = src(&[
            "fn f(p: *const u32) -> u32 {",
            "    // SAFETY: caller guarantees p is valid.",
            "    unsafe { *p }",
            "}",
        ]);
        assert!(rules_fired("rust/src/util/pool.rs", &good).is_empty());
    }

    #[test]
    fn safety_comment_fires_on_unsafe_impl() {
        let bad = "struct P(*mut u8);\nunsafe impl Send for P {}\n";
        assert_eq!(rules_fired("rust/src/util/pool.rs", bad), vec!["safety-comment"]);
        let doc = src(&[
            "struct P(*mut u8);",
            "/// SAFETY: P is only handed to one thread at a time.",
            "unsafe impl Send for P {}",
        ]);
        assert!(rules_fired("rust/src/util/pool.rs", &doc).is_empty());
    }

    #[test]
    fn safety_comment_skips_fn_pointer_types() {
        let src = "type Hook = unsafe fn(usize);\n";
        // `unsafe fn(` is a type, not a declaration site.
        assert!(rules_fired("rust/src/util/pool.rs", src).is_empty());
    }

    #[test]
    fn static_mut_fires() {
        let bad = "static mut COUNTER: u64 = 0;\n";
        assert_eq!(rules_fired("rust/src/util/foo.rs", bad), vec!["static-mut"]);
        let good = "static COUNTER: AtomicU64 = AtomicU64::new(0);\n";
        assert!(rules_fired("rust/src/util/foo.rs", good).is_empty());
    }

    #[test]
    fn no_bare_unwrap_fires_in_fault_contained_dirs() {
        let bad = src(&["fn f(x: Option<u32>) -> u32 {", "    x.unwrap()", "}"]);
        assert_eq!(rules_fired("rust/src/engine/mod.rs", &bad), vec!["no-bare-unwrap"]);
        let exp = "fn f(x: Option<u32>) -> u32 { x.expect(\"caller sets x\") }\n";
        assert_eq!(rules_fired("rust/src/solver/lcp.rs", exp), vec!["no-bare-unwrap"]);
        // Outside the scoped dirs the same code is fine.
        assert!(rules_fired("rust/src/util/pool.rs", &bad).is_empty());
        // Recoverable forms don't trip the substring match.
        let ok = "fn f(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 0) }\n";
        assert!(rules_fired("rust/src/batch/mod.rs", ok).is_empty());
    }

    #[test]
    fn no_bare_unwrap_exempts_tests_and_allows() {
        let tests = src(&[
            "#[cfg(test)]",
            "mod tests {",
            "    fn t(x: Option<u32>) -> u32 { x.unwrap() }",
            "}",
        ]);
        assert!(rules_fired("rust/src/batch/pipeline.rs", &tests).is_empty());
        let allowed = src(&[
            "fn f(x: Option<u32>) -> u32 {",
            "    // lint:allow(no-bare-unwrap: invariant — x is Some by construction)",
            "    x.unwrap()",
            "}",
        ]);
        assert!(rules_fired("rust/src/engine/mod.rs", &allowed).is_empty());
    }

    #[test]
    fn line_allow_suppresses_on_same_and_previous_line() {
        let same = src(&[
            "fn f(a: f64, b: f64) {",
            "    let _ = a.partial_cmp(&b); // lint:allow(float-ord: NaN-free)",
            "}",
        ]);
        assert!(rules_fired("rust/src/ml/opt.rs", &same).is_empty());
        let above = src(&[
            "fn f(a: f64, b: f64) {",
            "    // lint:allow(float-ord: NaN-free by construction)",
            "    let _ = a.partial_cmp(&b);",
            "}",
        ]);
        assert!(rules_fired("rust/src/ml/opt.rs", &above).is_empty());
    }

    #[test]
    fn file_allow_suppresses_everywhere() {
        let code = src(&[
            "// lint:allow-file(wallclock: telemetry-gated timings only)",
            "use std::time::Instant;",
            "fn f() { let _ = Instant::now(); }",
        ]);
        assert!(rules_fired("rust/src/solver/lcp.rs", &code).is_empty());
    }

    #[test]
    fn allow_lists_multiple_rules() {
        let code = src(&[
            "fn f(a: f64, b: f64) {",
            "    // lint:allow(float-ord, wallclock: both fine here)",
            "    let _ = a.partial_cmp(&b);",
            "}",
        ]);
        assert!(rules_fired("rust/src/ml/opt.rs", &code).is_empty());
    }

    #[test]
    fn test_regions_are_exempt_from_determinism_rules() {
        let code = src(&[
            "fn prod() {}",
            "",
            "#[cfg(test)]",
            "mod tests {",
            "    use std::collections::HashSet;",
            "    #[test]",
            "    fn t() {",
            "        let _ = std::time::Instant::now();",
            "        let _: HashSet<u32> = HashSet::new();",
            "    }",
            "}",
        ]);
        assert!(rules_fired("rust/src/collision/foo.rs", &code).is_empty());
    }

    #[test]
    fn test_region_ends_at_closing_brace() {
        let code = src(&[
            "#[cfg(test)]",
            "mod tests {",
            "    fn t() {}",
            "}",
            "",
            "fn prod(a: f64, b: f64) {",
            "    let _ = a.partial_cmp(&b);",
            "}",
        ]);
        assert_eq!(rules_fired("rust/src/ml/opt.rs", &code), vec!["float-ord"]);
    }

    #[test]
    fn cfg_test_on_lone_use_does_not_swallow_following_code() {
        let code = src(&[
            "#[cfg(test)]",
            "use std::collections::HashSet;",
            "fn prod(a: f64, b: f64) {",
            "    let _ = a.partial_cmp(&b);",
            "}",
        ]);
        assert_eq!(rules_fired("rust/src/collision/foo.rs", &code), vec!["float-ord"]);
    }

    #[test]
    fn loom_cfg_counts_as_test_region() {
        let code = src(&[
            "#[cfg(all(loom, test))]",
            "mod loom_tests {",
            "    fn t() { let _ = std::time::Instant::now(); }",
            "}",
        ]);
        assert!(rules_fired("rust/src/util/foo.rs", &code).is_empty());
    }

    #[test]
    fn raw_strings_and_char_literals_are_stripped() {
        let src = "fn f() -> (&'static str, char) { (r#\"partial_cmp \" quote\"#, '\"') }\n";
        assert!(rules_fired("rust/src/ml/opt.rs", src).is_empty());
    }

    #[test]
    fn every_rule_has_a_catalog_entry() {
        // The Display impl points users at the rule name; make sure
        // every name the checker can emit exists in RULES.
        let emitted = [
            "float-ord",
            "hash-iter",
            "thread-spawn",
            "wallclock",
            "safety-comment",
            "static-mut",
            "no-bare-unwrap",
        ];
        for name in emitted {
            assert!(RULES.iter().any(|r| r.name == name), "missing catalog entry: {name}");
        }
    }

    /// The real tree must be clean: this is the same check CI runs as
    /// a hard gate, wired as a unit test so `cargo test -p xtask`
    /// alone catches regressions.
    #[test]
    fn tree_is_clean() {
        let root = crate::default_root();
        let src = root.join("rust").join("src");
        assert!(src.is_dir(), "expected rust/src under {}", root.display());
        let mut files = Vec::new();
        crate::collect_rs(&src, &mut files);
        files.sort();
        assert!(!files.is_empty());
        let mut violations = Vec::new();
        for path in &files {
            let source = std::fs::read_to_string(path).expect("read source file");
            let rel = path
                .strip_prefix(&root)
                .unwrap_or(path)
                .to_string_lossy()
                .replace('\\', "/");
            violations.extend(check_file(&rel, &source));
        }
        let rendered: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
        assert!(violations.is_empty(), "tree has lint violations:\n{}", rendered.join("\n"));
    }
}
