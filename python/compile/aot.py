"""AOT export: lower the L2 graphs to HLO *text* and write
artifacts/manifest.json for the rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction-id
protos, but `HloModuleProto::from_text_file` reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.rigid_transform import TILE

# Exported buckets. The rust coordinator pads work into the smallest
# fitting bucket; shapes here are the contract (mirrored in manifest.json).
RIGID_BATCHES = [128, 512, 2048]
# (n dofs, m constraints, batch) per zone-backward bucket.
ZONE_BUCKETS = [(6, 8, 16), (12, 16, 16), (24, 32, 8), (48, 64, 4)]
# Cloth grids (nx, nz).
CLOTH_GRIDS = [(8, 8), (16, 16)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides big constants (baked index tables!) as '{...}', which the
    # text parser then silently zero-fills — the computation runs but
    # gathers garbage.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO printer elided a large constant"
    return text


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec_json(s):
    return {"shape": list(s.shape), "dtype": "f32"}


def export(outdir):
    os.makedirs(outdir, exist_ok=True)
    manifest = []

    def emit(name, fn, specs, outputs_doc):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        manifest.append(
            {
                "name": name,
                "path": path,
                "inputs": [spec_json(s) for s in specs],
                "outputs": outputs_doc,
            }
        )
        print(f"  {name}: {len(text) / 1024:.0f} KiB hlo")

    for b in RIGID_BATCHES:
        emit(
            f"rigid_transform_b{b}",
            model.rigid_transform_model,
            [f32(b, 6), f32(b, 3)],
            [{"shape": [b, 3], "dtype": "f32"}, {"shape": [b, 18], "dtype": "f32"}],
        )

    for n, m, b in ZONE_BUCKETS:
        emit(
            f"zone_backward_n{n}_m{m}_b{b}",
            model.zone_backward_model,
            [f32(b, n, n), f32(b, m, n), f32(b, m), f32(b, n)],
            [{"shape": [b, n], "dtype": "f32"}],
        )

    for nx, nz in CLOTH_GRIDS:
        step = model.make_cloth_step(nx, nz)
        nv = step.n_verts
        ns = step.n_springs_padded
        emit(
            f"cloth_step_r{nx}x{nz}",
            step,
            [
                f32(nv, 3),  # x
                f32(nv, 3),  # v
                f32(nv, 3),  # ext
                f32(nv),  # pinned (0/1)
                f32(nv),  # node_mass
                f32(ns, 1),  # rest lengths
                f32(1),  # k_stretch
                f32(1),  # k_bend
                f32(1),  # damping
                f32(1),  # h
                f32(1),  # gy
            ],
            [{"shape": [nv, 3], "dtype": "f32"}],
        )

    meta = {
        "tile": TILE,
        "rigid_batches": RIGID_BATCHES,
        "zone_buckets": ZONE_BUCKETS,
        "cloth_grids": CLOTH_GRIDS,
        "artifacts": manifest,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {len(manifest)} artifacts + manifest to {outdir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    export(args.outdir)


if __name__ == "__main__":
    main()
