"""L1 Pallas kernel: batched spring forces (cloth stretch/bend elements).

Per edge e = (i, j): f_i = k_e (|d| - L0_e) d/|d|, d = x_j - x_i (and
f_j = -f_i, applied by the caller's segment-sum). The gather (edge ->
endpoint positions) and scatter (force accumulation) are jnp ops in the
surrounding L2 graph; the kernel is the dense per-edge arithmetic, tiled
over the edge batch.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _kernel(xi_ref, xj_ref, l0_ref, k_ref, f_ref):
    dx = xj_ref[:, 0] - xi_ref[:, 0]
    dy = xj_ref[:, 1] - xi_ref[:, 1]
    dz = xj_ref[:, 2] - xi_ref[:, 2]
    l2 = dx * dx + dy * dy + dz * dz
    l = jnp.sqrt(jnp.maximum(l2, 1e-24))
    coeff = k_ref[:, 0] * (l - l0_ref[:, 0]) / l
    f_ref[:, 0] = coeff * dx
    f_ref[:, 1] = coeff * dy
    f_ref[:, 2] = coeff * dz


def spring_forces(xi, xj, l0, k):
    """Force on endpoint i of each spring. xi/xj: (B,3); l0/k: (B,1)."""
    b = xi.shape[0]
    assert b % TILE == 0, f"batch {b} not a multiple of {TILE}"
    return pl.pallas_call(
        _kernel,
        grid=(b // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 3), xi.dtype),
        interpret=True,
    )(xi, xj, l0, k)
