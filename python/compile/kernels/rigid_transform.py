"""L1 Pallas kernel: batched rigid vertex transform + Jacobian.

Computes, for a batch of (generalized coordinate, body-frame point) pairs,
the world position x = R(r)·p0 + t (paper Eq. 23) and the 3x6 Jacobian
nabla-f (Eq. 24 / Appendix C). This is the innermost op of both constraint
assembly and implicit differentiation: it runs for every contact vertex,
every zone-solver iteration, and every backward pass.

TPU mapping (DESIGN.md section 7): the batch dimension is tiled into VMEM
blocks via BlockSpec; the per-element math is pure VPU elementwise work.
On this image the kernel runs with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); the lowered HLO is what `aot.py` ships to rust.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM tile. 128 aligns with the TPU lane width; the batch is
# padded to a multiple by the caller (aot.py exports per-bucket shapes).
TILE = 128


def _kernel(q_ref, p0_ref, x_ref, jac_ref):
    """One (TILE, ...) block: q (TILE, 6), p0 (TILE, 3) ->
    x (TILE, 3), jac (TILE, 18) [row-major 3x6]."""
    phi = q_ref[:, 0]
    theta = q_ref[:, 1]
    psi = q_ref[:, 2]
    sp, cp = jnp.sin(phi), jnp.cos(phi)
    st, ct = jnp.sin(theta), jnp.cos(theta)
    ss, cs = jnp.sin(psi), jnp.cos(psi)
    px, py, pz = p0_ref[:, 0], p0_ref[:, 1], p0_ref[:, 2]

    # Rotation matrix R = Rz(psi) Ry(theta) Rx(phi) (Appendix B).
    r11 = ct * cs
    r12 = -cp * ss + sp * st * cs
    r13 = sp * ss + cp * st * cs
    r21 = ct * ss
    r22 = cp * cs + sp * st * ss
    r23 = -sp * cs + cp * st * ss
    r31 = -st
    r32 = sp * ct
    r33 = cp * ct

    x_ref[:, 0] = r11 * px + r12 * py + r13 * pz + q_ref[:, 3]
    x_ref[:, 1] = r21 * px + r22 * py + r23 * pz + q_ref[:, 4]
    x_ref[:, 2] = r31 * px + r32 * py + r33 * pz + q_ref[:, 5]

    # dR/dphi = Rz Ry dRx, dR/dtheta = Rz dRy Rx, dR/dpsi = dRz Ry Rx —
    # expanded analytically (matches euler::rotation_derivs on the rust
    # side and the finite-difference oracle in ref.py).
    # --- dR/dphi (only R's phi-dependent entries are columns 2,3) ---
    dphi_r12 = sp * ss + cp * st * cs
    dphi_r13 = cp * ss - sp * st * cs
    dphi_r22 = -sp * cs + cp * st * ss
    dphi_r23 = -cp * cs - sp * st * ss
    dphi_r32 = cp * ct
    dphi_r33 = -sp * ct
    jx_phi = dphi_r12 * py + dphi_r13 * pz
    jy_phi = dphi_r22 * py + dphi_r23 * pz
    jz_phi = dphi_r32 * py + dphi_r33 * pz

    # --- dR/dtheta ---
    dth_r11 = -st * cs
    dth_r12 = sp * ct * cs
    dth_r13 = cp * ct * cs
    dth_r21 = -st * ss
    dth_r22 = sp * ct * ss
    dth_r23 = cp * ct * ss
    dth_r31 = -ct
    dth_r32 = -sp * st
    dth_r33 = -cp * st
    jx_th = dth_r11 * px + dth_r12 * py + dth_r13 * pz
    jy_th = dth_r21 * px + dth_r22 * py + dth_r23 * pz
    jz_th = dth_r31 * px + dth_r32 * py + dth_r33 * pz

    # --- dR/dpsi ---
    dps_r11 = -ct * ss
    dps_r12 = -cp * cs - sp * st * ss
    dps_r13 = sp * cs - cp * st * ss
    dps_r21 = ct * cs
    dps_r22 = -cp * ss + sp * st * cs
    dps_r23 = sp * ss + cp * st * cs
    jx_ps = dps_r11 * px + dps_r12 * py + dps_r13 * pz
    jy_ps = dps_r21 * px + dps_r22 * py + dps_r23 * pz
    jz_ps = 0.0 * px  # dR3k/dpsi = 0

    one = jnp.ones_like(px)
    zero = jnp.zeros_like(px)
    # jac rows: x -> [jx_phi jx_th jx_ps 1 0 0], y -> [... 0 1 0], z -> [... 0 0 1]
    jac_ref[:, 0] = jx_phi
    jac_ref[:, 1] = jx_th
    jac_ref[:, 2] = jx_ps
    jac_ref[:, 3] = one
    jac_ref[:, 4] = zero
    jac_ref[:, 5] = zero
    jac_ref[:, 6] = jy_phi
    jac_ref[:, 7] = jy_th
    jac_ref[:, 8] = jy_ps
    jac_ref[:, 9] = zero
    jac_ref[:, 10] = one
    jac_ref[:, 11] = zero
    jac_ref[:, 12] = jz_phi
    jac_ref[:, 13] = jz_th
    jac_ref[:, 14] = jz_ps
    jac_ref[:, 15] = zero
    jac_ref[:, 16] = zero
    jac_ref[:, 17] = one


@functools.partial(jax.jit, static_argnames=())
def rigid_transform_jac(q, p0):
    """Batched f(q) and nabla-f. q: (B, 6), p0: (B, 3) -> ((B, 3), (B, 18)).

    B must be a multiple of TILE (aot.py exports padded buckets).
    """
    b = q.shape[0]
    assert b % TILE == 0, f"batch {b} not a multiple of {TILE}"
    grid = (b // TILE,)
    x, jac = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, 6), lambda i: (i, 0)),
            pl.BlockSpec((TILE, 3), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE, 18), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 3), q.dtype),
            jax.ShapeDtypeStruct((b, 18), q.dtype),
        ],
        interpret=True,
    )(q, p0)
    return x, jac
