# L1: Pallas kernels for the engine's compute hot-spots.
from . import ref  # noqa: F401
from .rigid_transform import rigid_transform_jac  # noqa: F401
from .springs import spring_forces  # noqa: F401
