"""Pure-jnp oracles for the Pallas kernels — the correctness reference
pytest sweeps against (and the rust engine mirrors in f64)."""

import jax.numpy as jnp


def rotation(r):
    """R = Rz(psi) Ry(theta) Rx(phi) for r = (phi, theta, psi). (..., 3) -> (..., 3, 3)."""
    phi, theta, psi = r[..., 0], r[..., 1], r[..., 2]
    sp, cp = jnp.sin(phi), jnp.cos(phi)
    st, ct = jnp.sin(theta), jnp.cos(theta)
    ss, cs = jnp.sin(psi), jnp.cos(psi)
    rows = [
        [ct * cs, -cp * ss + sp * st * cs, sp * ss + cp * st * cs],
        [ct * ss, cp * cs + sp * st * ss, -sp * cs + cp * st * ss],
        [-st, sp * ct, cp * ct],
    ]
    return jnp.stack([jnp.stack(row, axis=-1) for row in rows], axis=-2)


def rigid_transform_jac_ref(q, p0, eps=1e-6):
    """Oracle via jnp rotation + central finite differences for the
    Jacobian's rotational columns (translation columns are identity).
    Computed in float64 so the FD truncation/rounding error sits well
    below the f32 kernel tolerance being verified."""
    q = q.astype(jnp.float64)
    p0 = p0.astype(jnp.float64)
    r, t = q[:, :3], q[:, 3:]
    x = jnp.einsum("bij,bj->bi", rotation(r), p0) + t
    cols = []
    for a in range(3):
        dr = jnp.zeros_like(r).at[:, a].set(eps)
        xp = jnp.einsum("bij,bj->bi", rotation(r + dr), p0)
        xm = jnp.einsum("bij,bj->bi", rotation(r - dr), p0)
        cols.append((xp - xm) / (2 * eps))
    dcols = jnp.stack(cols, axis=-1)  # (B, 3, 3): d x / d angles
    eye = jnp.broadcast_to(jnp.eye(3, dtype=q.dtype), dcols.shape)
    jac = jnp.concatenate([dcols, eye], axis=-1)  # (B, 3, 6)
    return x, jac.reshape(q.shape[0], 18)


def spring_forces_ref(xi, xj, l0, k):
    d = xj - xi
    l = jnp.maximum(jnp.linalg.norm(d, axis=-1, keepdims=True), 1e-12)
    return k * (l - l0) * d / l


def zone_backward_ref(mass, jac, lam, grad_z, active_eps=1e-10, reg=1e-9):
    """Oracle for the zone implicit-diff backward (numpy, one item):
    grad_q = g - J_A^T (J_A M^-1 J_A^T + reg I)^-1 J_A M^-1 g over the
    active rows (lambda > eps). Mirrors diff::implicit on the rust side."""
    import numpy as np

    mass = np.asarray(mass, dtype=np.float64)
    jac = np.asarray(jac, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    g = np.asarray(grad_z, dtype=np.float64)
    mask = (lam > active_eps).astype(np.float64)
    ja = jac * mask[:, None]
    minv_g = np.linalg.solve(mass, g)
    minv_jat = np.linalg.solve(mass, ja.T)
    s = ja @ minv_jat + reg * np.eye(jac.shape[0])
    w = np.linalg.solve(s, ja @ minv_g)
    return g - ja.T @ w
