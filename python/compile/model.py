"""L2: the JAX compute graphs that `aot.py` lowers to HLO for the rust
coordinator. Three entry points:

* `rigid_transform_model` — batched vertex transform + Jacobian (wraps the
  L1 Pallas kernel): the inner op of constraint assembly (paper Eq. 23/24).
* `zone_backward_model` — batched implicit-diff backward of the zone
  projection (paper section 6): active-set Schur complement solved with
  fixed-iteration CG (pure HLO ops — no LAPACK custom calls, which the
  standalone PJRT runtime cannot execute).
* `cloth_step_model` — one implicit-Euler cloth velocity update (Eq. 3)
  for a fixed grid resolution: spring forces via the L1 Pallas kernel,
  matrix-free Jacobian products, fixed-iteration CG.

Everything here is shape-static; the rust coordinator pads into the
exported buckets (see artifacts/manifest.json).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels.rigid_transform import TILE, rigid_transform_jac
from .kernels.springs import spring_forces


def rigid_transform_model(q, p0):
    """(B, 6), (B, 3) -> ((B, 3), (B, 18)); B multiple of TILE."""
    return rigid_transform_jac(q, p0)


# --------------------------------------------------------------------------
# Zone backward (paper Eqs. 9/14-15, Schur-complement form).
# --------------------------------------------------------------------------

CG_ITERS = 96
ACTIVE_EPS = 1e-8
REG_REL = 1e-4
REG_ABS = 1e-7


def _cg(matvec, b, iters):
    """Fixed-iteration conjugate gradients (SPD), shape-static."""

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = jnp.maximum(jnp.vdot(p, ap), 1e-30)
        alpha = rs / denom
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        return x, r, p, rs_new

    x0 = jnp.zeros_like(b)
    carry = (x0, b, b, jnp.vdot(b, b))
    x, *_ = lax.fori_loop(0, iters, body, carry)
    return x


def _zone_backward_single(mass, jac, lam, g):
    m = jac.shape[0]
    mask = (lam > ACTIVE_EPS).astype(mass.dtype)
    ja = jac * mask[:, None]
    msolve = lambda b: _cg(lambda v: mass @ v, b, CG_ITERS)
    minv_g = msolve(g)
    # S = Ja M^-1 Ja^T (m x m, small). Conditioning for f32 CG: active
    # rows get a trace-relative Tikhonov shift (active sets are routinely
    # rank-deficient); inactive/empty rows are pinned to ~identity scale
    # so they cannot drive the iteration to NaN.
    minv_jat = jax.vmap(msolve, in_axes=1, out_axes=1)(ja.T)  # (n, m)
    s = ja @ minv_jat
    tr = jnp.trace(s) / m
    reg = REG_REL * tr + REG_ABS
    diag = jnp.where(mask > 0.5, reg, 1.0 + tr)
    s = s + jnp.diag(diag)
    w = _cg(lambda v: s @ v, ja @ minv_g, CG_ITERS)
    return g - ja.T @ w


def zone_backward_model(mass, jac, lam, g):
    """Batched zone backward.
    mass: (B, n, n), jac: (B, m, n), lam: (B, m), g: (B, n) -> (B, n)."""
    return jax.vmap(_zone_backward_single)(mass, jac, lam, g)


# --------------------------------------------------------------------------
# Cloth implicit-Euler step for a fixed grid (Eq. 3).
# --------------------------------------------------------------------------


def grid_topology(nx, nz):
    """Mirror of rust `mesh::primitives::cloth_grid` + `build_topology`:
    vertices (i, k) -> i*(nz+1)+k, alternating diagonals, unique edges,
    bend pairs (opposite vertices of face-adjacent triangles)."""
    idx = lambda i, k: i * (nz + 1) + k
    faces = []
    for i in range(nx):
        for k in range(nz):
            if (i + k) % 2 == 0:
                faces.append((idx(i, k), idx(i + 1, k), idx(i + 1, k + 1)))
                faces.append((idx(i, k), idx(i + 1, k + 1), idx(i, k + 1)))
            else:
                faces.append((idx(i, k), idx(i + 1, k), idx(i, k + 1)))
                faces.append((idx(i + 1, k), idx(i + 1, k + 1), idx(i, k + 1)))
    edge_faces = {}
    edges = []
    for fi, f in enumerate(faces):
        for a, b in ((f[0], f[1]), (f[1], f[2]), (f[2], f[0])):
            key = (min(a, b), max(a, b))
            if key not in edge_faces:
                edge_faces[key] = []
                edges.append(key)
            edge_faces[key].append(fi)
    bend = []
    for key in edges:
        fs = edge_faces[key]
        if len(fs) == 2:
            opp = []
            for fi in fs:
                opp.append(next(v for v in faces[fi] if v not in key))
            bend.append((opp[0], opp[1]))
    return np.array(faces), np.array(edges), np.array(bend)


def grid_positions(nx, nz, size_x, size_z):
    verts = np.zeros(((nx + 1) * (nz + 1), 3))
    vi = 0
    for i in range(nx + 1):
        for k in range(nz + 1):
            verts[vi] = [
                size_x * (i / nx - 0.5),
                0.0,
                size_z * (k / nz - 0.5),
            ]
            vi += 1
    return verts


def make_cloth_step(nx, nz, size_x=1.0, size_z=1.0, cg_iters=96):
    """Build a shape-static cloth step fn for an (nx, nz) grid.

    Returns `step(x, v, ext, pinned, node_mass, k_stretch, k_bend,
    damping, h, gy) -> dv` with all-array args (scalars as (1,) arrays).
    """
    _, edges_np, bend_np = grid_topology(nx, nz)
    springs_np = np.concatenate([edges_np, bend_np], axis=0)
    n_edges = len(edges_np)
    n_springs = len(springs_np)
    pad = (-n_springs) % TILE
    nv = (nx + 1) * (nz + 1)
    del size_x, size_z  # rest lengths are a runtime input (see `step`)

    spr_i = jnp.array(np.concatenate([springs_np[:, 0], np.zeros(pad, np.int64)]))
    spr_j = jnp.array(np.concatenate([springs_np[:, 1], np.zeros(pad, np.int64)]))
    # 1 for stretch springs, 0 for bend springs (scaled by k at call time);
    # padded springs get k = 0 so i == j == 0 contributes nothing.
    is_stretch = jnp.array(
        np.concatenate(
            [np.ones(n_edges), np.zeros(n_springs - n_edges), np.zeros(pad)]
        ),
        dtype=jnp.float32,
    ).reshape(-1, 1)
    is_bend = jnp.array(
        np.concatenate(
            [np.zeros(n_edges), np.ones(n_springs - n_edges), np.zeros(pad)]
        ),
        dtype=jnp.float32,
    ).reshape(-1, 1)

    def spring_k(k_stretch, k_bend):
        return is_stretch * k_stretch + is_bend * k_bend

    def forces(x, v, ext, pinned, node_mass, rest, ks, kb, damping, gy):
        xi = x[spr_i]
        xj = x[spr_j]
        f_edge = spring_forces(xi, xj, rest, spring_k(ks, kb))
        f = jnp.zeros_like(x)
        f = f.at[spr_i].add(f_edge)
        f = f.at[spr_j].add(-f_edge)
        grav = jnp.stack(
            [jnp.zeros_like(node_mass), gy * node_mass, jnp.zeros_like(node_mass)],
            axis=-1,
        )
        f = f + grav + ext - damping * node_mass[:, None] * v
        return f * (1.0 - pinned)[:, None]

    def jx_product(x, p, pinned, rest, ks, kb):
        """(SPD-clamped) spring Jacobian times p, matrix-free."""
        d = x[spr_j] - x[spr_i]
        l2 = jnp.sum(d * d, axis=-1, keepdims=True)
        l = jnp.sqrt(jnp.maximum(l2, 1e-24))
        dn = d / l
        k = spring_k(ks, kb)
        pm = p * (1.0 - pinned)[:, None]
        dp = pm[spr_j] - pm[spr_i]
        lateral = k * jnp.maximum(1.0 - rest / l, 0.0)
        along = jnp.sum(dn * dp, axis=-1, keepdims=True) * dn
        jdp = lateral * (dp - along) + k * along
        out = jnp.zeros_like(p)
        out = out.at[spr_i].add(jdp)
        out = out.at[spr_j].add(-jdp)
        return out * (1.0 - pinned)[:, None]

    def step(x, v, ext, pinned, node_mass, rest, ks, kb, damping, h, gy):
        """rest: (S, 1) per-spring rest lengths (S = padded spring count,
        zeros in the padding)."""
        ks = ks[0]
        kb = kb[0]
        damping = damping[0]
        h = h[0]
        gy = gy[0]
        f0 = forces(x, v, ext, pinned, node_mass, rest, ks, kb, damping, gy)
        vm = v * (1.0 - pinned)[:, None]
        jv = jx_product(x, vm, pinned, rest, ks, kb)
        b = h * (f0 + h * jv) * (1.0 - pinned)[:, None]

        def amat(p):
            # A p = M p - h (df/dv) p - h^2 Jx p; pinned rows identity.
            mp = node_mass[:, None] * p
            drag = -damping * node_mass[:, None] * p
            out = mp - h * drag - h * h * jx_product(x, p, pinned, rest, ks, kb)
            return jnp.where(pinned[:, None] > 0.5, p, out)

        flat = lambda a: a.reshape(-1)
        unflat = lambda a: a.reshape(nv, 3)
        dv = _cg(lambda pf: flat(amat(unflat(pf))), flat(b), cg_iters)
        return unflat(dv) * (1.0 - pinned)[:, None]

    step.n_springs_padded = n_springs + pad
    step.n_verts = nv
    return step
