"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps
shapes and values) — the CORE kernel correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    rigid_transform_jac_ref,
    spring_forces_ref,
    zone_backward_ref,
)
from compile.kernels.rigid_transform import TILE, rigid_transform_jac
from compile.kernels.springs import spring_forces


def rand(rng, *shape, lo=-2.0, hi=2.0):
    return jnp.asarray(
        rng.uniform(lo, hi, size=shape).astype(np.float32)
    )


@settings(max_examples=8, deadline=None)
@given(tiles=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_rigid_transform_matches_ref(tiles, seed):
    rng = np.random.default_rng(seed)
    b = tiles * TILE
    q = rand(rng, b, 6)
    p0 = rand(rng, b, 3)
    x, jac = rigid_transform_jac(q, p0)
    xr, jacr = rigid_transform_jac_ref(q, p0)
    np.testing.assert_allclose(x, xr, rtol=1e-4, atol=1e-4)
    # f32 kernel vs f64 FD oracle: tolerance = f32 accuracy class.
    np.testing.assert_allclose(jac, jacr, rtol=1e-3, atol=1e-3)


def test_rigid_transform_identity():
    q = jnp.zeros((TILE, 6), jnp.float32)
    p0 = jnp.arange(TILE * 3, dtype=jnp.float32).reshape(TILE, 3) / 100.0
    x, jac = rigid_transform_jac(q, p0)
    np.testing.assert_allclose(x, p0, atol=1e-7)
    jac = jac.reshape(TILE, 3, 6)
    np.testing.assert_allclose(jac[:, :, 3:], np.broadcast_to(np.eye(3), (TILE, 3, 3)), atol=1e-7)


def test_rigid_transform_translation_only():
    rng = np.random.default_rng(0)
    q = jnp.concatenate(
        [jnp.zeros((TILE, 3), jnp.float32), rand(rng, TILE, 3)], axis=1
    )
    p0 = rand(rng, TILE, 3)
    x, _ = rigid_transform_jac(q, p0)
    np.testing.assert_allclose(x, p0 + q[:, 3:], atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(tiles=st.integers(1, 2), seed=st.integers(0, 2**31 - 1))
def test_spring_forces_match_ref(tiles, seed):
    rng = np.random.default_rng(seed)
    b = tiles * TILE
    xi = rand(rng, b, 3)
    xj = rand(rng, b, 3)
    l0 = rand(rng, b, 1, lo=0.1, hi=2.0)
    k = rand(rng, b, 1, lo=0.0, hi=100.0)
    f = spring_forces(xi, xj, l0, k)
    fr = spring_forces_ref(xi, xj, l0, k)
    np.testing.assert_allclose(f, fr, rtol=1e-4, atol=1e-4)


def test_spring_force_at_rest_is_zero():
    xi = jnp.zeros((TILE, 3), jnp.float32)
    xj = jnp.zeros((TILE, 3), jnp.float32).at[:, 0].set(1.0)
    l0 = jnp.ones((TILE, 1), jnp.float32)
    k = jnp.full((TILE, 1), 50.0, jnp.float32)
    f = spring_forces(xi, xj, l0, k)
    np.testing.assert_allclose(f, 0.0, atol=1e-6)


def test_spring_force_direction():
    # Stretched spring pulls i toward j.
    xi = jnp.zeros((TILE, 3), jnp.float32)
    xj = jnp.zeros((TILE, 3), jnp.float32).at[:, 1].set(2.0)
    l0 = jnp.ones((TILE, 1), jnp.float32)
    k = jnp.ones((TILE, 1), jnp.float32)
    f = spring_forces(xi, xj, l0, k)
    assert float(f[0, 1]) > 0.9  # k (l - l0) = 1.0 toward +y


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_zone_backward_model_matches_ref(seed):
    """L2 graph (fixed-iteration CG Schur) vs dense numpy oracle."""
    from compile.model import zone_backward_model

    rng = np.random.default_rng(seed)
    bsz, n, m = 4, 6, 8
    base = rng.normal(size=(bsz, n, n)).astype(np.float32)
    mass = np.einsum("bij,bkj->bik", base, base) + 3.0 * np.eye(n, dtype=np.float32)
    jac = rng.normal(size=(bsz, m, n)).astype(np.float32)
    lam = np.abs(rng.normal(size=(bsz, m))).astype(np.float32)
    lam[:, m // 2 :] = 0.0  # half inactive
    g = rng.normal(size=(bsz, n)).astype(np.float32)
    out = np.asarray(zone_backward_model(mass, jac, lam, g))
    for b in range(bsz):
        want = zone_backward_ref(mass[b], jac[b], lam[b], g[b])
        # f32 fixed-iteration CG vs f64 direct solve: loose tolerance.
        np.testing.assert_allclose(out[b], want, rtol=3e-2, atol=3e-2)


def test_zone_backward_no_active_is_identity():
    from compile.model import zone_backward_model

    rng = np.random.default_rng(3)
    bsz, n, m = 2, 6, 8
    mass = np.broadcast_to(np.eye(n, dtype=np.float32), (bsz, n, n)).copy()
    jac = rng.normal(size=(bsz, m, n)).astype(np.float32)
    lam = np.zeros((bsz, m), np.float32)
    g = rng.normal(size=(bsz, n)).astype(np.float32)
    out = np.asarray(zone_backward_model(mass, jac, lam, g))
    np.testing.assert_allclose(out, g, rtol=1e-5, atol=1e-5)
