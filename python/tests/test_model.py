"""L2 model checks: cloth step physics + topology parity with the rust
mesh builder, and AOT lowering smoke."""

import jax.numpy as jnp
import numpy as np

from compile.model import grid_positions, grid_topology, make_cloth_step


def build_inputs(nx, nz, step, rho=0.2):
    nv = step.n_verts
    verts = grid_positions(nx, nz, 1.0, 1.0).astype(np.float32)
    faces, edges, bend = grid_topology(nx, nz)
    # Node masses: rho * adjacent face area / 3 (mirrors rust).
    node_mass = np.zeros(nv, np.float32)
    for f in faces:
        a, b, c = verts[f[0]], verts[f[1]], verts[f[2]]
        area = 0.5 * np.linalg.norm(np.cross(b - a, c - a))
        for v in f:
            node_mass[v] += rho * area / 3.0
    springs = np.concatenate([edges, bend], axis=0)
    rest = np.linalg.norm(verts[springs[:, 0]] - verts[springs[:, 1]], axis=-1)
    rest_padded = np.zeros((step.n_springs_padded, 1), np.float32)
    rest_padded[: len(rest), 0] = rest
    return verts, node_mass, rest_padded


def test_grid_topology_counts():
    faces, edges, bend = grid_topology(4, 3)
    assert len(faces) == 4 * 3 * 2
    # Euler for a disc: V - E + F = 1.
    v = 5 * 4
    assert v - len(edges) + len(faces) == 1
    # Interior edges only in bend pairs; boundary = 2*(4+3).
    assert len(bend) == len(edges) - 2 * (4 + 3)


def test_cloth_free_fall():
    nx = nz = 8
    step = make_cloth_step(nx, nz)
    x, node_mass, rest = build_inputs(nx, nz, step)
    nv = step.n_verts
    zeros = np.zeros((nv, 3), np.float32)
    one = lambda v: np.array([v], np.float32)
    dv = step(
        jnp.asarray(x),
        jnp.asarray(zeros),
        jnp.asarray(zeros),
        jnp.zeros(nv, jnp.float32),
        jnp.asarray(node_mass),
        jnp.asarray(rest),
        one(500.0),
        one(2.0),
        one(0.0),
        one(0.01),
        one(-9.8),
    )
    # Rest state + gravity: dv = h*g on every node.
    np.testing.assert_allclose(np.asarray(dv)[:, 1], -0.098, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dv)[:, [0, 2]], 0.0, atol=1e-5)


def test_cloth_pinned_nodes_zero():
    nx = nz = 8
    step = make_cloth_step(nx, nz)
    x, node_mass, rest = build_inputs(nx, nz, step)
    nv = step.n_verts
    pinned = np.zeros(nv, np.float32)
    pinned[0] = 1.0
    pinned[nz] = 1.0
    one = lambda v: np.array([v], np.float32)
    zeros = np.zeros((nv, 3), np.float32)
    dv = np.asarray(
        step(
            jnp.asarray(x),
            jnp.asarray(zeros),
            jnp.asarray(zeros),
            jnp.asarray(pinned),
            jnp.asarray(node_mass),
            jnp.asarray(rest),
            one(500.0),
            one(2.0),
            one(0.0),
            one(0.01),
            one(-9.8),
        )
    )
    assert abs(dv[0]).max() < 1e-7
    assert abs(dv[nz]).max() < 1e-7
    assert dv[nv // 2, 1] < -0.05


def test_cloth_hang_simulation_stable():
    nx = nz = 8
    step = make_cloth_step(nx, nz)
    x, node_mass, rest = build_inputs(nx, nz, step)
    nv = step.n_verts
    pinned = np.zeros(nv, np.float32)
    pinned[0] = 1.0
    pinned[nz] = 1.0
    one = lambda v: np.array([v], np.float32)
    v = np.zeros((nv, 3), np.float32)
    ext = np.zeros((nv, 3), np.float32)
    h = 0.02
    for _ in range(100):
        dv = np.asarray(
            step(
                jnp.asarray(x),
                jnp.asarray(v),
                jnp.asarray(ext),
                jnp.asarray(pinned),
                jnp.asarray(node_mass),
                jnp.asarray(rest),
                one(2000.0),
                one(5.0),
                one(0.5),
                one(h),
                one(-9.8),
            )
        )
        v = (v + dv) * (1.0 - pinned)[:, None]
        x = x + h * v
        assert np.isfinite(x).all()
        assert np.abs(x).max() < 10.0
    # Draped below the pins.
    assert x[:, 1].min() < -0.3


def test_aot_lowering_produces_hlo_text(tmp_path):
    """Smoke: every artifact lowers to parseable HLO text."""
    from compile import aot

    # Shrink the export set for test speed.
    old = (aot.RIGID_BATCHES, aot.ZONE_BUCKETS, aot.CLOTH_GRIDS)
    aot.RIGID_BATCHES = [128]
    aot.ZONE_BUCKETS = [(6, 8, 4)]
    aot.CLOTH_GRIDS = [(4, 4)]
    try:
        aot.export(str(tmp_path))
    finally:
        aot.RIGID_BATCHES, aot.ZONE_BUCKETS, aot.CLOTH_GRIDS = old
    manifest = (tmp_path / "manifest.json").read_text()
    import json

    meta = json.loads(manifest)
    assert len(meta["artifacts"]) == 3
    for art in meta["artifacts"]:
        text = (tmp_path / art["path"]).read_text()
        assert "HloModule" in text
        assert "ENTRY" in text
