# Enable float64 so the oracles in kernels/ref.py really run in double
# precision (the kernels themselves keep their explicit f32 dtypes).
import jax

jax.config.update("jax_enable_x64", True)
