//! Infrastructure substrates. The offline image vendors only the `xla`
//! crate and its dependencies, so the usual ecosystem crates (rand, serde,
//! clap, tokio, criterion, proptest) are re-implemented here at the scale
//! this engine needs.
//!
//! The memory/concurrency substrate is three layers that compose:
//! [`pool`] (the persistent worker runtime every parallel path runs on),
//! [`scratch`] (per-worker thread-local solver temporaries), and
//! [`arena`] (the cross-scene [`arena::BatchArena`] pooling per-step
//! batch buffers), with [`memory`] providing the category-level
//! logical-bytes accounting all of them report through.
pub mod arena;
pub mod bench;
pub mod cli;
pub mod faultinject;
pub mod json;
pub mod logging;
pub mod memory;
pub mod pool;
pub mod quick;
pub mod scratch;
pub mod rng;
pub mod telemetry;
pub mod timer;
