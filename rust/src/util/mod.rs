//! Infrastructure substrates. The offline image vendors only the `xla`
//! crate and its dependencies, so the usual ecosystem crates (rand, serde,
//! clap, tokio, criterion, proptest) are re-implemented here at the scale
//! this engine needs.
pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod memory;
pub mod pool;
pub mod quick;
pub mod scratch;
pub mod rng;
pub mod timer;
