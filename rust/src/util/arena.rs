//! Cross-scene memory pooling: the shape-keyed [`BatchArena`].
//!
//! A batch of N scenes ([`crate::batch::SceneBatch`]) repeats the same
//! per-step allocations N times: collision candidate/contact lists
//! ([`crate::collision::detect_in`], and the incremental pipeline's
//! cull-cache scratch in [`crate::collision::detect_incremental`]),
//! per-zone solver state
//! ([`crate::solver::zone_solver::ZoneProblem::build_in`]), and — across
//! rollouts — tape record storage
//! ([`crate::diff::tape::StepRecord::recycle`]). Left independent, batch
//! memory scales as `n_scenes × worst_case` and allocator traffic scales
//! with `n_scenes × steps × passes`. The arena makes those buffers a
//! shared, reusable resource: scenes check buffers out per (scene, step),
//! and return them when the step (or the tape) is done, so a warm batch
//! holds roughly `max_live` buffer sets — bounded by the worker budget of
//! the pool driving the batch ([`crate::util::pool::Pool`]), not by the
//! population size.
//!
//! This is the cross-scene second slice of the ROADMAP's memory-pooling
//! item; the first slice, [`crate::util::scratch`], pools *thread-local*
//! solver temporaries and stays as-is underneath this layer.
//!
//! # Shape keying
//!
//! Shelved buffers are keyed by element type and a power-of-two size
//! class of their capacity. A checkout for capacity `c` probes its own
//! class and the next two larger ones (a capacity-0 hint takes any class
//! — right for accumulator lists whose final size is unknown); a miss
//! falls back to a fresh allocation. Classes are approximate: a reused
//! buffer may still regrow, `Vec` handles that transparently.
//!
//! # Modes and the no-arena fallback
//!
//! * [`BatchArena::disabled`] (the [`Default`], and what a standalone
//!   [`crate::engine::Simulation`] starts with): every checkout is a
//!   plain allocation, every return a plain drop, and nothing is
//!   charged to any tracker — zero overhead, byte-for-byte the
//!   pre-arena behavior.
//! * [`BatchArena::tracked`]: no pooling, but checkouts are charged to
//!   the [`MemTracker`] categories — the instrumented "no-arena"
//!   baseline the `batch_memory` bench compares against.
//! * [`BatchArena::new`] (pooled): reuse *and* accounting. Parked bytes
//!   are charged to [`MemCategory::ArenaRetained`]; a retention cap
//!   (default [`DEFAULT_RETAIN_CAP`]) drops returns that would exceed
//!   it, so a pathological workload degrades to plain allocation
//!   instead of hoarding.
//!
//! # Invariants
//!
//! * **Bitwise parity.** Every checkout is cleared (or zero-filled)
//!   before it is handed out and fully overwritten before use; buffer
//!   *contents* never depend on pooling history, so trajectories and
//!   gradients are bitwise-identical with the arena on, off, shared, or
//!   per-scene (asserted in `rust/tests/integration_batch.rs`).
//! * **Determinism.** Shelf state affects only which allocation backs a
//!   buffer, never control flow or numerics. Concurrent checkouts from
//!   pool workers race only for *which* parked allocation they receive.
//! * **Panic behavior.** Arena paths never panic on exhaustion (a miss
//!   allocates) and guard drops during unwinding skip a poisoned shelf
//!   lock rather than aborting; the arena stays usable after a caught
//!   task panic, like [`crate::util::pool`].
//! * **Accounting is advisory.** Charges saturate; losing track of a
//!   loan distorts a report, never correctness.
//!
//! # RAII vs. loans
//!
//! Short-lived buffers use the [`ArenaVec`] guard (returned on drop).
//! Buffers embedded in longer-lived structs (`ZoneProblem::q0`, zone
//! mass matrices, tape records) are *loaned* as plain `Vec`s and handed
//! back explicitly — [`crate::solver::zone_solver::ZoneProblem::retire`]
//! on commit for untaped steps, [`crate::diff::tape::StepRecord::recycle`]
//! at `clear_tape` for taped ones.

use crate::util::memory::{self, MemCategory, MemTracker};
use std::any::{Any, TypeId};
use std::collections::{BTreeMap, HashMap};
use std::mem::size_of;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Default retention cap per pooled arena: beyond this many parked
/// bytes, returned buffers are dropped instead of shelved. The working
/// set of a 16-scene contact-rich batch is a few MiB, so the default
/// never bites in practice while still bounding pathological retention.
pub const DEFAULT_RETAIN_CAP: usize = 64 << 20;

// Process-wide mirrors of every arena's reuse counters, so experiment
// drivers can report arena behavior without holding the (function-local)
// arena handles. Retained bytes decrement when an arena is dropped.
static P_TAKES: AtomicU64 = AtomicU64::new(0);
static P_HITS: AtomicU64 = AtomicU64::new(0);
static P_MISSES: AtomicU64 = AtomicU64::new(0);
static P_PARKS: AtomicU64 = AtomicU64::new(0);
static P_EVICTIONS: AtomicU64 = AtomicU64::new(0);
static P_RETAINED_BYTES: AtomicUsize = AtomicUsize::new(0);
static P_RETAINED_BUFS: AtomicUsize = AtomicUsize::new(0);

/// Snapshot of an arena's reuse behavior (or, via [`process_stats`],
/// of every arena in the process).
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Checkouts requested (pooled arenas only).
    pub takes: u64,
    /// Checkouts served from a parked buffer.
    pub hits: u64,
    /// Checkouts that fell back to a fresh allocation.
    pub misses: u64,
    /// Buffers successfully parked on return.
    pub parks: u64,
    /// Returns dropped because the retention cap was reached.
    pub evictions: u64,
    /// Bytes currently parked.
    pub retained_bytes: usize,
    /// Buffers currently parked.
    pub retained_buffers: usize,
}

impl ArenaStats {
    /// Fraction of checkouts served from a parked buffer.
    pub fn hit_rate(&self) -> f64 {
        if self.takes == 0 {
            0.0
        } else {
            self.hits as f64 / self.takes as f64
        }
    }
}

/// Process-wide [`ArenaStats`] aggregated over every arena ever created
/// (retained counts reflect arenas still alive).
pub fn process_stats() -> ArenaStats {
    ArenaStats {
        takes: P_TAKES.load(Ordering::Relaxed),
        hits: P_HITS.load(Ordering::Relaxed),
        misses: P_MISSES.load(Ordering::Relaxed),
        parks: P_PARKS.load(Ordering::Relaxed),
        evictions: P_EVICTIONS.load(Ordering::Relaxed),
        retained_bytes: P_RETAINED_BYTES.load(Ordering::Relaxed),
        retained_buffers: P_RETAINED_BUFS.load(Ordering::Relaxed),
    }
}

/// Size class: index of the power of two covering `cap`.
fn class_of(cap: usize) -> u8 {
    cap.max(1).next_power_of_two().trailing_zeros() as u8
}

/// Shelved buffers: element type → size class → parked allocations.
/// Buffers are type-erased (`Vec<T>` boxed as `Any`); the `TypeId` key
/// guarantees every downcast succeeds.
struct Shelves {
    by_type: HashMap<TypeId, BTreeMap<u8, Vec<Box<dyn Any + Send>>>>,
    retained_bytes: usize,
    retained_buffers: usize,
}

struct Inner {
    shelves: Mutex<Shelves>,
    retain_cap: usize,
    tracker: Arc<MemTracker>,
    takes: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    parks: AtomicU64,
    evictions: AtomicU64,
}

impl Drop for Inner {
    fn drop(&mut self) {
        if let Ok(sh) = self.shelves.get_mut() {
            self.tracker.free_cat(MemCategory::ArenaRetained, sh.retained_bytes);
            let cur_bytes = P_RETAINED_BYTES.load(Ordering::Relaxed);
            P_RETAINED_BYTES.fetch_sub(sh.retained_bytes.min(cur_bytes), Ordering::Relaxed);
            let cur_bufs = P_RETAINED_BUFS.load(Ordering::Relaxed);
            P_RETAINED_BUFS.fetch_sub(sh.retained_buffers.min(cur_bufs), Ordering::Relaxed);
        }
    }
}

/// Cheap-to-clone handle to one cross-scene buffer arena (or to the
/// disabled/tracked fallbacks — see the module docs for the modes).
#[derive(Clone)]
pub struct BatchArena {
    inner: Option<Arc<Inner>>,
    /// Charge checkouts/loans to `tracker` categories. True for pooled
    /// and tracked arenas, false for disabled ones.
    charge: bool,
    tracker: Arc<MemTracker>,
}

impl Default for BatchArena {
    fn default() -> BatchArena {
        BatchArena::disabled()
    }
}

impl BatchArena {
    /// Pooled arena with the default retention cap, charging the
    /// [`memory::global`] tracker.
    pub fn new() -> BatchArena {
        BatchArena::pooled_with(DEFAULT_RETAIN_CAP, memory::global().clone())
    }

    /// Pooled arena with an explicit retention cap and tracker.
    pub fn pooled_with(retain_cap: usize, tracker: Arc<MemTracker>) -> BatchArena {
        let inner = Inner {
            shelves: Mutex::new(Shelves {
                by_type: HashMap::new(),
                retained_bytes: 0,
                retained_buffers: 0,
            }),
            retain_cap,
            tracker: tracker.clone(),
            takes: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            parks: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        };
        BatchArena { inner: Some(Arc::new(inner)), charge: true, tracker }
    }

    /// No pooling, no accounting — the zero-overhead standalone default.
    pub fn disabled() -> BatchArena {
        BatchArena { inner: None, charge: false, tracker: memory::global().clone() }
    }

    /// No pooling, but checkouts/loans are charged to the global
    /// tracker's categories (the instrumented "no-arena" baseline).
    pub fn tracked() -> BatchArena {
        BatchArena::tracked_with(memory::global().clone())
    }

    /// [`BatchArena::tracked`] against an injected tracker.
    pub fn tracked_with(tracker: Arc<MemTracker>) -> BatchArena {
        BatchArena { inner: None, charge: true, tracker }
    }

    /// Whether returns are actually shelved (pooled mode).
    pub fn is_pooling(&self) -> bool {
        self.inner.is_some()
    }

    /// The tracker this arena charges.
    pub fn tracker(&self) -> &MemTracker {
        &self.tracker
    }

    /// Reuse counters (zeros for disabled/tracked arenas).
    pub fn stats(&self) -> ArenaStats {
        let Some(inner) = &self.inner else {
            return ArenaStats::default();
        };
        let (retained_bytes, retained_buffers) = match inner.shelves.lock() {
            Ok(sh) => (sh.retained_bytes, sh.retained_buffers),
            Err(_) => (0, 0),
        };
        ArenaStats {
            takes: inner.takes.load(Ordering::Relaxed),
            hits: inner.hits.load(Ordering::Relaxed),
            misses: inner.misses.load(Ordering::Relaxed),
            parks: inner.parks.load(Ordering::Relaxed),
            evictions: inner.evictions.load(Ordering::Relaxed),
            retained_bytes,
            retained_buffers,
        }
    }

    /// Register `bytes` as application-held under `cat` (no-op for
    /// disabled arenas). Public so domain layers can transfer a loan
    /// between categories (e.g. Solver → Tape when a zone record moves
    /// onto the tape).
    pub fn charge(&self, cat: MemCategory, bytes: usize) {
        if self.charge && bytes > 0 {
            self.tracker.alloc_cat(cat, bytes);
        }
    }

    /// Release a [`BatchArena::charge`], saturating.
    pub fn uncharge(&self, cat: MemCategory, bytes: usize) {
        if self.charge && bytes > 0 {
            self.tracker.free_cat(cat, bytes);
        }
    }

    /// Pop a parked `Vec<T>` for requested capacity `cap` (0 = any),
    /// cleared; `None` on miss or when not pooling.
    fn take_raw<T: Send + 'static>(&self, cap: usize) -> Option<Vec<T>> {
        let inner = self.inner.as_ref()?;
        inner.takes.fetch_add(1, Ordering::Relaxed);
        P_TAKES.fetch_add(1, Ordering::Relaxed);
        let mut popped: Option<Box<dyn Any + Send>> = None;
        {
            let mut sh = inner.shelves.lock().expect("arena shelf lock");
            if let Some(bins) = sh.by_type.get_mut(&TypeId::of::<Vec<T>>()) {
                // Empty class lists are removed eagerly, so any present
                // key has a buffer — no temporary key collection needed
                // under the lock. A capacity-0 hint takes the *largest*
                // class so growing accumulators start from the biggest
                // parked buffer instead of regrowing a small one.
                let key = if cap == 0 {
                    bins.keys().next_back().copied()
                } else {
                    let k = class_of(cap);
                    bins.range(k..=k.saturating_add(2)).map(|(&c, _)| c).next()
                };
                if let Some(k) = key {
                    if let Some(list) = bins.get_mut(&k) {
                        if let Some(b) = list.pop() {
                            if list.is_empty() {
                                bins.remove(&k);
                            }
                            popped = Some(b);
                        }
                    }
                }
            }
            if let Some(b) = &popped {
                let bytes = b
                    .downcast_ref::<Vec<T>>()
                    .map(|v| v.capacity() * size_of::<T>())
                    .unwrap_or(0);
                sh.retained_bytes = sh.retained_bytes.saturating_sub(bytes);
                sh.retained_buffers = sh.retained_buffers.saturating_sub(1);
            }
        }
        match popped {
            Some(boxed) => {
                let mut v = *boxed.downcast::<Vec<T>>().expect("shelf keyed by TypeId");
                let bytes = v.capacity() * size_of::<T>();
                self.tracker.free_cat(MemCategory::ArenaRetained, bytes);
                let cur_bytes = P_RETAINED_BYTES.load(Ordering::Relaxed);
                P_RETAINED_BYTES.fetch_sub(bytes.min(cur_bytes), Ordering::Relaxed);
                let cur_bufs = P_RETAINED_BUFS.load(Ordering::Relaxed);
                P_RETAINED_BUFS.fetch_sub(1usize.min(cur_bufs), Ordering::Relaxed);
                inner.hits.fetch_add(1, Ordering::Relaxed);
                P_HITS.fetch_add(1, Ordering::Relaxed);
                v.clear();
                Some(v)
            }
            None => {
                inner.misses.fetch_add(1, Ordering::Relaxed);
                P_MISSES.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Park a `Vec<T>` for reuse (drop when not pooling, capacity-0, or
    /// over the retention cap). Does not touch category charges other
    /// than [`MemCategory::ArenaRetained`].
    fn park_raw<T: Send + 'static>(&self, v: Vec<T>) {
        let Some(inner) = &self.inner else {
            return;
        };
        let bytes = v.capacity() * size_of::<T>();
        if bytes == 0 {
            return;
        }
        // Tolerate a poisoned lock (guard drops run during unwinding).
        let Ok(mut sh) = inner.shelves.lock() else {
            return;
        };
        if sh.retained_bytes + bytes > inner.retain_cap {
            inner.evictions.fetch_add(1, Ordering::Relaxed);
            P_EVICTIONS.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let class = class_of(v.capacity());
        sh.by_type
            .entry(TypeId::of::<Vec<T>>())
            .or_default()
            .entry(class)
            .or_default()
            .push(Box::new(v));
        sh.retained_bytes += bytes;
        sh.retained_buffers += 1;
        drop(sh);
        inner.parks.fetch_add(1, Ordering::Relaxed);
        P_PARKS.fetch_add(1, Ordering::Relaxed);
        P_RETAINED_BYTES.fetch_add(bytes, Ordering::Relaxed);
        P_RETAINED_BUFS.fetch_add(1, Ordering::Relaxed);
        self.tracker.alloc_cat(MemCategory::ArenaRetained, bytes);
    }

    /// RAII checkout: an empty `Vec<T>`-like buffer with capacity at
    /// least `cap` (0 = reuse anything), charged to `cat`, returned to
    /// the arena when the guard drops. A reused buffer from a slightly
    /// smaller size class is topped up here, so the capacity contract
    /// holds and any growth happens once at checkout, not mid-use.
    pub fn vec<T: Send + 'static>(&self, cap: usize, cat: MemCategory) -> ArenaVec<T> {
        let mut v = self
            .take_raw::<T>(cap)
            .unwrap_or_else(|| if cap == 0 { Vec::new() } else { Vec::with_capacity(cap) });
        if v.capacity() < cap {
            v.reserve(cap);
        }
        let charged = v.capacity() * size_of::<T>();
        self.charge(cat, charged);
        ArenaVec { vec: v, charged, cat, home: self.clone() }
    }

    /// Loan a zero-filled `Vec<f64>` of exactly `len` elements, charged
    /// to `cat` — bitwise-identical to `vec![0.0; len]`. On a shelf miss
    /// (and always for disabled/tracked arenas) this *is*
    /// `vec![0.0; len]`, so the plain-allocation path keeps its
    /// `alloc_zeroed` behavior instead of paying an explicit memset.
    /// Pair with [`BatchArena::retire_f64`] (or park +
    /// [`BatchArena::uncharge`]).
    pub fn loan_f64_zeroed(&self, len: usize, cat: MemCategory) -> Vec<f64> {
        self.charge(cat, len * 8);
        match self.take_raw::<f64>(len) {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Hand back a [`BatchArena::loan_f64_zeroed`] of `charged_len`
    /// elements: releases the charge and parks the allocation.
    pub fn retire_f64(&self, v: Vec<f64>, charged_len: usize, cat: MemCategory) {
        self.uncharge(cat, charged_len * 8);
        self.park_raw(v);
    }

    /// Loan an empty, uncharged `Vec<T>` (capacity hint `cap`; 0 = reuse
    /// anything). For accumulators whose bytes are accounted by their
    /// eventual owner (e.g. tape records). Return via
    /// [`BatchArena::park_vec`].
    pub fn loan_vec<T: Send + 'static>(&self, cap: usize) -> Vec<T> {
        self.take_raw(cap)
            .unwrap_or_else(|| if cap == 0 { Vec::new() } else { Vec::with_capacity(cap) })
    }

    /// Park an arbitrary `Vec<T>` for reuse without touching category
    /// charges (retained bytes are still accounted).
    pub fn park_vec<T: Send + 'static>(&self, v: Vec<T>) {
        self.park_raw(v);
    }
}

/// RAII arena checkout: derefs to `Vec<T>`, releases its category
/// charge and parks the allocation on drop.
pub struct ArenaVec<T: Send + 'static> {
    vec: Vec<T>,
    charged: usize,
    cat: MemCategory,
    home: BatchArena,
}

impl<T: Send + 'static> ArenaVec<T> {
    /// Re-sync the category charge to the buffer's current capacity
    /// (call after a fill that may have grown it, so peak accounting
    /// sees the growth).
    pub fn recharge(&mut self) {
        let now = self.vec.capacity() * size_of::<T>();
        if now > self.charged {
            self.home.charge(self.cat, now - self.charged);
            self.charged = now;
        }
    }

    /// Detach the buffer from the arena (charge released, nothing
    /// parked) — the plain-`Vec` escape hatch.
    pub fn into_inner(mut self) -> Vec<T> {
        self.home.uncharge(self.cat, self.charged);
        self.charged = 0;
        std::mem::take(&mut self.vec)
    }
}

impl<T: Send + 'static> Deref for ArenaVec<T> {
    type Target = Vec<T>;
    fn deref(&self) -> &Vec<T> {
        &self.vec
    }
}

impl<T: Send + 'static> DerefMut for ArenaVec<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.vec
    }
}

impl<T: Send + 'static> Drop for ArenaVec<T> {
    fn drop(&mut self) {
        self.home.uncharge(self.cat, self.charged);
        let v = std::mem::take(&mut self.vec);
        self.home.park_raw(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh() -> (BatchArena, Arc<MemTracker>) {
        let t = Arc::new(MemTracker::new());
        (BatchArena::pooled_with(DEFAULT_RETAIN_CAP, t.clone()), t)
    }

    #[test]
    fn checkout_park_reuse_roundtrip() {
        let (a, _t) = fresh();
        {
            let mut g: ArenaVec<u64> = a.vec(100, MemCategory::Contacts);
            g.extend(0..50u64);
        } // parked here
        let s = a.stats();
        assert_eq!(s.takes, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.parks, 1);
        assert_eq!(s.retained_buffers, 1);
        assert!(s.retained_bytes >= 100 * 8);
        // Same size class → hit, and contents start cleared.
        let g: ArenaVec<u64> = a.vec(90, MemCategory::Contacts);
        assert!(g.is_empty());
        assert!(g.capacity() >= 90);
        let s = a.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.retained_buffers, 0, "checked out again");
    }

    #[test]
    fn size_classes_separate_small_and_large() {
        let (a, _t) = fresh();
        drop(a.vec::<u64>(100, MemCategory::Contacts)); // class of 128
        let _big: ArenaVec<u64> = a.vec(4000, MemCategory::Contacts); // class of 4096
        let s = a.stats();
        assert_eq!(s.hits, 0, "a 4000-cap request must not reuse a 128-cap buffer");
        assert_eq!(s.misses, 2);
        // But a capacity-0 hint takes anything.
        let any: ArenaVec<u64> = a.vec(0, MemCategory::Contacts);
        assert!(any.capacity() >= 100);
        assert_eq!(a.stats().hits, 1);
    }

    #[test]
    fn retention_cap_evicts_instead_of_hoarding() {
        let t = Arc::new(MemTracker::new());
        let a = BatchArena::pooled_with(256, t.clone());
        drop(a.vec::<u64>(16, MemCategory::Contacts)); // 128 bytes parked
        drop(a.vec::<u64>(64, MemCategory::Contacts)); // 512 bytes: over cap
        let s = a.stats();
        assert_eq!(s.parks, 1);
        assert_eq!(s.evictions, 1);
        assert!(s.retained_bytes <= 256, "cap respected: {}", s.retained_bytes);
        assert_eq!(t.current_cat(MemCategory::ArenaRetained), s.retained_bytes);
    }

    #[test]
    fn loans_are_zeroed_charged_and_retired() {
        let (a, t) = fresh();
        let mut v = a.loan_f64_zeroed(32, MemCategory::Solver);
        assert_eq!(v.len(), 32);
        assert!(v.iter().all(|&x| x == 0.0));
        assert_eq!(t.current_cat(MemCategory::Solver), 32 * 8);
        v[7] = 3.25; // dirty it
        a.retire_f64(v, 32, MemCategory::Solver);
        assert_eq!(t.current_cat(MemCategory::Solver), 0);
        assert!(t.current_cat(MemCategory::ArenaRetained) >= 32 * 8);
        // The reused loan is zeroed again — stale contents never leak.
        let v2 = a.loan_f64_zeroed(32, MemCategory::Solver);
        assert!(v2.iter().all(|&x| x == 0.0));
        assert_eq!(a.stats().hits, 1);
    }

    #[test]
    fn guard_charges_follow_capacity_and_release_on_drop() {
        let (a, t) = fresh();
        {
            let mut g: ArenaVec<u8> = a.vec(64, MemCategory::Contacts);
            assert_eq!(t.current_cat(MemCategory::Contacts), g.capacity());
            g.extend(std::iter::repeat(7u8).take(1000)); // grows
            g.recharge();
            assert_eq!(t.current_cat(MemCategory::Contacts), g.capacity());
        }
        assert_eq!(t.current_cat(MemCategory::Contacts), 0);
        assert!(t.peak_cat(MemCategory::Contacts) >= 1000);
    }

    #[test]
    fn into_inner_detaches_without_parking() {
        let (a, t) = fresh();
        let mut g: ArenaVec<u64> = a.vec(8, MemCategory::Contacts);
        g.push(42);
        let v = g.into_inner();
        assert_eq!(v, vec![42]);
        assert_eq!(t.current_cat(MemCategory::Contacts), 0);
        assert_eq!(a.stats().parks, 0);
    }

    #[test]
    fn disabled_arena_is_a_plain_allocator() {
        let a = BatchArena::disabled();
        assert!(!a.is_pooling());
        {
            let mut g: ArenaVec<u64> = a.vec(16, MemCategory::Contacts);
            g.push(1);
        }
        let v = a.loan_f64_zeroed(8, MemCategory::Solver);
        assert_eq!(v, vec![0.0; 8]);
        a.retire_f64(v, 8, MemCategory::Solver);
        let s = a.stats();
        assert_eq!((s.takes, s.hits, s.parks), (0, 0, 0));
    }

    #[test]
    fn tracked_arena_accounts_without_pooling() {
        let t = Arc::new(MemTracker::new());
        let a = BatchArena::tracked_with(t.clone());
        let v = a.loan_f64_zeroed(100, MemCategory::Solver);
        assert_eq!(t.current_cat(MemCategory::Solver), 800);
        a.retire_f64(v, 100, MemCategory::Solver);
        assert_eq!(t.current_cat(MemCategory::Solver), 0);
        assert_eq!(t.current_cat(MemCategory::ArenaRetained), 0, "nothing parked");
        assert_eq!(a.stats().takes, 0);
    }

    #[test]
    fn dropping_the_arena_releases_retained_accounting() {
        let t = Arc::new(MemTracker::new());
        let a = BatchArena::pooled_with(DEFAULT_RETAIN_CAP, t.clone());
        drop(a.vec::<u64>(128, MemCategory::Contacts));
        assert!(t.current_cat(MemCategory::ArenaRetained) > 0);
        drop(a);
        assert_eq!(t.current_cat(MemCategory::ArenaRetained), 0);
    }

    #[test]
    fn shared_across_threads() {
        let (a, _t) = fresh();
        // Warm one buffer per worker's worth of work, then hammer it
        // from several threads; the arena must stay consistent.
        std::thread::scope(|s| {
            for _ in 0..4 {
                let a = a.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let mut g: ArenaVec<u64> = a.vec(0, MemCategory::Contacts);
                        g.extend(0..32u64);
                    }
                });
            }
        });
        let s = a.stats();
        assert_eq!(s.takes, 200);
        assert!(s.hits > 0, "warm takes must reuse: {s:?}");
        assert!(s.retained_buffers <= 4, "at most one set per thread live at once");
    }
}
