//! Per-worker reusable scratch buffers — the first (thread-local) slice
//! of the ROADMAP "cross-scene memory pooling" item; the cross-scene
//! slice is [`crate::util::arena`], which pools per-(scene, step)
//! buffers across a batch while this module keeps pooling per-worker
//! solver temporaries underneath it. Invariants match the arena's:
//! every take is fully overwritten before use (bitwise parity), reuse
//! never changes control flow (determinism), and retention is capped so
//! hoarding cannot occur.
//!
//! The persistent pool ([`crate::util::pool`]) keeps worker threads
//! alive across calls, so buffers parked in thread-local storage
//! actually amortize: the coordinator's mass/Jacobian packing buffers
//! (`zone_solve_batch` / `zone_backward_batch`) and the zone solver's
//! per-iteration temporaries are re-filled in place instead of being
//! reallocated on every call. The arena is keyed by the executing
//! thread (each persistent worker owns one store), RAII guards return
//! buffers on drop, and every take fully overwrites its buffer before
//! use — so numerics are bitwise-identical to the allocating versions.
//!
//! Usage:
//! ```
//! let mut buf = diffsim::util::scratch::f64s(8, 0.0); // len 8, zeroed
//! buf[3] = 2.5;
//! // dropping `buf` parks the allocation for the next take
//! ```

use crate::math::dense::Mat;
use crate::util::telemetry::{self, Counter};
use std::cell::RefCell;
use std::ops::{Deref, DerefMut};
use std::sync::OnceLock;

/// Retained buffers per kind; beyond this, returned buffers are freed
/// (the engine's working set is a handful of mats + packing buffers per
/// worker, so hoarding indicates a leak, not a workload).
const KEEP: usize = 32;

#[derive(Default)]
struct Store {
    f32s: Vec<Vec<f32>>,
    f64s: Vec<Vec<f64>>,
    mats: Vec<Mat>,
    takes: u64,
    reuses: u64,
}

thread_local! {
    static STORE: RefCell<Store> = RefCell::new(Store::default());
}

/// (total takes, takes served from a parked buffer) for the calling
/// thread — test/diagnostic visibility into reuse.
pub fn stats() -> (u64, u64) {
    STORE.with(|s| {
        let s = s.borrow();
        (s.takes, s.reuses)
    })
}

/// Process-wide mirrors of the per-thread take/reuse counts, living in
/// the telemetry registry as `scratch.takes` / `scratch.reuses` (the
/// per-store fields above stay authoritative for per-thread tests).
/// Cached handles: one `OnceLock` load + a relaxed add per take.
fn counters() -> &'static (Counter, Counter) {
    static C: OnceLock<(Counter, Counter)> = OnceLock::new();
    C.get_or_init(|| (telemetry::counter("scratch.takes"), telemetry::counter("scratch.reuses")))
}

/// Process-wide (takes, reuses) across all threads, as accumulated in
/// the telemetry registry.
pub fn process_stats() -> (u64, u64) {
    let (t, r) = counters();
    (t.get(), r.get())
}

macro_rules! buf_kind {
    ($guard:ident, $take:ident, $elem:ty, $field:ident) => {
        /// RAII scratch buffer; derefs to a slice and returns its
        /// allocation to the thread-local arena on drop.
        pub struct $guard(Vec<$elem>);

        impl Deref for $guard {
            type Target = [$elem];
            fn deref(&self) -> &[$elem] {
                &self.0
            }
        }

        impl DerefMut for $guard {
            fn deref_mut(&mut self) -> &mut [$elem] {
                &mut self.0
            }
        }

        impl $guard {
            /// Replace the contents with `len` copies of `fill`
            /// (capacity is kept).
            pub fn refill(&mut self, len: usize, fill: $elem) {
                self.0.clear();
                self.0.resize(len, fill);
            }

            /// Clear, then append from an iterator (the `collect`
            /// replacement for reused buffers).
            pub fn fill_with(&mut self, it: impl Iterator<Item = $elem>) {
                self.0.clear();
                self.0.extend(it);
            }

            pub fn as_vec(&mut self) -> &mut Vec<$elem> {
                &mut self.0
            }
        }

        impl Drop for $guard {
            fn drop(&mut self) {
                let v = std::mem::take(&mut self.0);
                STORE.with(|s| {
                    let mut s = s.borrow_mut();
                    if s.$field.len() < KEEP {
                        s.$field.push(v);
                    }
                });
            }
        }

        /// Take a scratch buffer of `len` copies of `fill` from the
        /// calling thread's arena (allocating only on cold start).
        pub fn $take(len: usize, fill: $elem) -> $guard {
            let (p_takes, p_reuses) = counters();
            p_takes.incr();
            let mut v = STORE.with(|s| {
                let mut s = s.borrow_mut();
                s.takes += 1;
                match s.$field.pop() {
                    Some(v) => {
                        s.reuses += 1;
                        p_reuses.incr();
                        v
                    }
                    None => Vec::new(),
                }
            });
            v.clear();
            v.resize(len, fill);
            $guard(v)
        }
    };
}

buf_kind!(F32Buf, f32s, f32, f32s);
buf_kind!(F64Buf, f64s, f64, f64s);

/// RAII scratch matrix; derefs to [`Mat`] and returns the backing
/// allocation to the thread-local arena on drop.
pub struct MatBuf(Mat);

impl Deref for MatBuf {
    type Target = Mat;
    fn deref(&self) -> &Mat {
        &self.0
    }
}

impl DerefMut for MatBuf {
    fn deref_mut(&mut self) -> &mut Mat {
        &mut self.0
    }
}

impl Drop for MatBuf {
    fn drop(&mut self) {
        let m = std::mem::replace(&mut self.0, Mat::zeros(0, 0));
        STORE.with(|s| {
            let mut s = s.borrow_mut();
            if s.mats.len() < KEEP {
                s.mats.push(m);
            }
        });
    }
}

/// Take a zeroed `rows × cols` scratch matrix from the calling thread's
/// arena.
pub fn mat(rows: usize, cols: usize) -> MatBuf {
    let (p_takes, p_reuses) = counters();
    p_takes.incr();
    let mut m = STORE.with(|s| {
        let mut s = s.borrow_mut();
        s.takes += 1;
        match s.mats.pop() {
            Some(m) => {
                s.reuses += 1;
                p_reuses.incr();
                m
            }
            None => Mat::zeros(0, 0),
        }
    });
    m.reset(rows, cols);
    MatBuf(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_reused_and_reset() {
        // Drain any parked buffers so the reuse accounting is ours.
        let pre: Vec<F64Buf> = (0..KEEP).map(|_| f64s(4, 7.0)).collect();
        drop(pre);
        let (t0, r0) = stats();
        {
            let mut a = f64s(16, 0.0);
            a[5] = 3.5;
        } // returned to the arena here
        let b = f64s(16, 0.0);
        assert!(b.iter().all(|&x| x == 0.0), "stale contents leaked through");
        assert_eq!(b.len(), 16);
        let (t1, r1) = stats();
        assert_eq!(t1 - t0, 2);
        assert!(r1 > r0, "second take must reuse the first allocation");
    }

    #[test]
    fn mat_scratch_resizes_and_zeroes() {
        {
            let mut m = mat(3, 5);
            m[(2, 4)] = 9.0;
            assert_eq!((m.rows, m.cols), (3, 5));
        }
        let m = mat(5, 3);
        assert_eq!((m.rows, m.cols), (5, 3));
        assert!(m.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn process_stats_mirror_per_thread_counts() {
        let (pt0, pr0) = process_stats();
        let (t0, r0) = stats();
        {
            let _a = f64s(8, 0.0);
            let _m = mat(2, 2);
        }
        let _b = f64s(8, 0.0);
        let (t1, r1) = stats();
        let (pt1, pr1) = process_stats();
        assert!(t1 - t0 >= 3);
        // The registry mirror accumulates across all threads, so it
        // saw at least this thread's activity.
        assert!(pt1 - pt0 >= t1 - t0);
        assert!(pr1 - pr0 >= r1 - r0);
    }

    #[test]
    fn f32_refill_and_fill_with() {
        let mut v = f32s(3, 1.0);
        v.refill(5, 2.0);
        assert_eq!(&*v, &[2.0; 5]);
        v.fill_with((0..3).map(|i| i as f32));
        assert_eq!(&*v, &[0.0, 1.0, 2.0]);
    }
}
