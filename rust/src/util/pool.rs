//! Thread-pool substrate (no tokio offline): scoped parallel map with an
//! atomic work-stealing cursor. The coordinator uses it to solve
//! independent impact zones in parallel.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Fixed-size worker pool. Work is submitted as a parallel indexed map —
/// the dominant pattern in the engine (N independent zones / bodies).
pub struct Pool {
    workers: usize,
}

impl Pool {
    pub fn new(workers: usize) -> Pool {
        Pool { workers: workers.max(1) }
    }

    /// Pool sized to the machine, capped (zone solves are memory-bound
    /// beyond a few cores).
    pub fn default_for_machine() -> Pool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Pool::new(n.min(16))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel map over `0..n`; results returned in index order.
    /// Work-stealing via an atomic cursor keeps unequal zone sizes
    /// balanced across workers.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        // Zero-sized slots: `map` is `map_mut` with nothing to mutate.
        let mut slots = vec![(); n];
        self.map_mut(&mut slots, |i, _| f(i))
    }

    /// Parallel mutable indexed map over a slice (the batch-stepping
    /// primitive: N independent `Simulation`s advanced concurrently).
    /// Each index is claimed exactly once via the atomic cursor, so the
    /// per-element `&mut T` handed to `f` never aliases. Results are
    /// returned in index order.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if self.workers == 1 || n == 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        // Shared base pointer; safe to hand to workers because every
        // index is visited by exactly one worker (cursor) and T: Send.
        struct Base<T>(*mut T);
        unsafe impl<T: Send> Sync for Base<T> {}
        let base = Base(items.as_mut_ptr());
        let cursor = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.workers.min(n))
                .map(|_| {
                    let cursor = &cursor;
                    let f = &f;
                    let base = &base;
                    scope.spawn(move || {
                        let mut local = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            // SAFETY: `i` was claimed exactly once across
                            // all workers, so this is the only live
                            // reference to items[i].
                            let item = unsafe { &mut *base.0.add(i) };
                            local.push((i, f(i, item)));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
        });
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for part in parts {
            for (i, v) in part {
                out[i] = Some(v);
            }
        }
        out.into_iter().map(|o| o.expect("pool: missing result")).collect()
    }
}

/// Run `f` over `0..n` in parallel for side effects (e.g. writes into
/// disjoint pre-partitioned storage guarded by interior mutability).
pub fn parallel_for<F>(workers: usize, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn map_returns_in_order() {
        let p = Pool::new(4);
        let out = p.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_and_single() {
        let p = Pool::new(4);
        assert!(p.map(0, |i| i).is_empty());
        assert_eq!(p.map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn single_worker_pool() {
        let p = Pool::new(1);
        assert_eq!(p.map(10, |i| i), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let seen = Mutex::new(vec![0usize; 1000]);
        parallel_for(8, 1000, |i| {
            let mut s = seen.lock().unwrap();
            s[i] += 1;
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn map_mut_visits_each_element_once_in_order() {
        let p = Pool::new(4);
        let mut items: Vec<usize> = vec![0; 200];
        let out = p.map_mut(&mut items, |i, v| {
            *v += i + 1;
            *v * 2
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i + 1, "element {i} mutated wrongly");
        }
        assert_eq!(out, (0..200).map(|i| 2 * (i + 1)).collect::<Vec<_>>());
    }

    #[test]
    fn map_mut_single_worker_and_empty() {
        let p = Pool::new(1);
        let mut items = vec![1, 2, 3];
        let out = p.map_mut(&mut items, |_, v| {
            *v *= 10;
            *v
        });
        assert_eq!(items, vec![10, 20, 30]);
        assert_eq!(out, vec![10, 20, 30]);
        let mut empty: Vec<i32> = Vec::new();
        assert!(Pool::new(4).map_mut(&mut empty, |_, v| *v).is_empty());
    }

    #[test]
    fn map_with_uneven_work() {
        let p = Pool::default_for_machine();
        let out = p.map(64, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 997) {
                acc = acc.wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 64);
        // Deterministic irrespective of scheduling.
        let seq: Vec<u64> = (0..64)
            .map(|i| {
                let mut acc = 0u64;
                for k in 0..(i as u64 * 997) {
                    acc = acc.wrapping_add(k ^ i as u64);
                }
                acc
            })
            .collect();
        assert_eq!(out, seq);
    }
}
