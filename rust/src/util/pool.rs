//! Thread-pool substrate (no tokio offline): scoped parallel map with an
//! atomic work-stealing cursor. The coordinator uses it to solve
//! independent impact zones in parallel.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Fixed-size worker pool. Work is submitted as a parallel indexed map —
/// the dominant pattern in the engine (N independent zones / bodies).
pub struct Pool {
    workers: usize,
}

impl Pool {
    pub fn new(workers: usize) -> Pool {
        Pool { workers: workers.max(1) }
    }

    /// Pool sized to the machine, capped (zone solves are memory-bound
    /// beyond a few cores).
    pub fn default_for_machine() -> Pool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Pool::new(n.min(16))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Parallel map over `0..n`; results returned in index order.
    /// Work-stealing via an atomic cursor keeps unequal zone sizes
    /// balanced across workers.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.workers == 1 || n == 1 {
            return (0..n).map(&f).collect();
        }
        let cursor = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.workers.min(n))
                .map(|_| {
                    let cursor = &cursor;
                    let f = &f;
                    scope.spawn(move || {
                        let mut local = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
        });
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for part in parts {
            for (i, v) in part {
                out[i] = Some(v);
            }
        }
        out.into_iter().map(|o| o.expect("pool: missing result")).collect()
    }
}

/// Run `f` over `0..n` in parallel for side effects (e.g. writes into
/// disjoint pre-partitioned storage guarded by interior mutability).
pub fn parallel_for<F>(workers: usize, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn map_returns_in_order() {
        let p = Pool::new(4);
        let out = p.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_and_single() {
        let p = Pool::new(4);
        assert!(p.map(0, |i| i).is_empty());
        assert_eq!(p.map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn single_worker_pool() {
        let p = Pool::new(1);
        assert_eq!(p.map(10, |i| i), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let seen = Mutex::new(vec![0usize; 1000]);
        parallel_for(8, 1000, |i| {
            let mut s = seen.lock().unwrap();
            s[i] += 1;
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn map_with_uneven_work() {
        let p = Pool::default_for_machine();
        let out = p.map(64, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 997) {
                acc = acc.wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 64);
        // Deterministic irrespective of scheduling.
        let seq: Vec<u64> = (0..64)
            .map(|i| {
                let mut acc = 0u64;
                for k in 0..(i as u64 * 997) {
                    acc = acc.wrapping_add(k ^ i as u64);
                }
                acc
            })
            .collect();
        assert_eq!(out, seq);
    }
}
