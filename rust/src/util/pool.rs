//! Persistent worker-pool runtime (no tokio/rayon offline): long-lived
//! worker threads fed through a Mutex+Condvar submission queue, with the
//! same atomic work-stealing cursor semantics the engine has always
//! relied on. The coordinator uses it to solve independent impact zones
//! in parallel; `batch::SceneBatch` uses it for cross-scene stepping and
//! batched gradient gathers.
//!
//! The previous implementation spawned a fresh `thread::scope` per
//! `map`/`map_mut`/`parallel_for` call. The lockstep forward issues
//! several such calls per simulated step (stage barriers + one per
//! fail-safe pass), so small scenes and large batches paid OS thread
//! creation on the hottest path. Here workers are created once, park on
//! a condvar while idle, and claim indices from submitted jobs — zero
//! thread spawns per call after warmup (see [`thread_spawns`] and
//! `benches/batch_throughput.rs` → `BENCH_pool.json`).
//!
//! # Execution model
//!
//! * A `map`/`map_mut` call packages the closure as a type-erased *job*
//!   (index cursor + completion counter) and pushes it on the runtime's
//!   queue. **The submitting thread participates**: it claims indices
//!   alongside the workers and only blocks once the cursor is
//!   exhausted. This is what makes nested/re-entrant maps safe (see
//!   below) and keeps a one-budget handle exactly as fast as inline.
//! * Results are written into per-index slots, so outputs are in index
//!   order and bitwise-independent of scheduling — determinism is
//!   identical to the old scoped pool and to sequential execution.
//! * Each handle carries a *worker budget*: at most `workers()` threads
//!   (submitter included) execute one job concurrently, so
//!   `Pool::shared(2)` on a 16-thread runtime still honors a 2-worker
//!   budget per call.
//!
//! # Sharing
//!
//! [`Pool::global`]/[`Pool::shared`] hand out handles to one
//! process-wide runtime sized by [`Pool::machine_workers`]; the engine
//! ([`crate::engine::Simulation`]), the batch layer
//! ([`crate::batch::SceneBatch`]), and the lockstep forward/backward
//! paths all draw from this single worker set. A handle's budget also
//! bounds how many scenes of a batch execute a stage concurrently,
//! which is what caps the live checkout count of the cross-scene
//! [`crate::util::arena::BatchArena`] — batch buffer memory scales with
//! the budget, not the population. [`Pool::new`] builds a
//! dedicated runtime (own threads, shut down on `Drop`) for isolation —
//! mostly tests. [`Pool::scoped`] keeps the old spawn-per-call behavior
//! as a measurable baseline for the perf benches.
//!
//! # Nested maps
//!
//! Calling `map`/`map_mut` from *inside* a pool task (same runtime) is
//! supported: the inner submitter executes its own job's indices, so
//! progress never depends on another worker being free — no deadlock by
//! construction. Idle workers may join the inner job as usual.
//!
//! # Panics
//!
//! A panic inside a task does not kill the worker: it is caught, the
//! remaining indices still run (matching the old `thread::scope` join
//! semantics), and the first payload is re-thrown on the submitting
//! thread once the job completes. The pool stays usable afterwards.
//!
//! # Detached jobs
//!
//! [`Pool::submit`] enqueues a single closure *without blocking*: it
//! returns a [`JobHandle`] immediately and the closure runs on a pool
//! worker whenever one frees up. This is the primitive under
//! [`crate::batch::pipeline::BatchPipeline`] — the submitting thread
//! keeps doing useful work (loss evaluation, next-generation scene
//! construction) while scenes step elsewhere. Contracts:
//!
//! * **Budgets are respected.** Each handle family (a `Pool` and its
//!   clones) carries a gate sized to the handle's worker budget: at most
//!   `workers()` of its detached jobs execute concurrently, however many
//!   are queued. A `Pool::shared(4)` handle therefore never occupies
//!   more than 4 of the process runtime's threads with detached work —
//!   which is also what keeps the live checkout count of a shared
//!   [`crate::util::arena::BatchArena`] bounded by the budget when
//!   scenes step as detached jobs.
//! * **Panic-at-wait.** A panic inside a detached job is caught on the
//!   worker and re-thrown on the caller of [`JobHandle::wait`] — never
//!   on the worker loop, so the pool survives.
//! * **Drop-before-wait.** Dropping a `JobHandle` without waiting
//!   *blocks until the job finishes*, then discards its result; a panic
//!   in a dropped job is swallowed. (This is what makes it sound for
//!   higher layers to submit jobs that borrow stack data and drain them
//!   on every exit path, like `thread::scope`.)
//! * **Degeneration.** On a 1-worker (inline) handle, `submit` runs the
//!   closure synchronously on the caller before returning — a pipeline
//!   over an inline pool is exactly the sequential loop. On the
//!   [`Pool::scoped`] baseline it spawns one thread per job (counted by
//!   [`thread_spawns`]); the gate still caps concurrency.
//! * **Never block on a handle from inside a pool task.** Waiting a
//!   `JobHandle` (or letting one drop, which also blocks) from *inside*
//!   any task on the same runtime — map task or detached job, same
//!   handle family or not — can deadlock: detached jobs have no
//!   submitter participation, so if every worker is blocked waiting,
//!   no worker is left to execute the jobs being waited on (the gate
//!   only makes this easier to hit, it is not required). Nested `map`s
//!   remain deadlock-free as before (the inner submitter executes its
//!   own job); the batch pipeline only waits on handles from the
//!   submitting thread.
//!
//! # Verification
//!
//! The protocol above is model-checked and instrumented (see
//! ARCHITECTURE.md §"Correctness & static analysis"):
//!
//! * **loom** — build with `RUSTFLAGS="--cfg loom"` (and the `loom`
//!   dev-dependency uncommented in Cargo.toml) and the `sync` shim
//!   below swaps every `Mutex`/`Condvar`/`Arc`/atomic for loom's
//!   model-checked doubles; `loom_tests` then exhausts interleavings of
//!   the submission queue, `Gate` budget, and `JobHandle` drop/wait
//!   paths. The `Scoped` baseline and `HandleState::Thread` stay on
//!   real `std::thread` and are not modeled.
//! * **Miri** — `rust/tests/miri_unsafe_core.rs` drives the pointer
//!   erasure (`TaskRef`, `SendPtr`, `batch::pipeline::erase_job`)
//!   through dedicated `Pool::new` runtimes under the interpreter.
//! * **TSan** — the CI `tsan` lane runs the pipeline/batch integration
//!   tests under `-Zsanitizer=thread`.

#[cfg(not(loom))]
use crate::util::telemetry::{self, Counter, Gauge, Hist};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

/// Synchronization layer: `std::sync` normally, loom's model-checked
/// doubles under `--cfg loom` so the model tests exercise the exact
/// queue/gate/completion protocol shipped here (not a copy of it).
#[cfg(not(loom))]
mod sync {
    pub use std::sync::atomic::{AtomicUsize, Ordering};
    pub use std::sync::{Arc, Condvar, Mutex};
}
#[cfg(loom)]
mod sync {
    pub use loom::sync::atomic::{AtomicUsize, Ordering};
    pub use loom::sync::{Arc, Condvar, Mutex};
}
use sync::{Arc, AtomicUsize, Condvar, Mutex, Ordering};

/// Process-wide count of OS threads spawned by the pool layer —
/// persistent workers and spawn-per-call baseline threads alike. Lives
/// in the telemetry registry as `pool.thread_spawns`; this cached
/// handle keeps the increment a single relaxed add.
#[cfg(not(loom))]
fn spawn_counter() -> &'static Counter {
    static C: OnceLock<Counter> = OnceLock::new();
    C.get_or_init(|| telemetry::counter("pool.thread_spawns"))
}

/// Jobs submitted to the persistent runtime and not yet completed
/// (`pool.jobs_in_flight`); only jobs submitted while the registry is
/// enabled are tracked, and each tracked job decrements on completion
/// regardless of later toggles, so the gauge never drifts.
#[cfg(not(loom))]
fn inflight_gauge() -> &'static Gauge {
    static G: OnceLock<Gauge> = OnceLock::new();
    G.get_or_init(|| telemetry::gauge("pool.jobs_in_flight"))
}

/// Queue depth observed at each persistent-runtime submission
/// (`pool.queue_depth`), recorded only while the registry is enabled.
#[cfg(not(loom))]
fn queue_depth_hist() -> &'static Hist {
    static H: OnceLock<Hist> = OnceLock::new();
    H.get_or_init(|| telemetry::hist("pool.queue_depth"))
}

/// Total OS threads the pool layer has ever spawned. Benches read the
/// delta across a measured phase to prove "zero spawns per step after
/// warmup" for the persistent runtime. Thin wrapper over the
/// `pool.thread_spawns` registry counter.
pub fn thread_spawns() -> u64 {
    #[cfg(loom)]
    return 0;
    #[cfg(not(loom))]
    spawn_counter().get()
}

// Telemetry touchpoints, no-ops under loom: the registry uses real
// process-global OnceLock/atomics, which loom's scheduler must not see
// (loom only models its own primitives, and globals outlive a model).
fn note_thread_spawn() {
    #[cfg(not(loom))]
    spawn_counter().incr();
}

fn note_inflight(delta: i64) {
    #[cfg(not(loom))]
    inflight_gauge().add(delta);
    #[cfg(loom)]
    let _ = delta;
}

fn note_queue_depth(depth: usize) {
    #[cfg(not(loom))]
    if telemetry::enabled() {
        queue_depth_hist().record(depth as f64);
    }
    #[cfg(loom)]
    let _ = depth;
}

fn obs_enabled() -> bool {
    #[cfg(loom)]
    return false;
    #[cfg(not(loom))]
    telemetry::enabled()
}

// ---------------------------------------------------------------- jobs

/// Type- and lifetime-erased `Fn(usize)` executing one index of a map.
///
/// Sound because the submitter blocks in [`run_on`] until
/// `completed == n`, so the referenced closure and output slots outlive
/// every dereference; workers never touch the pointer once the cursor
/// is exhausted.
struct TaskRef(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls from any thread are fine)
// and the struct doc's liveness argument holds: the submitter outlives
// every worker dereference, so sending/sharing the raw pointer is sound.
unsafe impl Send for TaskRef {}
// SAFETY: see `Send` above — `&TaskRef` only exposes `&dyn Fn + Sync`.
unsafe impl Sync for TaskRef {}

/// What a job executes per index: a borrowed closure (maps, where the
/// submitter blocks until completion) or an owned one (detached
/// [`Pool::submit`] jobs, which outlive their submission site).
enum Task {
    Borrowed(TaskRef),
    Owned(Box<dyn Fn(usize) + Send + Sync>),
}

/// Per-handle-family concurrency gate for detached jobs: at most
/// `limit` of a handle's submitted jobs execute at once, however many
/// are queued. Maps don't use it (their per-job `limit` already caps
/// them); workers probe with [`Gate::try_acquire`] during the queue
/// scan, the spawn-per-call baseline blocks in [`Gate::acquire`].
///
/// Liveness: a full gate can only be freed by a running executor, and
/// every executor re-scans the queue after [`Job::leave`] releases its
/// slot — so a claimable gated job is always picked up by the releaser
/// (or an already-awake worker) without any extra wakeup traffic.
struct Gate {
    limit: usize,
    active: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new(limit: usize) -> Gate {
        Gate { limit: limit.max(1), active: Mutex::new(0), cv: Condvar::new() }
    }

    fn try_acquire(&self) -> bool {
        let mut a = self.active.lock().unwrap();
        if *a < self.limit {
            *a += 1;
            true
        } else {
            false
        }
    }

    fn acquire(&self) {
        let mut a = self.active.lock().unwrap();
        while *a >= self.limit {
            a = self.cv.wait(a).unwrap();
        }
        *a += 1;
    }

    fn release(&self) {
        *self.active.lock().unwrap() -= 1;
        self.cv.notify_one();
    }
}

struct Job {
    task: Task,
    n: usize,
    /// Next unclaimed index — the work-stealing cursor that keeps
    /// unequal zone sizes balanced across workers.
    cursor: AtomicUsize,
    /// Indices fully executed; `done` flips when it reaches `n`.
    completed: AtomicUsize,
    /// Executors currently inside the job (submitter included), capped
    /// at `limit` so per-handle worker budgets stay honored on the
    /// shared runtime.
    active: AtomicUsize,
    limit: usize,
    /// Detached jobs additionally hold a slot in their handle family's
    /// gate while executing ([`Pool::submit`] budget); `None` for maps.
    gate: Option<Arc<Gate>>,
    /// First task panic, re-thrown on the submitting thread.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
    /// Whether this job was counted in `pool.jobs_in_flight` at
    /// submission (registry enabled then); completion decrements
    /// exactly when set, independent of the flag's current state.
    tracked: bool,
}

impl Job {
    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.n
    }

    /// Reserve an executor slot; fails when the job is exhausted, at
    /// its concurrency budget, or (detached jobs) when its handle
    /// family's gate is full.
    fn try_join(&self) -> bool {
        if self.exhausted() {
            return false;
        }
        if let Some(g) = &self.gate {
            if !g.try_acquire() {
                return false;
            }
        }
        let mut a = self.active.load(Ordering::Relaxed);
        loop {
            if a >= self.limit || self.exhausted() {
                if let Some(g) = &self.gate {
                    g.release();
                }
                return false;
            }
            match self.active.compare_exchange_weak(
                a,
                a + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => a = now,
            }
        }
    }

    fn leave(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
        if let Some(g) = &self.gate {
            g.release();
        }
    }

    /// Claim and execute indices until the cursor is exhausted.
    fn run(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            let run_index = || match &self.task {
                // SAFETY: see `TaskRef` — the submitter keeps the
                // closure alive until every claimed index has completed.
                Task::Borrowed(r) => (unsafe { &*r.0 })(i),
                Task::Owned(b) => b(i),
            };
            if let Err(p) = catch_unwind(AssertUnwindSafe(run_index)) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            // AcqRel: the final increment synchronizes with every prior
            // executor's release, so the submitter observes all writes.
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
                if self.tracked {
                    note_inflight(-1);
                }
                *self.done.lock().unwrap() = true;
                self.done_cv.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut d = self.done.lock().unwrap();
        while !*d {
            d = self.done_cv.wait(d).unwrap();
        }
    }
}

// ------------------------------------------------------------- runtime

struct Queue {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    cv: Condvar,
}

/// Worker threads are real OS threads normally and loom threads under
/// the model checker (loom has no named-thread `Builder`, hence the
/// split helper).
#[cfg(not(loom))]
type WorkerHandle = std::thread::JoinHandle<()>;
#[cfg(loom)]
type WorkerHandle = loom::thread::JoinHandle<()>;

#[cfg(not(loom))]
fn spawn_worker(k: usize, sh: Arc<Shared>) -> WorkerHandle {
    std::thread::Builder::new()
        .name(format!("pool-worker-{k}"))
        .spawn(move || worker_loop(&sh))
        .expect("spawn pool worker")
}

#[cfg(loom)]
fn spawn_worker(_k: usize, sh: Arc<Shared>) -> WorkerHandle {
    loom::thread::spawn(move || worker_loop(&sh))
}

/// A set of persistent worker threads. Dropped (last handle) → shutdown
/// flag + condvar broadcast; workers drain claimable work, exit, and are
/// joined.
struct PoolRuntime {
    shared: Arc<Shared>,
    handles: Mutex<Vec<WorkerHandle>>,
}

impl PoolRuntime {
    fn new(workers: usize) -> PoolRuntime {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|k| {
                note_thread_spawn();
                spawn_worker(k, shared.clone())
            })
            .collect();
        PoolRuntime { shared, handles: Mutex::new(handles) }
    }

    fn submit(&self, job: &Arc<Job>) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push_back(job.clone());
        let depth = q.jobs.len();
        drop(q);
        note_queue_depth(depth);
        self.shared.cv.notify_all();
    }
}

impl Drop for PoolRuntime {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                // Exhausted jobs leave the queue here; any executors
                // still inside them hold their own Arcs.
                q.jobs.retain(|j| !j.exhausted());
                if let Some(j) = q.jobs.iter().find(|j| j.try_join()) {
                    break Arc::clone(j);
                }
                if q.shutdown {
                    return;
                }
                // Park until new work (or shutdown) is announced.
                q = sh.cv.wait(q).unwrap();
            }
        };
        job.run();
        job.leave();
    }
}

/// Submit `task` over `0..n` on `rt` with concurrency `budget`, with
/// the submitting thread participating; blocks until every index has
/// completed, then re-throws the first task panic, if any.
fn run_on(rt: &Arc<PoolRuntime>, budget: usize, n: usize, task: &(dyn Fn(usize) + Sync)) {
    // SAFETY: lifetime erasure only — this function does not return
    // until `completed == n`, so the 'static reference never outlives
    // the actual borrow (see `TaskRef`).
    let task: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(task) };
    let tracked = obs_enabled();
    if tracked {
        note_inflight(1);
    }
    let job = Arc::new(Job {
        task: Task::Borrowed(TaskRef(task as *const _)),
        n,
        cursor: AtomicUsize::new(0),
        completed: AtomicUsize::new(0),
        active: AtomicUsize::new(1), // the submitter's slot
        limit: budget.min(n).max(1),
        gate: None,
        panic: Mutex::new(None),
        done: Mutex::new(false),
        done_cv: Condvar::new(),
        tracked,
    });
    rt.submit(&job);
    job.run();
    job.leave();
    job.wait();
    if let Some(p) = job.panic.lock().unwrap().take() {
        resume_unwind(p);
    }
}

fn global_runtime() -> &'static Arc<PoolRuntime> {
    static GLOBAL: OnceLock<Arc<PoolRuntime>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(PoolRuntime::new(Pool::machine_workers())))
}

// ---------------------------------------------------------------- Pool

#[derive(Clone)]
enum Backend {
    /// One worker: run on the caller, no queue traffic.
    Inline,
    /// Spawn-per-call `thread::scope` — the pre-persistent behavior,
    /// kept as a measurable baseline for `BENCH_pool.json`. The gate
    /// budgets detached [`Pool::submit`] jobs (one spawned thread each).
    Scoped { workers: usize, gate: Arc<Gate> },
    /// Persistent runtime (dedicated or the process-wide one) with a
    /// per-handle concurrency budget; the gate enforces the same budget
    /// for detached [`Pool::submit`] jobs across the handle family.
    Persistent { rt: Arc<PoolRuntime>, budget: usize, gate: Arc<Gate> },
}

/// Handle to a worker pool. Cheap to clone; clones share the same
/// worker threads. Work is submitted as a parallel indexed map — the
/// dominant pattern in the engine (N independent zones / bodies /
/// scenes).
#[derive(Clone)]
pub struct Pool {
    backend: Backend,
}

impl Pool {
    /// Dedicated persistent pool with a `workers` concurrency budget:
    /// spawns `workers − 1` owned threads once (the submitter is the
    /// remaining executor) and shuts them down when the last handle is
    /// dropped. `workers <= 1` degenerates to inline execution.
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        if workers == 1 {
            Pool { backend: Backend::Inline }
        } else {
            Pool {
                backend: Backend::Persistent {
                    rt: Arc::new(PoolRuntime::new(workers - 1)),
                    budget: workers,
                    gate: Arc::new(Gate::new(workers)),
                },
            }
        }
    }

    /// Handle to the process-wide shared runtime with a per-call
    /// concurrency budget of `workers`. The runtime itself is created
    /// on first use with [`Pool::machine_workers`] threads and lives for
    /// the process. This is what [`crate::engine::Simulation`] and
    /// [`crate::batch::SceneBatch`] use, so one worker set serves
    /// per-pass zone solves, cross-scene stepping, and batched gradient
    /// gathers.
    pub fn shared(workers: usize) -> Pool {
        if workers.max(1) == 1 {
            Pool { backend: Backend::Inline }
        } else {
            Pool {
                backend: Backend::Persistent {
                    rt: global_runtime().clone(),
                    budget: workers,
                    gate: Arc::new(Gate::new(workers)),
                },
            }
        }
    }

    /// The process-wide pool at full machine budget —
    /// `Pool::shared(Pool::machine_workers())`.
    pub fn global() -> Pool {
        Pool::shared(Pool::machine_workers())
    }

    /// Spawn-per-call baseline (the pre-persistent implementation):
    /// every `map`/`map_mut` spawns `workers.min(n)` scoped threads and
    /// joins them. Kept for benchmarking the persistent runtime against;
    /// do not use on hot paths.
    pub fn scoped(workers: usize) -> Pool {
        let workers = workers.max(1);
        Pool { backend: Backend::Scoped { workers, gate: Arc::new(Gate::new(workers)) } }
    }

    /// Worker count the machine supports, capped (zone solves are
    /// memory-bound beyond a few cores). Use this instead of
    /// constructing a pool just to read `.workers()`.
    pub fn machine_workers() -> usize {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        n.min(16)
    }

    /// Pool sized to the machine — now a handle to the shared runtime
    /// (no threads spawned per call; see [`Pool::shared`]).
    pub fn default_for_machine() -> Pool {
        Pool::global()
    }

    /// This handle's concurrency budget per submitted map.
    pub fn workers(&self) -> usize {
        match &self.backend {
            Backend::Inline => 1,
            Backend::Scoped { workers, .. } => *workers,
            Backend::Persistent { budget, .. } => *budget,
        }
    }

    /// Enqueue `f` as a *detached* job and return immediately with a
    /// completion handle (see the module docs' "Detached jobs" section
    /// for the full contract). The closure runs on a pool worker when
    /// one frees up; at most [`Pool::workers`] detached jobs of this
    /// handle family execute concurrently (the budget gate). A panic in
    /// `f` is re-thrown by [`JobHandle::wait`]; dropping the handle
    /// waits for completion and swallows it.
    ///
    /// On a 1-worker handle this degenerates to synchronous execution
    /// on the caller (the handle is returned already complete), so code
    /// written against `submit` stays sequential-exact at budget 1.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        // Named fault-injection site: an armed `pool.job` firing panics
        // inside the job body, exercising the panic-at-wait drain paths.
        // Without the `faultinject` feature `should_fire` is a constant
        // `false` and this wrapper folds away.
        let f = move || {
            if crate::util::faultinject::should_fire(crate::util::faultinject::site::POOL_JOB) {
                panic!("injected fault: pool.job");
            }
            f()
        };
        match &self.backend {
            Backend::Inline => match catch_unwind(AssertUnwindSafe(f)) {
                Ok(t) => JobHandle {
                    inner: Some(HandleState::Done { result: Some(t), panic: None }),
                },
                Err(p) => JobHandle {
                    inner: Some(HandleState::Done { result: None, panic: Some(p) }),
                },
            },
            Backend::Scoped { gate, .. } => {
                let gate = gate.clone();
                note_thread_spawn();
                let handle = std::thread::Builder::new()
                    .name("pool-detached".to_string())
                    .spawn(move || {
                        gate.acquire();
                        let out = catch_unwind(AssertUnwindSafe(f));
                        gate.release();
                        match out {
                            Ok(t) => t,
                            Err(p) => resume_unwind(p),
                        }
                    })
                    .expect("spawn detached job thread");
                JobHandle { inner: Some(HandleState::Thread { handle }) }
            }
            Backend::Persistent { rt, gate, .. } => {
                let result = Arc::new(Mutex::new(None::<T>));
                let slot = result.clone();
                // FnOnce → Fn: the cell is taken exactly once (n = 1).
                let cell = Mutex::new(Some(f));
                let task: Box<dyn Fn(usize) + Send + Sync> = Box::new(move |_i| {
                    let f = cell.lock().unwrap().take().expect("detached task runs once");
                    *slot.lock().unwrap() = Some(f());
                });
                let tracked = obs_enabled();
                if tracked {
                    note_inflight(1);
                }
                let job = Arc::new(Job {
                    task: Task::Owned(task),
                    n: 1,
                    cursor: AtomicUsize::new(0),
                    completed: AtomicUsize::new(0),
                    active: AtomicUsize::new(0), // no submitter participation
                    limit: 1,
                    gate: Some(gate.clone()),
                    panic: Mutex::new(None),
                    done: Mutex::new(false),
                    done_cv: Condvar::new(),
                    tracked,
                });
                rt.submit(&job);
                JobHandle { inner: Some(HandleState::Queued { job, result }) }
            }
        }
    }

    /// Parallel map over `0..n`; results returned in index order.
    /// Work-stealing via an atomic cursor keeps unequal zone sizes
    /// balanced across workers.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        // Zero-sized slots: `map` is `map_mut` with nothing to mutate.
        let mut slots = vec![(); n];
        self.map_mut(&mut slots, |i, _| f(i))
    }

    /// Parallel mutable indexed map over a slice (the batch-stepping
    /// primitive: N independent `Simulation`s advanced concurrently).
    /// Each index is claimed exactly once via the atomic cursor, so the
    /// per-element `&mut T` handed to `f` never aliases. Results are
    /// returned in index order.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if self.workers() == 1 || n == 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        match &self.backend {
            Backend::Inline => unreachable!("workers() == 1 handled above"),
            Backend::Scoped { workers, .. } => scoped_map_mut(*workers, items, f),
            Backend::Persistent { rt, budget, .. } => {
                let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
                {
                    let items_base = SendPtr(items.as_mut_ptr());
                    let out_base = SendPtr(out.as_mut_ptr());
                    let f = &f;
                    let runner = move |i: usize| {
                        // SAFETY: `i` was claimed exactly once across all
                        // executors, so these are the only live references
                        // to items[i] / out[i].
                        let item = unsafe { &mut *items_base.0.add(i) };
                        let r = f(i, item);
                        unsafe { *out_base.0.add(i) = Some(r) };
                    };
                    run_on(rt, *budget, n, &runner);
                }
                out.into_iter().map(|o| o.expect("pool: missing result")).collect()
            }
        }
    }
}

/// Completion handle for a detached [`Pool::submit`] job.
///
/// Invariants (documented in the module's "Detached jobs" section):
/// [`JobHandle::wait`] blocks until the job finishes and returns its
/// result, re-throwing the job's panic payload on the caller if it
/// panicked; dropping the handle without waiting *blocks until the job
/// finishes* and then discards the result (a panic in a dropped job is
/// swallowed). Completion order between handles is whatever the workers
/// produce — determinism is the caller's job, e.g. by waiting handles
/// in submission order like `BatchPipeline` does.
pub struct JobHandle<T> {
    inner: Option<HandleState<T>>,
}

enum HandleState<T> {
    /// Executed synchronously at submit time (1-worker inline handles).
    Done { result: Option<T>, panic: Option<Box<dyn Any + Send>> },
    /// Queued on a persistent runtime.
    Queued { job: Arc<Job>, result: Arc<Mutex<Option<T>>> },
    /// One spawned thread (the `Pool::scoped` baseline).
    Thread { handle: std::thread::JoinHandle<T> },
}

impl<T> JobHandle<T> {
    /// Block until the job completes; returns its result or re-throws
    /// its panic payload on this thread (the pool stays usable).
    pub fn wait(mut self) -> T {
        match self.inner.take().expect("JobHandle::wait consumes the handle") {
            HandleState::Done { result, panic } => {
                if let Some(p) = panic {
                    resume_unwind(p);
                }
                result.expect("inline detached job stored a result")
            }
            HandleState::Queued { job, result } => {
                job.wait();
                if let Some(p) = job.panic.lock().unwrap().take() {
                    resume_unwind(p);
                }
                let out = result.lock().unwrap().take();
                out.expect("detached job stored a result")
            }
            HandleState::Thread { handle } => match handle.join() {
                Ok(t) => t,
                Err(p) => resume_unwind(p),
            },
        }
    }

    /// Non-blocking completion probe (a `true` answer means `wait`
    /// would return without blocking).
    pub fn is_done(&self) -> bool {
        match self.inner.as_ref() {
            None => true,
            Some(HandleState::Done { .. }) => true,
            Some(HandleState::Queued { job, .. }) => *job.done.lock().unwrap(),
            Some(HandleState::Thread { handle }) => handle.is_finished(),
        }
    }
}

impl<T> Drop for JobHandle<T> {
    fn drop(&mut self) {
        if let Some(state) = self.inner.take() {
            match state {
                HandleState::Done { .. } => {}
                HandleState::Queued { job, .. } => job.wait(),
                HandleState::Thread { handle } => {
                    let _ = handle.join();
                }
            }
        }
    }
}

/// Shared base pointer for parallel indexed writes.
struct SendPtr<T>(*mut T);
// SAFETY: every index is claimed by exactly one executor (the atomic
// cursor), so `base.add(i)` never aliases across threads, and `T: Send`
// makes moving each element's ownership to its executor sound.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: see `Send` above — executors share `&SendPtr` but write only
// through their exclusively-claimed offsets.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// The old scoped implementation, kept verbatim as the spawn-per-call
/// baseline ([`Pool::scoped`]).
fn scoped_map_mut<T, R, F>(workers: usize, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let base = SendPtr(items.as_mut_ptr());
    let cursor = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers.min(n))
            .map(|_| {
                let cursor = &cursor;
                let f = &f;
                let base = &base;
                note_thread_spawn();
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // SAFETY: `i` was claimed exactly once across
                        // all workers, so this is the only live
                        // reference to items[i].
                        let item = unsafe { &mut *base.0.add(i) };
                        local.push((i, f(i, item)));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|o| o.expect("pool: missing result")).collect()
}

/// Run `f` over `0..n` in parallel for side effects (e.g. writes into
/// disjoint pre-partitioned storage guarded by interior mutability).
/// Routed through the process-wide persistent runtime with a `workers`
/// budget — no threads are spawned per call.
pub fn parallel_for<F>(workers: usize, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    run_on(global_runtime(), workers, n, &|i| f(i));
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use std::time::Duration;

    #[test]
    fn map_returns_in_order() {
        let p = Pool::new(4);
        let out = p.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_and_single() {
        let p = Pool::new(4);
        assert!(p.map(0, |i| i).is_empty());
        assert_eq!(p.map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn single_worker_pool() {
        let p = Pool::new(1);
        assert_eq!(p.map(10, |i| i), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let seen = Mutex::new(vec![0usize; 1000]);
        parallel_for(8, 1000, |i| {
            let mut s = seen.lock().unwrap();
            s[i] += 1;
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn map_mut_visits_each_element_once_in_order() {
        let p = Pool::new(4);
        let mut items: Vec<usize> = vec![0; 200];
        let out = p.map_mut(&mut items, |i, v| {
            *v += i + 1;
            *v * 2
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i + 1, "element {i} mutated wrongly");
        }
        assert_eq!(out, (0..200).map(|i| 2 * (i + 1)).collect::<Vec<_>>());
    }

    #[test]
    fn map_mut_single_worker_and_empty() {
        let p = Pool::new(1);
        let mut items = vec![1, 2, 3];
        let out = p.map_mut(&mut items, |_, v| {
            *v *= 10;
            *v
        });
        assert_eq!(items, vec![10, 20, 30]);
        assert_eq!(out, vec![10, 20, 30]);
        let mut empty: Vec<i32> = Vec::new();
        assert!(Pool::new(4).map_mut(&mut empty, |_, v| *v).is_empty());
    }

    #[test]
    fn map_with_uneven_work() {
        let p = Pool::default_for_machine();
        let out = p.map(64, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 997) {
                acc = acc.wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 64);
        // Deterministic irrespective of scheduling.
        let seq: Vec<u64> = (0..64)
            .map(|i| {
                let mut acc = 0u64;
                for k in 0..(i as u64 * 997) {
                    acc = acc.wrapping_add(k ^ i as u64);
                }
                acc
            })
            .collect();
        assert_eq!(out, seq);
    }

    #[test]
    fn all_backends_agree_bitwise() {
        let work = |i: usize| {
            let mut acc = 1.0f64;
            for k in 0..(i * 31 + 7) {
                acc = (acc * 1.000001 + k as f64).sin();
            }
            acc
        };
        let inline: Vec<f64> = (0..40).map(work).collect();
        assert_eq!(Pool::scoped(4).map(40, work), inline);
        assert_eq!(Pool::new(4).map(40, work), inline);
        assert_eq!(Pool::shared(4).map(40, work), inline);
        assert_eq!(Pool::global().map(40, work), inline);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let p = Pool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            p.map(32, |i| {
                if i == 17 {
                    panic!("boom {i}");
                }
                i
            })
        }));
        let payload = r.expect_err("panic must propagate to the submitter");
        let msg = payload.downcast_ref::<String>().expect("String payload");
        assert!(msg.contains("boom 17"), "payload: {msg}");
        // The pool keeps serving work after a task panicked.
        assert_eq!(p.map(8, |i| i * 2), (0..8).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_shutdown_with_work_in_flight() {
        // Drop one handle while a clone is mid-map: the runtime stays up
        // for the in-flight job (clone holds it) and joins its workers
        // only when the last handle goes — a hang here is the failure.
        let p = Pool::new(3);
        let p2 = p.clone();
        let h = std::thread::spawn(move || {
            p2.map(64, |i| {
                std::thread::sleep(Duration::from_millis(1));
                i
            })
        });
        drop(p);
        let out = h.join().expect("in-flight map must complete");
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn nested_map_from_inside_a_task() {
        // Re-entrant submission on the same runtime: the inner submitter
        // participates in its own job, so this cannot deadlock even with
        // every worker busy in outer tasks.
        let p = Pool::new(3);
        let out = p.map(6, |i| p.map(5, move |j| i * 10 + j).into_iter().sum::<usize>());
        let expect: Vec<usize> =
            (0..6).map(|i| (0..5).map(|j| i * 10 + j).sum::<usize>()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn persistent_pool_spawns_no_threads_per_call() {
        // THREAD_SPAWNS is process-global and sibling tests run
        // concurrently, so assert with margins rather than equality:
        // siblings contribute a handful of spawns (dedicated pools,
        // the lazy global runtime, one scoped map), while 100
        // spawn-per-call maps at 4 workers would add ~400.
        let p = Pool::new(4); // dedicated workers spawn here, once
        p.map(32, |i| i); // warmup
        let s0 = thread_spawns();
        for _ in 0..100 {
            p.map(32, |i| i);
        }
        let persistent_delta = thread_spawns() - s0;
        assert!(
            persistent_delta < 100,
            "persistent pool spawned per call: +{persistent_delta} threads over 100 maps"
        );
        // The scoped baseline does spawn per call — the counter sees it.
        let s1 = thread_spawns();
        let sc = Pool::scoped(4);
        for _ in 0..100 {
            sc.map(32, |i| i);
        }
        assert!(
            thread_spawns() - s1 >= 300,
            "scoped baseline must spawn per call"
        );
    }

    #[test]
    fn submit_returns_result_at_wait() {
        let p = Pool::new(3);
        let hs: Vec<JobHandle<usize>> = (0..8).map(|i| p.submit(move || i * i)).collect();
        let out: Vec<usize> = hs.into_iter().map(|h| h.wait()).collect();
        assert_eq!(out, (0..8).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn submit_inline_pool_runs_synchronously() {
        // A 1-worker handle degenerates to sequential execution: the
        // side effect is visible before wait() is ever called.
        let p = Pool::new(1);
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = flag.clone();
        let h = p.submit(move || f2.fetch_add(1, Ordering::SeqCst));
        assert_eq!(flag.load(Ordering::SeqCst), 1, "inline submit executes eagerly");
        assert!(h.is_done());
        assert_eq!(h.wait(), 0);
    }

    #[test]
    fn submit_panic_rethrown_at_wait_pool_survives() {
        let p = Pool::new(3);
        let ok = p.submit(|| 7usize);
        let bad = p.submit(|| -> usize { panic!("detached boom") });
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| bad.wait()));
        let payload = r.expect_err("panic must surface at wait");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("detached boom"), "payload: {msg}");
        assert_eq!(ok.wait(), 7);
        // The pool keeps serving maps and submits afterwards.
        assert_eq!(p.map(6, |i| i + 1), (1..7).collect::<Vec<_>>());
        assert_eq!(p.submit(|| 11usize).wait(), 11);
    }

    #[test]
    fn drop_before_wait_blocks_until_done_and_swallows_panics() {
        let p = Pool::new(3);
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = done.clone();
        let h = p.submit(move || {
            std::thread::sleep(Duration::from_millis(20));
            d2.fetch_add(1, Ordering::SeqCst);
        });
        drop(h); // must block until the job has actually run
        assert_eq!(done.load(Ordering::SeqCst), 1, "drop returned before the job finished");
        // A dropped panicking job must not unwind anywhere.
        let h: JobHandle<()> = p.submit(|| panic!("swallowed"));
        drop(h);
        assert_eq!(p.map(4, |i| i), (0..4).collect::<Vec<_>>());
    }

    #[test]
    fn submit_budget_gate_caps_detached_concurrency() {
        // A budget-2 handle on the (large) shared runtime must never
        // have more than 2 of its detached jobs executing at once.
        let p = Pool::shared(2);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let hs: Vec<JobHandle<()>> = (0..12)
            .map(|_| {
                let live = live.clone();
                let peak = peak.clone();
                p.submit(move || {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(2));
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.wait();
        }
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "budget 2 exceeded by detached jobs: peak {}",
            peak.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn submit_on_scoped_baseline_spawns_and_completes() {
        let p = Pool::scoped(2);
        let s0 = thread_spawns();
        let hs: Vec<JobHandle<usize>> = (0..4).map(|i| p.submit(move || 10 * i)).collect();
        let out: Vec<usize> = hs.into_iter().map(|h| h.wait()).collect();
        assert_eq!(out, vec![0, 10, 20, 30]);
        assert!(thread_spawns() - s0 >= 4, "scoped submit spawns per job");
    }

    #[test]
    fn detached_jobs_and_maps_share_the_runtime() {
        let p = Pool::new(4);
        let h = p.submit(|| {
            std::thread::sleep(Duration::from_millis(5));
            41usize
        });
        let m = p.map(32, |i| i * 2);
        assert_eq!(m, (0..32).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(h.wait() + 1, 42);
    }

    #[test]
    fn budget_caps_concurrency_on_shared_runtime() {
        use std::sync::atomic::AtomicUsize;
        let p = Pool::shared(2);
        assert_eq!(p.workers(), 2);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        p.map(64, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(Duration::from_micros(200));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "budget 2 exceeded: peak {}",
            peak.load(Ordering::SeqCst)
        );
    }
}

/// Loom model tests: exhaustive interleaving checks of the submission
/// queue, `Gate` budget, and `JobHandle` completion protocol. They use
/// the *production* types — the `sync` shim swaps the primitives, not
/// the logic. Run (CI `loom` lane; needs the `loom` dev-dependency
/// uncommented in Cargo.toml):
///
/// ```text
/// RUSTFLAGS="--cfg loom" cargo test --release --lib loom_
/// ```
///
/// Thread budget: loom models at most 4 threads, so every model keeps
/// `spawned + main <= 4`. Preemptions are bounded (see `model`) — the
/// standard loom trade: nearly all real bugs surface within bound 2.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    fn model<F>(f: F)
    where
        F: Fn() + Sync + Send + 'static,
    {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(2);
        b.check(f);
    }

    /// Gate invariant #1: with `limit = 2` and three acquirers, no
    /// interleaving ever sees three holders at once.
    #[test]
    fn loom_gate_budget_never_exceeded() {
        model(|| {
            let gate = Arc::new(Gate::new(2));
            let live = Arc::new(AtomicUsize::new(0));
            let hs: Vec<_> = (0..3)
                .map(|_| {
                    let gate = Arc::clone(&gate);
                    let live = Arc::clone(&live);
                    loom::thread::spawn(move || {
                        gate.acquire();
                        let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                        assert!(now <= 2, "gate budget exceeded: {now} holders");
                        live.fetch_sub(1, Ordering::SeqCst);
                        gate.release();
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        });
    }

    /// Gate invariant #2: no lost wakeups — on a full `limit = 1` gate,
    /// a blocked `acquire` always completes once the holder releases
    /// (the join hangs, and loom flags the deadlock, if a wakeup is
    /// ever dropped).
    #[test]
    fn loom_gate_no_lost_wakeup() {
        model(|| {
            let gate = Arc::new(Gate::new(1));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let gate = Arc::clone(&gate);
                    loom::thread::spawn(move || {
                        gate.acquire();
                        gate.release();
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        });
    }

    /// `try_acquire` never oversubscribes: two probes against a full
    /// `limit = 1` gate admit at most one holder.
    #[test]
    fn loom_gate_try_acquire_respects_limit() {
        model(|| {
            let gate = Arc::new(Gate::new(1));
            let got = Arc::new(AtomicUsize::new(0));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let gate = Arc::clone(&gate);
                    let got = Arc::clone(&got);
                    loom::thread::spawn(move || {
                        if gate.try_acquire() {
                            got.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert!(got.load(Ordering::SeqCst) <= 1, "try_acquire oversubscribed");
        });
    }

    /// The map path end to end on a 1-worker runtime (submitter
    /// participating): every index runs exactly once, `run_on` returns
    /// only after both did, and shutdown joins cleanly. Exercises the
    /// work-stealing cursor, the completed-counter release sequence,
    /// and the `done` handshake under every interleaving.
    #[test]
    fn loom_map_runs_each_index_once_and_completes() {
        model(|| {
            let rt = Arc::new(PoolRuntime::new(1));
            let hits = Arc::new(AtomicUsize::new(0));
            {
                let hits = Arc::clone(&hits);
                let task = move |_i: usize| {
                    hits.fetch_add(1, Ordering::SeqCst);
                };
                run_on(&rt, 2, 2, &task);
            }
            assert_eq!(hits.load(Ordering::SeqCst), 2, "each index must run exactly once");
            drop(rt);
        });
    }

    /// `JobHandle` drop-while-running: dropping the handle of a
    /// detached job must block until the job has actually executed
    /// (the side effect is visible after `drop`), under every
    /// interleaving of submitter and worker.
    #[test]
    fn loom_job_handle_drop_blocks_until_complete() {
        model(|| {
            let p = Pool::new(2); // one worker thread + the submitter
            let flag = Arc::new(AtomicUsize::new(0));
            let f2 = Arc::clone(&flag);
            let h = p.submit(move || {
                f2.store(1, Ordering::SeqCst);
            });
            drop(h);
            assert_eq!(
                flag.load(Ordering::SeqCst),
                1,
                "drop returned before the detached job finished"
            );
            drop(p);
        });
    }

    /// `JobHandle::wait` returns the job's result (the queued-state
    /// result slot is fully synchronized with the worker's write).
    #[test]
    fn loom_job_handle_wait_returns_result() {
        model(|| {
            let p = Pool::new(2);
            let h = p.submit(|| 41usize);
            assert_eq!(h.wait(), 41);
            drop(p);
        });
    }
}
