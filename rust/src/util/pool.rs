//! Persistent worker-pool runtime (no tokio/rayon offline): long-lived
//! worker threads fed through a Mutex+Condvar submission queue, with the
//! same atomic work-stealing cursor semantics the engine has always
//! relied on. The coordinator uses it to solve independent impact zones
//! in parallel; `batch::SceneBatch` uses it for cross-scene stepping and
//! batched gradient gathers.
//!
//! The previous implementation spawned a fresh `thread::scope` per
//! `map`/`map_mut`/`parallel_for` call. The lockstep forward issues
//! several such calls per simulated step (stage barriers + one per
//! fail-safe pass), so small scenes and large batches paid OS thread
//! creation on the hottest path. Here workers are created once, park on
//! a condvar while idle, and claim indices from submitted jobs — zero
//! thread spawns per call after warmup (see [`thread_spawns`] and
//! `benches/batch_throughput.rs` → `BENCH_pool.json`).
//!
//! # Execution model
//!
//! * A `map`/`map_mut` call packages the closure as a type-erased *job*
//!   (index cursor + completion counter) and pushes it on the runtime's
//!   queue. **The submitting thread participates**: it claims indices
//!   alongside the workers and only blocks once the cursor is
//!   exhausted. This is what makes nested/re-entrant maps safe (see
//!   below) and keeps a one-budget handle exactly as fast as inline.
//! * Results are written into per-index slots, so outputs are in index
//!   order and bitwise-independent of scheduling — determinism is
//!   identical to the old scoped pool and to sequential execution.
//! * Each handle carries a *worker budget*: at most `workers()` threads
//!   (submitter included) execute one job concurrently, so
//!   `Pool::shared(2)` on a 16-thread runtime still honors a 2-worker
//!   budget per call.
//!
//! # Sharing
//!
//! [`Pool::global`]/[`Pool::shared`] hand out handles to one
//! process-wide runtime sized by [`Pool::machine_workers`]; the engine
//! ([`crate::engine::Simulation`]), the batch layer
//! ([`crate::batch::SceneBatch`]), and the lockstep forward/backward
//! paths all draw from this single worker set. A handle's budget also
//! bounds how many scenes of a batch execute a stage concurrently,
//! which is what caps the live checkout count of the cross-scene
//! [`crate::util::arena::BatchArena`] — batch buffer memory scales with
//! the budget, not the population. [`Pool::new`] builds a
//! dedicated runtime (own threads, shut down on `Drop`) for isolation —
//! mostly tests. [`Pool::scoped`] keeps the old spawn-per-call behavior
//! as a measurable baseline for the perf benches.
//!
//! # Nested maps
//!
//! Calling `map`/`map_mut` from *inside* a pool task (same runtime) is
//! supported: the inner submitter executes its own job's indices, so
//! progress never depends on another worker being free — no deadlock by
//! construction. Idle workers may join the inner job as usual.
//!
//! # Panics
//!
//! A panic inside a task does not kill the worker: it is caught, the
//! remaining indices still run (matching the old `thread::scope` join
//! semantics), and the first payload is re-thrown on the submitting
//! thread once the job completes. The pool stays usable afterwards.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Process-wide count of OS threads spawned by the pool layer —
/// persistent workers and spawn-per-call baseline threads alike.
static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// Total OS threads the pool layer has ever spawned. Benches read the
/// delta across a measured phase to prove "zero spawns per step after
/// warmup" for the persistent runtime.
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------- jobs

/// Type- and lifetime-erased `Fn(usize)` executing one index of a map.
///
/// SAFETY: sound because the submitter blocks in [`run_on`] until
/// `completed == n`, so the referenced closure and output slots outlive
/// every dereference; workers never touch the pointer once the cursor
/// is exhausted.
struct TaskRef(*const (dyn Fn(usize) + Sync));
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

struct Job {
    task: TaskRef,
    n: usize,
    /// Next unclaimed index — the work-stealing cursor that keeps
    /// unequal zone sizes balanced across workers.
    cursor: AtomicUsize,
    /// Indices fully executed; `done` flips when it reaches `n`.
    completed: AtomicUsize,
    /// Executors currently inside the job (submitter included), capped
    /// at `limit` so per-handle worker budgets stay honored on the
    /// shared runtime.
    active: AtomicUsize,
    limit: usize,
    /// First task panic, re-thrown on the submitting thread.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl Job {
    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.n
    }

    /// Reserve an executor slot; fails when the job is exhausted or at
    /// its concurrency budget.
    fn try_join(&self) -> bool {
        let mut a = self.active.load(Ordering::Relaxed);
        loop {
            if a >= self.limit || self.exhausted() {
                return false;
            }
            match self.active.compare_exchange_weak(
                a,
                a + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => a = now,
            }
        }
    }

    fn leave(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Claim and execute indices until the cursor is exhausted.
    fn run(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            // SAFETY: see `TaskRef` — the submitter keeps the closure
            // alive until every claimed index has completed.
            let task = unsafe { &*self.task.0 };
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(i))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            // AcqRel: the final increment synchronizes with every prior
            // executor's release, so the submitter observes all writes.
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
                *self.done.lock().unwrap() = true;
                self.done_cv.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut d = self.done.lock().unwrap();
        while !*d {
            d = self.done_cv.wait(d).unwrap();
        }
    }
}

// ------------------------------------------------------------- runtime

struct Queue {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    cv: Condvar,
}

/// A set of persistent worker threads. Dropped (last handle) → shutdown
/// flag + condvar broadcast; workers drain claimable work, exit, and are
/// joined.
struct PoolRuntime {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl PoolRuntime {
    fn new(workers: usize) -> PoolRuntime {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|k| {
                let sh = shared.clone();
                THREAD_SPAWNS.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("pool-worker-{k}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        PoolRuntime { shared, handles: Mutex::new(handles) }
    }

    fn submit(&self, job: &Arc<Job>) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push_back(job.clone());
        drop(q);
        self.shared.cv.notify_all();
    }
}

impl Drop for PoolRuntime {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                // Exhausted jobs leave the queue here; any executors
                // still inside them hold their own Arcs.
                q.jobs.retain(|j| !j.exhausted());
                if let Some(j) = q.jobs.iter().find(|j| j.try_join()) {
                    break Arc::clone(j);
                }
                if q.shutdown {
                    return;
                }
                // Park until new work (or shutdown) is announced.
                q = sh.cv.wait(q).unwrap();
            }
        };
        job.run();
        job.leave();
    }
}

/// Submit `task` over `0..n` on `rt` with concurrency `budget`, with
/// the submitting thread participating; blocks until every index has
/// completed, then re-throws the first task panic, if any.
fn run_on(rt: &Arc<PoolRuntime>, budget: usize, n: usize, task: &(dyn Fn(usize) + Sync)) {
    // Lifetime erasure; sound because this function does not return
    // until `completed == n` (see `TaskRef`).
    let task: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(task) };
    let job = Arc::new(Job {
        task: TaskRef(task as *const _),
        n,
        cursor: AtomicUsize::new(0),
        completed: AtomicUsize::new(0),
        active: AtomicUsize::new(1), // the submitter's slot
        limit: budget.min(n).max(1),
        panic: Mutex::new(None),
        done: Mutex::new(false),
        done_cv: Condvar::new(),
    });
    rt.submit(&job);
    job.run();
    job.leave();
    job.wait();
    if let Some(p) = job.panic.lock().unwrap().take() {
        resume_unwind(p);
    }
}

fn global_runtime() -> &'static Arc<PoolRuntime> {
    static GLOBAL: OnceLock<Arc<PoolRuntime>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(PoolRuntime::new(Pool::machine_workers())))
}

// ---------------------------------------------------------------- Pool

#[derive(Clone)]
enum Backend {
    /// One worker: run on the caller, no queue traffic.
    Inline,
    /// Spawn-per-call `thread::scope` — the pre-persistent behavior,
    /// kept as a measurable baseline for `BENCH_pool.json`.
    Scoped { workers: usize },
    /// Persistent runtime (dedicated or the process-wide one) with a
    /// per-handle concurrency budget.
    Persistent { rt: Arc<PoolRuntime>, budget: usize },
}

/// Handle to a worker pool. Cheap to clone; clones share the same
/// worker threads. Work is submitted as a parallel indexed map — the
/// dominant pattern in the engine (N independent zones / bodies /
/// scenes).
#[derive(Clone)]
pub struct Pool {
    backend: Backend,
}

impl Pool {
    /// Dedicated persistent pool with a `workers` concurrency budget:
    /// spawns `workers − 1` owned threads once (the submitter is the
    /// remaining executor) and shuts them down when the last handle is
    /// dropped. `workers <= 1` degenerates to inline execution.
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        if workers == 1 {
            Pool { backend: Backend::Inline }
        } else {
            Pool {
                backend: Backend::Persistent {
                    rt: Arc::new(PoolRuntime::new(workers - 1)),
                    budget: workers,
                },
            }
        }
    }

    /// Handle to the process-wide shared runtime with a per-call
    /// concurrency budget of `workers`. The runtime itself is created
    /// on first use with [`Pool::machine_workers`] threads and lives for
    /// the process. This is what [`crate::engine::Simulation`] and
    /// [`crate::batch::SceneBatch`] use, so one worker set serves
    /// per-pass zone solves, cross-scene stepping, and batched gradient
    /// gathers.
    pub fn shared(workers: usize) -> Pool {
        if workers.max(1) == 1 {
            Pool { backend: Backend::Inline }
        } else {
            Pool { backend: Backend::Persistent { rt: global_runtime().clone(), budget: workers } }
        }
    }

    /// The process-wide pool at full machine budget —
    /// `Pool::shared(Pool::machine_workers())`.
    pub fn global() -> Pool {
        Pool::shared(Pool::machine_workers())
    }

    /// Spawn-per-call baseline (the pre-persistent implementation):
    /// every `map`/`map_mut` spawns `workers.min(n)` scoped threads and
    /// joins them. Kept for benchmarking the persistent runtime against;
    /// do not use on hot paths.
    pub fn scoped(workers: usize) -> Pool {
        Pool { backend: Backend::Scoped { workers: workers.max(1) } }
    }

    /// Worker count the machine supports, capped (zone solves are
    /// memory-bound beyond a few cores). Use this instead of
    /// constructing a pool just to read `.workers()`.
    pub fn machine_workers() -> usize {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        n.min(16)
    }

    /// Pool sized to the machine — now a handle to the shared runtime
    /// (no threads spawned per call; see [`Pool::shared`]).
    pub fn default_for_machine() -> Pool {
        Pool::global()
    }

    /// This handle's concurrency budget per submitted map.
    pub fn workers(&self) -> usize {
        match &self.backend {
            Backend::Inline => 1,
            Backend::Scoped { workers } => *workers,
            Backend::Persistent { budget, .. } => *budget,
        }
    }

    /// Parallel map over `0..n`; results returned in index order.
    /// Work-stealing via an atomic cursor keeps unequal zone sizes
    /// balanced across workers.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        // Zero-sized slots: `map` is `map_mut` with nothing to mutate.
        let mut slots = vec![(); n];
        self.map_mut(&mut slots, |i, _| f(i))
    }

    /// Parallel mutable indexed map over a slice (the batch-stepping
    /// primitive: N independent `Simulation`s advanced concurrently).
    /// Each index is claimed exactly once via the atomic cursor, so the
    /// per-element `&mut T` handed to `f` never aliases. Results are
    /// returned in index order.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if self.workers() == 1 || n == 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        match &self.backend {
            Backend::Inline => unreachable!("workers() == 1 handled above"),
            Backend::Scoped { workers } => scoped_map_mut(*workers, items, f),
            Backend::Persistent { rt, budget } => {
                let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
                {
                    let items_base = SendPtr(items.as_mut_ptr());
                    let out_base = SendPtr(out.as_mut_ptr());
                    let f = &f;
                    let runner = move |i: usize| {
                        // SAFETY: `i` was claimed exactly once across all
                        // executors, so these are the only live references
                        // to items[i] / out[i].
                        let item = unsafe { &mut *items_base.0.add(i) };
                        let r = f(i, item);
                        unsafe { *out_base.0.add(i) = Some(r) };
                    };
                    run_on(rt, *budget, n, &runner);
                }
                out.into_iter().map(|o| o.expect("pool: missing result")).collect()
            }
        }
    }
}

/// Shared base pointer; safe to hand to executors because every index
/// is visited by exactly one executor (cursor) and T: Send.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// The old scoped implementation, kept verbatim as the spawn-per-call
/// baseline ([`Pool::scoped`]).
fn scoped_map_mut<T, R, F>(workers: usize, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let base = SendPtr(items.as_mut_ptr());
    let cursor = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers.min(n))
            .map(|_| {
                let cursor = &cursor;
                let f = &f;
                let base = &base;
                THREAD_SPAWNS.fetch_add(1, Ordering::Relaxed);
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // SAFETY: `i` was claimed exactly once across
                        // all workers, so this is the only live
                        // reference to items[i].
                        let item = unsafe { &mut *base.0.add(i) };
                        local.push((i, f(i, item)));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|o| o.expect("pool: missing result")).collect()
}

/// Run `f` over `0..n` in parallel for side effects (e.g. writes into
/// disjoint pre-partitioned storage guarded by interior mutability).
/// Routed through the process-wide persistent runtime with a `workers`
/// budget — no threads are spawned per call.
pub fn parallel_for<F>(workers: usize, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    run_on(global_runtime(), workers, n, &|i| f(i));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use std::time::Duration;

    #[test]
    fn map_returns_in_order() {
        let p = Pool::new(4);
        let out = p.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_and_single() {
        let p = Pool::new(4);
        assert!(p.map(0, |i| i).is_empty());
        assert_eq!(p.map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn single_worker_pool() {
        let p = Pool::new(1);
        assert_eq!(p.map(10, |i| i), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let seen = Mutex::new(vec![0usize; 1000]);
        parallel_for(8, 1000, |i| {
            let mut s = seen.lock().unwrap();
            s[i] += 1;
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn map_mut_visits_each_element_once_in_order() {
        let p = Pool::new(4);
        let mut items: Vec<usize> = vec![0; 200];
        let out = p.map_mut(&mut items, |i, v| {
            *v += i + 1;
            *v * 2
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i + 1, "element {i} mutated wrongly");
        }
        assert_eq!(out, (0..200).map(|i| 2 * (i + 1)).collect::<Vec<_>>());
    }

    #[test]
    fn map_mut_single_worker_and_empty() {
        let p = Pool::new(1);
        let mut items = vec![1, 2, 3];
        let out = p.map_mut(&mut items, |_, v| {
            *v *= 10;
            *v
        });
        assert_eq!(items, vec![10, 20, 30]);
        assert_eq!(out, vec![10, 20, 30]);
        let mut empty: Vec<i32> = Vec::new();
        assert!(Pool::new(4).map_mut(&mut empty, |_, v| *v).is_empty());
    }

    #[test]
    fn map_with_uneven_work() {
        let p = Pool::default_for_machine();
        let out = p.map(64, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 997) {
                acc = acc.wrapping_add(k ^ i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 64);
        // Deterministic irrespective of scheduling.
        let seq: Vec<u64> = (0..64)
            .map(|i| {
                let mut acc = 0u64;
                for k in 0..(i as u64 * 997) {
                    acc = acc.wrapping_add(k ^ i as u64);
                }
                acc
            })
            .collect();
        assert_eq!(out, seq);
    }

    #[test]
    fn all_backends_agree_bitwise() {
        let work = |i: usize| {
            let mut acc = 1.0f64;
            for k in 0..(i * 31 + 7) {
                acc = (acc * 1.000001 + k as f64).sin();
            }
            acc
        };
        let inline: Vec<f64> = (0..40).map(work).collect();
        assert_eq!(Pool::scoped(4).map(40, work), inline);
        assert_eq!(Pool::new(4).map(40, work), inline);
        assert_eq!(Pool::shared(4).map(40, work), inline);
        assert_eq!(Pool::global().map(40, work), inline);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let p = Pool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            p.map(32, |i| {
                if i == 17 {
                    panic!("boom {i}");
                }
                i
            })
        }));
        let payload = r.expect_err("panic must propagate to the submitter");
        let msg = payload.downcast_ref::<String>().expect("String payload");
        assert!(msg.contains("boom 17"), "payload: {msg}");
        // The pool keeps serving work after a task panicked.
        assert_eq!(p.map(8, |i| i * 2), (0..8).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_shutdown_with_work_in_flight() {
        // Drop one handle while a clone is mid-map: the runtime stays up
        // for the in-flight job (clone holds it) and joins its workers
        // only when the last handle goes — a hang here is the failure.
        let p = Pool::new(3);
        let p2 = p.clone();
        let h = std::thread::spawn(move || {
            p2.map(64, |i| {
                std::thread::sleep(Duration::from_millis(1));
                i
            })
        });
        drop(p);
        let out = h.join().expect("in-flight map must complete");
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn nested_map_from_inside_a_task() {
        // Re-entrant submission on the same runtime: the inner submitter
        // participates in its own job, so this cannot deadlock even with
        // every worker busy in outer tasks.
        let p = Pool::new(3);
        let out = p.map(6, |i| p.map(5, move |j| i * 10 + j).into_iter().sum::<usize>());
        let expect: Vec<usize> =
            (0..6).map(|i| (0..5).map(|j| i * 10 + j).sum::<usize>()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn persistent_pool_spawns_no_threads_per_call() {
        // THREAD_SPAWNS is process-global and sibling tests run
        // concurrently, so assert with margins rather than equality:
        // siblings contribute a handful of spawns (dedicated pools,
        // the lazy global runtime, one scoped map), while 100
        // spawn-per-call maps at 4 workers would add ~400.
        let p = Pool::new(4); // dedicated workers spawn here, once
        p.map(32, |i| i); // warmup
        let s0 = thread_spawns();
        for _ in 0..100 {
            p.map(32, |i| i);
        }
        let persistent_delta = thread_spawns() - s0;
        assert!(
            persistent_delta < 100,
            "persistent pool spawned per call: +{persistent_delta} threads over 100 maps"
        );
        // The scoped baseline does spawn per call — the counter sees it.
        let s1 = thread_spawns();
        let sc = Pool::scoped(4);
        for _ in 0..100 {
            sc.map(32, |i| i);
        }
        assert!(
            thread_spawns() - s1 >= 300,
            "scoped baseline must spawn per call"
        );
    }

    #[test]
    fn budget_caps_concurrency_on_shared_runtime() {
        use std::sync::atomic::AtomicUsize;
        let p = Pool::shared(2);
        assert_eq!(p.workers(), 2);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        p.map(64, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(Duration::from_micros(200));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "budget 2 exceeded: peak {}",
            peak.load(Ordering::SeqCst)
        );
    }
}
