//! Minimal JSON substrate (no `serde` offline): a dynamic `Json` value,
//! a recursive-descent parser, and a writer.
//!
//! Used for scene/experiment config files, the AOT artifact manifest
//! produced by `python/compile/aot.py`, and metric dumps from benches.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if not an object (programming error).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(key)` with a typed fetch and default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no inf/nan; emit null (documented behaviour).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) => {
                    // Re-borrow as utf-8: collect continuation bytes.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        self.pos = start + len;
                        if self.pos > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|_| self.err("bad utf-8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"zone","sizes":[6,12,24],"pi":3.25,"on":true}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"\\u00e9clair \u{1f680}\"").unwrap();
        match j {
            Json::Str(s) => assert_eq!(s, "éclair 🚀"),
            _ => panic!(),
        }
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("n", 3usize).set("xs", vec![1.0, 2.0]).set("tag", "ok");
        assert_eq!(j.usize_or("n", 0), 3);
        assert_eq!(j.str_or("tag", ""), "ok");
        assert_eq!(j.f64_or("missing", 7.5), 7.5);
    }
}
