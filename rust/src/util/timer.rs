//! Timing + statistics helpers shared by the bench harness, the
//! coordinator's metrics, and the telemetry registry
//! ([`crate::util::telemetry`]): [`Stats`] carries Welford moments plus
//! a fixed-bucket histogram, so span timers and bench rows share one
//! p50/p90/p99 implementation (the bucket scheme is exported for the
//! registry's lock-free cells).

use std::time::Instant;

/// Number of fixed log-spaced quantile buckets shared by [`Stats`] and
/// the telemetry registry's histogram cells.
pub const QUANT_BUCKETS: usize = 64;

/// Lower edge of bucket 0. Values at or below it land in bucket 0.
const QUANT_MIN: f64 = 1e-9;

/// Decades covered by the bucket range: `1e-9 ..= 1e7` spans sub-ns
/// span timings up to multi-day durations (and, reused for counts,
/// anything up to 1e7).
const QUANT_DECADES: f64 = 16.0;

/// Per-bucket geometric growth factor (`10^(16/64) ≈ 1.778`) — the
/// worst-case multiplicative error of a bucket-estimated quantile.
pub fn quant_ratio() -> f64 {
    10f64.powf(QUANT_DECADES / QUANT_BUCKETS as f64)
}

/// Bucket index for a (positive) sample. Non-positive and NaN samples
/// land in bucket 0; oversized ones clamp to the last bucket.
pub fn quant_bucket(x: f64) -> usize {
    if !(x > QUANT_MIN) {
        return 0;
    }
    let i = ((x / QUANT_MIN).log10() * (QUANT_BUCKETS as f64 / QUANT_DECADES)).floor() as isize;
    i.clamp(0, QUANT_BUCKETS as isize - 1) as usize
}

/// Geometric midpoint of bucket `i` — the value a quantile estimate
/// reports for a rank that falls in that bucket.
pub fn quant_bucket_mid(i: usize) -> f64 {
    QUANT_MIN * 10f64.powf(QUANT_DECADES * (i as f64 + 0.5) / QUANT_BUCKETS as f64)
}

/// Estimate quantile `q` (in `[0, 1]`) from fixed-bucket counts using
/// the nearest-rank definition, clamped to the observed `[min, max]`.
/// `n` must equal the sum of `buckets`. Returns 0 for an empty
/// histogram.
pub fn quantile_from_buckets(buckets: &[u64], n: u64, q: f64, min: f64, max: f64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return quant_bucket_mid(i).clamp(min, max);
        }
    }
    max
}

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Online mean/std/min/max accumulator (Welford) with a fixed-bucket
/// histogram for quantile estimation and parallel merge.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    /// Lazily sized to [`QUANT_BUCKETS`] on first push, so `Default`
    /// stays allocation-free.
    buckets: Vec<u64>,
}

impl Stats {
    pub fn new() -> Stats {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.buckets.is_empty() {
            self.buckets = vec![0; QUANT_BUCKETS];
        }
        self.buckets[quant_bucket(x)] += 1;
    }

    /// Fold `other` into `self` as if every sample of `other` had been
    /// pushed here (parallel Welford merge; exact for n/mean/m2/min/max,
    /// bucket-exact for quantiles).
    pub fn merge(&mut self, other: &Stats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * n1 * n2 / (n1 + n2);
        self.mean += d * n2 / (n1 + n2);
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        if self.buckets.is_empty() {
            self.buckets = vec![0; QUANT_BUCKETS];
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Nearest-rank quantile estimate from the fixed buckets, accurate
    /// to within one bucket ratio ([`quant_ratio`]) and clamped to the
    /// observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_from_buckets(&self.buckets, self.n as u64, q, self.min, self.max)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn from_slice(xs: &[f64]) -> Stats {
        let mut s = Stats::new();
        for &x in xs {
            s.push(x);
        }
        s
    }
}

/// Format seconds in a human unit, e.g. "1.23ms".
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_match_textbook() {
        let s = Stats::from_slice(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(Stats::new().mean(), 0.0);
        let s = Stats::from_slice(&[3.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn merge_matches_concatenation() {
        let xs: Vec<f64> = (0..50).map(|i| ((i * 37 % 101) as f64) * 0.1 + 0.05).collect();
        let (left, right) = xs.split_at(20);
        let mut merged = Stats::from_slice(left);
        merged.merge(&Stats::from_slice(right));
        let whole = Stats::from_slice(&xs);
        assert_eq!(merged.n, whole.n);
        assert!((merged.mean() - whole.mean()).abs() < 1e-12);
        assert!((merged.std() - whole.std()).abs() < 1e-12);
        assert_eq!(merged.min, whole.min);
        assert_eq!(merged.max, whole.max);
        assert_eq!(merged.quantile(0.5), whole.quantile(0.5));
        // Merging into an empty accumulator copies; merging an empty
        // one is a no-op.
        let mut e = Stats::new();
        e.merge(&whole);
        assert!((e.std() - whole.std()).abs() < 1e-12);
        let mut w = whole.clone();
        w.merge(&Stats::new());
        assert_eq!(w.n, whole.n);
        assert_eq!(w.mean(), whole.mean());
    }

    #[test]
    fn quantiles_match_sorted_oracle() {
        // Log-uniform-ish durations spanning 1us..10ms — the regime the
        // bucket layout is designed for.
        let xs: Vec<f64> = (0..500)
            .map(|i| {
                let u = (i * 197 % 500) as f64 / 500.0;
                1e-6 * 10f64.powf(4.0 * u)
            })
            .collect();
        let s = Stats::from_slice(&xs);
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        let ratio = quant_ratio();
        for &q in &[0.5, 0.9, 0.99] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let oracle = sorted[rank - 1];
            let est = s.quantile(q);
            // The estimate is exactly the midpoint of the bucket the
            // oracle value falls in (same bucket function both sides)…
            assert_eq!(est, quant_bucket_mid(quant_bucket(oracle)).clamp(s.min, s.max));
            // …which bounds the multiplicative error by one bucket
            // ratio against the true sorted-vector answer.
            assert!(est >= s.min && est <= s.max);
            assert!(
                est / oracle <= ratio && oracle / est <= ratio,
                "q={q}: est {est} vs oracle {oracle} (allowed ratio {ratio})"
            );
        }
        // Empty and degenerate inputs stay finite.
        assert_eq!(Stats::new().quantile(0.5), 0.0);
        let one = Stats::from_slice(&[2.5e-3]);
        assert_eq!(one.quantile(0.5), 2.5e-3); // clamped to [min, max]
    }

    #[test]
    fn bucket_layout_is_monotone_and_clamped() {
        assert_eq!(quant_bucket(0.0), 0);
        assert_eq!(quant_bucket(-1.0), 0);
        assert_eq!(quant_bucket(f64::NAN), 0);
        assert_eq!(quant_bucket(1e99), QUANT_BUCKETS - 1);
        let mut prev = 0;
        for e in -8..7 {
            let b = quant_bucket(10f64.powi(e));
            assert!(b >= prev, "bucket index must be monotone in the sample");
            prev = b;
        }
        // Midpoints sit inside their bucket: same bucket round-trip.
        for i in 0..QUANT_BUCKETS {
            assert_eq!(quant_bucket(quant_bucket_mid(i)), i);
        }
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50us");
        assert_eq!(fmt_secs(2.6e-9), "3ns");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.seconds();
        let b = t.seconds();
        assert!(b >= a);
    }
}
