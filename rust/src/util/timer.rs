//! Timing + statistics helpers shared by the bench harness and the
//! coordinator's metrics.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Online mean/std/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn from_slice(xs: &[f64]) -> Stats {
        let mut s = Stats::new();
        for &x in xs {
            s.push(x);
        }
        s
    }
}

/// Format seconds in a human unit, e.g. "1.23ms".
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_match_textbook() {
        let s = Stats::from_slice(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(Stats::new().mean(), 0.0);
        let s = Stats::from_slice(&[3.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50us");
        assert_eq!(fmt_secs(2.6e-9), "3ns");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.seconds();
        let b = t.seconds();
        assert!(b >= a);
    }
}
