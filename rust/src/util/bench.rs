//! Benchmark harness substrate (no `criterion` offline): warmup +
//! measured iterations with mean ± σ, a table printer, and JSON dumps to
//! `bench_output/`. Used by every `[[bench]]` target (harness = false).

use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};

/// Time `f` with `warmup` unmeasured calls and `iters` measured calls.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        stats.push(t.seconds());
    }
    stats
}

pub struct Bench {
    pub name: String,
    rows: Vec<(String, Json)>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        println!("\n==== bench: {name} ====");
        Bench { name: name.to_string(), rows: Vec::new() }
    }

    /// Record and print one named measurement.
    pub fn report(&mut self, label: &str, stats: &Stats) {
        println!(
            "{label:<40} {:>12} ± {:<10} (n={})",
            crate::util::timer::fmt_secs(stats.mean()),
            crate::util::timer::fmt_secs(stats.std()),
            stats.n
        );
        let mut j = Json::obj();
        j.set("mean_s", stats.mean()).set("std_s", stats.std()).set("n", stats.n);
        self.rows.push((label.to_string(), j));
    }

    /// Record and print a scalar metric (memory, ratio, count).
    pub fn metric(&mut self, label: &str, value: f64, unit: &str) {
        println!("{label:<40} {value:>12.4} {unit}");
        let mut j = Json::obj();
        j.set("value", value).set("unit", unit);
        self.rows.push((label.to_string(), j));
    }

    /// Write all recorded rows to bench_output/<name>.json.
    pub fn finish(self) {
        let mut obj = Json::obj();
        for (k, v) in self.rows {
            obj.set(&k, v);
        }
        let _ = std::fs::create_dir_all("bench_output");
        let path = format!("bench_output/{}.json", self.name);
        if std::fs::write(&path, obj.pretty()).is_ok() {
            println!("[wrote {path}]");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_right_count_and_positive() {
        let s = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn bench_report_roundtrip() {
        let mut b = Bench::new("selftest");
        let s = time(0, 2, || {});
        b.report("noop", &s);
        b.metric("answer", 42.0, "units");
        // finish() writes to bench_output; tolerate sandboxed CWD.
        b.finish();
    }
}
