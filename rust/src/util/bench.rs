//! Benchmark harness substrate (no `criterion` offline): warmup +
//! measured iterations with mean ± σ, a table printer, and JSON dumps to
//! `bench_output/`. Used by every `[[bench]]` target (harness = false).

use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};

/// Time `f` with `warmup` unmeasured calls and `iters` measured calls.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        stats.push(t.seconds());
    }
    stats
}

pub struct Bench {
    pub name: String,
    rows: Vec<(String, Json)>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        println!("\n==== bench: {name} ====");
        Bench { name: name.to_string(), rows: Vec::new() }
    }

    /// Record and print one named measurement.
    pub fn report(&mut self, label: &str, stats: &Stats) {
        println!(
            "{label:<40} {:>12} ± {:<10} (n={})",
            crate::util::timer::fmt_secs(stats.mean()),
            crate::util::timer::fmt_secs(stats.std()),
            stats.n
        );
        let mut j = Json::obj();
        j.set("mean_s", stats.mean()).set("std_s", stats.std()).set("n", stats.n);
        self.rows.push((label.to_string(), j));
    }

    /// Record and print a scalar metric (memory, ratio, count).
    pub fn metric(&mut self, label: &str, value: f64, unit: &str) {
        println!("{label:<40} {value:>12.4} {unit}");
        let mut j = Json::obj();
        j.set("value", value).set("unit", unit);
        self.rows.push((label.to_string(), j));
    }

    /// Write all recorded rows to `bench_output/<name>.json`.
    pub fn finish(self) {
        let mut obj = Json::obj();
        for (k, v) in self.rows {
            obj.set(&k, v);
        }
        let _ = std::fs::create_dir_all("bench_output");
        let path = format!("bench_output/{}.json", self.name);
        if std::fs::write(&path, obj.pretty()).is_ok() {
            println!("[wrote {path}]");
        }
    }
}

/// Merge `rows` into the shared machine-readable results file at
/// `path`, under top-level key `section` (read-modify-write, so several
/// bench binaries can each contribute a section — e.g. both
/// `batch_throughput` and `micro_hotpaths` write into
/// `BENCH_pool.json` for perf-trajectory tracking).
pub fn merge_section(path: &str, section: &str, rows: Json) {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .filter(|j| j.as_obj().is_some())
        .unwrap_or_else(Json::obj);
    root.set(section, rows);
    if std::fs::write(path, root.pretty()).is_ok() {
        println!("[merged section '{section}' into {path}]");
    }
}

/// Validate a telemetry trace file (one JSON event per line, schema
/// [`crate::util::telemetry::TRACE_SCHEMA_VERSION`]): every non-empty
/// line must parse, carry the right `v`, a string `span`, a numeric
/// `step`, and a non-negative `dur_s`. Returns the event count; an
/// empty or absent trace is an error (the CI smoke step exists to catch
/// exactly the silently-emitted-nothing failure).
pub fn check_trace_jsonl(path: &str) -> Result<usize, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{path}: unreadable trace: {e}"))?;
    let mut events = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line)
            .map_err(|e| format!("{path}:{}: invalid json: {e}", lineno + 1))?;
        let v = ev.get("v").and_then(Json::as_usize).map(|x| x as u64);
        if v != Some(crate::util::telemetry::TRACE_SCHEMA_VERSION) {
            return Err(format!(
                "{path}:{}: schema version {v:?}, expected {}",
                lineno + 1,
                crate::util::telemetry::TRACE_SCHEMA_VERSION
            ));
        }
        if ev.get("span").and_then(Json::as_str).is_none() {
            return Err(format!("{path}:{}: missing string field 'span'", lineno + 1));
        }
        if ev.get("step").and_then(Json::as_f64).is_none() {
            return Err(format!("{path}:{}: missing numeric field 'step'", lineno + 1));
        }
        match ev.get("dur_s").and_then(Json::as_f64) {
            Some(d) if d >= 0.0 => {}
            other => {
                return Err(format!(
                    "{path}:{}: 'dur_s' must be a non-negative number, got {other:?}",
                    lineno + 1
                ))
            }
        }
        events += 1;
    }
    if events == 0 {
        return Err(format!("{path}: trace contains no events"));
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_right_count_and_positive() {
        let s = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn merge_section_read_modify_write() {
        let path = std::env::temp_dir().join("diffsim_merge_section_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        let mut a = Json::obj();
        a.set("x", 1.0);
        merge_section(path, "first", a);
        let mut b = Json::obj();
        b.set("y", 2.0);
        merge_section(path, "second", b);
        let j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(j.get("first").unwrap().f64_or("x", 0.0), 1.0);
        assert_eq!(j.get("second").unwrap().f64_or("y", 0.0), 2.0);
        // Re-writing a section replaces it, not the whole file.
        let mut c = Json::obj();
        c.set("x", 3.0);
        merge_section(path, "first", c);
        let j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(j.get("first").unwrap().f64_or("x", 0.0), 3.0);
        assert_eq!(j.get("second").unwrap().f64_or("y", 0.0), 2.0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bench_report_roundtrip() {
        let mut b = Bench::new("selftest");
        let s = time(0, 2, || {});
        b.report("noop", &s);
        b.metric("answer", 42.0, "units");
        // finish() writes to bench_output; tolerate sandboxed CWD.
        b.finish();
    }
}
