//! Leveled stderr logger substrate. Controlled by `DIFFSIM_LOG`
//! (error|warn|info|debug|trace) or programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn level_from_env() -> Level {
    match std::env::var("DIFFSIM_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        _ => Level::Info,
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let l = level_from_env();
        LEVEL.store(l as u8, Ordering::Relaxed);
        l
    } else {
        // SAFETY-free decode: values only ever set from Level.
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {module}: {msg}", format!("{l:?}").to_lowercase());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_query() {
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
        assert!(enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_level(Level::Info);
    }
}
