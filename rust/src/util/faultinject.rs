//! Deterministic, seeded fault injection for the recovery paths.
//!
//! The engine's fault-containment layer (engine retry ladder, per-scene
//! quarantine in `batch`, coordinator dispatch fallback, pool panic
//! drain) only runs when something goes wrong — which healthy scenes
//! never do. This module makes "something goes wrong" a reproducible,
//! schedulable event so tests can drive every recovery path on demand.
//!
//! A handful of *named sites* are compiled into the hot paths:
//!
//! | site              | location                               | effect when armed            |
//! |-------------------|----------------------------------------|------------------------------|
//! | `zone.solve`      | `ZoneProblem::solve` tail              | solution reported diverged   |
//! | `ccd`             | `collision::ccd::cubic_roots_01`       | conservative miss (no roots) |
//! | `coord.dispatch`  | `Coordinator::zone_solve_batch` entry  | buckets down → native path   |
//! | `pool.job`        | `Pool::submit` detached-job body       | job panics                   |
//!
//! Everything here is gated on the `faultinject` cargo feature. Without
//! it, [`should_fire`] is a `const false` that the optimizer deletes,
//! so release builds carry **zero** overhead and all trajectories stay
//! bitwise-identical to a tree without the hooks. With the feature on
//! but no plan installed, the cost is one relaxed atomic load per site
//! visit.
//!
//! Schedules are deterministic: a [`FaultPlan`] arms a site either at
//! explicit 0-based invocation indices ([`FaultPlan::arm_at`]) or with
//! a seeded per-site PCG stream ([`FaultPlan::arm_prob`]), so a given
//! (plan, workload) pair always fires at the same invocations.
//!
//! ```text
//! let mut plan = FaultPlan::new(42);
//! plan.arm_at(site::ZONE_SOLVE, &[0, 3]); // 1st and 4th zone solve fail
//! faultinject::install(plan);
//! // ... run the workload, assert fault.* counters ...
//! faultinject::clear();
//! ```

/// Canonical site names, so call sites and tests can't drift apart on
/// spelling. The strings (not the constants) are the identity: a plan
/// armed with `"zone.solve"` matches [`site::ZONE_SOLVE`].
pub mod site {
    /// Zone solver tail — an armed firing reports the solution as
    /// diverged (`converged: false`, violation forced above tolerance).
    pub const ZONE_SOLVE: &str = "zone.solve";
    /// CCD cubic root finder — an armed firing drops the candidate
    /// roots (a conservative miss).
    pub const CCD: &str = "ccd";
    /// Coordinator batched-solve dispatch — an armed firing takes the
    /// bucket layer down for that call, so every zone routes through
    /// the counted native fallback.
    pub const COORD_DISPATCH: &str = "coord.dispatch";
    /// Pool detached-job body — an armed firing panics inside the job
    /// so `JobHandle::wait` rethrows.
    pub const POOL_JOB: &str = "pool.job";
}

#[cfg(feature = "faultinject")]
mod imp {
    use crate::util::rng::Pcg32;
    use crate::util::telemetry as obs;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    /// Fast-path gate: one relaxed load decides "is any plan armed at
    /// all" before touching the mutex, so un-armed feature builds stay
    /// cheap on the hot paths.
    static ARMED: AtomicBool = AtomicBool::new(false);

    static PLAN: Mutex<Option<PlanState>> = Mutex::new(None);

    enum Schedule {
        /// Fire at these 0-based invocation indices of the site.
        At(Vec<u64>),
        /// Fire each invocation independently with probability `p`,
        /// drawn from a per-site PCG stream (deterministic per plan).
        Prob { rng: Pcg32, p: f64 },
    }

    struct SiteState {
        schedule: Schedule,
        /// Invocations seen (armed or not, fired or not).
        visits: u64,
        /// Invocations that fired.
        fired: u64,
    }

    struct PlanState {
        sites: BTreeMap<&'static str, SiteState>,
    }

    /// A deterministic injection schedule: which sites fail, and at
    /// which of their invocations. Build one, [`install`](super::install)
    /// it, run the workload, [`clear`](super::clear).
    pub struct FaultPlan {
        seed: u64,
        sites: BTreeMap<&'static str, Schedule>,
    }

    impl FaultPlan {
        /// New empty plan. `seed` feeds the per-site PCG streams used
        /// by [`arm_prob`](Self::arm_prob); index-armed sites ignore it.
        pub fn new(seed: u64) -> Self {
            FaultPlan { seed, sites: BTreeMap::new() }
        }

        /// Arm `site` to fire at exactly these 0-based invocation
        /// indices (site-local count, starting from installation).
        pub fn arm_at(&mut self, site: &'static str, indices: &[u64]) -> &mut Self {
            self.sites.insert(site, Schedule::At(indices.to_vec()));
            self
        }

        /// Arm `site` to fire each invocation independently with
        /// probability `p`, from a stream seeded by (plan seed, site
        /// name) — same plan, same workload ⇒ same firings.
        pub fn arm_prob(&mut self, site: &'static str, p: f64) -> &mut Self {
            let rng = Pcg32::with_stream(self.seed, fnv1a(site));
            self.sites.insert(site, Schedule::Prob { rng, p });
            self
        }
    }

    fn fnv1a(s: &str) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in s.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Install `plan` process-wide, replacing any previous plan and
    /// resetting all per-site counters.
    pub fn install(plan: FaultPlan) {
        let state = PlanState {
            sites: plan
                .sites
                .into_iter()
                .map(|(k, schedule)| (k, SiteState { schedule, visits: 0, fired: 0 }))
                .collect(),
        };
        let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
        ARMED.store(!state.sites.is_empty(), Ordering::Release);
        *slot = Some(state);
    }

    /// Remove the installed plan; every site goes quiet again.
    pub fn clear() {
        let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
        ARMED.store(false, Ordering::Release);
        *slot = None;
    }

    /// Should this invocation of `site` fail? Increments the site's
    /// visit counter; on a firing, bumps the `fault.injected` obs
    /// counter too. Always `false` when no plan is installed or the
    /// plan doesn't arm `site`.
    pub fn should_fire(site: &'static str) -> bool {
        if !ARMED.load(Ordering::Acquire) {
            return false;
        }
        let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
        let Some(state) = slot.as_mut() else { return false };
        let Some(s) = state.sites.get_mut(site) else { return false };
        let idx = s.visits;
        s.visits += 1;
        let fire = match &mut s.schedule {
            Schedule::At(indices) => indices.contains(&idx),
            Schedule::Prob { rng, p } => rng.uniform() < *p,
        };
        if fire {
            s.fired += 1;
            if obs::enabled() {
                obs::counter("fault.injected").incr();
            }
        }
        fire
    }

    /// How many times `site` has fired under the installed plan
    /// (0 if none installed).
    pub fn fired_count(site: &'static str) -> u64 {
        let slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
        slot.as_ref().and_then(|st| st.sites.get(site)).map(|s| s.fired).unwrap_or(0)
    }

    /// How many times `site` has been visited under the installed plan
    /// (0 if none installed).
    pub fn visit_count(site: &'static str) -> u64 {
        let slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
        slot.as_ref().and_then(|st| st.sites.get(site)).map(|s| s.visits).unwrap_or(0)
    }
}

#[cfg(feature = "faultinject")]
pub use imp::{clear, fired_count, install, should_fire, visit_count, FaultPlan};

/// No-feature stub: never fires, and the constant `false` lets the
/// optimizer delete the branch (and often the whole site) — release
/// builds are bitwise-identical to a tree without the hooks.
#[cfg(not(feature = "faultinject"))]
#[inline(always)]
pub fn should_fire(_site: &'static str) -> bool {
    false
}

#[cfg(all(test, not(feature = "faultinject")))]
mod noop_tests {
    #[test]
    fn stub_never_fires() {
        for _ in 0..4 {
            assert!(!super::should_fire(super::site::ZONE_SOLVE));
            assert!(!super::should_fire(super::site::POOL_JOB));
        }
    }
}

#[cfg(all(test, feature = "faultinject"))]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // The plan is process-global; tests that install one must not
    // interleave. Integration tests serialize the same way.
    static SEQ: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        SEQ.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn unarmed_site_never_fires() {
        let _g = locked();
        let mut plan = FaultPlan::new(1);
        plan.arm_at(site::CCD, &[0]);
        install(plan);
        assert!(!should_fire(site::ZONE_SOLVE));
        assert!(should_fire(site::CCD));
        clear();
        assert!(!should_fire(site::CCD));
    }

    #[test]
    fn index_schedule_fires_at_exact_invocations() {
        let _g = locked();
        let mut plan = FaultPlan::new(7);
        plan.arm_at(site::ZONE_SOLVE, &[1, 3]);
        install(plan);
        let fired: Vec<bool> = (0..5).map(|_| should_fire(site::ZONE_SOLVE)).collect();
        assert_eq!(fired, vec![false, true, false, true, false]);
        assert_eq!(fired_count(site::ZONE_SOLVE), 2);
        assert_eq!(visit_count(site::ZONE_SOLVE), 5);
        clear();
    }

    #[test]
    fn prob_schedule_is_deterministic_per_seed() {
        let _g = locked();
        let run = |seed: u64| -> Vec<bool> {
            let mut plan = FaultPlan::new(seed);
            plan.arm_prob(site::POOL_JOB, 0.5);
            install(plan);
            let v = (0..32).map(|_| should_fire(site::POOL_JOB)).collect();
            clear();
            v
        };
        let a = run(42);
        let b = run(42);
        let c = run(43);
        assert_eq!(a, b, "same seed must reproduce the same schedule");
        assert_ne!(a, c, "different seeds should (overwhelmingly) differ");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
    }

    #[test]
    fn reinstall_resets_counters() {
        let _g = locked();
        let mut plan = FaultPlan::new(3);
        plan.arm_at(site::CCD, &[0]);
        install(plan);
        assert!(should_fire(site::CCD));
        let mut plan = FaultPlan::new(3);
        plan.arm_at(site::CCD, &[0]);
        install(plan);
        assert_eq!(visit_count(site::CCD), 0);
        assert!(should_fire(site::CCD), "counter reset ⇒ index 0 fires again");
        clear();
    }
}
