//! Memory accounting: process peak-RSS probe (Linux `/proc/self/status`)
//! plus an explicit logical-bytes counter used to report *algorithmic*
//! memory (what Fig. 3 of the paper plots) independent of allocator
//! noise.
//!
//! The [`MemTracker`] carries both an uncategorized total (the original
//! Fig-3 counter, still used by the MPM baseline) and per-category
//! counters ([`MemCategory`]) so batched runs can attribute their peak
//! to tape records, collision candidate/contact lists, per-zone solver
//! state, or buffers parked for reuse in a
//! [`crate::util::arena::BatchArena`]. Category allocations also feed
//! the total, so `peak()` bounds the sum of the category peaks.
//!
//! A process-wide tracker ([`global`]) is what the engine, the arena,
//! and the experiment drivers charge by default; benches and tests
//! inject their own instance (`BatchArena::pooled_with` /
//! `BatchArena::tracked_with`) so parallel test threads cannot perturb
//! each other's numbers.
//!
//! Accounting is advisory, not load-bearing: frees saturate at zero
//! (never panic, never underflow), and dropping a `Simulation` without
//! calling `clear_tape` leaks *accounting* (the category `current`),
//! never memory — peaks, which are what every report uses, are
//! unaffected.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Peak resident set size of this process in bytes (VmHWM), or 0 if the
/// probe is unavailable (non-Linux).
pub fn peak_rss_bytes() -> usize {
    read_status_kb("VmHWM:").map(|kb| kb * 1024).unwrap_or(0)
}

/// Current resident set size in bytes (VmRSS).
pub fn current_rss_bytes() -> usize {
    read_status_kb("VmRSS:").map(|kb| kb * 1024).unwrap_or(0)
}

fn read_status_kb(field: &str) -> Option<usize> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb);
        }
    }
    None
}

/// What a tracked logical allocation is for — the categories the
/// batch-extended Fig-3 accounting reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemCategory {
    /// Tape records retained for the backward pass
    /// ([`crate::diff::tape::StepRecord`]).
    Tape,
    /// Collision candidate/contact lists: broadphase face pairs,
    /// impacts, impact-zone copies.
    Contacts,
    /// Per-zone solver state: stacked coordinates and zone mass
    /// matrices ([`crate::solver::zone_solver::ZoneProblem`]).
    Solver,
    /// Buffers currently parked in a
    /// [`crate::util::arena::BatchArena`] awaiting reuse.
    ArenaRetained,
}

impl MemCategory {
    /// All categories, in reporting order.
    pub const ALL: [MemCategory; 4] =
        [MemCategory::Tape, MemCategory::Contacts, MemCategory::Solver, MemCategory::ArenaRetained];

    fn index(self) -> usize {
        match self {
            MemCategory::Tape => 0,
            MemCategory::Contacts => 1,
            MemCategory::Solver => 2,
            MemCategory::ArenaRetained => 3,
        }
    }

    /// Stable snake_case label (JSON keys in `BENCH_memory.json`).
    pub fn label(self) -> &'static str {
        match self {
            MemCategory::Tape => "tape",
            MemCategory::Contacts => "contacts",
            MemCategory::Solver => "solver",
            MemCategory::ArenaRetained => "arena_retained",
        }
    }
}

const N_CATS: usize = MemCategory::ALL.len();

/// Logical allocation tracker. Simulators register the bytes they hold
/// (state vectors, tapes, grids); experiments report the peak. The
/// untyped [`MemTracker::alloc`]/[`MemTracker::free`] pair feeds only
/// the total; the `_cat` variants feed a category *and* the total.
pub struct MemTracker {
    current: AtomicUsize,
    peak: AtomicUsize,
    cat_current: [AtomicUsize; N_CATS],
    cat_peak: [AtomicUsize; N_CATS],
}

impl Default for MemTracker {
    fn default() -> MemTracker {
        MemTracker {
            current: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            cat_current: std::array::from_fn(|_| AtomicUsize::new(0)),
            cat_peak: std::array::from_fn(|_| AtomicUsize::new(0)),
        }
    }
}

fn bump(current: &AtomicUsize, peak: &AtomicUsize, bytes: usize) {
    let cur = current.fetch_add(bytes, Ordering::Relaxed) + bytes;
    peak.fetch_max(cur, Ordering::Relaxed);
}

fn sat_sub(current: &AtomicUsize, bytes: usize) {
    current.fetch_sub(bytes.min(current.load(Ordering::Relaxed)), Ordering::Relaxed);
}

impl MemTracker {
    pub fn new() -> MemTracker {
        MemTracker::default()
    }

    pub fn alloc(&self, bytes: usize) {
        bump(&self.current, &self.peak, bytes);
    }

    pub fn free(&self, bytes: usize) {
        sat_sub(&self.current, bytes);
    }

    /// Register `bytes` under `cat` (and in the total).
    pub fn alloc_cat(&self, cat: MemCategory, bytes: usize) {
        let i = cat.index();
        bump(&self.cat_current[i], &self.cat_peak[i], bytes);
        bump(&self.current, &self.peak, bytes);
    }

    /// Release `bytes` from `cat` (and from the total), saturating.
    pub fn free_cat(&self, cat: MemCategory, bytes: usize) {
        sat_sub(&self.cat_current[cat.index()], bytes);
        sat_sub(&self.current, bytes);
    }

    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn current_cat(&self, cat: MemCategory) -> usize {
        self.cat_current[cat.index()].load(Ordering::Relaxed)
    }

    pub fn peak_cat(&self, cat: MemCategory) -> usize {
        self.cat_peak[cat.index()].load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.current.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
        for i in 0..N_CATS {
            self.cat_current[i].store(0, Ordering::Relaxed);
            self.cat_peak[i].store(0, Ordering::Relaxed);
        }
    }
}

/// The process-wide tracker the engine, the arena, and the experiment
/// drivers charge by default. Benches reset it between configurations;
/// tests that assert exact numbers should inject their own
/// [`MemTracker`] instead (unit tests run in parallel threads).
pub fn global() -> &'static Arc<MemTracker> {
    static GLOBAL: OnceLock<Arc<MemTracker>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(MemTracker::new()))
}

/// Format bytes with binary units.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_probe_reads_something_on_linux() {
        // On the CI image (/proc exists) both should be nonzero.
        if std::path::Path::new("/proc/self/status").exists() {
            assert!(current_rss_bytes() > 0);
            assert!(peak_rss_bytes() >= current_rss_bytes() / 2);
        }
    }

    #[test]
    fn tracker_tracks_peak() {
        let t = MemTracker::new();
        t.alloc(100);
        t.alloc(200);
        t.free(250);
        t.alloc(10);
        assert_eq!(t.current(), 60);
        assert_eq!(t.peak(), 300);
        t.reset();
        assert_eq!(t.peak(), 0);
    }

    #[test]
    fn categories_feed_their_counter_and_the_total() {
        let t = MemTracker::new();
        t.alloc_cat(MemCategory::Tape, 100);
        t.alloc_cat(MemCategory::Solver, 50);
        t.alloc(25); // uncategorized joins the total only
        assert_eq!(t.current_cat(MemCategory::Tape), 100);
        assert_eq!(t.current_cat(MemCategory::Solver), 50);
        assert_eq!(t.current_cat(MemCategory::Contacts), 0);
        assert_eq!(t.current(), 175);
        assert_eq!(t.peak(), 175);
        t.free_cat(MemCategory::Tape, 100);
        assert_eq!(t.current_cat(MemCategory::Tape), 0);
        assert_eq!(t.peak_cat(MemCategory::Tape), 100);
        assert_eq!(t.current(), 75);
        // Over-free saturates instead of wrapping.
        t.free_cat(MemCategory::Solver, 9999);
        assert_eq!(t.current_cat(MemCategory::Solver), 0);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }
}
