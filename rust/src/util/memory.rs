//! Memory accounting: process peak-RSS probe (Linux `/proc/self/status`)
//! plus an explicit logical-bytes counter used to report *algorithmic*
//! memory (what Fig 3 of the paper plots) independent of allocator noise.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Peak resident set size of this process in bytes (VmHWM), or 0 if the
/// probe is unavailable (non-Linux).
pub fn peak_rss_bytes() -> usize {
    read_status_kb("VmHWM:").map(|kb| kb * 1024).unwrap_or(0)
}

/// Current resident set size in bytes (VmRSS).
pub fn current_rss_bytes() -> usize {
    read_status_kb("VmRSS:").map(|kb| kb * 1024).unwrap_or(0)
}

fn read_status_kb(field: &str) -> Option<usize> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            let kb: usize = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb);
        }
    }
    None
}

/// Logical allocation tracker. Simulators register the bytes they hold
/// (state vectors, tapes, grids); experiments report the peak.
#[derive(Default)]
pub struct MemTracker {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl MemTracker {
    pub fn new() -> MemTracker {
        MemTracker::default()
    }

    pub fn alloc(&self, bytes: usize) {
        let cur = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(cur, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: usize) {
        self.current.fetch_sub(bytes.min(self.current.load(Ordering::Relaxed)), Ordering::Relaxed);
    }

    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.current.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
    }
}

/// Format bytes with binary units.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_probe_reads_something_on_linux() {
        // On the CI image (/proc exists) both should be nonzero.
        if std::path::Path::new("/proc/self/status").exists() {
            assert!(current_rss_bytes() > 0);
            assert!(peak_rss_bytes() >= current_rss_bytes() / 2);
        }
    }

    #[test]
    fn tracker_tracks_peak() {
        let t = MemTracker::new();
        t.alloc(100);
        t.alloc(200);
        t.free(250);
        t.alloc(10);
        assert_eq!(t.current(), 60);
        assert_eq!(t.peak(), 300);
        t.reset();
        assert_eq!(t.peak(), 0);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }
}
