//! Tiny CLI argument parser substrate (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a usage printer.

use std::collections::BTreeMap;

/// Declarative option spec used for `--help` output.
#[derive(Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub program: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (first element = program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut it = raw.into_iter();
        let program = it.next().unwrap_or_default();
        let mut args = Args { program, ..Default::default() };
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    args.options.insert(body.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process arguments.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--sizes 100,200,300`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

/// Print a formatted usage block.
pub fn print_usage(program: &str, about: &str, specs: &[OptSpec]) {
    println!("{about}\n\nUSAGE:\n  {program} [OPTIONS]\n\nOPTIONS:");
    for s in specs {
        let def = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        println!("  --{:<18} {}{}", s.name, s.help, def);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(
            std::iter::once("prog".to_string()).chain(s.split_whitespace().map(String::from)),
        )
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("--n 10 --dt=0.02 run");
        assert_eq!(a.usize_or("n", 0), 10);
        assert_eq!(a.f64_or("dt", 0.0), 0.02);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn flags_and_defaults() {
        let a = parse("--verbose --n 5");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.str_or("backend", "native"), "native");
    }

    #[test]
    fn lists() {
        let a = parse("--sizes 100,200,300");
        assert_eq!(a.usize_list_or("sizes", &[]), vec![100, 200, 300]);
        assert_eq!(a.usize_list_or("other", &[7]), vec![7]);
    }

    #[test]
    fn trailing_flag_not_eating_positional() {
        let a = parse("--check");
        assert!(a.flag("check"));
        assert!(a.positional.is_empty());
    }
}
