//! Deterministic PRNG substrate (no `rand` crate offline): PCG32 core with
//! helpers for floats, normals (Box–Muller), ranges and shuffles.
//!
//! Every stochastic component in the engine (CMA-ES, DDPG exploration,
//! scene randomization, property tests) takes an explicit `Pcg32` so runs
//! are reproducible from a single seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from `seed` with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator selecting an independent `stream`.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next raw 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; the pair's twin
    /// is discarded for simplicity — this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg32::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
