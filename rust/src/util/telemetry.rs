//! Process-wide telemetry: named counters, gauges, and histogram
//! timers, lightweight spans over the staged step primitives, and a
//! per-rollout JSONL trace writer.
//!
//! This is the unified observability layer the scattered one-off
//! counters report through: [`crate::coordinator::metrics::CoordMetrics`]
//! increments mirror into `coord.*` counters, the
//! [`crate::util::pool`] thread-spawn global and queue depth live here
//! as `pool.*`, [`crate::util::scratch`] reuse stats as `scratch.*`,
//! and [`summary`] folds in the [`crate::util::arena`] process stats
//! and the global [`crate::util::memory::MemTracker`] as sections of
//! one snapshot.
//!
//! # Overhead contract
//!
//! * **Disabled** (the default): every instrumentation point is one
//!   relaxed atomic load ([`enabled`]). [`span`] returns an inert guard
//!   — no allocation, no registry lookup, no clock read.
//! * **Enabled**: recording is lock-free (atomic adds plus CAS loops
//!   for float accumulation); the registry mutex is taken only to
//!   intern a metric *name*, and the hot paths cache their handles.
//! * **Generation-checked**: [`enable`] bumps a generation; a span
//!   opened under one generation and closed under another is discarded,
//!   so toggling mid-flight never records torn intervals.
//! * **Observational only**: nothing here feeds back into stepping —
//!   trajectories and gradients are bitwise-identical with telemetry
//!   on, off, or mid-toggle.
//!
//! # Trace export
//!
//! A [`Trace`] is an `Arc`-shared JSONL sink: each staged step
//! primitive writes one schema-versioned event per call (span close)
//! with its duration and stage-specific payload (zones, contacts,
//! GN/CG iteration counts). Install per-rollout via
//! `Simulation::set_trace` / `SceneBatch::set_trace` (scenes share the
//! file, tagged by scene id), or process-wide via `--trace <path>` on
//! the experiment binaries ([`install_global_trace`]). Dropping the
//! last handle flushes the file. Tracing is independent of the
//! registry enable flag: a sim with a trace installed always writes
//! events, while registry counters/histograms accumulate only when
//! [`enabled`].

use crate::util::json::Json;
use crate::util::timer::{quant_bucket, quantile_from_buckets, QUANT_BUCKETS};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Version stamped on every JSONL trace event (`"v"`) and on
/// [`summary`] (`"schema_version"`). Bump on breaking schema changes.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

// ---------------------------------------------------------------------
// Enable flag
// ---------------------------------------------------------------------

/// 0 = disabled; otherwise the current enable generation.
static ENABLED_GEN: AtomicU64 = AtomicU64::new(0);
static NEXT_GEN: AtomicU64 = AtomicU64::new(1);

/// Is registry recording on? One relaxed load — the entire disabled-
/// mode cost of an instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED_GEN.load(Ordering::Relaxed) != 0
}

/// Turn registry recording on; returns the fresh generation. Spans
/// opened under an older generation are discarded at close.
pub fn enable() -> u64 {
    let g = NEXT_GEN.fetch_add(1, Ordering::Relaxed);
    ENABLED_GEN.store(g, Ordering::Relaxed);
    g
}

/// Turn registry recording off. In-flight spans are discarded at close.
pub fn disable() {
    ENABLED_GEN.store(0, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Metric cells
// ---------------------------------------------------------------------

/// Monotonic event counter. Cloning shares the cell; handles stay
/// valid for the process lifetime.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down level indicator (queue depth, jobs in flight).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free histogram cell: count/sum/min/max plus the fixed
/// log-spaced buckets shared with [`crate::util::timer::Stats`], so
/// snapshot p50/p90/p99 come from one quantile implementation. Floats
/// are accumulated with CAS loops on their bit patterns — no mutex on
/// the record path.
struct HistCell {
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
    buckets: [AtomicU64; QUANT_BUCKETS],
}

/// Handle to a registered histogram (durations in seconds, or any
/// non-negative value — occupancies, depths).
#[derive(Clone)]
pub struct Hist(Arc<HistCell>);

fn cas_f64(cell: &AtomicU64, fold: impl Fn(f64) -> Option<f64>) {
    let mut cur = cell.load(Ordering::Relaxed);
    while let Some(new) = fold(f64::from_bits(cur)) {
        match cell.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => break,
            Err(now) => cur = now,
        }
    }
}

impl Hist {
    pub fn record(&self, x: f64) {
        let c = &self.0;
        c.count.fetch_add(1, Ordering::Relaxed);
        cas_f64(&c.sum_bits, |cur| Some(cur + x));
        cas_f64(&c.min_bits, |cur| if x < cur { Some(x) } else { None });
        cas_f64(&c.max_bits, |cur| if x > cur { Some(x) } else { None });
        c.buckets[quant_bucket(x)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    fn min(&self) -> f64 {
        f64::from_bits(self.0.min_bits.load(Ordering::Relaxed))
    }

    fn max(&self) -> f64 {
        f64::from_bits(self.0.max_bits.load(Ordering::Relaxed))
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let buckets: Vec<u64> =
            self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        quantile_from_buckets(&buckets, self.count(), q, self.min(), self.max())
    }

    /// Snapshot as a JSON object (count/total/mean/min/max/p50/p90/p99).
    /// Non-finite values (empty histogram min/max) serialize as null.
    pub fn snapshot_json(&self) -> Json {
        let n = self.count();
        let mut j = Json::obj();
        j.set("count", n).set("total", self.sum());
        j.set("mean", if n == 0 { 0.0 } else { self.sum() / n as f64 });
        j.set("min", self.min()).set("max", self.max());
        j.set("p50", self.quantile(0.50));
        j.set("p90", self.quantile(0.90));
        j.set("p99", self.quantile(0.99));
        j
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

#[derive(Default)]
struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    hists: Mutex<BTreeMap<String, Hist>>,
}

fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

/// Intern (or look up) a named counter. Hot paths should cache the
/// returned handle; the lookup takes the registry mutex.
pub fn counter(name: &str) -> Counter {
    registry()
        .counters
        .lock()
        .unwrap()
        .entry(name.to_string())
        .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
        .clone()
}

/// Intern (or look up) a named gauge.
pub fn gauge(name: &str) -> Gauge {
    registry()
        .gauges
        .lock()
        .unwrap()
        .entry(name.to_string())
        .or_insert_with(|| Gauge(Arc::new(AtomicI64::new(0))))
        .clone()
}

/// Intern (or look up) a named histogram.
pub fn hist(name: &str) -> Hist {
    registry()
        .hists
        .lock()
        .unwrap()
        .entry(name.to_string())
        .or_insert_with(|| {
            Hist(Arc::new(HistCell {
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0f64.to_bits()),
                min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
                max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            }))
        })
        .clone()
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

/// RAII timer over a registry histogram: created by [`span`], records
/// its elapsed seconds into the named histogram on drop. When the
/// registry is disabled at creation the guard is inert — no clock, no
/// allocation, no registry touch — and a generation change between
/// enter and exit discards the sample.
pub struct Span {
    rec: Option<(Instant, Hist, u64)>,
}

impl Span {
    /// Whether this span will record on drop (modulo generation churn).
    pub fn is_recording(&self) -> bool {
        self.rec.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((t0, h, g)) = self.rec.take() {
            if ENABLED_GEN.load(Ordering::Relaxed) == g {
                h.record(t0.elapsed().as_secs_f64());
            }
        }
    }
}

/// Open a span over histogram `name`. Disabled mode returns an inert
/// guard without evaluating anything else.
pub fn span(name: &str) -> Span {
    let g = ENABLED_GEN.load(Ordering::Relaxed);
    if g == 0 {
        return Span { rec: None };
    }
    Span { rec: Some((Instant::now(), hist(name), g)) }
}

// ---------------------------------------------------------------------
// JSONL trace writer
// ---------------------------------------------------------------------

struct TraceFile {
    w: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl Drop for TraceFile {
    fn drop(&mut self) {
        if let Ok(mut w) = self.w.lock() {
            let _ = w.flush();
        }
    }
}

/// Per-rollout JSONL trace sink. Cheap to clone; clones share the
/// underlying file (one event per line, appended under a mutex, so a
/// batch of scenes can interleave safely). Dropping the last clone
/// flushes.
#[derive(Clone)]
pub struct Trace {
    file: Arc<TraceFile>,
    scene: usize,
}

impl Trace {
    /// Create (truncating) a trace file at `path`.
    pub fn to_file(path: impl AsRef<Path>) -> std::io::Result<Trace> {
        let f = std::fs::File::create(path)?;
        Ok(Trace {
            file: Arc::new(TraceFile { w: Mutex::new(std::io::BufWriter::new(f)) }),
            scene: 0,
        })
    }

    /// A handle to the same file whose events are tagged `scene: id` —
    /// how `SceneBatch` gives each scene its identity in a shared trace.
    pub fn for_scene(&self, id: usize) -> Trace {
        Trace { file: self.file.clone(), scene: id }
    }

    pub fn scene(&self) -> usize {
        self.scene
    }

    /// Append one event line. The schema version (`"v"`) and this
    /// handle's scene id are stamped on; callers provide `span`,
    /// `step`, `dur_s`, and stage-specific payload.
    pub fn write_event(&self, mut event: Json) {
        event.set("v", TRACE_SCHEMA_VERSION).set("scene", self.scene);
        if let Ok(mut w) = self.file.w.lock() {
            let _ = w.write_all(event.to_string().as_bytes());
            let _ = w.write_all(b"\n");
        }
    }

    pub fn flush(&self) {
        if let Ok(mut w) = self.file.w.lock() {
            let _ = w.flush();
        }
    }
}

/// Process-default trace sink + scene id dispenser, so `--trace` on a
/// binary reaches Simulations constructed deep inside drivers.
static GLOBAL_TRACE: Mutex<Option<Trace>> = Mutex::new(None);
static NEXT_SCENE: AtomicU64 = AtomicU64::new(0);

/// Install (or clear) the process-default trace sink and reset the
/// scene id dispenser. Simulations constructed afterwards pick it up
/// automatically with a fresh scene id each. Clearing drops the global
/// handle, which flushes the file once the last per-sim clone goes.
pub fn install_global_trace(t: Option<Trace>) {
    NEXT_SCENE.store(0, Ordering::Relaxed);
    *GLOBAL_TRACE.lock().unwrap() = t;
}

/// A clone of the global sink with a fresh scene id, if one is
/// installed — what `Simulation::new` starts from.
pub fn default_trace() -> Option<Trace> {
    let g = GLOBAL_TRACE.lock().unwrap();
    g.as_ref().map(|t| t.for_scene(NEXT_SCENE.fetch_add(1, Ordering::Relaxed) as usize))
}

// ---------------------------------------------------------------------
// Summary snapshot
// ---------------------------------------------------------------------

/// `items / slots` as a JSON number, or null when no padded slots were
/// ever shipped — after an all-fallback dispatch there is no occupancy
/// to report, and 0/0 must not render as 0.0 (or NaN).
fn occupancy_json(items: u64, slots: u64) -> Json {
    if slots == 0 {
        Json::Null
    } else {
        Json::Num(items as f64 / slots as f64)
    }
}

fn counter_value(name: &str) -> u64 {
    registry().counters.lock().unwrap().get(name).map(|c| c.get()).unwrap_or(0)
}

fn memory_section() -> Json {
    use crate::util::memory::{self, MemCategory};
    let t = memory::global();
    let mut j = Json::obj();
    j.set("current_bytes", t.current()).set("peak_bytes", t.peak());
    for c in MemCategory::ALL {
        j.set(&format!("peak_{}_bytes", c.label()), t.peak_cat(c));
    }
    j.set("peak_rss_bytes", memory::peak_rss_bytes());
    j
}

fn arena_section() -> Json {
    let s = crate::util::arena::process_stats();
    let mut j = Json::obj();
    j.set("takes", s.takes)
        .set("hits", s.hits)
        .set("misses", s.misses)
        .set("parks", s.parks)
        .set("evictions", s.evictions)
        .set("retained_bytes", s.retained_bytes)
        .set("retained_buffers", s.retained_buffers)
        .set("hit_rate", s.hit_rate());
    j
}

fn coordinator_section() -> Json {
    let mut j = Json::obj();
    for name in [
        "coord.zone_pjrt_calls",
        "coord.zone_native_fallback",
        "coord.zone_solve_dispatches",
        "coord.zone_solve_pjrt_calls",
        "coord.zone_solve_native_fallback",
        "coord.rigid_pjrt_calls",
    ] {
        j.set(name.trim_start_matches("coord."), counter_value(name));
    }
    j.set(
        "zone_occupancy",
        occupancy_json(counter_value("coord.zone_items"), counter_value("coord.zone_slots")),
    );
    j.set(
        "zone_solve_occupancy",
        occupancy_json(
            counter_value("coord.zone_solve_items"),
            counter_value("coord.zone_solve_slots"),
        ),
    );
    j.set(
        "rigid_occupancy",
        occupancy_json(counter_value("coord.rigid_items"), counter_value("coord.rigid_slots")),
    );
    j
}

/// One JSON snapshot of the whole registry: every counter, gauge, and
/// histogram (with p50/p90/p99), plus the absorbed sections — scratch
/// and pool convenience views, process arena stats, the global memory
/// tracker, and the coordinator counters with null-safe occupancies.
/// This is what the bench harness merges into `BENCH_trace.json`.
pub fn summary() -> Json {
    let mut j = Json::obj();
    j.set("schema_version", TRACE_SCHEMA_VERSION).set("enabled", enabled());
    let mut cj = Json::obj();
    for (k, c) in registry().counters.lock().unwrap().iter() {
        cj.set(k, c.get());
    }
    j.set("counters", cj);
    let mut gj = Json::obj();
    for (k, g) in registry().gauges.lock().unwrap().iter() {
        gj.set(k, g.get());
    }
    j.set("gauges", gj);
    let mut hj = Json::obj();
    for (k, h) in registry().hists.lock().unwrap().iter() {
        hj.set(k, h.snapshot_json());
    }
    j.set("spans", hj);
    let mut sj = Json::obj();
    sj.set("takes", counter_value("scratch.takes"))
        .set("reuses", counter_value("scratch.reuses"));
    j.set("scratch", sj);
    let mut pj = Json::obj();
    pj.set("thread_spawns", crate::util::pool::thread_spawns())
        .set("jobs_in_flight", gauge("pool.jobs_in_flight").get());
    j.set("pool", pj);
    j.set("arena", arena_section());
    j.set("memory", memory_section());
    j.set("coordinator", coordinator_section());
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Enable-state tests share the process-global flag; serialize them
    /// (and recover from a poisoned lock so one failure doesn't cascade).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn counters_gauges_hists_register_and_accumulate() {
        let c = counter("test.telemetry.alpha");
        let before = c.get();
        c.add(3);
        c.incr();
        // Interning the same name returns the same cell.
        assert_eq!(counter("test.telemetry.alpha").get(), before + 4);
        let g = gauge("test.telemetry.gauge");
        g.add(5);
        g.add(-2);
        assert_eq!(gauge("test.telemetry.gauge").get(), g.get());
        let j = summary();
        assert!(j.get("counters").unwrap().get("test.telemetry.alpha").is_some());
        for k in ["gauges", "spans", "scratch", "pool", "arena", "memory", "coordinator"] {
            assert!(j.get(k).is_some(), "summary missing section {k}");
        }
        // The snapshot round-trips through the JSON writer/parser.
        let t = Json::parse(&j.to_string()).unwrap();
        assert_eq!(t.usize_or("schema_version", 0) as u64, TRACE_SCHEMA_VERSION);
    }

    #[test]
    fn hist_moments_and_quantiles() {
        let h = hist("test.telemetry.hist");
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 0.505).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        // Oracle p50 = 5.0e-3; bucket estimation is within one ratio.
        assert!(p50 > 5.0e-3 / 2.0 && p50 < 5.0e-3 * 2.0, "p50 {p50}");
        let j = h.snapshot_json();
        assert_eq!(j.usize_or("count", 0), 100);
        assert!(j.f64_or("p99", 0.0) >= j.f64_or("p50", 1.0));
        assert!((j.f64_or("min", 0.0) - 1e-4).abs() < 1e-12);
        assert!((j.f64_or("max", 0.0) - 1e-2).abs() < 1e-12);
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _l = test_lock();
        disable();
        let h = hist("test.telemetry.noop");
        let n0 = h.count();
        {
            let s = span("test.telemetry.noop");
            assert!(!s.is_recording());
        }
        assert_eq!(h.count(), n0, "disabled span must not record");
        // Disabled spans touch nothing: a name only ever used while
        // disabled is never interned (no allocation on enter/exit).
        {
            let _s = span("test.telemetry.never.interned");
        }
        assert!(
            !registry().hists.lock().unwrap().contains_key("test.telemetry.never.interned"),
            "disabled span must not intern its name"
        );
    }

    #[test]
    fn enabled_spans_record_and_generation_discards_stale() {
        let _l = test_lock();
        enable();
        let h = hist("test.telemetry.span");
        let n0 = h.count();
        {
            let s = span("test.telemetry.span");
            assert!(s.is_recording());
        }
        assert_eq!(h.count(), n0 + 1);
        // A span straddling a disable is discarded at close.
        let s = span("test.telemetry.span");
        disable();
        drop(s);
        assert_eq!(h.count(), n0 + 1, "stale-generation span must be discarded");
        // …and one straddling a re-enable (new generation) likewise.
        enable();
        let s = span("test.telemetry.span");
        enable();
        drop(s);
        assert_eq!(h.count(), n0 + 1, "re-enabled generation must discard older spans");
        disable();
    }

    #[test]
    fn occupancy_nulls_instead_of_nan() {
        assert_eq!(occupancy_json(0, 0), Json::Null);
        assert_eq!(occupancy_json(5, 0), Json::Null);
        assert_eq!(occupancy_json(3, 4), Json::Num(0.75));
        // Through the writer: no NaN ever reaches the file.
        let mut j = Json::obj();
        j.set("occ", occupancy_json(0, 0));
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn trace_writer_roundtrips_and_passes_schema_check() {
        let path = std::env::temp_dir().join("diffsim_telemetry_roundtrip.jsonl");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        {
            let t = Trace::to_file(&path).unwrap();
            let t3 = t.for_scene(3);
            let mut ev = Json::obj();
            ev.set("span", "integrate").set("step", 0usize).set("dur_s", 1.5e-4);
            t.write_event(ev);
            let mut ev = Json::obj();
            ev.set("span", "scatter").set("step", 0usize).set("dur_s", 2.0e-4).set(
                "zones", 2usize,
            );
            t3.write_event(ev);
        } // drop flushes
        let n = crate::util::bench::check_trace_jsonl(&path).unwrap();
        assert_eq!(n, 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines[0].usize_or("scene", 99), 0);
        assert_eq!(lines[0].str_or("span", ""), "integrate");
        assert_eq!(lines[1].usize_or("scene", 99), 3);
        assert_eq!(lines[1].usize_or("v", 0) as u64, TRACE_SCHEMA_VERSION);
        assert_eq!(lines[1].usize_or("zones", 0), 2);
        // The checker rejects schema violations.
        std::fs::write(&path, "{\"span\": \"x\"}\n").unwrap();
        assert!(crate::util::bench::check_trace_jsonl(&path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(crate::util::bench::check_trace_jsonl(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn global_trace_hands_out_scene_ids() {
        let _l = test_lock();
        let path = std::env::temp_dir().join("diffsim_telemetry_global.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        install_global_trace(Some(Trace::to_file(&path_s).unwrap()));
        let a = default_trace().unwrap();
        let b = default_trace().unwrap();
        assert_eq!((a.scene(), b.scene()), (0, 1));
        install_global_trace(None);
        assert!(default_trace().is_none());
        let _ = std::fs::remove_file(&path);
    }
}
