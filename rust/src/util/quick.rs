//! Property-testing mini-framework substrate (no `proptest` offline).
//!
//! `quick(name, cases, |g| { ... })` runs a closure `cases` times with a
//! seeded [`Gen`]; assertion failures report the case's seed so it can be
//! replayed deterministically with `QUICK_SEED`.

use crate::util::rng::Pcg32;

/// Random-input generator handed to each property case.
pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Vector of uniform f64s.
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        self.rng.uniform_vec(n, lo, hi)
    }

    /// Vector of standard normals (well-conditioned random matrices).
    pub fn vec_normal(&mut self, n: usize) -> Vec<f64> {
        self.rng.normal_vec(n)
    }

    /// Unit 3-vector.
    pub fn unit3(&mut self) -> [f64; 3] {
        loop {
            let v = [self.rng.normal(), self.rng.normal(), self.rng.normal()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            if n > 1e-6 {
                return [v[0] / n, v[1] / n, v[2] / n];
            }
        }
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of property `prop`. Panics (failing the test)
/// with the case index and seed on the first violated assertion inside.
pub fn quick<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base_seed: u64 = std::env::var("QUICK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0000);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg32::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay with QUICK_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert |a-b| <= atol + rtol*|b| elementwise.
pub fn assert_close(a: &[f64], b: &[f64], atol: f64, rtol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for i in 0..a.len() {
        let tol = atol + rtol * b[i].abs();
        assert!(
            (a[i] - b[i]).abs() <= tol,
            "{what}: element {i} differs: {} vs {} (tol {tol})",
            a[i],
            b[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_runs_all_cases() {
        let mut count = 0;
        quick("counter", 50, |_g| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn quick_reports_failures_with_seed() {
        quick("fails", 10, |g| {
            let x = g.f64(0.0, 1.0);
            assert!(x < 2.0); // passes
            if g.case == 7 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn unit3_is_unit() {
        quick("unit3", 100, |g| {
            let v = g.unit3();
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-9, 2.0], 1e-6, 0.0, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_close(&[1.0], &[1.1], 1e-6, 0.0, "bad");
        });
        assert!(r.is_err());
    }
}
