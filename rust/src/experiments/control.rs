//! E8 — Fig. 8: learning control. An MLP (50, 200 hidden, ReLU — the
//! paper's controller) must push an object to a randomized target within
//! the episode. Ours: backprop *through the simulator* into the network,
//! one update per episode. Baseline: DDPG with a per-step reward.
//!
//! Task (a) "sticks": two rigid manipulators push a block on the ground.
//! Task (b) "cloth": corner forces steer a cloth carrying a ball.

use super::{dump_json, print_table};
use crate::batch::pipeline::BatchPipeline;
use crate::batch::SceneBatch;
use crate::bodies::{Cloth, RigidBody, System};
use crate::diff::tape::Grads;
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{box_mesh, cloth_grid, icosphere};
use crate::ml::adam::Adam;
use crate::ml::ddpg::{Ddpg, DdpgConfig, Transition};
use crate::ml::mlp::{Mlp, MlpTrace};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::rng::Pcg32;
use anyhow::Result;

pub const EP_STEPS: usize = 40;
const FMAX: f64 = 6.0;

/// The sticks system: manipulators are rigids 1-2, object rigid 3.
fn sticks_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for dz in [-0.35, 0.35] {
        sys.add_rigid(
            RigidBody::from_mesh(box_mesh(Vec3::new(0.08, 0.25, 0.08)), 2.0)
                .with_position(Vec3::new(-0.5, 0.251, dz)),
        );
    }
    sys.add_rigid(
        RigidBody::from_mesh(box_mesh(Vec3::splat(0.15)), 1.0)
            .with_position(Vec3::new(0.0, 0.151, 0.0)),
    );
    sys
}

fn sticks_scene() -> Simulation {
    Simulation::new(
        sticks_system(),
        SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() },
    )
}

/// Observation: object→target offset (x,z), object velocity (x,z),
/// remaining time — the paper's input layout.
fn obs(sim: &Simulation, object: usize, target: Vec3, step: usize) -> Vec<f64> {
    let p = sim.sys.rigids[object].translation();
    let v = sim.sys.rigids[object].linear_velocity();
    vec![
        target.x - p.x,
        target.z - p.z,
        v.x,
        v.z,
        (EP_STEPS - step) as f64 / EP_STEPS as f64,
    ]
}

/// Apply the policy to one sticks step (forces on manipulators 1-2);
/// returns the (trace, raw output) pair needed for the chain rule.
fn sticks_policy_step(
    net: &Mlp,
    sim: &mut Simulation,
    target: Vec3,
    s: usize,
) -> (MlpTrace, Vec<f64>) {
    let o = obs(sim, 3, target, s);
    let (raw, tr) = net.forward(&o);
    let a: Vec<f64> = raw.iter().map(|r| r.tanh() * FMAX).collect();
    sim.sys.rigids[1].ext_force = Vec3::new(a[0], 0.0, a[1]);
    sim.sys.rigids[2].ext_force = Vec3::new(a[2], 0.0, a[3]);
    (tr, raw)
}

/// Chain ∂L/∂force → tanh scaling → network params for one episode's
/// traces; `scale` averages minibatches (1.0 for a single episode).
fn sticks_chain_grads(
    net: &Mlp,
    traces: &[(MlpTrace, Vec<f64>)],
    g: &Grads,
    scale: f64,
    grad: &mut [f64],
) {
    for (s, (tr, raw)) in traces.iter().enumerate() {
        let df = [
            g.rigid_force[s][1].x,
            g.rigid_force[s][1].z,
            g.rigid_force[s][2].x,
            g.rigid_force[s][2].z,
        ];
        let draw: Vec<f64> = df
            .iter()
            .zip(raw)
            .map(|(d, r)| d * FMAX * (1.0 - r.tanh() * r.tanh()) * scale)
            .collect();
        net.backward(tr, &draw, grad);
    }
}

/// One taped episode driven by the policy; returns (loss, force grads
/// chained into the network via saved traces).
fn sticks_episode_ours(
    net: &Mlp,
    target: Vec3,
    grad: &mut [f64],
) -> f64 {
    let mut sim = sticks_scene();
    let mut traces = Vec::new();
    for s in 0..EP_STEPS {
        traces.push(sticks_policy_step(net, &mut sim, target, s));
        sim.step();
    }
    let p = sim.sys.rigids[3].translation();
    let loss = (p.x - target.x) * (p.x - target.x) + (p.z - target.z) * (p.z - target.z);
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[3][3] = 2.0 * (p.x - target.x);
    seed.rigid_q[3][5] = 2.0 * (p.z - target.z);
    let g = backward(&sim, &seed);
    sticks_chain_grads(net, &traces, &g, 1.0, grad);
    loss
}

/// Train our controller; returns per-episode losses.
pub fn train_ours_sticks(episodes: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let mut net = Mlp::new(&[5, 50, 200, 4], &mut rng);
    let mut opt = Adam::new(net.n_params(), 3e-3);
    let mut losses = Vec::new();
    for _ in 0..episodes {
        let target = Vec3::new(rng.range(0.2, 0.8), 0.0, rng.range(-0.4, 0.4));
        let mut grad = vec![0.0; net.n_params()];
        let loss = sticks_episode_ours(&net, target, &mut grad);
        opt.step(&mut net.params, &grad);
        losses.push(loss);
    }
    losses
}

/// One minibatched BPTT update on a pre-built [`SceneBatch`]: draw
/// `batch` random targets, roll the episodes out in lockstep with
/// taping, chain the force gradients into the network averaged over the
/// minibatch, and take one Adam step. Returns the minibatch mean loss.
/// Factored out so the pipelined and synchronous drivers run the exact
/// same math (their curves are bitwise-identical, asserted in
/// `rust/tests/integration_pipeline.rs`).
fn sticks_minibatch_update(
    sb: &mut SceneBatch,
    rng: &mut Pcg32,
    net: &mut Mlp,
    opt: &mut Adam,
    batch: usize,
) -> f64 {
    let targets: Vec<Vec3> = (0..batch)
        .map(|_| Vec3::new(rng.range(0.2, 0.8), 0.0, rng.range(-0.4, 0.4)))
        .collect();
    let res = {
        let net_ref: &Mlp = net;
        let targets_ref = &targets;
        sb.rollout_grad_lockstep(
            EP_STEPS,
            |_| Vec::with_capacity(EP_STEPS),
            |traces: &mut Vec<(MlpTrace, Vec<f64>)>, i, s, sim| {
                traces.push(sticks_policy_step(net_ref, sim, targets_ref[i], s));
            },
            |i, sim, _| {
                let p = sim.sys.rigids[3].translation();
                let t = targets_ref[i];
                let loss = (p.x - t.x) * (p.x - t.x) + (p.z - t.z) * (p.z - t.z);
                let mut seed_g = LossGrad::zeros(sim);
                seed_g.rigid_q[3][3] = 2.0 * (p.x - t.x);
                seed_g.rigid_q[3][5] = 2.0 * (p.z - t.z);
                (loss, seed_g)
            },
        )
    };
    // Chain the force gradients into the network, averaged over the
    // minibatch.
    let mut grad = vec![0.0; net.n_params()];
    let inv_b = 1.0 / batch as f64;
    for (i, traces) in res.states.iter().enumerate() {
        sticks_chain_grads(net, traces, &res.grads[i], inv_b, &mut grad);
    }
    opt.step(&mut net.params, &grad);
    res.mean_loss()
}

/// Minibatched "ours" training, *pipelined*: every update rolls out
/// `batch` episodes with independent random targets through a
/// [`SceneBatch`] in lockstep (forward zone solves pooled across the
/// minibatch per fail-safe pass; batched backward included) and
/// averages the policy gradients into one Adam step — while update
/// *k+1*'s scene construction runs on pool workers as a detached job
/// ([`BatchPipeline::generations`]). The drain barrier sits at the
/// gradient-consuming boundary (each update's rollout+Adam step runs
/// synchronously on the submitter), so the curve is bitwise-identical
/// to the synchronous fallback [`train_ours_sticks_lockstep`]. Returns
/// the mean episode loss per update.
pub fn train_ours_sticks_batch(updates: usize, batch: usize, seed: u64) -> Vec<f64> {
    train_ours_sticks_minibatched(updates, batch, seed, true)
}

/// Synchronous fallback: the same minibatched lockstep trainer without
/// generation double-buffering (scene construction blocks between
/// updates). Kept as the blocking reference path; bitwise-identical
/// curves to [`train_ours_sticks_batch`].
pub fn train_ours_sticks_lockstep(updates: usize, batch: usize, seed: u64) -> Vec<f64> {
    train_ours_sticks_minibatched(updates, batch, seed, false)
}

fn train_ours_sticks_minibatched(
    updates: usize,
    batch: usize,
    seed: u64,
    pipelined: bool,
) -> Vec<f64> {
    let batch = batch.max(1);
    let mut rng = Pcg32::new(seed);
    let mut net = Mlp::new(&[5, 50, 200, 4], &mut rng);
    let mut opt = Adam::new(net.n_params(), 3e-3);
    let workers = Pool::machine_workers();
    let cfg = SimConfig { record_tape: true, dt: 1.0 / 100.0, workers, ..Default::default() };
    if pipelined {
        // Scene construction is policy- and target-independent, so
        // update k+1's SceneBatch builds while update k rolls out and
        // backpropagates. Targets are still drawn inside each update,
        // in update order — the rng sequence is untouched.
        let pipe = BatchPipeline::new(workers);
        let base = sticks_system();
        let build_cfg = cfg.clone();
        pipe.generations(
            updates,
            move |_g| SceneBatch::from_scene(&base, &build_cfg, batch, |_, _| {}),
            |_g, mut sb| sticks_minibatch_update(&mut sb, &mut rng, &mut net, &mut opt, batch),
        )
    } else {
        (0..updates)
            .map(|_| {
                let mut sb = SceneBatch::from_scene(&sticks_system(), &cfg, batch, |_, _| {});
                sticks_minibatch_update(&mut sb, &mut rng, &mut net, &mut opt, batch)
            })
            .collect()
    }
}

/// DDPG on the same environment/steps budget; per-episode final loss.
pub fn train_ddpg_sticks(episodes: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let cfg = DdpgConfig { action_scale: FMAX, ..Default::default() };
    let mut agent = Ddpg::new(5, 4, cfg, &mut rng);
    let mut losses = Vec::new();
    for _ in 0..episodes {
        let target = Vec3::new(rng.range(0.2, 0.8), 0.0, rng.range(-0.4, 0.4));
        let mut sim = sticks_scene();
        sim.cfg.record_tape = false;
        agent.reset_noise();
        let mut prev_obs = obs(&sim, 3, target, 0);
        for s in 0..EP_STEPS {
            let a = agent.act_explore(&prev_obs, &mut rng);
            sim.sys.rigids[1].ext_force = Vec3::new(a[0], 0.0, a[1]);
            sim.sys.rigids[2].ext_force = Vec3::new(a[2], 0.0, a[3]);
            sim.step();
            let o2 = obs(&sim, 3, target, s + 1);
            let p = sim.sys.rigids[3].translation();
            let reward = -((p.x - target.x).powi(2) + (p.z - target.z).powi(2));
            agent.replay.push(Transition {
                state: prev_obs.clone(),
                action: a,
                reward,
                next_state: o2.clone(),
                done: s + 1 == EP_STEPS,
            });
            // DDPG "receives a reward signal and updates the network
            // weights in each time step" (paper).
            agent.update(&mut rng);
            prev_obs = o2;
        }
        let p = sim.sys.rigids[3].translation();
        losses.push((p.x - target.x).powi(2) + (p.z - target.z).powi(2));
    }
    losses
}

/// Task (b): cloth manipulation. The cloth's four corners are driven by
/// network forces; a ball rests in the cloth; bring the ball to the
/// target. Returns per-episode losses for our method.
pub fn train_ours_cloth(episodes: usize, seed: u64) -> Vec<f64> {
    train_ours_cloth_opt(episodes, seed, None)
}

pub fn train_ours_cloth_opt(episodes: usize, seed: u64, fixed: Option<Vec3>) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let mut net = Mlp::new(&[5, 50, 200, 4], &mut rng);
    let mut opt = Adam::new(net.n_params(), 3e-3);
    let corners = [0usize, 6, 42, 48];
    let mut losses = Vec::new();
    for _ in 0..episodes {
        let target =
            fixed.unwrap_or_else(|| Vec3::new(rng.range(-0.3, 0.3), 0.0, rng.range(-0.3, 0.3)));
        let mut sys = System::new();
        let cloth = Cloth::from_grid(
            cloth_grid(6, 6, 1.2, 1.2).translated(Vec3::new(0.0, 0.5, 0.0)),
            0.4,
            2500.0,
            2.0,
            3.0,
        );
        sys.add_cloth(cloth);
        sys.add_rigid(
            RigidBody::from_mesh(icosphere(0.12, 1), 2.0)
                .with_position(Vec3::new(0.0, 0.64, 0.0)),
        );
        let mut sim = Simulation::new(
            sys,
            SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() },
        );
        let mut traces = Vec::new();
        for s in 0..EP_STEPS {
            let o = obs(&sim, 0, target, s);
            let (raw, tr) = net.forward(&o);
            // Gentler authority for the light cloth (FMAX would fling it).
            let fc = 1.5;
            let a: Vec<f64> = raw.iter().map(|r| r.tanh() * fc).collect();
            // Corner forces: (x, z) on the two pairs of diagonal corners,
            // plus lift to keep the cloth taut.
            for (k, &c) in corners.iter().enumerate() {
                let (fx, fz) = if k % 2 == 0 { (a[0], a[1]) } else { (a[2], a[3]) };
                sim.sys.cloths[0].ext_force[c] = Vec3::new(fx, 1.0, fz);
            }
            traces.push((tr, raw));
            sim.step();
        }
        let p = sim.sys.rigids[0].translation();
        let loss = (p.x - target.x).powi(2) + (p.z - target.z).powi(2);
        let mut seed_g = LossGrad::zeros(&sim);
        seed_g.rigid_q[0][3] = 2.0 * (p.x - target.x);
        seed_g.rigid_q[0][5] = 2.0 * (p.z - target.z);
        let g = backward(&sim, &seed_g);
        let mut grad = vec![0.0; net.n_params()];
        for (s, (tr, raw)) in traces.iter().enumerate() {
            let mut df = [0.0; 4];
            for (k, &c) in corners.iter().enumerate() {
                let gf = g.cloth_force[s][0][c];
                if k % 2 == 0 {
                    df[0] += gf.x;
                    df[1] += gf.z;
                } else {
                    df[2] += gf.x;
                    df[3] += gf.z;
                }
            }
            let draw: Vec<f64> = df
                .iter()
                .zip(raw)
                .map(|(d, r)| d * 1.5 * (1.0 - r.tanh() * r.tanh()))
                .collect();
            net.backward(tr, &draw, &mut grad);
        }
        opt.step(&mut net.params, &grad);
        losses.push(loss);
    }
    losses
}

fn tail_mean(xs: &[f64], n: usize) -> f64 {
    let k = xs.len().saturating_sub(n);
    let tail = &xs[k..];
    tail.iter().sum::<f64>() / tail.len() as f64
}

pub fn run(args: &Args) -> Result<()> {
    let batch = args.usize_or("batch", 4).max(1);
    let updates = (args.usize_or("episodes", 40) + batch - 1) / batch;
    // Keep the episode budgets comparable: every trainer gets exactly
    // updates·batch episodes.
    let episodes = updates * batch;
    // Fresh Fig-3-style accounting for this run's batched rollouts.
    crate::util::memory::global().reset();
    println!(
        "training sticks controllers: ours = {updates} minibatched updates x{batch} \
         parallel episodes, DDPG = {episodes} episodes..."
    );
    let ours = train_ours_sticks_batch(updates, batch, 11);
    let ddpg = train_ddpg_sticks(episodes, 11);
    println!("training cloth controller (ours) for {episodes} episodes...");
    let ours_cloth = train_ours_cloth(episodes, 13);
    // `ours` is a per-update curve of `batch`-episode means; tail over
    // ceil(5/batch) updates ≈ the same ~5-episode window DDPG gets.
    let ours_tail = (5 + batch - 1) / batch;
    let rows = vec![
        vec![
            "sticks".into(),
            format!("{:.4}", tail_mean(&ours, ours_tail)),
            format!("{:.4}", tail_mean(&ddpg, 5)),
        ],
        vec![
            "cloth".into(),
            format!("{:.4}", tail_mean(&ours_cloth, 5)),
            "—".into(),
        ],
    ];
    print_table(
        &format!(
            "Fig 8: final-distance² after {episodes} episodes (tail mean; \
             ours entries are {batch}-episode minibatch means)"
        ),
        &["task", "ours (batched diff-sim BPTT)", "DDPG"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "fig8")
        .set("episodes", episodes)
        .set("batch", batch)
        .set("ours_sticks", Json::Arr(ours.iter().map(|&l| Json::Num(l)).collect()))
        .set("ddpg_sticks", Json::Arr(ddpg.iter().map(|&l| Json::Num(l)).collect()))
        .set("ours_cloth", Json::Arr(ours_cloth.iter().map(|&l| Json::Num(l)).collect()))
        .set("memory", super::batch_memory_report("fig8"));
    dump_json("fig8_control", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_improves_and_beats_ddpg_on_small_budget() {
        let ours = train_ours_sticks(16, 3);
        let ddpg = train_ddpg_sticks(16, 3);
        let ours_start = tail_mean(&ours[..4], 4);
        let ours_end = tail_mean(&ours, 4);
        assert!(ours_end < ours_start, "no learning: {ours_start} -> {ours_end}");
        assert!(
            ours_end < tail_mean(&ddpg, 4) * 1.2,
            "ours {ours_end} vs ddpg {}",
            tail_mean(&ddpg, 4)
        );
    }

    #[test]
    fn batched_trainer_runs_and_stays_finite() {
        let curve = train_ours_sticks_batch(3, 2, 9);
        assert_eq!(curve.len(), 3);
        assert!(curve.iter().all(|l| l.is_finite()), "{curve:?}");
    }

    #[test]
    fn cloth_task_learns() {
        // Fixed, far target → deterministic objective with headroom for
        // the descent to show (episode losses are noisy early on while
        // the policy explores force scales).
        let l = train_ours_cloth_opt(18, 5, Some(Vec3::new(0.35, 0.0, 0.25)));
        let head = tail_mean(&l[..4], 4);
        let best_tail = l.iter().rev().take(6).cloned().fold(f64::MAX, f64::min);
        assert!(
            best_tail < head * 0.6,
            "cloth controller did not improve: head {head}, best tail {best_tail}, {l:?}"
        );
    }
}
