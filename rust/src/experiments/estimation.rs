//! E9 — Fig. 9: parameter estimation. Two cubes collide head-on with
//! velocities ±v; estimate the left cube's mass so the post-collision
//! total momentum matches a target (paper: p = (3,0,0), m₁ → 5.4 after
//! 90 gradient steps).

use super::{dump_json, print_table};
use crate::bodies::{RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::unit_box;
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;

/// Simulate the collision with left-cube mass `m1`; returns
/// (total momentum x, sim-with-tape).
fn collide(m1: f64, record: bool) -> (f64, Simulation) {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), m1)
            .with_position(Vec3::new(-1.2, 0.02, 0.05))
            .with_velocity(Vec3::new(1.0, 0.0, 0.0)),
    );
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.0, 0.0))
            .with_velocity(Vec3::new(-1.0, 0.0, 0.0)),
    );
    let mut sim = Simulation::new(
        sys,
        SimConfig {
            record_tape: record,
            gravity: Vec3::default(),
            dt: 1.0 / 100.0,
            ..Default::default()
        },
    );
    sim.run(60);
    (sim.sys.linear_momentum().x, sim)
}

/// Gradient-descent mass estimation; returns (mass history, loss history).
pub fn estimate(p_target: f64, iters: usize, lr: f64) -> (Vec<f64>, Vec<f64>) {
    let mut m1: f64 = 1.0;
    let mut ms = vec![m1];
    let mut losses = Vec::new();
    for _ in 0..iters {
        let (p, sim) = collide(m1, true);
        let loss = (p - p_target) * (p - p_target);
        losses.push(loss);
        // L = (p − p*)², p = m₁·v₁' + m₂·v₂' ⇒ seeds on final velocities
        // (scaled by each body's mass) + the explicit ∂p/∂m₁ = v₁' term.
        let d = 2.0 * (p - p_target);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_v[0][3] = d * sim.sys.rigids[0].mass;
        seed.rigid_v[1][3] = d * sim.sys.rigids[1].mass;
        let g = backward(&sim, &seed);
        let grad = g.rigid_mass[0] + d * sim.sys.rigids[0].qdot[3];
        m1 = (m1 - lr * grad).max(0.05);
        ms.push(m1);
    }
    (ms, losses)
}

pub fn run(args: &Args) -> Result<()> {
    let p_target = args.f64_or("p-target", 3.0);
    let iters = args.usize_or("iters", 90);
    let lr = args.f64_or("lr", 0.15);
    let (ms, losses) = estimate(p_target, iters, lr);
    let m_final = *ms.last().unwrap();
    let (p_final, _) = collide(m_final, false);
    let mut rows = Vec::new();
    for i in [0, 9, 29, 59, iters - 1] {
        if i < losses.len() {
            rows.push(vec![
                format!("{}", i + 1),
                format!("{:.4}", ms[i + 1]),
                format!("{:.5}", losses[i]),
            ]);
        }
    }
    print_table("Fig 9: mass estimation (target p_x)", &["iter", "m1", "loss"], &rows);
    println!("estimated m1 = {m_final:.3}; achieved momentum {p_final:.3} (target {p_target})");
    let mut out = Json::obj();
    out.set("experiment", "fig9")
        .set("p_target", p_target)
        .set("m1_final", m_final)
        .set("p_final", p_final)
        .set("m1_curve", Json::Arr(ms.iter().map(|&m| Json::Num(m)).collect()))
        .set("loss_curve", Json::Arr(losses.iter().map(|&l| Json::Num(l)).collect()));
    dump_json("fig9_estimation", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_momentum_matching_mass() {
        // Head-on inelastic collision conserves momentum:
        // p = m₁·1 + 1·(−1) ⇒ m₁* = p* + 1.
        let p_target = 1.5;
        let (ms, losses) = estimate(p_target, 40, 0.3);
        let m_final = *ms.last().unwrap();
        assert!(
            (m_final - (p_target + 1.0)).abs() < 0.15,
            "m1 = {m_final}, want ≈ {}",
            p_target + 1.0
        );
        assert!(losses.last().unwrap() < &0.01, "loss {:?}", losses.last());
    }
}
