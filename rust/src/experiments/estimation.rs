//! E9 — Fig. 9: parameter estimation. Two cubes collide head-on with
//! velocities ±v; estimate the left cube's mass so the post-collision
//! total momentum matches a target (paper: p = (3,0,0), m₁ → 5.4 after
//! 90 gradient steps).
//!
//! The batched variant ([`estimate_multi`]) advances K gradient chains
//! with different initial masses in lockstep: each iteration is one
//! parallel taped rollout plus one batched backward over all K scenes
//! through [`crate::batch::SceneBatch`].

use super::{dump_json, print_table};
use crate::batch::SceneBatch;
use crate::bodies::{RigidBody, System};
use crate::engine::backward::LossGrad;
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::unit_box;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::pool::Pool;
use anyhow::Result;

const COLLIDE_STEPS: usize = 60;

fn left_cube(m1: f64) -> RigidBody {
    RigidBody::from_mesh(unit_box(), m1)
        .with_position(Vec3::new(-1.2, 0.02, 0.05))
        .with_velocity(Vec3::new(1.0, 0.0, 0.0))
}

fn collide_system(m1: f64) -> System {
    let mut sys = System::new();
    sys.add_rigid(left_cube(m1));
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.0, 0.0))
            .with_velocity(Vec3::new(-1.0, 0.0, 0.0)),
    );
    sys
}

fn collide_cfg(record: bool) -> SimConfig {
    SimConfig {
        record_tape: record,
        gravity: Vec3::default(),
        dt: 1.0 / 100.0,
        ..Default::default()
    }
}

/// Simulate the collision with left-cube mass `m1`; returns
/// (total momentum x, sim-with-tape).
fn collide(m1: f64, record: bool) -> (f64, Simulation) {
    let mut sim = Simulation::new(collide_system(m1), collide_cfg(record));
    sim.run(COLLIDE_STEPS);
    (sim.sys.linear_momentum().x, sim)
}

/// Batched multi-start estimation: `inits.len()` gradient chains advance
/// together, one `SceneBatch` rollout + batched backward per iteration.
/// Returns (per-chain mass history, per-chain loss history).
pub fn estimate_multi(
    inits: &[f64],
    p_target: f64,
    iters: usize,
    lr: f64,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let mut mass: Vec<f64> = inits.to_vec();
    let mut ms: Vec<Vec<f64>> = inits.iter().map(|&m| vec![m]).collect();
    let mut losses: Vec<Vec<f64>> = vec![Vec::new(); inits.len()];
    let mut cfg = collide_cfg(true);
    cfg.workers = Pool::machine_workers();
    for _ in 0..iters {
        let mass_now = mass.clone();
        let mut batch =
            SceneBatch::from_scene(&collide_system(1.0), &cfg, mass_now.len(), |i, sys| {
                sys.rigids[0] = left_cube(mass_now[i]);
            });
        let res = batch.rollout_grad(
            COLLIDE_STEPS,
            |_| (),
            |_, _, _, _| {},
            |_, sim, _| {
                let p = sim.sys.linear_momentum().x;
                let loss = (p - p_target) * (p - p_target);
                // L = (p − p*)², p = m₁·v₁' + m₂·v₂' ⇒ seeds on final
                // velocities (scaled by each body's mass) + the explicit
                // ∂p/∂m₁ = v₁' term added after the backward.
                let d = 2.0 * (p - p_target);
                let mut seed = LossGrad::zeros(sim);
                seed.rigid_v[0][3] = d * sim.sys.rigids[0].mass;
                seed.rigid_v[1][3] = d * sim.sys.rigids[1].mass;
                (loss, seed)
            },
        );
        for i in 0..mass.len() {
            let sim = batch.sim(i);
            let p = sim.sys.linear_momentum().x;
            let d = 2.0 * (p - p_target);
            let grad = res.grads[i].rigid_mass[0] + d * sim.sys.rigids[0].qdot[3];
            losses[i].push(res.losses[i]);
            mass[i] = (mass[i] - lr * grad).max(0.05);
            ms[i].push(mass[i]);
        }
    }
    (ms, losses)
}

/// Gradient-descent mass estimation (single chain from m₁ = 1); returns
/// (mass history, loss history).
pub fn estimate(p_target: f64, iters: usize, lr: f64) -> (Vec<f64>, Vec<f64>) {
    let (ms, losses) = estimate_multi(&[1.0], p_target, iters, lr);
    (ms.into_iter().next().unwrap(), losses.into_iter().next().unwrap())
}

pub fn run(args: &Args) -> Result<()> {
    let p_target = args.f64_or("p-target", 3.0);
    let iters = args.usize_or("iters", 90);
    let lr = args.f64_or("lr", 0.15);
    let mut inits: Vec<f64> = args
        .str_or("inits", "1.0,0.3,2.5")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    if inits.is_empty() {
        crate::warnlog!("--inits had no parseable masses; using 1.0");
        inits.push(1.0);
    }
    let (ms_all, losses_all) = estimate_multi(&inits, p_target, iters, lr);
    let (ms, losses) = (&ms_all[0], &losses_all[0]);
    let m_final = *ms.last().unwrap();
    let (p_final, _) = collide(m_final, false);
    let mut rows = Vec::new();
    for i in [0, 9, 29, 59, iters - 1] {
        if i < losses.len() {
            rows.push(vec![
                format!("{}", i + 1),
                format!("{:.4}", ms[i + 1]),
                format!("{:.5}", losses[i]),
            ]);
        }
    }
    print_table("Fig 9: mass estimation (target p_x)", &["iter", "m1", "loss"], &rows);
    println!("estimated m1 = {m_final:.3}; achieved momentum {p_final:.3} (target {p_target})");
    for (k, (init, chain)) in inits.iter().zip(&ms_all).enumerate() {
        println!("  chain {k}: m1 {init:.3} -> {:.3}", chain.last().unwrap());
    }
    let mut out = Json::obj();
    out.set("experiment", "fig9")
        .set("p_target", p_target)
        .set("m1_final", m_final)
        .set("p_final", p_final)
        .set("inits", Json::Arr(inits.iter().map(|&m| Json::Num(m)).collect()))
        .set("m1_curve", Json::Arr(ms.iter().map(|&m| Json::Num(m)).collect()))
        .set(
            "m1_finals",
            Json::Arr(ms_all.iter().map(|c| Json::Num(*c.last().unwrap())).collect()),
        )
        .set("loss_curve", Json::Arr(losses.iter().map(|&l| Json::Num(l)).collect()));
    dump_json("fig9_estimation", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_momentum_matching_mass() {
        // Head-on inelastic collision conserves momentum:
        // p = m₁·1 + 1·(−1) ⇒ m₁* = p* + 1.
        let p_target = 1.5;
        let (ms, losses) = estimate(p_target, 40, 0.3);
        let m_final = *ms.last().unwrap();
        assert!(
            (m_final - (p_target + 1.0)).abs() < 0.15,
            "m1 = {m_final}, want ≈ {}",
            p_target + 1.0
        );
        assert!(losses.last().unwrap() < &0.01, "loss {:?}", losses.last());
    }

    #[test]
    fn multi_start_chains_converge_together() {
        // Chains from different initial masses must reach the same
        // momentum-matching mass — the batched vectorized-gradient path.
        let p_target = 1.2;
        let (ms, _) = estimate_multi(&[0.4, 1.0, 3.0], p_target, 50, 0.3);
        for (k, chain) in ms.iter().enumerate() {
            let m_final = *chain.last().unwrap();
            assert!(
                (m_final - (p_target + 1.0)).abs() < 0.2,
                "chain {k}: m1 = {m_final}, want ≈ {}",
                p_target + 1.0
            );
        }
    }
}
