//! E6 — Fig. 6: ball-on-trampoline, ours vs the "MuJoCo-style"
//! capsule-grid cloth. The baseline's collision geometry is node geoms
//! only, so a ball smaller than the grid hole passes straight through;
//! our mesh-level CCD catches it regardless of resolution.

use super::{dump_json, print_table};
use crate::baselines::capsule_cloth::{Ball, CapsuleCloth, CapsuleClothConfig};
use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{cloth_grid, icosphere};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;

/// Our simulator: returns the ball's minimum center height (ball starts
/// at 1.6, trampoline at 1.0; < 0.5 ⇒ fell through).
pub fn ours_min_y(grid: usize, ball_r: f64, steps: usize) -> f64 {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(grid, grid, 2.0, 2.0).translated(Vec3::new(0.0, 1.0, 0.0)),
        0.3,
        5000.0,
        2.0,
        0.5,
    );
    for i in 0..=grid {
        for k in 0..=grid {
            if i == 0 || i == grid || k == 0 || k == grid {
                cloth.pin(i * (grid + 1) + k);
            }
        }
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(ball_r, 2), 2.0)
            .with_position(Vec3::new(0.12, 1.6, 0.12))
            .with_velocity(Vec3::new(0.0, -2.0, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 250.0, ..Default::default() });
    let mut min_y = f64::MAX;
    for _ in 0..steps {
        sim.step();
        min_y = min_y.min(sim.sys.rigids[0].translation().y);
    }
    min_y
}

/// Baseline: same scenario in the capsule-grid model.
pub fn baseline_min_y(grid: usize, ball_r: f64, steps: usize) -> f64 {
    let mut cloth = CapsuleCloth::new(
        CapsuleClothConfig { nx: grid, nz: grid, ..Default::default() },
        Vec3::new(0.0, 1.0, 0.0),
    );
    cloth.pin_boundary();
    let mut ball = Ball {
        pos: Vec3::new(0.12, 1.6, 0.12),
        vel: Vec3::new(0.0, -2.0, 0.0),
        radius: ball_r,
        mass: 0.5,
    };
    let mut min_y = f64::MAX;
    for _ in 0..steps {
        cloth.step(&mut ball);
        min_y = min_y.min(ball.pos.y);
    }
    min_y
}

pub fn run(args: &Args) -> Result<()> {
    let ball_r = args.f64_or("radius", 0.08);
    let grids = args.usize_list_or("grids", &[6, 8, 12]);
    let steps = args.usize_or("steps", 1200);
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for &g in &grids {
        let ours = ours_min_y(g, ball_r, steps / 2);
        let base = baseline_min_y(g, ball_r, steps);
        let ours_ok = ours > 0.6;
        let base_ok = base > 0.6;
        let mut j = Json::obj();
        j.set("grid", g)
            .set("ours_min_y", ours)
            .set("mujoco_style_min_y", base)
            .set("ours_caught", ours_ok)
            .set("mujoco_style_caught", base_ok);
        jrows.push(j);
        rows.push(vec![
            format!("{g}x{g}"),
            format!("{ours:.2} ({})", if ours_ok { "caught" } else { "THROUGH" }),
            format!("{base:.2} ({})", if base_ok { "caught" } else { "THROUGH" }),
        ]);
    }
    print_table(
        &format!("Fig 6: trampoline, ball r={ball_r} — min ball height (sheet at 1.0)"),
        &["grid", "ours", "capsule-grid (MuJoCo-style)"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "fig6").set("ball_radius", ball_r).set("rows", Json::Arr(jrows));
    dump_json("fig6_trampoline", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_catches_where_baseline_tunnels() {
        // Sparse grid + small ball: the paper's Fig. 6 contrast.
        let ours = ours_min_y(8, 0.08, 400);
        let base = baseline_min_y(8, 0.08, 1200);
        assert!(ours > 0.6, "our sim let the ball through: {ours}");
        assert!(base < 0.5, "baseline should tunnel: {base}");
    }
}
