//! E5 — Figs. 5/11: two-way rigid⇄cloth coupling case studies.
//! (a) figurines lifted by a cloth hoisted at its corners;
//! (b) a domino chain started and finished by interactions.
//! Reported metrics: lift height, interpenetration (must be ~0), chain
//! completion — the quantitative face of the paper's qualitative figures.

use super::{dump_json, print_table};
use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{armadillo, box_mesh, bunny, cloth_grid};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;

/// Fig. 5a / Fig. 11: bunny + armadillo standing on a cloth; the cloth's
/// corners are hoisted. Returns (bunny lift, armadillo lift, max
/// penetration depth observed).
pub fn lift_figurines(steps: usize) -> (f64, f64, f64) {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(cloth_grid(12, 12, 2.4, 2.4), 0.4, 6000.0, 3.0, 2.0);
    let corners = [0usize, 12, 12 * 13, 13 * 13 - 1];
    for &c in &corners {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(bunny(0.22, 1), 0.6).with_position(Vec3::new(-0.35, 0.3, 0.0)),
    );
    sys.add_rigid(
        RigidBody::from_mesh(armadillo(0.22, 1), 0.6).with_position(Vec3::new(0.35, 0.3, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 400.0, ..Default::default() });
    // Settle.
    sim.run(steps / 4);
    let y0 = [sim.sys.rigids[0].translation().y, sim.sys.rigids[1].translation().y];
    let mut max_pen: f64 = 0.0;
    // Hoist.
    for _ in 0..steps {
        for &c in &corners {
            sim.sys.cloths[0].x[c].y += 0.0008;
        }
        sim.step();
        // Penetration metric: figurine vertices below the cloth's lowest
        // point minus thickness would indicate pass-through; use min
        // distance of body verts to cloth min-y plane as a cheap proxy.
        let cloth_min = sim.sys.cloths[0].x.iter().map(|p| p.y).fold(f64::MAX, f64::min);
        for b in &sim.sys.rigids {
            let body_min = b.world_verts().iter().map(|p| p.y).fold(f64::MAX, f64::min);
            max_pen = max_pen.max((cloth_min - body_min - 0.02).max(0.0));
        }
    }
    (
        sim.sys.rigids[0].translation().y - y0[0],
        sim.sys.rigids[1].translation().y - y0[1],
        max_pen,
    )
}

/// Fig. 5b: a pushed block starts a domino chain. Returns the number of
/// dominoes toppled (|rotation| > 0.5 rad).
pub fn domino_chain(n_dominoes: usize, steps: usize) -> usize {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    // Dominoes: thin boxes 0.1 × 0.6 × 0.3 spaced 0.35 apart.
    for k in 0..n_dominoes {
        sys.add_rigid(
            RigidBody::from_mesh(box_mesh(Vec3::new(0.05, 0.3, 0.15)), 1.0)
                .with_position(Vec3::new(0.35 * k as f64, 0.301, 0.0)),
        );
    }
    // Striker: a small heavy block sliding into the first domino.
    sys.add_rigid(
        RigidBody::from_mesh(box_mesh(Vec3::new(0.08, 0.08, 0.08)), 8.0)
            .with_position(Vec3::new(-0.6, 0.45, 0.0))
            .with_velocity(Vec3::new(2.0, 0.0, 0.0)),
    );
    let mut sim = Simulation::new(
        sys,
        SimConfig { dt: 1.0 / 400.0, angular_damping: 0.05, ..Default::default() },
    );
    sim.run(steps);
    (1..=n_dominoes)
        .filter(|&k| {
            let b = &sim.sys.rigids[k];
            let r = b.euler();
            r.norm() > 0.5 || (b.translation().y - 0.301).abs() > 0.1
        })
        .count()
}

pub fn run(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 600);
    let n_dominoes = args.usize_or("dominoes", 5);
    let (lift_b, lift_a, pen) = lift_figurines(steps);
    let toppled = domino_chain(n_dominoes, args.usize_or("domino-steps", 1200));
    print_table(
        "Fig 5/11: two-way coupling metrics",
        &["scene", "metric", "value"],
        &[
            vec!["lift (a)".into(), "bunny Δy".into(), format!("{lift_b:+.3} m")],
            vec!["lift (a)".into(), "armadillo Δy".into(), format!("{lift_a:+.3} m")],
            vec!["lift (a)".into(), "max penetration".into(), format!("{pen:.4} m")],
            vec![
                "dominoes (b)".into(),
                "toppled".into(),
                format!("{toppled}/{n_dominoes}"),
            ],
        ],
    );
    let mut out = Json::obj();
    out.set("experiment", "fig5")
        .set("bunny_lift_m", lift_b)
        .set("armadillo_lift_m", lift_a)
        .set("max_penetration_m", pen)
        .set("dominoes_toppled", toppled)
        .set("dominoes_total", n_dominoes);
    dump_json("fig5_coupling", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figurines_are_lifted_without_penetration() {
        let (lift_b, lift_a, pen) = lift_figurines(400);
        assert!(lift_b > 0.1, "bunny lift {lift_b}");
        assert!(lift_a > 0.1, "armadillo lift {lift_a}");
        assert!(pen < 0.05, "penetration {pen}");
    }

    #[test]
    fn domino_chain_propagates() {
        let toppled = domino_chain(3, 1500);
        assert!(toppled >= 2, "only {toppled} toppled");
    }
}
