//! E1/E2 — Fig. 3: runtime and memory vs scene complexity, ours (mesh +
//! local zones) against the MPM particle/grid baseline.
//!
//! Top row: the number of falling objects grows (20 → 1000 in the paper)
//! with constant stride, so the scene's spatial extent grows with N. Our
//! cost is linear in N; MPM's grid must cover the extent → cubic blow-up
//! until OOM (the paper's baseline dies at 200 objects / 640³).
//!
//! Bottom row: a rigid bunny strikes a cloth whose relative scale grows
//! 1:1 → 10:1. Our cost is constant (resolution-independent); MPM must
//! keep its dx fine enough for the bunny over a growing domain.

use super::{dump_json, print_table};
use crate::baselines::mpm::{Mpm, MpmConfig};
use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{box_mesh, bunny, cloth_grid, unit_box};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::timer::Timer;
use anyhow::Result;

/// Ours: N cubes falling on a ground plane with stride 2.5, simulated
/// `steps` steps with the tape recorded, then one backward pass.
/// Returns (seconds, logical bytes).
pub fn ours_objects(n: usize, steps: usize) -> (f64, usize) {
    let side = (n as f64).sqrt().ceil() as usize;
    let stride = 2.5;
    let mut sys = System::new();
    let extent = side as f64 * stride + 4.0;
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(extent, 0.5, extent)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for k in 0..n {
        let (i, j) = (k % side, k / side);
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
            stride * i as f64 - stride * side as f64 / 2.0,
            0.7 + 0.02 * ((k * 7919) % 13) as f64,
            stride * j as f64 - stride * side as f64 / 2.0,
        )));
    }
    let mut sim = Simulation::new(
        sys,
        SimConfig { record_tape: true, workers: 4, dt: 1.0 / 150.0, ..Default::default() },
    );
    let t = Timer::start();
    sim.run(steps);
    let mut seed = LossGrad::zeros(&sim);
    for b in 1..=n {
        seed.rigid_q[b][4] = 1.0;
    }
    let _ = backward(&sim, &seed);
    let time = t.seconds();
    let mem = sim.tape_bytes() + sim.sys.state_bytes();
    (time, mem)
}

/// MPM baseline: same N objects as particle boxes. The domain edge grows
/// with the scene and the grid must keep dx fine enough to resolve a
/// unit cube → n_grid ∝ side·stride. Beyond `max_grid` the baseline
/// "OOMs" (like the paper's at 640³) and the would-be memory is
/// reported instead. Returns (time?, tape bytes, note).
pub fn mpm_objects(n: usize, steps: usize, max_grid: usize) -> (Option<f64>, usize, String) {
    let side = (n as f64).sqrt().ceil() as usize;
    let stride = 2.5;
    let extent = side as f64 * stride + 4.0;
    let n_grid = (extent / 0.125).ceil() as usize; // 8 cells per unit cube
    if n_grid > max_grid {
        let would_bytes =
            n_grid * n_grid * n_grid * 4 * 8 * steps + n * 4096 * 24 * 8 * steps;
        return (None, would_bytes, format!("OOM (needs {n_grid}^3 grid)"));
    }
    let mut m = Mpm::new(MpmConfig { n_grid, extent, dt: 2e-4, ..Default::default() });
    for k in 0..n {
        let (i, j) = (k % side, k / side);
        let cx = extent / 2.0 + stride * (i as f64 - side as f64 / 2.0);
        let cz = extent / 2.0 + stride * (j as f64 - side as f64 / 2.0);
        m.add_box(
            Vec3::new(cx - 0.5, 1.0, cz - 0.5),
            Vec3::new(cx + 0.5, 2.0, cz + 0.5),
            Vec3::default(),
        );
    }
    let t = Timer::start();
    for _ in 0..steps {
        m.step();
    }
    (
        Some(t.seconds()),
        m.tape_bytes(),
        format!("{n_grid}^3 grid, {} particles", m.n_particles()),
    )
}

/// Ours, Fig. 3 bottom: bunny dropped on a cloth of relative scale
/// `ratio` (cloth mesh resolution FIXED — mesh cost tracks features,
/// not spatial extent).
pub fn ours_scale(ratio: f64, steps: usize) -> (f64, usize) {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(16, 16, 2.0 * ratio, 2.0 * ratio),
        0.3,
        3000.0,
        2.0,
        1.0,
    );
    for &c in &[0usize, 16, 16 * 17, 17 * 17 - 1] {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(bunny(0.4, 2), 1.0).with_position(Vec3::new(0.0, 1.0, 0.0)),
    );
    let mut sim = Simulation::new(
        sys,
        SimConfig { record_tape: true, dt: 1.0 / 200.0, ..Default::default() },
    );
    let t = Timer::start();
    sim.run(steps);
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[0][4] = 1.0;
    let _ = backward(&sim, &seed);
    (t.seconds(), sim.tape_bytes() + sim.sys.state_bytes())
}

/// MPM, Fig. 3 bottom: the domain must cover the scaled cloth while the
/// grid dx keeps the bunny resolved → n_grid ∝ ratio.
pub fn mpm_scale(ratio: f64, steps: usize, max_grid: usize) -> (Option<f64>, usize, String) {
    let extent = 2.0 * ratio + 2.0;
    let n_grid = (extent / 0.05).ceil() as usize;
    if n_grid > max_grid {
        let would = n_grid * n_grid * n_grid * 4 * 8 * steps;
        return (None, would, format!("OOM (needs {n_grid}^3 grid)"));
    }
    let mut m = Mpm::new(MpmConfig { n_grid, extent, dt: 2e-4, ..Default::default() });
    let c = extent / 2.0;
    // Bunny as a particle blob + cloth as a thin particle sheet.
    m.add_box(
        Vec3::new(c - 0.4, c + 0.5, c - 0.4),
        Vec3::new(c + 0.4, c + 1.3, c + 0.4),
        Vec3::default(),
    );
    m.add_box(
        Vec3::new(c - ratio, c, c - ratio),
        Vec3::new(c + ratio, c + 0.08, c + ratio),
        Vec3::default(),
    );
    let t = Timer::start();
    for _ in 0..steps {
        m.step();
    }
    (
        Some(t.seconds()),
        m.tape_bytes(),
        format!("{n_grid}^3 grid, {} particles", m.n_particles()),
    )
}

pub fn run_objects(args: &Args) -> Result<()> {
    let sizes = args.usize_list_or("sizes", &[20, 50, 100, 200]);
    let steps = args.usize_or("steps", 30);
    let max_grid = args.usize_or("max-grid", 128);
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for &n in &sizes {
        let (ot, om) = ours_objects(n, steps);
        let (mt, mm, note) = mpm_objects(n, steps, max_grid);
        let mut j = Json::obj();
        j.set("n", n)
            .set("ours_time_s", ot)
            .set("ours_mem_bytes", om)
            .set("mpm_time_s", mt.unwrap_or(-1.0))
            .set("mpm_mem_bytes", mm)
            .set("mpm_note", note.clone());
        jrows.push(j);
        rows.push(vec![
            n.to_string(),
            format!("{ot:.2}s"),
            crate::util::memory::fmt_bytes(om),
            mt.map(|t| format!("{t:.2}s")).unwrap_or_else(|| "—".into()),
            crate::util::memory::fmt_bytes(mm),
            note,
        ]);
    }
    print_table(
        &format!("Fig 3 (top): objects sweep, {steps} simulated steps (fwd+bwd)"),
        &["#objects", "ours time", "ours mem", "MPM time", "MPM mem", "MPM status"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "fig3-objects").set("steps", steps).set("rows", Json::Arr(jrows));
    dump_json("fig3_objects", &out)
}

pub fn run_scale(args: &Args) -> Result<()> {
    let ratios = args.usize_list_or("ratios", &[1, 2, 4, 6, 8, 10]);
    let steps = args.usize_or("steps", 30);
    let max_grid = args.usize_or("max-grid", 160);
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for &r in &ratios {
        let ratio = r as f64;
        let (ot, om) = ours_scale(ratio, steps);
        let (mt, mm, note) = mpm_scale(ratio, steps, max_grid);
        let mut j = Json::obj();
        j.set("ratio", r)
            .set("ours_time_s", ot)
            .set("ours_mem_bytes", om)
            .set("mpm_time_s", mt.unwrap_or(-1.0))
            .set("mpm_mem_bytes", mm)
            .set("mpm_note", note.clone());
        jrows.push(j);
        rows.push(vec![
            format!("{r}:1"),
            format!("{ot:.2}s"),
            crate::util::memory::fmt_bytes(om),
            mt.map(|t| format!("{t:.2}s")).unwrap_or_else(|| "—".into()),
            crate::util::memory::fmt_bytes(mm),
            note,
        ]);
    }
    print_table(
        &format!("Fig 3 (bottom): cloth:bunny scale sweep, {steps} steps"),
        &["scale", "ours time", "ours mem", "MPM time", "MPM mem", "MPM status"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "fig3-scale").set("steps", steps).set("rows", Json::Arr(jrows));
    dump_json("fig3_scale", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_scales_roughly_linearly() {
        let (t20, m20) = ours_objects(8, 6);
        let (t80, m80) = ours_objects(32, 6);
        // 4× objects: time within ~linear±, memory likewise (generous CI
        // bounds; the bench reports the real series).
        assert!(t80 < t20 * 20.0, "t: {t20} -> {t80}");
        assert!(m80 > m20, "mem should grow");
        assert!(m80 < m20 * 16, "mem superlinear: {m20} -> {m80}");
    }

    #[test]
    fn mpm_objects_hits_oom_wall() {
        let (t, mem, note) = mpm_objects(200, 5, 64);
        assert!(t.is_none(), "should OOM");
        assert!(note.contains("OOM"));
        assert!(mem > (1 << 30), "projected memory should be huge: {mem}");
    }

    #[test]
    fn ours_scale_constant_mpm_grows() {
        let (_, m1) = ours_scale(1.0, 4);
        let (_, m4) = ours_scale(4.0, 4);
        assert!(
            m4 < 2 * m1,
            "our memory should be ~scale-independent: {m1} -> {m4}"
        );
        let (_, g1, _) = mpm_scale(1.0, 2, 512);
        let (_, g2, _) = mpm_scale(2.0, 2, 512);
        assert!(g2 > 2 * g1, "MPM memory should blow up: {g1} -> {g2}");
    }
}
