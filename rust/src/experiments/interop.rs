//! E10 — Fig. 10: interoperability. Three cubes on smooth ground; apply
//! forces so they end up stuck together while minimizing force. The LOSS
//! is evaluated in an *external, non-differentiable* simulator (a simple
//! impulse-based rigid integrator standing in for MuJoCo), while the
//! GRADIENT is evaluated in DiffSim — demonstrating that states and
//! control signals transfer across engines.

use super::{dump_json, print_table};
use crate::bodies::{RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{box_mesh, unit_box};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;

pub const STEPS: usize = 30;
const FORCE_REG: f64 = 1e-6;
const X0: [f64; 3] = [-1.4, 0.0, 1.4];

/// External simulator: cubes as 1-D point masses with inelastic pairwise
/// collision (diameter 1), symplectic Euler. Deliberately independent of
/// the engine — the "MuJoCo" of this experiment.
pub fn external_sim(forces: &[f64]) -> [f64; 3] {
    let mut x = X0;
    let mut v = [0.0f64; 3];
    let h = 1.0 / 100.0;
    for s in 0..STEPS {
        for k in 0..3 {
            v[k] += h * forces[3 * s + k];
            x[k] += h * v[k];
        }
        // Inelastic pairwise resolution (sorted order is preserved).
        for _ in 0..3 {
            for k in 0..2 {
                if x[k + 1] - x[k] < 1.0 {
                    let mid = 0.5 * (x[k] + x[k + 1]);
                    x[k] = mid - 0.5;
                    x[k + 1] = mid + 0.5;
                    let vm = 0.5 * (v[k] + v[k + 1]);
                    v[k] = vm;
                    v[k + 1] = vm;
                }
            }
        }
    }
    x
}

/// Loss in the external simulator: squared gaps between neighbors +
/// force regularizer ("stick together while minimizing applied force").
pub fn external_loss(forces: &[f64]) -> f64 {
    let x = external_sim(forces);
    let g1 = x[1] - x[0] - 1.0;
    let g2 = x[2] - x[1] - 1.0;
    g1 * g1 + g2 * g2 + FORCE_REG * forces.iter().map(|f| f * f).sum::<f64>()
}

/// Gradient from DiffSim: run the same controls in the mesh engine and
/// backpropagate the same objective through it.
pub fn diffsim_grad(forces: &[f64]) -> Vec<f64> {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for &x in &X0 {
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(x, 0.501, 0.0)),
        );
    }
    let mut sim = Simulation::new(
        sys,
        SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() },
    );
    for s in 0..STEPS {
        for k in 0..3 {
            sim.sys.rigids[k + 1].ext_force = Vec3::new(forces[3 * s + k], 0.0, 0.0);
        }
        sim.step();
    }
    let xs: Vec<f64> = (1..4).map(|b| sim.sys.rigids[b].translation().x).collect();
    let g1 = xs[1] - xs[0] - 1.0;
    let g2 = xs[2] - xs[1] - 1.0;
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[1][3] = -2.0 * g1;
    seed.rigid_q[2][3] = 2.0 * g1 - 2.0 * g2;
    seed.rigid_q[3][3] = 2.0 * g2;
    let g = backward(&sim, &seed);
    let mut grad = vec![0.0; forces.len()];
    for s in 0..STEPS {
        for k in 0..3 {
            grad[3 * s + k] = g.rigid_force[s][k + 1].x + 2.0 * FORCE_REG * forces[3 * s + k];
        }
    }
    grad
}

/// Cross-simulator optimization loop; returns external-sim loss curve.
/// Adam handles the poor scaling of per-step force parameters.
pub fn optimize(iters: usize, lr: f64) -> Vec<f64> {
    let mut forces = vec![0.0; 3 * STEPS];
    let mut opt = crate::ml::adam::Adam::new(forces.len(), lr);
    let mut curve = Vec::new();
    for _ in 0..iters {
        curve.push(external_loss(&forces));
        let grad = diffsim_grad(&forces);
        opt.step(&mut forces, &grad);
    }
    curve.push(external_loss(&forces));
    curve
}

pub fn run(args: &Args) -> Result<()> {
    let iters = args.usize_or("iters", 10);
    let lr = args.f64_or("lr", 2.0);
    let curve = optimize(iters, lr);
    let rows: Vec<Vec<String>> = curve
        .iter()
        .enumerate()
        .map(|(i, l)| vec![i.to_string(), format!("{l:.5}")])
        .collect();
    print_table(
        "Fig 10: interop — loss in EXTERNAL sim, gradients from DiffSim",
        &["gradient step", "external loss"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "fig10")
        .set("curve", Json::Arr(curve.iter().map(|&l| Json::Num(l)).collect()));
    dump_json("fig10_interop", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn external_sim_sticks_on_contact() {
        // Push outer cubes inward hard: all three should end adjacent.
        let mut forces = vec![0.0; 3 * STEPS];
        for s in 0..STEPS {
            forces[3 * s] = 16.0;
            forces[3 * s + 2] = -16.0;
        }
        let x = external_sim(&forces);
        assert!((x[1] - x[0] - 1.0).abs() < 0.05, "{x:?}");
        assert!((x[2] - x[1] - 1.0).abs() < 0.05, "{x:?}");
    }

    #[test]
    fn cross_simulator_gradients_reduce_external_loss() {
        let curve = optimize(12, 2.0);
        let first = curve[0];
        let last = *curve.last().unwrap();
        assert!(
            last < 0.3 * first,
            "external loss did not drop: {first} -> {last} ({curve:?})"
        );
    }
}
