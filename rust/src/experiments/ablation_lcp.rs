//! E3 — Table 1: backpropagation cost with the global LCP-style solver
//! (one optimization over all contacts, de Avila Belbute-Peres 2018) vs
//! localized impact zones. N cubes are dropped on the ground; contacts
//! are pairwise-independent, so the local method scales linearly while
//! the global one pays the full (ΣN, ΣM) system.

use super::{dump_json, print_table};
use crate::bodies::{RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{CollisionMode, SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{box_mesh, unit_box};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};
use anyhow::Result;

/// Build N settled-ish cubes (small drop) and run `meas_steps` taped
/// steps + backward per trial; returns per-step backprop seconds stats.
pub fn backprop_time(n: usize, mode: CollisionMode, trials: usize) -> Stats {
    let side = (n as f64).sqrt().ceil() as usize;
    let mut stats = Stats::new();
    for trial in 0..trials {
        let mut sys = System::new();
        let extent = side as f64 * 1.5 + 4.0;
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(extent, 0.5, extent)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        for k in 0..n {
            let (i, j) = (k % side, k / side);
            sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
                1.5 * (i as f64 - side as f64 / 2.0) + 0.01 * (trial as f64 + 1.0),
                0.502,
                1.5 * (j as f64 - side as f64 / 2.0),
            )));
        }
        let mut sim = Simulation::new(
            sys,
            SimConfig {
                record_tape: false,
                // Settle in local mode (identical physics, cheaper), then
                // measure in the requested mode.
                collision_mode: CollisionMode::LocalZones,
                dt: 1.0 / 150.0,
                ..Default::default()
            },
        );
        sim.run(15);
        assert!(sim.last_stats.impacts > 0, "no contacts to measure");
        sim.cfg.collision_mode = mode;
        sim.cfg.record_tape = true;
        let meas_steps = 3;
        sim.run(meas_steps);
        let mut seed = LossGrad::zeros(&sim);
        for b in 1..=n {
            seed.rigid_q[b][3] = 1.0;
            seed.rigid_q[b][4] = 1.0;
        }
        let t = Timer::start();
        let _ = backward(&sim, &seed);
        stats.push(t.seconds() / meas_steps as f64);
    }
    stats
}

pub fn run(args: &Args) -> Result<()> {
    let sizes = args.usize_list_or("sizes", &[100, 200, 300]);
    let trials = args.usize_or("trials", 3);
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for &n in &sizes {
        let global = backprop_time(n, CollisionMode::Global, trials);
        let local = backprop_time(n, CollisionMode::LocalZones, trials);
        let speedup = global.mean() / local.mean().max(1e-12);
        let mut j = Json::obj();
        j.set("n", n)
            .set("global_mean_s", global.mean())
            .set("global_std_s", global.std())
            .set("local_mean_s", local.mean())
            .set("local_std_s", local.std())
            .set("speedup", speedup);
        jrows.push(j);
        rows.push(vec![
            n.to_string(),
            format!("{:.4}s ± {:.4}s", global.mean(), global.std()),
            format!("{:.4}s ± {:.4}s", local.mean(), local.std()),
            format!("{speedup:.2}x"),
        ]);
    }
    print_table(
        "Table 1: backprop seconds/step — global LCP-style vs local zones (ours)",
        &["# of cubes", "LCP (global)", "Ours (local)", "speedup"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "table1").set("rows", Json::Arr(jrows));
    dump_json("table1_lcp", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_beats_global_and_gap_widens() {
        let g1 = backprop_time(9, CollisionMode::Global, 1).mean();
        let l1 = backprop_time(9, CollisionMode::LocalZones, 1).mean();
        let g2 = backprop_time(36, CollisionMode::Global, 1).mean();
        let l2 = backprop_time(36, CollisionMode::LocalZones, 1).mean();
        assert!(l1 < g1, "local {l1} vs global {g1} at n=9");
        assert!(l2 < g2, "local {l2} vs global {g2} at n=36");
        // The paper's headline: the gap widens with scene complexity.
        assert!(
            g2 / l2 > g1 / l1 * 0.8,
            "speedup should (roughly) widen: {} -> {}",
            g1 / l1,
            g2 / l2
        );
    }
}
