//! E7 — Fig. 7: inverse problem. A marble rests on a corner-pinned soft
//! sheet; find the sequence of horizontal forces that drives it to a
//! target position while minimizing total applied force. Gradient-based
//! optimization (through the differentiable simulator) vs CMA-ES.

use super::{dump_json, print_table};
use crate::batch::SceneBatch;
use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{cloth_grid, icosphere};
use crate::ml::adam::Adam;
use crate::ml::cmaes::CmaEs;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::rng::Pcg32;
use anyhow::Result;

pub const STEPS: usize = 40;
const SETTLE_STEPS: usize = 30;
const FORCE_REG: f64 = 1e-3;

/// The Fig. 7 scene: a marble resting on a corner-pinned soft sheet.
fn marble_scene() -> System {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(8, 8, 2.0, 2.0).translated(Vec3::new(0.0, 0.5, 0.0)),
        0.3,
        3000.0,
        2.0,
        1.5,
    );
    for &c in &[0usize, 8, 72, 80] {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.12, 1), 3.0).with_position(Vec3::new(0.0, 0.63, 0.0)),
    );
    sys
}

fn episode_cfg() -> SimConfig {
    SimConfig { record_tape: false, dt: 1.0 / 100.0, ..Default::default() }
}

fn episode_loss(sim: &Simulation, forces: &[f64], target: Vec3) -> f64 {
    let p = sim.sys.rigids[0].translation();
    let d = Vec3::new(p.x - target.x, 0.0, p.z - target.z);
    d.norm2() + FORCE_REG * forces.iter().map(|f| f * f).sum::<f64>()
}

/// Roll out the marble-on-sheet episode with per-step horizontal forces
/// (2·STEPS parameters). Returns (loss, sim-with-tape).
fn rollout(forces: &[f64], target: Vec3, record: bool) -> (f64, Simulation) {
    let mut sim = Simulation::new(marble_scene(), episode_cfg());
    // Let the marble settle into its pocket first (untaped) so the
    // controlled segment starts from steady contact.
    sim.run(SETTLE_STEPS);
    sim.cfg.record_tape = record;
    for s in 0..STEPS {
        sim.sys.rigids[0].ext_force = Vec3::new(forces[2 * s], 0.0, forces[2 * s + 1]);
        sim.step();
    }
    let loss = episode_loss(&sim, forces, target);
    (loss, sim)
}

/// Batched population evaluation: one scene per candidate force
/// sequence, all stepped through a [`SceneBatch`] in *lockstep* (the
/// CMA-ES population / perturbation-set workload) so every fail-safe
/// pass's zone solves pool across the whole population — one
/// `Coordinator::zone_solve_batch` call per pass level when a shared
/// coordinator is installed, one cross-scene pool map otherwise.
/// Losses come back in candidate order and are bitwise-identical to
/// sequential `loss_only`.
pub fn loss_only_batch(cands: &[Vec<f64>], target: Vec3) -> Vec<f64> {
    if cands.is_empty() {
        return Vec::new();
    }
    let mut cfg = episode_cfg();
    cfg.workers = Pool::machine_workers();
    let mut batch = SceneBatch::from_scene(&marble_scene(), &cfg, cands.len(), |_, _| {});
    batch.run_lockstep(SETTLE_STEPS); // settle into the pocket, untaped
    batch.rollout_lockstep(STEPS, |_| (), |_, i, s, sim| {
        sim.sys.rigids[0].ext_force = Vec3::new(cands[i][2 * s], 0.0, cands[i][2 * s + 1]);
    });
    cands
        .iter()
        .enumerate()
        .map(|(i, forces)| episode_loss(batch.sim(i), forces, target))
        .collect()
}

/// Loss + gradient via the tape.
pub fn loss_and_grad(forces: &[f64], target: Vec3) -> (f64, Vec<f64>) {
    let (loss, sim) = rollout(forces, target, true);
    let p = sim.sys.rigids[0].translation();
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[0][3] = 2.0 * (p.x - target.x);
    seed.rigid_q[0][5] = 2.0 * (p.z - target.z);
    let g = backward(&sim, &seed);
    let mut grad = vec![0.0; forces.len()];
    for s in 0..STEPS {
        grad[2 * s] = g.rigid_force[s][0].x + 2.0 * FORCE_REG * forces[2 * s];
        grad[2 * s + 1] = g.rigid_force[s][0].z + 2.0 * FORCE_REG * forces[2 * s + 1];
    }
    (loss, grad)
}

pub fn loss_only(forces: &[f64], target: Vec3) -> f64 {
    rollout(forces, target, false).0
}

/// Gradient-based optimization; returns the loss curve (one entry per
/// simulation episode, to compare sample efficiency with CMA-ES).
pub fn optimize_gradient(target: Vec3, iters: usize) -> Vec<f64> {
    optimize_gradient_lr(target, iters, 0.01)
}

pub fn optimize_gradient_lr(target: Vec3, iters: usize, lr: f64) -> Vec<f64> {
    let mut forces = vec![0.0; 2 * STEPS];
    let mut opt = Adam::new(forces.len(), lr);
    let mut curve = Vec::new();
    for _ in 0..iters {
        let (loss, grad) = loss_and_grad(&forces, target);
        curve.push(loss);
        opt.step(&mut forces, &grad);
    }
    curve
}

/// CMA-ES baseline; returns best-so-far loss per EPISODE (each candidate
/// evaluation is one simulation — the x-axis the paper plots). The whole
/// population of each generation is evaluated in parallel through
/// [`loss_only_batch`]; the curve is identical to sequential evaluation.
pub fn optimize_cmaes(target: Vec3, episodes: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let mut es = CmaEs::new(&vec![0.0; 2 * STEPS], 0.5);
    let mut curve = Vec::new();
    let mut best = f64::MAX;
    loop {
        let remaining = episodes.saturating_sub(curve.len());
        if remaining == 0 {
            break;
        }
        let mut pop = es.ask(&mut rng);
        // Don't simulate candidates past the episode budget: a truncated
        // generation never reaches `tell`, so dropping them is
        // behavior-identical to stopping mid-population.
        let truncated = pop.len() > remaining;
        pop.truncate(remaining);
        let fits = loss_only_batch(&pop, target);
        let mut scored = Vec::with_capacity(pop.len());
        for (x, l) in pop.into_iter().zip(fits) {
            best = best.min(l);
            curve.push(best);
            scored.push((x, l));
        }
        if truncated {
            break;
        }
        es.tell(scored);
    }
    curve
}

pub fn run(args: &Args) -> Result<()> {
    let target = Vec3::new(args.f64_or("tx", 0.5), 0.0, args.f64_or("tz", 0.3));
    let grad_iters = args.usize_or("grad-iters", 15);
    let cma_episodes = args.usize_or("cma-episodes", 200);
    // Fresh Fig-3-style accounting for this run's batched populations.
    crate::util::memory::global().reset();
    println!("target = ({}, {}), horizon {STEPS} steps", target.x, target.z);
    let gcurve = optimize_gradient(target, grad_iters);
    let ccurve = optimize_cmaes(target, cma_episodes, 42);
    let mut rows = Vec::new();
    for (i, l) in gcurve.iter().enumerate() {
        rows.push(vec![format!("grad #{i}"), format!("{l:.5}")]);
    }
    for i in [0, 9, 49, 99, cma_episodes - 1] {
        if i < ccurve.len() {
            rows.push(vec![format!("cma ep{}", i + 1), format!("{:.5}", ccurve[i])]);
        }
    }
    print_table("Fig 7: inverse problem — loss vs episodes", &["episode", "loss"], &rows);
    let g_final = *gcurve.last().unwrap();
    let c_final = *ccurve.last().unwrap();
    println!(
        "gradient reaches {g_final:.5} in {} episodes; CMA-ES at {c_final:.5} after {} episodes",
        gcurve.len(),
        ccurve.len()
    );
    let mut out = Json::obj();
    out.set("experiment", "fig7")
        .set("grad_curve", Json::Arr(gcurve.iter().map(|&l| Json::Num(l)).collect()))
        .set("cma_curve", Json::Arr(ccurve.iter().map(|&l| Json::Num(l)).collect()))
        .set("memory", super::batch_memory_report("fig7"));
    dump_json("fig7_inverse", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_population_matches_sequential_losses() {
        let target = Vec3::new(0.3, 0.0, 0.1);
        let mut rng = Pcg32::new(2);
        let cands: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..2 * STEPS).map(|_| rng.range(-0.5, 0.5)).collect())
            .collect();
        let batched = loss_only_batch(&cands, target);
        for (c, lb) in cands.iter().zip(&batched) {
            let ls = loss_only(c, target);
            assert!(ls == *lb, "batch {lb} differs from sequential {ls}");
        }
    }

    #[test]
    fn gradient_optimization_beats_cmaes_budget() {
        // The paper's claim, scaled down: a handful of gradient episodes
        // beat CMA-ES given an order of magnitude more episodes.
        let target = Vec3::new(0.4, 0.0, 0.2);
        let g = optimize_gradient(target, 12);
        let c = optimize_cmaes(target, 60, 7);
        let g_final = *g.last().unwrap();
        let c_final = *c.last().unwrap();
        assert!(g_final < g[0] * 0.5, "gradient barely improved: {g:?}");
        assert!(
            g_final < c_final,
            "gradient ({g_final}) should beat CMA-ES ({c_final}) at 10x fewer episodes"
        );
    }
}
