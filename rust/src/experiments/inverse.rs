//! E7 — Fig. 7: inverse problem. A marble rests on a corner-pinned soft
//! sheet; find the sequence of horizontal forces that drives it to a
//! target position while minimizing total applied force. Gradient-based
//! optimization (through the differentiable simulator) vs CMA-ES.

use super::{dump_json, print_table};
use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{cloth_grid, icosphere};
use crate::ml::adam::Adam;
use crate::ml::cmaes::CmaEs;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use anyhow::Result;

pub const STEPS: usize = 40;
const FORCE_REG: f64 = 1e-3;

/// Roll out the marble-on-sheet episode with per-step horizontal forces
/// (2·STEPS parameters). Returns (loss, sim-with-tape).
fn rollout(forces: &[f64], target: Vec3, record: bool) -> (f64, Simulation) {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(8, 8, 2.0, 2.0).translated(Vec3::new(0.0, 0.5, 0.0)),
        0.3,
        3000.0,
        2.0,
        1.5,
    );
    for &c in &[0usize, 8, 72, 80] {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.12, 1), 3.0).with_position(Vec3::new(0.0, 0.63, 0.0)),
    );
    let mut sim = Simulation::new(
        sys,
        SimConfig { record_tape: false, dt: 1.0 / 100.0, ..Default::default() },
    );
    // Let the marble settle into its pocket first (untaped) so the
    // controlled segment starts from steady contact.
    sim.run(30);
    sim.cfg.record_tape = record;
    for s in 0..STEPS {
        sim.sys.rigids[0].ext_force = Vec3::new(forces[2 * s], 0.0, forces[2 * s + 1]);
        sim.step();
    }
    let p = sim.sys.rigids[0].translation();
    let d = Vec3::new(p.x - target.x, 0.0, p.z - target.z);
    let loss = d.norm2() + FORCE_REG * forces.iter().map(|f| f * f).sum::<f64>();
    (loss, sim)
}

/// Loss + gradient via the tape.
pub fn loss_and_grad(forces: &[f64], target: Vec3) -> (f64, Vec<f64>) {
    let (loss, sim) = rollout(forces, target, true);
    let p = sim.sys.rigids[0].translation();
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[0][3] = 2.0 * (p.x - target.x);
    seed.rigid_q[0][5] = 2.0 * (p.z - target.z);
    let g = backward(&sim, &seed);
    let mut grad = vec![0.0; forces.len()];
    for s in 0..STEPS {
        grad[2 * s] = g.rigid_force[s][0].x + 2.0 * FORCE_REG * forces[2 * s];
        grad[2 * s + 1] = g.rigid_force[s][0].z + 2.0 * FORCE_REG * forces[2 * s + 1];
    }
    (loss, grad)
}

pub fn loss_only(forces: &[f64], target: Vec3) -> f64 {
    rollout(forces, target, false).0
}

/// Gradient-based optimization; returns the loss curve (one entry per
/// simulation episode, to compare sample efficiency with CMA-ES).
pub fn optimize_gradient(target: Vec3, iters: usize) -> Vec<f64> {
    optimize_gradient_lr(target, iters, 0.01)
}

pub fn optimize_gradient_lr(target: Vec3, iters: usize, lr: f64) -> Vec<f64> {
    let mut forces = vec![0.0; 2 * STEPS];
    let mut opt = Adam::new(forces.len(), lr);
    let mut curve = Vec::new();
    for _ in 0..iters {
        let (loss, grad) = loss_and_grad(&forces, target);
        curve.push(loss);
        opt.step(&mut forces, &grad);
    }
    curve
}

/// CMA-ES baseline; returns best-so-far loss per EPISODE (each candidate
/// evaluation is one simulation — the x-axis the paper plots).
pub fn optimize_cmaes(target: Vec3, episodes: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let mut es = CmaEs::new(&vec![0.0; 2 * STEPS], 0.5);
    let mut curve = Vec::new();
    let mut best = f64::MAX;
    'outer: loop {
        let pop = es.ask(&mut rng);
        let mut scored = Vec::with_capacity(pop.len());
        for x in pop {
            let l = loss_only(&x, target);
            best = best.min(l);
            curve.push(best);
            scored.push((x, l));
            if curve.len() >= episodes {
                break 'outer;
            }
        }
        es.tell(scored);
    }
    curve
}

pub fn run(args: &Args) -> Result<()> {
    let target = Vec3::new(args.f64_or("tx", 0.5), 0.0, args.f64_or("tz", 0.3));
    let grad_iters = args.usize_or("grad-iters", 15);
    let cma_episodes = args.usize_or("cma-episodes", 200);
    println!("target = ({}, {}), horizon {STEPS} steps", target.x, target.z);
    let gcurve = optimize_gradient(target, grad_iters);
    let ccurve = optimize_cmaes(target, cma_episodes, 42);
    let mut rows = Vec::new();
    for (i, l) in gcurve.iter().enumerate() {
        rows.push(vec![format!("grad #{i}"), format!("{l:.5}")]);
    }
    for i in [0, 9, 49, 99, cma_episodes - 1] {
        if i < ccurve.len() {
            rows.push(vec![format!("cma ep{}", i + 1), format!("{:.5}", ccurve[i])]);
        }
    }
    print_table("Fig 7: inverse problem — loss vs episodes", &["episode", "loss"], &rows);
    let g_final = *gcurve.last().unwrap();
    let c_final = *ccurve.last().unwrap();
    println!(
        "gradient reaches {g_final:.5} in {} episodes; CMA-ES at {c_final:.5} after {} episodes",
        gcurve.len(),
        ccurve.len()
    );
    let mut out = Json::obj();
    out.set("experiment", "fig7")
        .set("grad_curve", Json::Arr(gcurve.iter().map(|&l| Json::Num(l)).collect()))
        .set("cma_curve", Json::Arr(ccurve.iter().map(|&l| Json::Num(l)).collect()));
    dump_json("fig7_inverse", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_optimization_beats_cmaes_budget() {
        // The paper's claim, scaled down: a handful of gradient episodes
        // beat CMA-ES given an order of magnitude more episodes.
        let target = Vec3::new(0.4, 0.0, 0.2);
        let g = optimize_gradient(target, 12);
        let c = optimize_cmaes(target, 60, 7);
        let g_final = *g.last().unwrap();
        let c_final = *c.last().unwrap();
        assert!(g_final < g[0] * 0.5, "gradient barely improved: {g:?}");
        assert!(
            g_final < c_final,
            "gradient ({g_final}) should beat CMA-ES ({c_final}) at 10x fewer episodes"
        );
    }
}
