//! E7 — Fig. 7: inverse problem. A marble rests on a corner-pinned soft
//! sheet; find the sequence of horizontal forces that drives it to a
//! target position while minimizing total applied force. Gradient-based
//! optimization (through the differentiable simulator) vs CMA-ES.

use super::{dump_json, print_table};
use crate::batch::pipeline::{BatchPipeline, Generation};
use crate::batch::SceneBatch;
use crate::util::arena::BatchArena;
use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{cloth_grid, icosphere};
use crate::ml::adam::Adam;
use crate::ml::cmaes::CmaEs;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::rng::Pcg32;
use anyhow::Result;

pub const STEPS: usize = 40;
const SETTLE_STEPS: usize = 30;
const FORCE_REG: f64 = 1e-3;

/// The Fig. 7 scene: a marble resting on a corner-pinned soft sheet.
fn marble_scene() -> System {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(8, 8, 2.0, 2.0).translated(Vec3::new(0.0, 0.5, 0.0)),
        0.3,
        3000.0,
        2.0,
        1.5,
    );
    for &c in &[0usize, 8, 72, 80] {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.12, 1), 3.0).with_position(Vec3::new(0.0, 0.63, 0.0)),
    );
    sys
}

fn episode_cfg() -> SimConfig {
    SimConfig { record_tape: false, dt: 1.0 / 100.0, ..Default::default() }
}

fn episode_loss(sim: &Simulation, forces: &[f64], target: Vec3) -> f64 {
    let p = sim.sys.rigids[0].translation();
    let d = Vec3::new(p.x - target.x, 0.0, p.z - target.z);
    d.norm2() + FORCE_REG * forces.iter().map(|f| f * f).sum::<f64>()
}

/// Roll out the marble-on-sheet episode with per-step horizontal forces
/// (2·STEPS parameters). Returns (loss, sim-with-tape).
fn rollout(forces: &[f64], target: Vec3, record: bool) -> (f64, Simulation) {
    let mut sim = Simulation::new(marble_scene(), episode_cfg());
    // Let the marble settle into its pocket first (untaped) so the
    // controlled segment starts from steady contact.
    sim.run(SETTLE_STEPS);
    sim.cfg.record_tape = record;
    for s in 0..STEPS {
        sim.sys.rigids[0].ext_force = Vec3::new(forces[2 * s], 0.0, forces[2 * s + 1]);
        sim.step();
    }
    let loss = episode_loss(&sim, forces, target);
    (loss, sim)
}

/// Prepare one candidate-independent scene for the pipelined population
/// evaluation: marble on the sheet, sharing the population's arena,
/// settled untaped into its pocket. Candidate forces only apply during
/// the controlled segment, which is why generation *k+1*'s settling can
/// overlap generation *k*'s stepping without changing a single bit.
fn prepare_settled(pipe: &BatchPipeline, n: usize, arena: &BatchArena) -> Generation<Simulation> {
    let arena = arena.clone();
    pipe.prepare(n, move |_| {
        let mut sim = Simulation::new(marble_scene(), episode_cfg());
        sim.set_arena(arena.clone());
        sim.run(SETTLE_STEPS);
        sim
    })
}

/// Stream a prepared generation against `cands`: each scene's
/// controlled rollout runs on a pool worker, its loss is evaluated on
/// the submitter while slower scenes still step. Losses come back in
/// candidate order, bitwise-identical to sequential [`loss_only`].
fn stream_losses(
    pipe: &BatchPipeline,
    generation: Generation<Simulation>,
    cands: &[Vec<f64>],
    target: Vec3,
) -> Vec<f64> {
    pipe.stream(
        generation,
        |i, mut sim: Simulation| {
            for s in 0..STEPS {
                sim.sys.rigids[0].ext_force =
                    Vec3::new(cands[i][2 * s], 0.0, cands[i][2 * s + 1]);
                sim.step();
            }
            sim
        },
        |i, sim| episode_loss(&sim, &cands[i], target),
    )
}

/// *Pipelined* population evaluation (the CMA-ES / perturbation-set
/// workload): one scene per candidate force sequence, streamed through
/// a [`BatchPipeline`] window so finished candidates' losses are scored
/// on the submitter while slower candidates still step. Losses come
/// back in candidate order and are bitwise-identical to both sequential
/// [`loss_only`] and the lockstep fallback [`loss_only_lockstep`].
pub fn loss_only_batch(cands: &[Vec<f64>], target: Vec3) -> Vec<f64> {
    if cands.is_empty() {
        return Vec::new();
    }
    let pipe = BatchPipeline::new(Pool::machine_workers());
    let arena = BatchArena::new();
    let generation = prepare_settled(&pipe, cands.len(), &arena);
    stream_losses(&pipe, generation, cands, target)
}

/// Synchronous fallback: the pre-pipeline *lockstep* population
/// evaluation — all scenes advance through a blocking [`SceneBatch`],
/// pooling every fail-safe pass's zone solves across the population
/// (one `Coordinator::zone_solve_batch` call per pass level when a
/// shared coordinator is installed, one cross-scene pool map
/// otherwise). Bitwise-identical losses to [`loss_only_batch`]; prefer
/// it when a PJRT coordinator should amortize across the population.
pub fn loss_only_lockstep(cands: &[Vec<f64>], target: Vec3) -> Vec<f64> {
    if cands.is_empty() {
        return Vec::new();
    }
    let mut cfg = episode_cfg();
    cfg.workers = Pool::machine_workers();
    let mut batch = SceneBatch::from_scene(&marble_scene(), &cfg, cands.len(), |_, _| {});
    batch.run_lockstep(SETTLE_STEPS); // settle into the pocket, untaped
    batch.rollout_lockstep(STEPS, |_| (), |_, i, s, sim| {
        sim.sys.rigids[0].ext_force = Vec3::new(cands[i][2 * s], 0.0, cands[i][2 * s + 1]);
    });
    cands
        .iter()
        .enumerate()
        .map(|(i, forces)| episode_loss(batch.sim(i), forces, target))
        .collect()
}

/// Loss + gradient via the tape.
pub fn loss_and_grad(forces: &[f64], target: Vec3) -> (f64, Vec<f64>) {
    let (loss, sim) = rollout(forces, target, true);
    let p = sim.sys.rigids[0].translation();
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[0][3] = 2.0 * (p.x - target.x);
    seed.rigid_q[0][5] = 2.0 * (p.z - target.z);
    let g = backward(&sim, &seed);
    let mut grad = vec![0.0; forces.len()];
    for s in 0..STEPS {
        grad[2 * s] = g.rigid_force[s][0].x + 2.0 * FORCE_REG * forces[2 * s];
        grad[2 * s + 1] = g.rigid_force[s][0].z + 2.0 * FORCE_REG * forces[2 * s + 1];
    }
    (loss, grad)
}

pub fn loss_only(forces: &[f64], target: Vec3) -> f64 {
    rollout(forces, target, false).0
}

/// Gradient-based optimization; returns the loss curve (one entry per
/// simulation episode, to compare sample efficiency with CMA-ES).
pub fn optimize_gradient(target: Vec3, iters: usize) -> Vec<f64> {
    optimize_gradient_lr(target, iters, 0.01)
}

pub fn optimize_gradient_lr(target: Vec3, iters: usize, lr: f64) -> Vec<f64> {
    let mut forces = vec![0.0; 2 * STEPS];
    let mut opt = Adam::new(forces.len(), lr);
    let mut curve = Vec::new();
    for _ in 0..iters {
        let (loss, grad) = loss_and_grad(&forces, target);
        curve.push(loss);
        opt.step(&mut forces, &grad);
    }
    curve
}

/// CMA-ES baseline; returns best-so-far loss per EPISODE (each candidate
/// evaluation is one simulation — the x-axis the paper plots). Each
/// generation's population streams through a [`BatchPipeline`] window
/// (losses scored on the submitter while slower candidates step), and
/// the *next* generation's scenes — construction plus untaped settling,
/// both candidate-independent — are built by detached jobs while the
/// current generation evaluates. The drain barrier is `tell`/`ask` (the
/// CMA-ES state update needs every loss), so the curve is identical to
/// sequential evaluation.
pub fn optimize_cmaes(target: Vec3, episodes: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let mut es = CmaEs::new(&vec![0.0; 2 * STEPS], 0.5);
    let mut curve = Vec::new();
    let mut best = f64::MAX;
    let pipe = BatchPipeline::new(Pool::machine_workers());
    let arena = BatchArena::new();
    // Generation k+1's settled scenes, building while generation k runs.
    let mut prepared: Option<Generation<Simulation>> = None;
    loop {
        let remaining = episodes.saturating_sub(curve.len());
        if remaining == 0 {
            break;
        }
        let mut pop = es.ask(&mut rng);
        // Don't simulate candidates past the episode budget: a truncated
        // generation never reaches `tell`, so dropping them is
        // behavior-identical to stopping mid-population.
        let truncated = pop.len() > remaining;
        pop.truncate(remaining);
        let mut generation = prepared
            .take()
            .unwrap_or_else(|| prepare_settled(&pipe, pop.len(), &arena));
        generation.truncate(pop.len());
        if !truncated && remaining > pop.len() {
            // Double-buffer: the next generation's scenes settle on the
            // workers while this generation's candidates stream. Sized
            // to the episodes the budget can still afford, so a short
            // final generation never builds (then blocking-drops)
            // surplus settles.
            let next_pop = es.lambda.min(remaining - pop.len());
            prepared = Some(prepare_settled(&pipe, next_pop, &arena));
        }
        let fits = stream_losses(&pipe, generation, &pop, target);
        let mut scored = Vec::with_capacity(pop.len());
        for (x, l) in pop.into_iter().zip(fits) {
            best = best.min(l);
            curve.push(best);
            scored.push((x, l));
        }
        if truncated {
            break;
        }
        es.tell(scored);
    }
    curve
}

pub fn run(args: &Args) -> Result<()> {
    let target = Vec3::new(args.f64_or("tx", 0.5), 0.0, args.f64_or("tz", 0.3));
    let grad_iters = args.usize_or("grad-iters", 15);
    let cma_episodes = args.usize_or("cma-episodes", 200);
    // Fresh Fig-3-style accounting for this run's batched populations.
    crate::util::memory::global().reset();
    println!("target = ({}, {}), horizon {STEPS} steps", target.x, target.z);
    let gcurve = optimize_gradient(target, grad_iters);
    let ccurve = optimize_cmaes(target, cma_episodes, 42);
    let mut rows = Vec::new();
    for (i, l) in gcurve.iter().enumerate() {
        rows.push(vec![format!("grad #{i}"), format!("{l:.5}")]);
    }
    for i in [0, 9, 49, 99, cma_episodes - 1] {
        if i < ccurve.len() {
            rows.push(vec![format!("cma ep{}", i + 1), format!("{:.5}", ccurve[i])]);
        }
    }
    print_table("Fig 7: inverse problem — loss vs episodes", &["episode", "loss"], &rows);
    let g_final = *gcurve.last().unwrap();
    let c_final = *ccurve.last().unwrap();
    println!(
        "gradient reaches {g_final:.5} in {} episodes; CMA-ES at {c_final:.5} after {} episodes",
        gcurve.len(),
        ccurve.len()
    );
    let mut out = Json::obj();
    out.set("experiment", "fig7")
        .set("grad_curve", Json::Arr(gcurve.iter().map(|&l| Json::Num(l)).collect()))
        .set("cma_curve", Json::Arr(ccurve.iter().map(|&l| Json::Num(l)).collect()))
        .set("memory", super::batch_memory_report("fig7"));
    dump_json("fig7_inverse", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_population_matches_sequential_losses() {
        // Pipelined == lockstep == sequential, bitwise (the fig7
        // acceptance bar; the full three-way sweep also lives in
        // rust/tests/integration_pipeline.rs).
        let target = Vec3::new(0.3, 0.0, 0.1);
        let mut rng = Pcg32::new(2);
        let cands: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..2 * STEPS).map(|_| rng.range(-0.5, 0.5)).collect())
            .collect();
        let pipelined = loss_only_batch(&cands, target);
        let lockstep = loss_only_lockstep(&cands, target);
        for (i, c) in cands.iter().enumerate() {
            let ls = loss_only(c, target);
            assert!(ls == pipelined[i], "pipelined {} differs from sequential {ls}", pipelined[i]);
            assert!(ls == lockstep[i], "lockstep {} differs from sequential {ls}", lockstep[i]);
        }
    }

    #[test]
    fn gradient_optimization_beats_cmaes_budget() {
        // The paper's claim, scaled down: a handful of gradient episodes
        // beat CMA-ES given an order of magnitude more episodes.
        let target = Vec3::new(0.4, 0.0, 0.2);
        let g = optimize_gradient(target, 12);
        let c = optimize_cmaes(target, 60, 7);
        let g_final = *g.last().unwrap();
        let c_final = *c.last().unwrap();
        assert!(g_final < g[0] * 0.5, "gradient barely improved: {g:?}");
        assert!(
            g_final < c_final,
            "gradient ({g_final}) should beat CMA-ES ({c_final}) at 10x fewer episodes"
        );
    }
}
