//! E4 — Table 2: backpropagation cost without vs with the fast
//! differentiation scheme (§6). N cubes densely stacked in two layers
//! form ONE connected impact zone, so every constraint lands in a single
//! KKT system: the dense (n+m)³ solve ("W/o FD") vs the QR path.

use super::{dump_json, print_table};
use crate::bodies::{RigidBody, System};
use crate::engine::backward::{backward, LossGrad};
use crate::engine::{CollisionMode, DiffMode, SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives::{box_mesh, unit_box};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};
use anyhow::Result;

/// N cubes packed in two tight layers (one connected component),
/// stepped briefly with tape. The expensive global forward is built ONCE;
/// both diff modes are then timed on the same tape (fair comparison, and
/// the forward cost is excluded as in the paper's "runtime of
/// backpropagation").
pub fn backprop_time_both(n: usize, trials: usize) -> (Stats, Stats) {
    let per_layer = n.div_ceil(2);
    let side = (per_layer as f64).sqrt().ceil() as usize;
    let mut dense_stats = Stats::new();
    let mut qr_stats = Stats::new();
    for trial in 0..trials {
        let mut sys = System::new();
        let extent = side as f64 * 1.1 + 4.0;
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(extent, 0.5, extent)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        let mut placed = 0;
        'outer: for layer in 0..2 {
            for k in 0..per_layer {
                if placed >= n {
                    break 'outer;
                }
                let (i, j) = (k % side, k / side);
                sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
                    1.02 * (i as f64 - side as f64 / 2.0) + 0.3 * layer as f64,
                    0.505 + 1.01 * layer as f64 + 0.001 * (trial + 1) as f64,
                    1.02 * (j as f64 - side as f64 / 2.0) + 0.3 * layer as f64,
                )));
                placed += 1;
            }
        }
        let mut sim = Simulation::new(
            sys,
            SimConfig {
                record_tape: false,
                collision_mode: CollisionMode::LocalZones,
                dt: 1.0 / 150.0,
                ..Default::default()
            },
        );
        sim.run(15);
        // One global zone ≙ "one big connected component": both diff
        // modes face identical KKT sizes during measurement.
        sim.cfg.collision_mode = CollisionMode::Global;
        sim.cfg.record_tape = true;
        let meas_steps = 1;
        sim.run(meas_steps);
        let mut seed = LossGrad::zeros(&sim);
        for b in 1..=placed {
            seed.rigid_q[b][4] = 1.0;
        }
        sim.cfg.diff_mode = DiffMode::Dense;
        let t = Timer::start();
        let _ = backward(&sim, &seed);
        dense_stats.push(t.seconds() / meas_steps as f64);
        sim.cfg.diff_mode = DiffMode::Qr;
        let t = Timer::start();
        let _ = backward(&sim, &seed);
        qr_stats.push(t.seconds() / meas_steps as f64);
    }
    (dense_stats, qr_stats)
}

/// Back-compat wrapper used by benches/tests.
pub fn backprop_time(n: usize, mode: DiffMode, trials: usize) -> Stats {
    let (d, q) = backprop_time_both(n, trials);
    match mode {
        DiffMode::Dense => d,
        _ => q,
    }
}

pub fn run(args: &Args) -> Result<()> {
    let sizes = args.usize_list_or("sizes", &[100, 200, 300]);
    let trials = args.usize_or("trials", 3);
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for &n in &sizes {
        let (wofd, ours) = backprop_time_both(n, trials);
        let speedup = wofd.mean() / ours.mean().max(1e-12);
        let mut j = Json::obj();
        j.set("n", n)
            .set("wofd_mean_s", wofd.mean())
            .set("wofd_std_s", wofd.std())
            .set("ours_mean_s", ours.mean())
            .set("ours_std_s", ours.std())
            .set("speedup", speedup);
        jrows.push(j);
        rows.push(vec![
            n.to_string(),
            format!("{:.4}s ± {:.4}s", wofd.mean(), wofd.std()),
            format!("{:.4}s ± {:.4}s", ours.mean(), ours.std()),
            format!("{speedup:.2}x"),
        ]);
    }
    print_table(
        "Table 2: backprop seconds/step — W/o FD (dense KKT) vs ours (QR)",
        &["# of cubes", "W/o FD", "Ours", "speedup"],
        &rows,
    );
    let mut out = Json::obj();
    out.set("experiment", "table2").set("rows", Json::Arr(jrows));
    dump_json("table2_fd", &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_beats_dense_on_connected_stacks() {
        let (dense, qr) = backprop_time_both(24, 1);
        assert!(qr.mean() < dense.mean(), "qr {} vs dense {}", qr.mean(), dense.mean());
    }
}
