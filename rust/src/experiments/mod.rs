//! Experiment drivers — one per table/figure of the paper's §7.
//! See DESIGN.md §5 for the experiment index (E1–E11); [`registry`]
//! lists the CLI ids. Batched drivers run their populations through
//! the batch layer — fig7 [`inverse`] and fig8 [`control`] via the
//! async [`crate::batch::pipeline::BatchPipeline`] (windowed streaming
//! + generation double-buffering, lockstep kept as the synchronous
//! fallback), fig9 [`estimation`] via lockstep
//! [`crate::batch::SceneBatch`] — and report Fig-3-style memory via
//! [`batch_memory_report`].

use crate::util::cli::Args;
use anyhow::{bail, Result};

/// (id, summary) of every registered experiment.
pub fn registry() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig3-objects", "Fig 3 top: runtime+memory vs #objects (ours vs MPM)"),
        ("fig3-scale", "Fig 3 bottom: runtime+memory vs cloth:bunny scale ratio"),
        ("table1", "Table 1: backprop s/step, global LCP vs local zones"),
        ("table2", "Table 2: backprop s/step, W/o FD vs QR fast diff"),
        ("fig5", "Fig 5/11: two-way coupling (lift + dominoes) metrics"),
        ("fig6", "Fig 6: trampoline — capsule-cloth baseline vs ours"),
        ("fig7", "Fig 7: inverse problem, gradient vs CMA-ES"),
        ("fig8", "Fig 8: learning control, ours vs DDPG"),
        ("fig9", "Fig 9: mass parameter estimation"),
        ("fig10", "Fig 10: interoperability with an external simulator"),
    ]
}

pub fn registry_help() -> String {
    registry()
        .iter()
        .map(|(id, s)| format!("  {id:<14} {s}"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Dispatch `diffsim experiment <id> ...`. With `--trace <path>`, the
/// telemetry registry is enabled and a process-wide JSONL trace sink is
/// installed for the duration of the run (every `Simulation` the driver
/// constructs inherits it with a fresh scene id); afterwards the
/// registry snapshot is written to `bench_output/telemetry_summary.json`.
pub fn run_from_cli(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(String::as_str).unwrap_or("");
    let tracing = match args.get("trace") {
        Some(path) => {
            crate::util::telemetry::enable();
            let t = crate::util::telemetry::Trace::to_file(path)
                .map_err(|e| anyhow::anyhow!("creating trace file {path}: {e}"))?;
            crate::util::telemetry::install_global_trace(Some(t));
            println!("[tracing to {path}]");
            true
        }
        None => false,
    };
    let result = match id {
        "fig3-objects" => scalability::run_objects(args),
        "fig3-scale" => scalability::run_scale(args),
        "table1" => ablation_lcp::run(args),
        "table2" => ablation_fd::run(args),
        "fig5" => coupling::run(args),
        "fig6" => trampoline::run(args),
        "fig7" => inverse::run(args),
        "fig8" => control::run(args),
        "fig9" => estimation::run(args),
        "fig10" => interop::run(args),
        other => bail!("unknown experiment '{other}'; available:\n{}", registry_help()),
    };
    if tracing {
        // Drop the global sink first (flushes once the drivers' per-sim
        // clones are gone), snapshot while still enabled, then disable.
        crate::util::telemetry::install_global_trace(None);
        dump_json("telemetry_summary", &crate::util::telemetry::summary())?;
        crate::util::telemetry::disable();
    }
    result
}

pub mod ablation_fd;
pub mod ablation_lcp;
pub mod control;
pub mod coupling;
pub mod estimation;
pub mod inverse;
pub mod interop;
pub mod scalability;
pub mod trampoline;

/// Shared table printer: fixed-width rows matching the paper's layout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let widths: Vec<usize> = header
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|r| r.get(i).map(String::len).unwrap_or(0))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(8)
        })
        .collect();
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for r in rows {
        line(r.clone());
    }
}

/// Write experiment output JSON under bench_output/.
pub fn dump_json(name: &str, j: &crate::util::json::Json) -> Result<()> {
    std::fs::create_dir_all("bench_output")?;
    let path = format!("bench_output/{name}.json");
    std::fs::write(&path, j.pretty())?;
    println!("[wrote {path}]");
    Ok(())
}

/// Fig-3-style memory block for batched experiment drivers (fig7/fig8):
/// per-category logical-byte peaks from the global
/// [`MemTracker`](crate::util::memory::MemTracker) plus process-wide
/// [`BatchArena`](crate::util::arena::BatchArena) reuse stats. Prints
/// one summary line and returns the block for the JSON dump. Call
/// `crate::util::memory::global().reset()` at the start of the driver
/// so the peaks describe this run only.
pub fn batch_memory_report(label: &str) -> crate::util::json::Json {
    use crate::util::memory::{self, fmt_bytes, MemCategory};
    let t = memory::global();
    let a = crate::util::arena::process_stats();
    println!(
        "[{label}] batch memory: peak logical {} (tape {}, contacts {}, solver {}, \
         arena-retained {}); arena reuse {}/{} takes",
        fmt_bytes(t.peak()),
        fmt_bytes(t.peak_cat(MemCategory::Tape)),
        fmt_bytes(t.peak_cat(MemCategory::Contacts)),
        fmt_bytes(t.peak_cat(MemCategory::Solver)),
        fmt_bytes(t.peak_cat(MemCategory::ArenaRetained)),
        a.hits,
        a.takes,
    );
    let mut j = crate::util::json::Json::obj();
    j.set("peak_bytes", t.peak())
        .set("tape_peak_bytes", t.peak_cat(MemCategory::Tape))
        .set("contacts_peak_bytes", t.peak_cat(MemCategory::Contacts))
        .set("solver_peak_bytes", t.peak_cat(MemCategory::Solver))
        .set("arena_retained_peak_bytes", t.peak_cat(MemCategory::ArenaRetained))
        .set("arena_takes", a.takes)
        .set("arena_hits", a.hits)
        .set("arena_hit_rate", a.hit_rate())
        .set("peak_rss_bytes", memory::peak_rss_bytes());
    j
}
