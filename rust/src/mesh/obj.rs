//! Wavefront OBJ load/save (v/f records only) so users can feed real
//! meshes (e.g. the actual Stanford bunny) to the engine.

use super::TriMesh;
use crate::math::Vec3;
use anyhow::{bail, Context, Result};

/// Parse OBJ text. Polygonal faces are fan-triangulated; `v/vt/vn` index
/// forms are accepted (only the vertex index is used). Indices may be
/// negative (relative) per the OBJ spec.
pub fn parse_obj(text: &str) -> Result<TriMesh> {
    let mut verts: Vec<Vec3> = Vec::new();
    let mut faces: Vec<[u32; 3]> = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match it.next() {
            Some("v") => {
                let mut c = [0.0f64; 3];
                for x in c.iter_mut() {
                    *x = it
                        .next()
                        .with_context(|| format!("line {}: short vertex", ln + 1))?
                        .parse()
                        .with_context(|| format!("line {}: bad vertex coord", ln + 1))?;
                }
                // `"inf"`/`"NaN"` parse as valid f64s, but a non-finite
                // vertex poisons every downstream mass/inertia/BVH
                // computation — reject it here with the line number
                // instead of letting NaNs leak into the engine.
                if !(c[0].is_finite() && c[1].is_finite() && c[2].is_finite()) {
                    bail!("line {}: non-finite vertex coordinate", ln + 1);
                }
                verts.push(Vec3::new(c[0], c[1], c[2]));
            }
            Some("f") => {
                let idxs: Vec<u32> = it
                    .map(|tok| parse_face_index(tok, verts.len(), ln + 1))
                    .collect::<Result<_>>()?;
                if idxs.len() < 3 {
                    bail!("line {}: face with <3 vertices", ln + 1);
                }
                for k in 1..idxs.len() - 1 {
                    faces.push([idxs[0], idxs[k], idxs[k + 1]]);
                }
            }
            _ => {} // vn, vt, o, g, s, usemtl, mtllib ... ignored
        }
    }
    let mesh = TriMesh { verts, faces };
    mesh.validate().map_err(|e| anyhow::anyhow!("invalid obj mesh: {e}"))?;
    Ok(mesh)
}

fn parse_face_index(tok: &str, n_verts: usize, line: usize) -> Result<u32> {
    let first = tok.split('/').next().unwrap_or("");
    let i: i64 = first.parse().with_context(|| format!("line {line}: bad face index '{tok}'"))?;
    let idx = if i > 0 {
        i - 1
    } else if i < 0 {
        n_verts as i64 + i
    } else {
        bail!("line {line}: obj indices are 1-based, got 0");
    };
    if idx < 0 || idx as usize >= n_verts {
        bail!("line {line}: face index {i} out of range ({n_verts} verts)");
    }
    Ok(idx as u32)
}

/// Serialize to OBJ text.
pub fn write_obj(mesh: &TriMesh) -> String {
    let mut s = String::with_capacity(mesh.n_verts() * 32 + mesh.n_faces() * 16);
    s.push_str("# diffsim mesh\n");
    for v in &mesh.verts {
        s.push_str(&format!("v {} {} {}\n", v.x, v.y, v.z));
    }
    for f in &mesh.faces {
        s.push_str(&format!("f {} {} {}\n", f[0] + 1, f[1] + 1, f[2] + 1));
    }
    s
}

pub fn load_obj(path: &std::path::Path) -> Result<TriMesh> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading obj {}", path.display()))?;
    parse_obj(&text)
}

pub fn save_obj(path: &std::path::Path, mesh: &TriMesh) -> Result<()> {
    std::fs::write(path, write_obj(mesh))
        .with_context(|| format!("writing obj {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::primitives::{icosphere, unit_box};

    #[test]
    fn roundtrip_box() {
        let m = unit_box();
        let text = write_obj(&m);
        let m2 = parse_obj(&text).unwrap();
        assert_eq!(m.n_verts(), m2.n_verts());
        assert_eq!(m.faces, m2.faces);
        for (a, b) in m.verts.iter().zip(&m2.verts) {
            assert!((*a - *b).norm() < 1e-12);
        }
    }

    #[test]
    fn parses_slash_forms_and_quads() {
        let text = "v 0 0 0\nv 1 0 0\nv 1 1 0\nv 0 1 0\nf 1/1/1 2/2/2 3/3/3 4/4/4\n";
        let m = parse_obj(text).unwrap();
        assert_eq!(m.n_verts(), 4);
        assert_eq!(m.n_faces(), 2); // fan-triangulated quad
    }

    #[test]
    fn negative_indices() {
        let text = "v 0 0 0\nv 1 0 0\nv 0 1 0\nf -3 -2 -1\n";
        let m = parse_obj(text).unwrap();
        assert_eq!(m.faces, vec![[0, 1, 2]]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_obj("f 1 2 3\n").is_err()); // no verts
        assert!(parse_obj("v 0 0\n").is_err()); // short vertex
        assert!(parse_obj("v 0 0 0\nf 0 1 2\n").is_err()); // 0-based
    }

    #[test]
    fn rejects_non_finite_coords_with_line_context() {
        // Rust's f64 parser accepts these spellings, so without the
        // explicit gate they'd flow straight into mass properties.
        for bad in ["inf", "-inf", "NaN", "infinity"] {
            let text = format!("v 0 0 0\nv 1 {bad} 0\nv 0 1 0\nf 1 2 3\n");
            let err = parse_obj(&text).expect_err(bad);
            let msg = format!("{err:#}");
            assert!(msg.contains("line 2"), "error should name the line: {msg}");
            assert!(msg.contains("non-finite"), "error should say why: {msg}");
        }
    }

    #[test]
    fn roundtrip_preserves_volume() {
        use crate::mesh::mass::mass_properties;
        let m = icosphere(1.0, 2);
        let m2 = parse_obj(&write_obj(&m)).unwrap();
        let (p, p2) = (mass_properties(&m, 1.0), mass_properties(&m2, 1.0));
        assert!((p.mass - p2.mass).abs() < 1e-9);
    }
}
