//! Triangle-mesh representation — the paper's core object representation
//! (§3: "we adopt meshes as a general representation of objects"):
//! the indexed [`TriMesh`], generator shapes ([`primitives`]), OBJ I/O
//! ([`obj`]), inertia/mass integrals ([`mass`]), and edge/adjacency
//! queries ([`topology`]).
pub mod mass;
pub mod obj;
pub mod primitives;
pub mod topology;

use crate::math::Vec3;

/// Indexed triangle mesh.
#[derive(Clone, Debug, Default)]
pub struct TriMesh {
    pub verts: Vec<Vec3>,
    pub faces: Vec<[u32; 3]>,
}

impl TriMesh {
    pub fn new(verts: Vec<Vec3>, faces: Vec<[u32; 3]>) -> TriMesh {
        let m = TriMesh { verts, faces };
        debug_assert!(m.validate().is_ok(), "{:?}", m.validate());
        m
    }

    pub fn n_verts(&self) -> usize {
        self.verts.len()
    }

    pub fn n_faces(&self) -> usize {
        self.faces.len()
    }

    /// Structural sanity: indices in range, no degenerate index triples.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.verts.len() as u32;
        for (fi, f) in self.faces.iter().enumerate() {
            for &v in f {
                if v >= n {
                    return Err(format!("face {fi} references vertex {v} >= {n}"));
                }
            }
            if f[0] == f[1] || f[1] == f[2] || f[0] == f[2] {
                return Err(format!("face {fi} is degenerate: {f:?}"));
            }
        }
        Ok(())
    }

    /// Face normal (unnormalized = 2·area·n̂).
    pub fn face_normal_raw(&self, f: usize) -> Vec3 {
        let [a, b, c] = self.faces[f];
        let (pa, pb, pc) =
            (self.verts[a as usize], self.verts[b as usize], self.verts[c as usize]);
        (pb - pa).cross(pc - pa)
    }

    pub fn face_normal(&self, f: usize) -> Vec3 {
        self.face_normal_raw(f).normalized()
    }

    pub fn face_area(&self, f: usize) -> f64 {
        0.5 * self.face_normal_raw(f).norm()
    }

    pub fn face_centroid(&self, f: usize) -> Vec3 {
        let [a, b, c] = self.faces[f];
        (self.verts[a as usize] + self.verts[b as usize] + self.verts[c as usize]) / 3.0
    }

    pub fn surface_area(&self) -> f64 {
        (0..self.faces.len()).map(|f| self.face_area(f)).sum()
    }

    /// Axis-aligned bounds (min, max).
    pub fn bounds(&self) -> (Vec3, Vec3) {
        let mut lo = Vec3::splat(f64::INFINITY);
        let mut hi = Vec3::splat(f64::NEG_INFINITY);
        for v in &self.verts {
            lo = lo.min_c(*v);
            hi = hi.max_c(*v);
        }
        (lo, hi)
    }

    /// Translate all vertices.
    pub fn translated(&self, d: Vec3) -> TriMesh {
        TriMesh {
            verts: self.verts.iter().map(|&v| v + d).collect(),
            faces: self.faces.clone(),
        }
    }

    /// Uniformly scale about the origin.
    pub fn scaled(&self, s: f64) -> TriMesh {
        TriMesh {
            verts: self.verts.iter().map(|&v| v * s).collect(),
            faces: self.faces.clone(),
        }
    }

    /// Non-uniform scale about the origin.
    pub fn scaled3(&self, s: Vec3) -> TriMesh {
        TriMesh {
            verts: self
                .verts
                .iter()
                .map(|&v| Vec3::new(v.x * s.x, v.y * s.y, v.z * s.z))
                .collect(),
            faces: self.faces.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use primitives::unit_box;

    #[test]
    fn box_mesh_is_valid_closed_surface() {
        let m = unit_box();
        assert_eq!(m.n_verts(), 8);
        assert_eq!(m.n_faces(), 12);
        assert!(m.validate().is_ok());
        // Surface area of unit cube = 6.
        assert!((m.surface_area() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn bounds_and_transforms() {
        let m = unit_box();
        let (lo, hi) = m.bounds();
        assert_eq!(lo, Vec3::splat(-0.5));
        assert_eq!(hi, Vec3::splat(0.5));
        let t = m.translated(Vec3::new(1.0, 0.0, 0.0)).scaled(2.0);
        let (lo2, hi2) = t.bounds();
        assert_eq!(lo2, Vec3::new(1.0, -1.0, -1.0));
        assert_eq!(hi2, Vec3::new(3.0, 1.0, 1.0));
    }

    #[test]
    fn validate_catches_bad_indices() {
        let bad = TriMesh { verts: vec![Vec3::default(); 2], faces: vec![[0, 1, 5]] };
        assert!(bad.validate().is_err());
        let degen = TriMesh { verts: vec![Vec3::default(); 3], faces: vec![[0, 1, 1]] };
        assert!(degen.validate().is_err());
    }

    #[test]
    fn outward_normals_for_box() {
        let m = unit_box();
        for f in 0..m.n_faces() {
            let n = m.face_normal(f);
            let c = m.face_centroid(f);
            // Outward: normal points away from the center (origin).
            assert!(n.dot(c) > 0.0, "face {f} normal {n:?} centroid {c:?}");
        }
    }
}
