//! Procedural mesh primitives. The paper's scenes use cubes, cloth grids,
//! sticks (cylinders), a marble (sphere), dominoes (thin boxes), and two
//! "complex" figurines (bunny, armadillo). The figurines here are
//! procedural stand-ins (DESIGN.md §6 substitutions): nonconvex,
//! irregular genus-0 meshes built by displacing icospheres — experiments
//! only rely on "complex nonconvex mesh with many vertices".

use super::TriMesh;
use crate::math::Vec3;
use crate::util::rng::Pcg32;

/// Axis-aligned box centered at the origin with half-extents `h`.
pub fn box_mesh(h: Vec3) -> TriMesh {
    let verts = vec![
        Vec3::new(-h.x, -h.y, -h.z),
        Vec3::new(h.x, -h.y, -h.z),
        Vec3::new(h.x, h.y, -h.z),
        Vec3::new(-h.x, h.y, -h.z),
        Vec3::new(-h.x, -h.y, h.z),
        Vec3::new(h.x, -h.y, h.z),
        Vec3::new(h.x, h.y, h.z),
        Vec3::new(-h.x, h.y, h.z),
    ];
    // CCW when viewed from outside.
    let faces = vec![
        [0, 2, 1],
        [0, 3, 2], // z = -h
        [4, 5, 6],
        [4, 6, 7], // z = +h
        [0, 1, 5],
        [0, 5, 4], // y = -h
        [3, 6, 2],
        [3, 7, 6], // y = +h
        [0, 7, 3],
        [0, 4, 7], // x = -h
        [1, 2, 6],
        [1, 6, 5], // x = +h
    ];
    TriMesh::new(verts, faces)
}

/// Unit cube (edge length 1) centered at the origin.
pub fn unit_box() -> TriMesh {
    box_mesh(Vec3::splat(0.5))
}

/// Icosphere with the given radius and subdivision level (0 = icosahedron,
/// 20 faces; each level ×4).
pub fn icosphere(radius: f64, subdivisions: usize) -> TriMesh {
    let t = (1.0 + 5.0f64.sqrt()) / 2.0;
    let mut verts = vec![
        Vec3::new(-1.0, t, 0.0),
        Vec3::new(1.0, t, 0.0),
        Vec3::new(-1.0, -t, 0.0),
        Vec3::new(1.0, -t, 0.0),
        Vec3::new(0.0, -1.0, t),
        Vec3::new(0.0, 1.0, t),
        Vec3::new(0.0, -1.0, -t),
        Vec3::new(0.0, 1.0, -t),
        Vec3::new(t, 0.0, -1.0),
        Vec3::new(t, 0.0, 1.0),
        Vec3::new(-t, 0.0, -1.0),
        Vec3::new(-t, 0.0, 1.0),
    ];
    for v in &mut verts {
        *v = v.normalized();
    }
    let mut faces: Vec<[u32; 3]> = vec![
        [0, 11, 5],
        [0, 5, 1],
        [0, 1, 7],
        [0, 7, 10],
        [0, 10, 11],
        [1, 5, 9],
        [5, 11, 4],
        [11, 10, 2],
        [10, 7, 6],
        [7, 1, 8],
        [3, 9, 4],
        [3, 4, 2],
        [3, 2, 6],
        [3, 6, 8],
        [3, 8, 9],
        [4, 9, 5],
        [2, 4, 11],
        [6, 2, 10],
        [8, 6, 7],
        [9, 8, 1],
    ];
    for _ in 0..subdivisions {
        let mut midpoint_cache: std::collections::HashMap<(u32, u32), u32> =
            std::collections::HashMap::new();
        let mut new_faces = Vec::with_capacity(faces.len() * 4);
        let mut midpoint = |a: u32, b: u32, verts: &mut Vec<Vec3>| -> u32 {
            let key = if a < b { (a, b) } else { (b, a) };
            *midpoint_cache.entry(key).or_insert_with(|| {
                let m = (verts[a as usize] + verts[b as usize]).normalized();
                verts.push(m);
                (verts.len() - 1) as u32
            })
        };
        for [a, b, c] in faces {
            let ab = midpoint(a, b, &mut verts);
            let bc = midpoint(b, c, &mut verts);
            let ca = midpoint(c, a, &mut verts);
            new_faces.push([a, ab, ca]);
            new_faces.push([b, bc, ab]);
            new_faces.push([c, ca, bc]);
            new_faces.push([ab, bc, ca]);
        }
        faces = new_faces;
    }
    for v in &mut verts {
        *v = *v * radius;
    }
    TriMesh::new(verts, faces)
}

/// Rectangular cloth grid in the XZ plane at y = 0: `(nx+1)·(nz+1)`
/// vertices spanning `size_x × size_z`, centered at the origin.
/// Returns the mesh; vertex (i, k) has index `i·(nz+1) + k`.
pub fn cloth_grid(nx: usize, nz: usize, size_x: f64, size_z: f64) -> TriMesh {
    assert!(nx >= 1 && nz >= 1);
    let mut verts = Vec::with_capacity((nx + 1) * (nz + 1));
    for i in 0..=nx {
        for k in 0..=nz {
            verts.push(Vec3::new(
                size_x * (i as f64 / nx as f64 - 0.5),
                0.0,
                size_z * (k as f64 / nz as f64 - 0.5),
            ));
        }
    }
    let idx = |i: usize, k: usize| (i * (nz + 1) + k) as u32;
    let mut faces = Vec::with_capacity(nx * nz * 2);
    for i in 0..nx {
        for k in 0..nz {
            // Alternate the diagonal for isotropy.
            if (i + k) % 2 == 0 {
                faces.push([idx(i, k), idx(i + 1, k), idx(i + 1, k + 1)]);
                faces.push([idx(i, k), idx(i + 1, k + 1), idx(i, k + 1)]);
            } else {
                faces.push([idx(i, k), idx(i + 1, k), idx(i, k + 1)]);
                faces.push([idx(i + 1, k), idx(i + 1, k + 1), idx(i, k + 1)]);
            }
        }
    }
    TriMesh::new(verts, faces)
}

/// Closed cylinder along +Y with given radius/height ("stick" manipulator
/// in Fig. 8a). `segments` around the circumference.
pub fn cylinder(radius: f64, height: f64, segments: usize) -> TriMesh {
    assert!(segments >= 3);
    let mut verts = Vec::new();
    let h2 = height / 2.0;
    for ring in [-h2, h2] {
        for s in 0..segments {
            let a = 2.0 * std::f64::consts::PI * s as f64 / segments as f64;
            verts.push(Vec3::new(radius * a.cos(), ring, radius * a.sin()));
        }
    }
    let bottom_center = verts.len() as u32;
    verts.push(Vec3::new(0.0, -h2, 0.0));
    let top_center = verts.len() as u32;
    verts.push(Vec3::new(0.0, h2, 0.0));
    let mut faces = Vec::new();
    let n = segments as u32;
    for s in 0..n {
        let s1 = (s + 1) % n;
        // Side quad (bottom ring s..s1, top ring n+s..n+s1).
        faces.push([s, n + s, n + s1]);
        faces.push([s, n + s1, s1]);
        // Caps (outward: −y for bottom, +y for top).
        faces.push([bottom_center, s, s1]);
        faces.push([top_center, n + s1, n + s]);
    }
    TriMesh::new(verts, faces)
}

/// Procedural "bunny": icosphere displaced by deterministic lumpy noise +
/// two ear protrusions. Nonconvex, irregular, genus 0.
pub fn bunny(radius: f64, subdivisions: usize) -> TriMesh {
    figurine(radius, subdivisions, 0xb0_b0, &[(Vec3::new(0.35, 0.9, 0.0), 0.45, 1.1), (
        Vec3::new(-0.35, 0.9, 0.0),
        0.45,
        1.1,
    )])
}

/// Procedural "armadillo": icosphere with four limb bumps and a tail.
pub fn armadillo(radius: f64, subdivisions: usize) -> TriMesh {
    figurine(
        radius,
        subdivisions,
        0xa4_a4,
        &[
            (Vec3::new(0.7, -0.6, 0.0), 0.5, 0.8),
            (Vec3::new(-0.7, -0.6, 0.0), 0.5, 0.8),
            (Vec3::new(0.6, 0.55, 0.3), 0.45, 0.7),
            (Vec3::new(-0.6, 0.55, 0.3), 0.45, 0.7),
            (Vec3::new(0.0, -0.3, -0.95), 0.4, 0.9),
        ],
    )
}

fn figurine(
    radius: f64,
    subdivisions: usize,
    seed: u64,
    bumps: &[(Vec3, f64, f64)],
) -> TriMesh {
    let mut m = icosphere(1.0, subdivisions);
    let mut rng = Pcg32::new(seed);
    // Low-frequency lumpy displacement (deterministic per-seed harmonics).
    let h: Vec<(Vec3, f64, f64)> = (0..6)
        .map(|_| {
            (
                Vec3::new(rng.normal(), rng.normal(), rng.normal()).normalized(),
                rng.range(1.0, 3.0),
                rng.range(0.03, 0.08),
            )
        })
        .collect();
    for v in &mut m.verts {
        let dir = v.normalized();
        let mut disp = 0.0;
        for (axis, freq, amp) in &h {
            disp += amp * (freq * dir.dot(*axis) * 3.0).sin();
        }
        for (center, width, amp) in bumps {
            let d2 = (dir - center.normalized()).norm2();
            disp += amp * (-d2 / (width * width)).exp();
        }
        *v = dir * (1.0 + disp);
    }
    m = m.scaled(radius);
    debug_assert!(m.validate().is_ok());
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::mass::mass_properties;

    #[test]
    fn icosphere_vertices_on_sphere() {
        let m = icosphere(2.0, 2);
        assert_eq!(m.n_faces(), 20 * 16);
        for v in &m.verts {
            assert!((v.norm() - 2.0).abs() < 1e-12);
        }
        // Surface area approaches 4πr² from below.
        let area = m.surface_area();
        let exact = 4.0 * std::f64::consts::PI * 4.0;
        assert!(area < exact && area > 0.95 * exact, "area={area} exact={exact}");
    }

    #[test]
    fn cloth_grid_counts_and_flatness() {
        let m = cloth_grid(8, 5, 2.0, 1.0);
        assert_eq!(m.n_verts(), 9 * 6);
        assert_eq!(m.n_faces(), 8 * 5 * 2);
        for v in &m.verts {
            assert_eq!(v.y, 0.0);
        }
        // Total area = size_x * size_z.
        assert!((m.surface_area() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn cylinder_is_closed_and_right_volume() {
        let m = cylinder(0.5, 2.0, 24);
        let props = mass_properties(&m, 1.0);
        let exact = std::f64::consts::PI * 0.25 * 2.0;
        assert!((props.mass - exact).abs() / exact < 0.02, "vol={} exact={exact}", props.mass);
    }

    #[test]
    fn figurines_are_valid_and_nonconvex() {
        for m in [bunny(1.0, 2), armadillo(1.0, 2)] {
            assert!(m.validate().is_ok());
            let props = mass_properties(&m, 1.0);
            assert!(props.mass > 0.1);
            // Nonconvex: some vertex is much closer to centroid than max.
            let c = props.com;
            let ds: Vec<f64> = m.verts.iter().map(|v| (*v - c).norm()).collect();
            let (mn, mx) = ds.iter().fold((f64::MAX, 0.0f64), |(a, b), &d| (a.min(d), b.max(d)));
            assert!(mx / mn > 1.3, "figurine looks too spherical: {mn} {mx}");
        }
    }

    #[test]
    fn box_volume_via_mass_properties() {
        let m = box_mesh(Vec3::new(0.5, 1.0, 1.5));
        let p = mass_properties(&m, 2.0);
        assert!((p.mass - 2.0 * 1.0 * 2.0 * 3.0).abs() < 1e-9);
        assert!(p.com.norm() < 1e-12);
    }
}
