//! Mesh topology: unique edges, edge→face adjacency, and bending pairs
//! (the two vertices opposite a shared edge) for the cloth bending model.

use super::TriMesh;
// BTreeMap (not HashMap): topology construction orders `edges`, which
// downstream becomes cloth spring/bend element order — part of the
// deterministic dispatch surface the `hash-iter` xtask lint protects.
// (The map is lookup-only today, so this is belt-and-braces, not a fix
// of an observed divergence: `edges` is appended in face-scan order.)
use std::collections::BTreeMap;

/// A unique undirected edge with its incident faces.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub v: [u32; 2],
    /// Incident faces (u32::MAX if boundary).
    pub faces: [u32; 2],
}

/// Bending element: two triangles sharing edge (v0, v1) with opposite
/// vertices (v2, v3).
#[derive(Clone, Copy, Debug)]
pub struct BendPair {
    pub edge: [u32; 2],
    pub opp: [u32; 2],
}

#[derive(Clone, Debug, Default)]
pub struct Topology {
    pub edges: Vec<Edge>,
    pub bend_pairs: Vec<BendPair>,
}

pub fn build_topology(mesh: &TriMesh) -> Topology {
    let mut edge_map: BTreeMap<(u32, u32), usize> = BTreeMap::new();
    let mut edges: Vec<Edge> = Vec::new();
    for (fi, f) in mesh.faces.iter().enumerate() {
        for k in 0..3 {
            let (a, b) = (f[k], f[(k + 1) % 3]);
            let key = if a < b { (a, b) } else { (b, a) };
            match edge_map.get(&key) {
                Some(&ei) => {
                    let e = &mut edges[ei];
                    if e.faces[1] == u32::MAX {
                        e.faces[1] = fi as u32;
                    }
                }
                None => {
                    edge_map.insert(key, edges.len());
                    edges.push(Edge { v: [key.0, key.1], faces: [fi as u32, u32::MAX] });
                }
            }
        }
    }
    // Bending pairs from interior edges.
    let mut bend_pairs = Vec::new();
    for e in &edges {
        if e.faces[1] == u32::MAX {
            continue;
        }
        let opp = |fi: u32| -> u32 {
            let f = mesh.faces[fi as usize];
            *f.iter().find(|&&v| v != e.v[0] && v != e.v[1]).expect("triangle has 3 verts")
        };
        bend_pairs.push(BendPair { edge: e.v, opp: [opp(e.faces[0]), opp(e.faces[1])] });
    }
    Topology { edges, bend_pairs }
}

/// Number of boundary edges (for validation: closed meshes have zero).
pub fn boundary_edge_count(topo: &Topology) -> usize {
    topo.edges.iter().filter(|e| e.faces[1] == u32::MAX).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::primitives::{cloth_grid, icosphere, unit_box};

    #[test]
    fn cube_euler_formula() {
        let m = unit_box();
        let t = build_topology(&m);
        // V - E + F = 2 for genus 0: 8 - 18 + 12 = 2.
        assert_eq!(t.edges.len(), 18);
        assert_eq!(boundary_edge_count(&t), 0);
        assert_eq!(t.bend_pairs.len(), 18);
    }

    #[test]
    fn icosphere_closed() {
        let m = icosphere(1.0, 2);
        let t = build_topology(&m);
        assert_eq!(boundary_edge_count(&t), 0);
        let (v, e, f) = (m.n_verts() as i64, t.edges.len() as i64, m.n_faces() as i64);
        assert_eq!(v - e + f, 2);
    }

    #[test]
    fn cloth_grid_boundary() {
        let m = cloth_grid(4, 3, 1.0, 1.0);
        let t = build_topology(&m);
        // Boundary edges = perimeter segments = 2*(4+3) = 14.
        assert_eq!(boundary_edge_count(&t), 14);
        // Interior edges have valid bend pairs.
        for bp in &t.bend_pairs {
            assert_ne!(bp.opp[0], bp.opp[1]);
            assert!(!bp.edge.contains(&bp.opp[0]));
            assert!(!bp.edge.contains(&bp.opp[1]));
        }
    }

    #[test]
    fn every_interior_edge_has_two_distinct_faces() {
        let m = icosphere(1.0, 1);
        let t = build_topology(&m);
        for e in &t.edges {
            assert_ne!(e.faces[0], e.faces[1]);
        }
    }

    /// Edge and bend-pair *order* must be identical across repeated
    /// builds: cloth assembles its spring and bending elements in
    /// `edges` order, so any iteration-order nondeterminism here would
    /// reorder force accumulation and break bitwise reproducibility.
    #[test]
    fn topology_order_is_run_to_run_deterministic() {
        for mesh in [unit_box(), icosphere(1.0, 2), cloth_grid(5, 4, 1.0, 1.0)] {
            let reference = format!("{:?}", build_topology(&mesh));
            for run in 0..16 {
                let again = format!("{:?}", build_topology(&mesh));
                assert_eq!(again, reference, "topology order diverged on run {run}");
            }
        }
    }
}
