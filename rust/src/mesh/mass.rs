//! Mass properties of closed triangle meshes by signed-tetrahedron volume
//! integrals (Mirtich-style): mass, center of mass, and the inertia tensor
//! I′ about the COM — the ingredients of the paper's generalized mass
//! matrix M̂ (Appendix A).

use super::TriMesh;
use crate::math::{Mat3, Vec3};

#[derive(Clone, Copy, Debug)]
pub struct MassProperties {
    pub mass: f64,
    pub com: Vec3,
    /// Inertia tensor about the COM, in the mesh's own frame.
    pub inertia: Mat3,
}

/// Integrate over signed tetrahedra (origin, v0, v1, v2) per face.
/// Requires a closed, consistently-oriented (outward CCW) mesh.
pub fn mass_properties(mesh: &TriMesh, density: f64) -> MassProperties {
    let mut volume = 0.0;
    let mut com = Vec3::default();
    // Second moments accumulated about the origin.
    let (mut ixx, mut iyy, mut izz, mut ixy, mut ixz, mut iyz) =
        (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
    for f in &mesh.faces {
        let a = mesh.verts[f[0] as usize];
        let b = mesh.verts[f[1] as usize];
        let c = mesh.verts[f[2] as usize];
        let det = a.dot(b.cross(c)); // 6 × signed tet volume
        volume += det / 6.0;
        com += (a + b + c) * (det / 24.0);
        // Canonical tetrahedron second-moment integrals (about origin):
        // ∫ x² dV over tet = det/60 · (ax²+bx²+cx² + ax·bx + ax·cx + bx·cx)
        let sq = |pa: f64, pb: f64, pc: f64| {
            pa * pa + pb * pb + pc * pc + pa * pb + pa * pc + pb * pc
        };
        let mix = |pa: f64, pb: f64, pc: f64, qa: f64, qb: f64, qc: f64| {
            2.0 * (pa * qa + pb * qb + pc * qc)
                + pa * qb
                + pa * qc
                + pb * qa
                + pb * qc
                + pc * qa
                + pc * qb
        };
        ixx += det / 60.0 * sq(a.x, b.x, c.x);
        iyy += det / 60.0 * sq(a.y, b.y, c.y);
        izz += det / 60.0 * sq(a.z, b.z, c.z);
        ixy += det / 120.0 * mix(a.x, b.x, c.x, a.y, b.y, c.y);
        ixz += det / 120.0 * mix(a.x, b.x, c.x, a.z, b.z, c.z);
        iyz += det / 120.0 * mix(a.y, b.y, c.y, a.z, b.z, c.z);
    }
    assert!(volume > 1e-12, "mass_properties: mesh not closed/oriented (volume={volume})");
    let mass = density * volume;
    let com = com / volume;
    // Inertia about origin: I = ρ [ ∫(y²+z²), -∫xy, ... ]
    let i_origin = Mat3::new([
        [density * (iyy + izz), -density * ixy, -density * ixz],
        [-density * ixy, density * (ixx + izz), -density * iyz],
        [-density * ixz, -density * iyz, density * (ixx + iyy)],
    ]);
    // Parallel axis: shift to COM.
    let d = com;
    let shift = Mat3::new([
        [d.y * d.y + d.z * d.z, -d.x * d.y, -d.x * d.z],
        [-d.x * d.y, d.x * d.x + d.z * d.z, -d.y * d.z],
        [-d.x * d.z, -d.y * d.z, d.x * d.x + d.y * d.y],
    ]) * mass;
    let inertia = i_origin - shift;
    MassProperties { mass, com, inertia }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::primitives::{box_mesh, icosphere, unit_box};
    use crate::util::quick::quick;

    #[test]
    fn unit_cube_analytic() {
        let p = mass_properties(&unit_box(), 3.0);
        assert!((p.mass - 3.0).abs() < 1e-12);
        assert!(p.com.norm() < 1e-12);
        // Cube inertia: m/12 (a²+b²) = 3/12 * 2 * 0.5... for unit cube
        // I = m/6 for a unit cube? I = m (b²+c²)/12 = 3·(1+1)/12 = 0.5.
        let want = 3.0 * (1.0 + 1.0) / 12.0;
        for i in 0..3 {
            assert!((p.inertia.m[i][i] - want).abs() < 1e-12);
            for j in 0..3 {
                if i != j {
                    assert!(p.inertia.m[i][j].abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn sphere_analytic() {
        let r = 1.3;
        let m = icosphere(r, 3);
        let p = mass_properties(&m, 2.0);
        let vol_exact = 4.0 / 3.0 * std::f64::consts::PI * r * r * r;
        assert!((p.mass / (2.0 * vol_exact) - 1.0).abs() < 0.01, "mass={}", p.mass);
        let i_exact = 0.4 * p.mass * r * r;
        for i in 0..3 {
            assert!((p.inertia.m[i][i] / i_exact - 1.0).abs() < 0.02);
        }
    }

    #[test]
    fn translation_moves_com_keeps_inertia() {
        quick("mass-shift", 25, |g| {
            let h = Vec3::new(g.f64(0.2, 1.0), g.f64(0.2, 1.0), g.f64(0.2, 1.0));
            let d = Vec3::new(g.f64(-2.0, 2.0), g.f64(-2.0, 2.0), g.f64(-2.0, 2.0));
            let m0 = box_mesh(h);
            let m1 = m0.translated(d);
            let p0 = mass_properties(&m0, 1.0);
            let p1 = mass_properties(&m1, 1.0);
            assert!((p0.mass - p1.mass).abs() < 1e-9);
            assert!((p1.com - (p0.com + d)).norm() < 1e-9);
            assert!((p1.inertia - p0.inertia).fro() < 1e-8);
        });
    }

    #[test]
    fn box_inertia_formula() {
        let (a, b, c) = (0.8, 1.4, 2.2); // full extents
        let m = box_mesh(Vec3::new(a / 2.0, b / 2.0, c / 2.0));
        let p = mass_properties(&m, 1.0);
        let mass = a * b * c;
        let want = [
            mass * (b * b + c * c) / 12.0,
            mass * (a * a + c * c) / 12.0,
            mass * (a * a + b * b) / 12.0,
        ];
        for i in 0..3 {
            assert!((p.inertia.m[i][i] - want[i]).abs() < 1e-9, "{i}");
        }
    }
}
