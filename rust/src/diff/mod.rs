//! Differentiation layer (paper §6, "Fast Differentiation").
//!
//! Gradients through a simulation step are assembled from three adjoint
//! primitives, each implemented with implicit differentiation rather than
//! unrolling the forward solver:
//!
//! * [`implicit`] — the zone projection argmin (Eq. 6): KKT implicit
//!   differentiation (Eqs. 8–9) with two backends: the dense
//!   (n+m)-system LU solve ("W/o FD" ablation) and the paper's QR
//!   acceleration (Eqs. 14–15, O(n·m²)).
//! * [`dynamics_grad`] — the implicit-Euler linear solve (Eq. 3):
//!   adjoint solve Aᵀu = ḡ.
//! * [`tape`] — per-step records the engine's backward pass walks.
//!
//! Approximations (documented in DESIGN.md §4): constraint geometry
//! (normals n, barycentric weights α) is treated as locally constant, and
//! second-order force/mass derivative terms (∂A/∂q contracted with Δq̇)
//! are dropped — the same Gauss–Newton-style treatment used by Liang et
//! al. (2019); gradients are validated against finite differences in the
//! tests at commensurate tolerances.
pub mod dynamics_grad;
pub mod implicit;
pub mod tape;
