//! Implicit differentiation of the zone projection (paper §6).
//!
//! At the solution (z*, λ*) of Eq. 6 the KKT conditions (Eq. 7) hold:
//!
//!   M̂·z* − M̂·q − Jᵀ·λ* = 0,      D(λ*)·C(z*) = 0,
//!
//! with J = ∇C(z*) (= −G∇f in the paper's notation). Linearizing f
//! around z*, the adjoint system for a loss L(z*) is
//!
//! ```text
//!   [ M̂      Jᵀ·D(λ*) ] [u_z]   [∂L/∂z]
//!   [ −J     D(C)     ] [u_λ] = [  0   ]        (paper Eq. 9)
//! ```
//!
//! and ∂L/∂q = M̂·u_z (Eq. 10). Two backends:
//!
//! * [`backward_dense`] — assemble the full (n+m)² system, LU solve:
//!   O((n+m)³). This is the "W/o FD" condition of Table 2.
//! * [`backward_qr`] — restrict to the active set, factor
//!   L⁻¹·Jₐᵀ = Q·R (L the Cholesky factor of M̂, playing the paper's
//!   √M̂⁻¹) and use Eqs. 14–15: O(n·m²).

use crate::math::dense::Mat;
use crate::solver::zone_solver::{ZoneProblem, ZoneSolution};

/// Gradient of the loss w.r.t. the zone's pre-projection coordinates q,
/// given ∂L/∂z (gradient at the resolved coordinates z*).
pub struct ZoneBackward {
    pub grad_q: Vec<f64>,
    /// Adjoint u_z (diagnostics / chained geometry gradients).
    pub u_z: Vec<f64>,
}

/// Threshold deciding which multipliers count as active.
const ACTIVE_EPS: f64 = 1e-10;

/// Dense KKT adjoint ("W/o FD", Table 2 ablation).
pub fn backward_dense(zp: &ZoneProblem, sol: &ZoneSolution, grad_z: &[f64]) -> ZoneBackward {
    let n = zp.n;
    let m = zp.constraints.len();
    assert_eq!(grad_z.len(), n);
    let jac = zp.jacobian(&sol.q);
    let c = zp.eval(&sol.q);
    // K^T layout (adjoint of the linearized KKT map):
    //   [ M̂        Jᵀ·D(λ) ] [u_z]   [g]
    //   [ −J       D(C)    ] [u_λ] = [0]
    let mut k = Mat::zeros(n + m, n + m);
    for i in 0..n {
        for j in 0..n {
            k[(i, j)] = zp.mass[(i, j)];
        }
    }
    for j in 0..m {
        for i in 0..n {
            k[(i, n + j)] = jac[(j, i)] * sol.lambda[j];
            k[(n + j, i)] = -jac[(j, i)];
        }
        // Regularized complementarity diagonal: exact KKT has C_j = 0 for
        // active rows; inactive rows (λ=0) carry D(C) to zero out u_λ.
        k[(n + j, n + j)] = c[j] - ACTIVE_EPS;
    }
    let mut rhs = vec![0.0; n + m];
    rhs[..n].copy_from_slice(grad_z);
    let u = k.lu_solve(&rhs).unwrap_or_else(|| {
        // Redundant active constraints make K singular; Tikhonov-
        // regularize (u_z stays well-defined, only u_λ is non-unique).
        let scale = (0..n).map(|i| k[(i, i)].abs()).fold(0.0, f64::max).max(1.0);
        let mut kr = k.clone();
        for i in 0..n + m {
            kr[(i, i)] += 1e-10 * scale * if i < n { 1.0 } else { -1.0 };
        }
        kr.lu_solve(&rhs).unwrap_or_else(|| vec![0.0; n + m])
    });
    let u_z = u[..n].to_vec();
    let grad_q = zp.mass.matvec(&u_z);
    ZoneBackward { grad_q, u_z }
}

/// QR-accelerated adjoint (the paper's fast differentiation, Eqs. 14–15).
///
/// Active-set reduction: rows with λ⭑ ≈ 0 contribute nothing to u_z, so
/// the saddle system reduces to
///
///   M̂·u_z + Jₐᵀ·w = g,   Jₐ·u_z = 0,
///
/// solved by factoring A = L⁻¹·Jₐᵀ = Q·R (L·Lᵀ = M̂):
///
///   u_z = L⁻ᵀ·(I − Q·Qᵀ)·L⁻¹·g          (Eq. 14)
///   w   = R⁻¹·Qᵀ·L⁻¹·g                  (Eq. 15; u_λ = D(λ)⁻¹·w)
pub fn backward_qr(zp: &ZoneProblem, sol: &ZoneSolution, grad_z: &[f64]) -> ZoneBackward {
    let n = zp.n;
    assert_eq!(grad_z.len(), n);
    let active: Vec<usize> = (0..zp.constraints.len())
        .filter(|&j| sol.lambda[j] > ACTIVE_EPS)
        .collect();
    let a = active.len();
    // Cholesky of M̂ exploiting its block-diagonal structure (6×6 per
    // rigid body, 3×3 per cloth node): O(n) instead of O(n³) — perf item
    // §Perf L3-2; a dense factor dominated the QR path on large zones.
    let l = BlockChol::new(zp).expect("zone mass matrix must be SPD");
    // L⁻¹ g  (forward substitution).
    let linv_g = l.forward_sub(grad_z);
    if a == 0 {
        // No active constraints: z* = q ⇒ ∂L/∂q = g.
        let u_z = l.back_sub_t(&linv_g);
        let grad_q = zp.mass.matvec(&u_z);
        return ZoneBackward { grad_q, u_z };
    }
    let jac = zp.jacobian(&sol.q);
    // A = L⁻¹ Jₐᵀ, one block substitution per active constraint: O(n·a).
    let mut amat = Mat::zeros(n, a);
    for (col, &j) in active.iter().enumerate() {
        let jrow: Vec<f64> = (0..n).map(|i| jac[(j, i)]).collect();
        let v = l.forward_sub(&jrow);
        for i in 0..n {
            amat[(i, col)] = v[i];
        }
    }
    // Rank-revealing orthonormalization of A's columns (active sets are
    // routinely rank-deficient — e.g. four coplanar corner contacts span
    // only three directions; a blind thin QR would produce spurious
    // trailing Q columns and over-project). Modified Gram–Schmidt with
    // reorthogonalization, O(n·a·rank) — the same O(n·m²) class as the
    // paper's QR.
    let q = orthonormal_range_basis(&amat);
    // u_z = L⁻ᵀ (I − QQᵀ) L⁻¹ g
    let qt_g = q.matvec_t(&linv_g);
    let mut proj = linv_g.clone();
    let q_qtg = q.matvec(&qt_g);
    for i in 0..n {
        proj[i] -= q_qtg[i];
    }
    let u_z = l.back_sub_t(&proj);
    let grad_q = zp.mass.matvec(&u_z);
    ZoneBackward { grad_q, u_z }
}

/// Block-diagonal Cholesky of a zone's M̂: one small factor per entity.
struct BlockChol {
    /// (dof offset, lower-triangular factor) per entity block.
    blocks: Vec<(usize, Mat)>,
    n: usize,
}

impl BlockChol {
    fn new(zp: &ZoneProblem) -> Option<BlockChol> {
        let mut blocks = Vec::with_capacity(zp.entities.len());
        for (k, e) in zp.entities.iter().enumerate() {
            let off = zp.offsets[k];
            let d = e.dofs();
            let mut b = Mat::zeros(d, d);
            for i in 0..d {
                for j in 0..d {
                    b[(i, j)] = zp.mass[(off + i, off + j)];
                }
            }
            blocks.push((off, b.cholesky()?));
        }
        Some(BlockChol { blocks, n: zp.n })
    }

    /// Solve L·y = b.
    fn forward_sub(&self, b: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n];
        for (off, l) in &self.blocks {
            let d = l.rows;
            for i in 0..d {
                let mut s = b[off + i];
                for j in 0..i {
                    s -= l[(i, j)] * y[off + j];
                }
                y[off + i] = s / l[(i, i)];
            }
        }
        y
    }

    /// Solve Lᵀ·x = b.
    fn back_sub_t(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.n];
        for (off, l) in &self.blocks {
            let d = l.rows;
            for i in (0..d).rev() {
                let mut s = b[off + i];
                for j in i + 1..d {
                    s -= l[(j, i)] * x[off + j];
                }
                x[off + i] = s / l[(i, i)];
            }
        }
        x
    }
}

/// Orthonormal basis (n×r) of the column space of `a`, dropping
/// numerically dependent columns.
fn orthonormal_range_basis(a: &Mat) -> Mat {
    let n = a.rows;
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(a.cols);
    for c in 0..a.cols {
        let mut v: Vec<f64> = (0..n).map(|i| a[(i, c)]).collect();
        let orig = crate::math::dense::norm(&v);
        if orig < 1e-14 {
            continue;
        }
        for _ in 0..2 {
            for u in &cols {
                let d = crate::math::dense::dot(u, &v);
                for i in 0..n {
                    v[i] -= d * u[i];
                }
            }
        }
        let nv = crate::math::dense::norm(&v);
        if nv > 1e-10 * orig {
            for x in &mut v {
                *x /= nv;
            }
            cols.push(v);
        }
    }
    let mut q = Mat::zeros(n, cols.len());
    for (c, col) in cols.iter().enumerate() {
        for i in 0..n {
            q[(i, c)] = col[i];
        }
    }
    q
}



#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{RigidBody, System};
    use crate::collision::zones::build_zones;
    use crate::collision::{detect, surfaces_from_system};
    use crate::math::Vec3;
    use crate::mesh::primitives::{box_mesh, unit_box};
    use crate::util::quick::{assert_close, quick};

    /// Cube pushed below frozen ground: one zone, strictly active
    /// contacts — the canonical differentiable configuration.
    fn cube_on_ground(depth: f64) -> (System, ZoneProblem) {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(5.0, 0.5, 5.0)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 1.0, 0.0)),
        );
        let mut rigid_q: Vec<[f64; 6]> = sys.rigids.iter().map(|b| b.q).collect();
        rigid_q[1][4] = 0.5 - depth;
        let x1: Vec<Vec<Vec3>> = (0..2)
            .map(|b| {
                let mut tmp = sys.rigids[b].clone();
                tmp.q = rigid_q[b];
                tmp.world_verts()
            })
            .collect();
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 1);
        let zp = ZoneProblem::build(&sys, &zones[0], &rigid_q, &[], 1e-3);
        (sys, zp)
    }

    /// Finite-difference dz/dq contracted with grad_z.
    fn fd_grad_q(zp: &ZoneProblem, grad_z: &[f64], h: f64) -> Vec<f64> {
        let mut out = vec![0.0; zp.n];
        for k in 0..zp.n {
            let mut zp_p = clone_problem(zp);
            zp_p.q0[k] += h;
            let mut zp_m = clone_problem(zp);
            zp_m.q0[k] -= h;
            let zp_sol = zp_p.solve();
            let zm_sol = zp_m.solve();
            let mut s = 0.0;
            for i in 0..zp.n {
                s += grad_z[i] * (zp_sol.q[i] - zm_sol.q[i]) / (2.0 * h);
            }
            out[k] = s;
        }
        out
    }

    fn clone_problem(zp: &ZoneProblem) -> ZoneProblem {
        ZoneProblem {
            entities: zp.entities.clone(),
            offsets: zp.offsets.clone(),
            n: zp.n,
            q0: zp.q0.clone(),
            mass: zp.mass.clone(),
            constraints: zp.constraints.clone(),
            soa: zp.soa.clone(),
            warm_lambda: zp.warm_lambda.clone(),
        }
    }

    #[test]
    fn dense_backward_matches_finite_differences() {
        let (_sys, zp) = cube_on_ground(0.2);
        let sol = zp.solve();
        assert!(sol.converged);
        let mut grad_z = vec![0.0; zp.n];
        // Loss = resolved y translation of the cube.
        let off = zp.offsets[0];
        grad_z[off + 4] = 1.0;
        let bw = backward_dense(&zp, &sol, &grad_z);
        let fd = fd_grad_q(&zp, &grad_z, 1e-6);
        assert_close(&bw.grad_q, &fd, 1e-4, 5e-3, "dense vs fd");
    }

    #[test]
    fn qr_backward_matches_dense() {
        quick("qr-vs-dense", 20, |g| {
            let depth = g.f64(0.05, 0.3);
            let (_sys, zp) = cube_on_ground(depth);
            let sol = zp.solve();
            assert!(sol.converged);
            let grad_z = g.vec_normal(zp.n);
            let d = backward_dense(&zp, &sol, &grad_z);
            let q = backward_qr(&zp, &sol, &grad_z);
            assert_close(&q.grad_q, &d.grad_q, 1e-6, 1e-5, "qr vs dense");
        });
    }

    #[test]
    fn qr_backward_matches_finite_differences() {
        let (_sys, zp) = cube_on_ground(0.15);
        let sol = zp.solve();
        let mut grad_z = vec![0.0; zp.n];
        let off = zp.offsets[0];
        grad_z[off + 3] = 0.7; // x translation
        grad_z[off + 4] = 1.0; // y translation
        let bw = backward_qr(&zp, &sol, &grad_z);
        let fd = fd_grad_q(&zp, &grad_z, 1e-6);
        assert_close(&bw.grad_q, &fd, 1e-4, 5e-3, "qr vs fd");
    }

    #[test]
    fn no_contact_passes_gradient_through() {
        // Zone with no active constraints: z* = q, so ∂L/∂q = ∂L/∂z.
        let (_sys, mut zp) = cube_on_ground(0.1);
        zp.q0[zp.offsets[0] + 4] = 1.5; // lift out of contact
        let sol = zp.solve();
        assert!(sol.lambda.iter().all(|&l| l < 1e-9));
        let grad_z: Vec<f64> = (0..zp.n).map(|i| (i as f64 * 0.3).sin()).collect();
        let bw = backward_qr(&zp, &sol, &grad_z);
        assert_close(&bw.grad_q, &grad_z, 1e-9, 1e-9, "identity gradient");
    }

    #[test]
    fn blocked_direction_gradient_vanishes() {
        // With the cube resting on the ground and loss = y position,
        // perturbing q's y (pushing deeper) must NOT change z* (the
        // ground blocks it): the normal component of the gradient maps
        // to ~0, while tangential (x, z) gradients pass through.
        let (_sys, zp) = cube_on_ground(0.2);
        let sol = zp.solve();
        let off = zp.offsets[0];
        let mut grad_z = vec![0.0; zp.n];
        grad_z[off + 4] = 1.0;
        let bw = backward_qr(&zp, &sol, &grad_z);
        assert!(bw.grad_q[off + 4].abs() < 1e-6, "normal grad = {}", bw.grad_q[off + 4]);
        let mut grad_zx = vec![0.0; zp.n];
        grad_zx[off + 3] = 1.0;
        let bwx = backward_qr(&zp, &sol, &grad_zx);
        assert!(
            (bwx.grad_q[off + 3] - 1.0).abs() < 1e-6,
            "tangential grad = {}",
            bwx.grad_q[off + 3]
        );
    }

    #[test]
    fn qr_cost_structure_smoke() {
        // Not a timing test: just checks the QR path handles the m > n
        // fallback and the a == 0 shortcut without panicking.
        let (_sys, zp) = cube_on_ground(0.25);
        let sol = zp.solve();
        let grad_z = vec![1.0; zp.n];
        let _ = backward_qr(&zp, &sol, &grad_z);
    }
}
