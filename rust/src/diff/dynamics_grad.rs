//! Adjoint of the implicit-Euler linear solve (paper §6: "gradients for
//! the sparse linear system in Equation 3 can be computed via implicit
//! differentiation").
//!
//! Forward: A·Δq̇ = b. Backward: given ḡ = ∂L/∂Δq̇, the adjoint u solves
//! Aᵀ·u = ḡ (A is symmetric here), then ∂L/∂b = u and contributions to
//! upstream quantities flow through b's dependencies.

use crate::math::cg::pcg_csr;
use crate::math::sparse::Csr;

/// Solve Aᵀ·u = ḡ for the (symmetric) implicit-Euler operator.
pub fn adjoint_solve(a: &Csr, grad: &[f64]) -> Vec<f64> {
    let res = pcg_csr(a, grad, 1e-10, 20 * grad.len().max(10));
    res.x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::dense::{dot, Mat};
    use crate::math::sparse::Triplets;
    use crate::util::quick::{assert_close, quick};

    #[test]
    fn adjoint_gives_dldb() {
        // L = gᵀ·x with A·x = b ⇒ ∂L/∂b = A⁻ᵀ·g. Check against FD.
        quick("adjoint-dldb", 20, |g| {
            let n = g.usize(2, 12);
            let base = Mat::from_vec(n, n, g.vec_normal(n * n));
            let spd = base.transpose().matmul(&base).add(&Mat::identity(n).scale(n as f64));
            let mut t = Triplets::new(n, n);
            for i in 0..n {
                for j in 0..n {
                    t.push(i, j, spd[(i, j)]);
                }
            }
            let a = t.to_csr();
            let b = g.vec_normal(n);
            let gv = g.vec_normal(n);
            let u = adjoint_solve(&a, &gv);
            // FD on b.
            let h = 1e-6;
            let mut fd = vec![0.0; n];
            for k in 0..n {
                let mut bp = b.clone();
                bp[k] += h;
                let mut bm = b.clone();
                bm[k] -= h;
                let xp = spd.chol_solve(&bp).unwrap();
                let xm = spd.chol_solve(&bm).unwrap();
                fd[k] = (dot(&gv, &xp) - dot(&gv, &xm)) / (2.0 * h);
            }
            assert_close(&u, &fd, 1e-5, 1e-4, "adjoint vs fd");
        });
    }
}
