//! Step tape: everything the backward pass needs to replay one forward
//! step in reverse. The engine records one [`StepRecord`] per step (when
//! `record_tape` is on); [`crate::engine::backward`] walks them in
//! reverse order.
//!
//! Tape storage is the dominant *retained* memory of a taped rollout
//! (the paper's Fig-3 quantity): each record charges its
//! [`StepRecord::bytes`] to
//! [`MemCategory::Tape`](crate::util::memory::MemCategory) when pushed
//! and releases them when the tape is cleared. Between rollouts the
//! records' zone buffers *and* cloth solve buffers (the implicit-Euler
//! system/Jacobian CSRs, `dfdv`, `dv`) go back to the scene's
//! [`BatchArena`](crate::util::arena::BatchArena) through
//! [`StepRecord::recycle`], so repeated `rollout_grad` calls on a batch
//! re-fill warm buffers instead of reallocating every tape.

use crate::math::dense::Mat;
use crate::math::sparse::Csr;
use crate::math::Vec3;
use crate::solver::zone_solver::{ZoneProblem, ZoneSolution};
use crate::util::arena::BatchArena;

/// Per-cloth data retained from the implicit-Euler solve.
pub struct ClothSolveRec {
    /// System matrix A = M − h·∂f/∂q̇ − h²·∂f/∂q (for the adjoint solve).
    pub a: Csr,
    /// Exact stretch/bend Jacobian ∂f/∂x at x₀ (for ḡ_x₀, ḡ_v₀).
    pub jx: Csr,
    /// Diagonal ∂f/∂v per node.
    pub dfdv: Vec<f64>,
    /// Velocity increments produced by the solve.
    pub dv: Vec<Vec3>,
}

/// Per-rigid-body data retained from the velocity update.
pub struct RigidSolveRec {
    /// M̂ at q₀.
    pub mass: Mat,
    /// Velocity increment Δq̇.
    pub dqdot: [f64; 6],
    /// Generalized force Q (for mass-parameter gradients).
    pub q_gen: [f64; 6],
    /// World-frame external force that was applied this step.
    pub ext_force: Vec3,
}

/// One zone resolution (there may be several fail-safe passes per step;
/// they are recorded in solve order).
pub struct ZoneRec {
    pub problem: ZoneProblem,
    pub solution: ZoneSolution,
    /// Fail-safe resolution pass this zone was solved in (zones within a
    /// pass are independent — the coordinator batches them together).
    pub pass: usize,
}

/// Full record of one forward step.
pub struct StepRecord {
    pub h: f64,
    pub rigid_solves: Vec<RigidSolveRec>,
    pub cloth_solves: Vec<ClothSolveRec>,
    /// Cloth per-node external forces applied this step (control input).
    pub cloth_ext: Vec<Vec<Vec3>>,
    pub zones: Vec<ZoneRec>,
    /// Bytes retained by this record (Fig. 3 memory accounting).
    pub bytes: usize,
}

impl StepRecord {
    pub fn estimate_bytes(&self) -> usize {
        let mut b = 0;
        for c in &self.cloth_solves {
            b += c.a.bytes() + c.jx.bytes() + 8 * c.dfdv.len() + 24 * c.dv.len();
        }
        for _ in &self.rigid_solves {
            b += 6 * 6 * 8 + 6 * 8 * 2 + 24;
        }
        for z in &self.zones {
            let n = z.problem.n;
            let m = z.problem.constraints.len();
            b += n * n * 8 + n * 8 * 2 + m * 48;
        }
        b
    }

    /// Return this record's reusable buffers to `arena` for the next
    /// rollout: the zone buffers (problem `q0`/M̂, solution `q`/λ, and
    /// the `ZoneRec` list itself) and the cloth solve buffers (the
    /// system and Jacobian CSRs' `indptr`/`indices`/`data`, the `dfdv`
    /// diagonal, the `dv` increments, and the `ClothSolveRec` list) —
    /// the loan/retire mirror of `ZoneProblem::build_in`/`retire` and
    /// `cloth_implicit_step_in`. Category charges are the caller's job
    /// (the engine releases the record's `Tape` bytes before
    /// recycling); with a disabled arena this is exactly a drop.
    pub fn recycle(self, arena: &BatchArena) {
        let StepRecord { zones, cloth_solves, .. } = self;
        let mut zones = zones;
        for zr in zones.drain(..) {
            let ZoneRec { problem, solution, .. } = zr;
            let ZoneProblem { q0, mass, .. } = problem;
            arena.park_vec(q0);
            arena.park_vec(mass.data);
            let ZoneSolution { q, lambda, .. } = solution;
            arena.park_vec(q);
            arena.park_vec(lambda);
        }
        arena.park_vec(zones);
        let mut cloth_solves = cloth_solves;
        for cs in cloth_solves.drain(..) {
            let ClothSolveRec { a, jx, dfdv, dv } = cs;
            for csr in [a, jx] {
                arena.park_vec(csr.indptr);
                arena.park_vec(csr.indices);
                arena.park_vec(csr.data);
            }
            arena.park_vec(dfdv);
            arena.park_vec(dv);
        }
        arena.park_vec(cloth_solves);
    }
}

/// Gradient accumulators produced by the backward pass.
#[derive(Clone, Debug, Default)]
pub struct Grads {
    /// ∂L/∂q₀, ∂L/∂q̇₀ for rigid bodies (initial conditions of the episode).
    pub rigid_q0: Vec<[f64; 6]>,
    pub rigid_v0: Vec<[f64; 6]>,
    /// ∂L/∂x₀, ∂L/∂v₀ for cloth nodes.
    pub cloth_x0: Vec<Vec<Vec3>>,
    pub cloth_v0: Vec<Vec<Vec3>>,
    /// ∂L/∂(external world-frame force on rigid body b at step s):
    /// indexed `[step][body]`.
    pub rigid_force: Vec<Vec<Vec3>>,
    /// ∂L/∂(external force on cloth c node i at step s): `[step][cloth][node]`.
    pub cloth_force: Vec<Vec<Vec<Vec3>>>,
    /// ∂L/∂(mass of rigid body b) assuming uniform density scaling.
    pub rigid_mass: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_estimate_nonzero_for_zone_records() {
        use crate::math::sparse::Triplets;
        let rec = StepRecord {
            h: 0.01,
            rigid_solves: vec![RigidSolveRec {
                mass: Mat::identity(6),
                dqdot: [0.0; 6],
                q_gen: [0.0; 6],
                ext_force: Vec3::default(),
            }],
            cloth_solves: vec![ClothSolveRec {
                a: Triplets::new(3, 3).to_csr(),
                jx: Triplets::new(3, 3).to_csr(),
                dfdv: vec![0.0],
                dv: vec![Vec3::default()],
            }],
            cloth_ext: vec![],
            zones: vec![],
            bytes: 0,
        };
        assert!(rec.estimate_bytes() > 300);
    }
}
