//! Coordinator telemetry: batching occupancy and fallback counters —
//! the numbers §7.1's "collisions are sparse" claim is checked against.

use crate::util::json::Json;

#[derive(Clone, Debug, Default)]
pub struct CoordMetrics {
    /// PJRT calls made for zone backwards.
    pub zone_pjrt_calls: usize,
    /// Real zone items shipped.
    pub zone_items: usize,
    /// Total padded slots shipped (occupancy = items / slots).
    pub zone_slots: usize,
    /// Zones that ran on the native path (oversize or PJRT failure).
    pub zone_native_fallback: usize,
    /// `zone_solve_batch` invocations — one per (step, fail-safe pass)
    /// level under lockstep forward batching, covering every scene's
    /// zones at that level.
    pub zone_solve_dispatches: usize,
    /// PJRT calls made for forward zone solves.
    pub zone_solve_pjrt_calls: usize,
    /// Real forward-solve items shipped.
    pub zone_solve_items: usize,
    /// Total padded forward-solve slots shipped.
    pub zone_solve_slots: usize,
    /// Forward solves that ran the native AL solver (no bucket, missing
    /// artifact, or PJRT failure).
    pub zone_solve_native_fallback: usize,
    pub rigid_pjrt_calls: usize,
    pub rigid_items: usize,
    pub rigid_slots: usize,
}

impl CoordMetrics {
    pub fn zone_occupancy(&self) -> f64 {
        if self.zone_slots == 0 {
            0.0
        } else {
            self.zone_items as f64 / self.zone_slots as f64
        }
    }

    pub fn zone_solve_occupancy(&self) -> f64 {
        if self.zone_solve_slots == 0 {
            0.0
        } else {
            self.zone_solve_items as f64 / self.zone_solve_slots as f64
        }
    }

    pub fn rigid_occupancy(&self) -> f64 {
        if self.rigid_slots == 0 {
            0.0
        } else {
            self.rigid_items as f64 / self.rigid_slots as f64
        }
    }

    /// `items / slots` as JSON, or `null` when no slots were shipped.
    /// The f64 accessors above return 0.0 in that case (callers doing
    /// arithmetic want a number), but emitting `0.0` in reports reads as
    /// "terrible occupancy" after an all-fallback dispatch when the
    /// truth is "no batched dispatch happened" — so JSON says `null`.
    fn occupancy_json(items: usize, slots: usize) -> Json {
        if slots == 0 {
            Json::Null
        } else {
            Json::from(items as f64 / slots as f64)
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("zone_pjrt_calls", self.zone_pjrt_calls)
            .set("zone_items", self.zone_items)
            .set("zone_slots", self.zone_slots)
            .set("zone_occupancy", Self::occupancy_json(self.zone_items, self.zone_slots))
            .set("zone_native_fallback", self.zone_native_fallback)
            .set("zone_solve_dispatches", self.zone_solve_dispatches)
            .set("zone_solve_pjrt_calls", self.zone_solve_pjrt_calls)
            .set("zone_solve_items", self.zone_solve_items)
            .set("zone_solve_slots", self.zone_solve_slots)
            .set(
                "zone_solve_occupancy",
                Self::occupancy_json(self.zone_solve_items, self.zone_solve_slots),
            )
            .set("zone_solve_native_fallback", self.zone_solve_native_fallback)
            .set("rigid_pjrt_calls", self.rigid_pjrt_calls)
            .set("rigid_items", self.rigid_items)
            .set("rigid_slots", self.rigid_slots)
            .set("rigid_occupancy", Self::occupancy_json(self.rigid_items, self.rigid_slots));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let m = CoordMetrics {
            zone_items: 12,
            zone_slots: 16,
            zone_solve_items: 3,
            zone_solve_slots: 8,
            rigid_items: 100,
            rigid_slots: 128,
            ..Default::default()
        };
        assert!((m.zone_occupancy() - 0.75).abs() < 1e-12);
        assert!((m.zone_solve_occupancy() - 0.375).abs() < 1e-12);
        assert!((m.rigid_occupancy() - 100.0 / 128.0).abs() < 1e-12);
        assert_eq!(CoordMetrics::default().zone_occupancy(), 0.0);
        assert_eq!(CoordMetrics::default().zone_solve_occupancy(), 0.0);
    }

    #[test]
    fn json_dump_has_fields() {
        let j = CoordMetrics::default().to_json();
        assert!(j.get("zone_occupancy").is_some());
        assert!(j.get("zone_solve_dispatches").is_some());
        assert!(j.get("zone_solve_occupancy").is_some());
        assert!(j.get("rigid_items").is_some());
    }

    #[test]
    fn occupancy_null_after_all_fallback_dispatch() {
        // An all-fallback dispatch counts items but ships zero slots:
        // the JSON report must say `null` ("no batched dispatch"), not
        // 0/0 → 0.0 ("terrible occupancy") or NaN.
        let m = CoordMetrics {
            zone_solve_dispatches: 1,
            zone_solve_native_fallback: 5,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("zone_occupancy"), Some(&Json::Null));
        assert_eq!(j.get("zone_solve_occupancy"), Some(&Json::Null));
        assert_eq!(j.get("rigid_occupancy"), Some(&Json::Null));
        // Round-trips through the writer/parser as literal null.
        let back = Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(back.get("zone_solve_occupancy"), Some(&Json::Null));
        // With slots shipped, occupancy is the plain ratio again.
        let m = CoordMetrics { zone_solve_items: 3, zone_solve_slots: 8, ..m };
        let occ = m.to_json().get("zone_solve_occupancy").and_then(|v| v.as_f64());
        assert_eq!(occ, Some(0.375));
    }
}
