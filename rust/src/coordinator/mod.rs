//! The L3 coordinator: routes batched numeric work from the engine onto
//! the AOT-compiled PJRT executables.
//!
//! Zone shapes are dynamic but HLO shapes are static, so the coordinator
//! resolves the mismatch with *size buckets* (pad each zone's (n, m) up
//! to the smallest exported bucket) and *batching* (all zones sharing a
//! bucket go out in one PJRT call). Zones exceeding every bucket fall
//! back to the native rust path. The same strategy a serving router uses
//! for sequence-length buckets.

pub mod metrics;

use crate::diff::implicit::{backward_dense, backward_qr};
use crate::runtime::{Runtime, ZoneBucket};
use crate::solver::zone_solver::{ZoneProblem, ZoneSolution};
use crate::util::scratch;
use anyhow::Result;
use metrics::CoordMetrics;
use std::sync::{Arc, Mutex};

/// One zone-backward work item.
pub struct ZoneBwItem<'a> {
    pub problem: &'a ZoneProblem,
    pub solution: &'a ZoneSolution,
    pub grad_z: &'a [f64],
}

pub struct Coordinator {
    pub runtime: Arc<Runtime>,
    pub metrics: Mutex<CoordMetrics>,
}

impl Coordinator {
    pub fn new(runtime: Arc<Runtime>) -> Coordinator {
        Coordinator { runtime, metrics: Mutex::new(CoordMetrics::default()) }
    }

    /// Cheapest exported bucket fitting (n, m) from `buckets`, if any.
    /// "Cheapest" is padded cost n² + m·n (the mass + Jacobian footprint
    /// actually shipped), not the lexicographic (n, m) minimum — a
    /// bucket with minimal n but a hugely overshooting m must lose to a
    /// near-exact fit. Ties break on (n, m) so selection is
    /// deterministic.
    fn bucket_for_in(buckets: &[ZoneBucket], n: usize, m: usize) -> Option<ZoneBucket> {
        buckets
            .iter()
            .copied()
            .filter(|b| b.n >= n && b.m >= m)
            .min_by_key(|b| (b.n * b.n + b.m * b.n, b.n, b.m))
    }

    /// Buckets from `buckets` whose artifact (per `name`) actually
    /// exists in the manifest. Selecting only among these keeps the
    /// PJRT paths alive under partial exports (a manifest listing
    /// buckets the aot step didn't ship yet): a zone whose cheapest
    /// bucket is missing lands in the next-cheapest available one
    /// instead of silently falling back native.
    fn available_buckets(
        &self,
        buckets: &[ZoneBucket],
        name: fn(ZoneBucket) -> String,
    ) -> Vec<ZoneBucket> {
        buckets.iter().copied().filter(|&b| self.runtime.has(&name(b))).collect()
    }

    /// Batched zone-backward over independent zones: groups by bucket,
    /// pads, one PJRT call per bucket-batch; oversize zones run native.
    /// Returns ∂L/∂q per item (same order). Bucket groups dispatch in
    /// sorted (n, m) order, so PJRT call order, chunk boundaries, and
    /// fallback/metrics logs are identical across identical runs.
    pub fn zone_backward_batch(&self, items: &[ZoneBwItem<'_>]) -> Vec<Vec<f64>> {
        let avail = self.available_buckets(&self.runtime.zone_buckets, zone_backward_name);
        let mut out: Vec<Vec<f64>> = items.iter().map(|_| Vec::new()).collect();
        // Group item indices by bucket (ordered map: see above).
        let mut groups: std::collections::BTreeMap<(usize, usize), Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, it) in items.iter().enumerate() {
            let n = it.problem.n;
            let m = it.problem.constraints.len();
            match Coordinator::bucket_for_in(&avail, n, m) {
                Some(b) => groups.entry((b.n, b.m)).or_default().push(i),
                None => {
                    // Native fallback for oversize zones.
                    self.metrics.lock().unwrap().zone_native_fallback += 1;
                    obs_add("coord.zone_native_fallback", 1);
                    let bw = backward_qr(it.problem, it.solution, it.grad_z);
                    out[i] = bw.grad_q;
                }
            }
        }
        for ((bn, bm), idxs) in groups {
            let bucket = avail
                .iter()
                .copied()
                .find(|b| b.n == bn && b.m == bm)
                .expect("bucket vanished");
            let name = zone_backward_name(bucket);
            for chunk in idxs.chunks(bucket.batch) {
                match self.call_zone_bucket(&name, bucket, chunk, items) {
                    Ok(grads) => {
                        for (k, &i) in chunk.iter().enumerate() {
                            out[i] = grads[k].clone();
                        }
                        let mut m = self.metrics.lock().unwrap();
                        m.zone_pjrt_calls += 1;
                        m.zone_items += chunk.len();
                        m.zone_slots += bucket.batch;
                        drop(m);
                        obs_add("coord.zone_pjrt_calls", 1);
                        obs_add("coord.zone_items", chunk.len());
                        obs_add("coord.zone_slots", bucket.batch);
                    }
                    Err(e) => {
                        // PJRT trouble: degrade to native, keep running.
                        crate::warnlog!("pjrt zone backward failed ({e:#}); native fallback");
                        let mut m = self.metrics.lock().unwrap();
                        m.zone_native_fallback += chunk.len();
                        drop(m);
                        obs_add("coord.zone_native_fallback", chunk.len());
                        for &i in chunk {
                            let it = &items[i];
                            out[i] = backward_qr(it.problem, it.solution, it.grad_z).grad_q;
                        }
                    }
                }
            }
        }
        out
    }

    fn call_zone_bucket(
        &self,
        name: &str,
        bucket: ZoneBucket,
        chunk: &[usize],
        items: &[ZoneBwItem<'_>],
    ) -> Result<Vec<Vec<f64>>> {
        let (bn, bm, bb) = (bucket.n, bucket.m, bucket.batch);
        // Packing buffers come from the per-worker scratch arena: under
        // the persistent pool the same allocations serve every bucket
        // call this thread ever makes.
        let mut mass = scratch::f32s(bb * bn * bn, 0.0);
        fill_identity_padded_mass(&mut mass, bb, bn);
        let mut jac = scratch::f32s(bb * bm * bn, 0.0);
        let mut lam = scratch::f32s(bb * bm, 0.0);
        let mut g = scratch::f32s(bb * bn, 0.0);
        for (k, &i) in chunk.iter().enumerate() {
            let it = &items[i];
            let zp = it.problem;
            let n = zp.n;
            let m = zp.constraints.len();
            // Backward linearizes at the *solution* point.
            pack_mass_jac(&mut mass, &mut jac, k, bn, bm, zp, &it.solution.q);
            for r in 0..m {
                lam[k * bm + r] = it.solution.lambda[r] as f32;
            }
            for c in 0..n {
                g[k * bn + c] = it.grad_z[c] as f32;
            }
        }
        let outs = self.runtime.call_f32(name, &[&mass[..], &jac[..], &lam[..], &g[..]])?;
        let grad = &outs[0];
        let mut res = Vec::with_capacity(chunk.len());
        for (k, &i) in chunk.iter().enumerate() {
            let n = items[i].problem.n;
            res.push((0..n).map(|c| grad[k * bn + c] as f64).collect());
        }
        Ok(res)
    }

    /// Batched *forward* zone solve over independent zones — the
    /// lockstep forward's dispatch (`batch::SceneBatch::step_lockstep`).
    /// Groups by the cheapest *available* solve bucket, pads, one PJRT
    /// call per bucket-batch; zones exceeding every available bucket and
    /// zones in a failed PJRT call run the native augmented-Lagrangian
    /// solver on `pool` — exactly the degradation ladder of
    /// [`Coordinator::zone_backward_batch`] (the native work here is a
    /// full solve, not a backsolve, hence the caller-provided pool
    /// instead of inline fallback: worker budgets stay honored).
    /// Returns solutions in item order; bucket groups dispatch in sorted
    /// (n, m) order, so call order, chunking, and metrics are
    /// deterministic from day one.
    pub fn zone_solve_batch(
        &self,
        problems: &[&ZoneProblem],
        pool: &crate::util::pool::Pool,
    ) -> Vec<ZoneSolution> {
        if problems.is_empty() {
            // Not counted as a dispatch: the metric means "batched solve
            // levels", and an empty call does no solving.
            return Vec::new();
        }
        self.metrics.lock().unwrap().zone_solve_dispatches += 1;
        obs_add("coord.zone_solve_dispatches", 1);
        // Named fault-injection site: an armed `coord.dispatch` firing
        // takes the bucket layer down for this batched solve — no
        // bucket matches, so every zone routes through the counted
        // native fallback below. Constant `false` without the feature.
        let avail = if crate::util::faultinject::should_fire(
            crate::util::faultinject::site::COORD_DISPATCH,
        ) {
            Vec::new()
        } else {
            self.available_buckets(&self.runtime.zone_solve_buckets, zone_solve_name)
        };
        let mut out: Vec<Option<ZoneSolution>> = problems.iter().map(|_| None).collect();
        let mut groups: std::collections::BTreeMap<(usize, usize), Vec<usize>> =
            std::collections::BTreeMap::new();
        let mut native: Vec<usize> = Vec::new();
        for (i, zp) in problems.iter().enumerate() {
            match Coordinator::bucket_for_in(&avail, zp.n, zp.constraints.len()) {
                Some(b) => {
                    groups.entry((b.n, b.m)).or_default().push(i);
                }
                None => native.push(i),
            }
        }
        if !native.is_empty() {
            self.metrics.lock().unwrap().zone_solve_native_fallback += native.len();
            obs_add("coord.zone_solve_native_fallback", native.len());
            let sols = pool.map(native.len(), |j| problems[native[j]].solve());
            for (&i, sol) in native.iter().zip(sols) {
                out[i] = Some(sol);
            }
        }
        for ((bn, bm), idxs) in groups {
            let bucket = avail
                .iter()
                .copied()
                .find(|b| b.n == bn && b.m == bm)
                .expect("bucket vanished");
            let name = zone_solve_name(bucket);
            for chunk in idxs.chunks(bucket.batch) {
                match self.call_zone_solve_bucket(&name, bucket, chunk, problems) {
                    Ok(sols) => {
                        for (&i, sol) in chunk.iter().zip(sols) {
                            out[i] = Some(sol);
                        }
                        let mut m = self.metrics.lock().unwrap();
                        m.zone_solve_pjrt_calls += 1;
                        m.zone_solve_items += chunk.len();
                        m.zone_solve_slots += bucket.batch;
                        drop(m);
                        obs_add("coord.zone_solve_pjrt_calls", 1);
                        obs_add("coord.zone_solve_items", chunk.len());
                        obs_add("coord.zone_solve_slots", bucket.batch);
                    }
                    Err(e) => {
                        // PJRT trouble: degrade to native (full AL
                        // solves, so on the pool), keep running.
                        crate::warnlog!("pjrt zone solve failed ({e:#}); native fallback");
                        self.metrics.lock().unwrap().zone_solve_native_fallback += chunk.len();
                        obs_add("coord.zone_solve_native_fallback", chunk.len());
                        let sols = pool.map(chunk.len(), |j| problems[chunk[j]].solve());
                        for (&i, sol) in chunk.iter().zip(sols) {
                            out[i] = Some(sol);
                        }
                    }
                }
            }
        }
        out.into_iter().map(|o| o.expect("every zone solved")).collect()
    }

    /// One padded PJRT call for a chunk of same-bucket forward solves.
    /// Inputs: block mass (identity in empty slots), constraint Jacobian
    /// at q0, constraint values C(q0) (padded rows strictly satisfied so
    /// they stay inactive), and q0. Outputs: resolved q and multipliers.
    fn call_zone_solve_bucket(
        &self,
        name: &str,
        bucket: ZoneBucket,
        chunk: &[usize],
        problems: &[&ZoneProblem],
    ) -> Result<Vec<ZoneSolution>> {
        let (bn, bm, bb) = (bucket.n, bucket.m, bucket.batch);
        let mut mass = scratch::f32s(bb * bn * bn, 0.0);
        fill_identity_padded_mass(&mut mass, bb, bn);
        let mut jac = scratch::f32s(bb * bm * bn, 0.0);
        let mut c0 = scratch::f32s(bb * bm, 1.0);
        let mut q0 = scratch::f32s(bb * bn, 0.0);
        let mut cvals = scratch::f64s(0, 0.0);
        for (k, &i) in chunk.iter().enumerate() {
            let zp = problems[i];
            let n = zp.n;
            let m = zp.constraints.len();
            // Forward linearizes at the *candidate* point q0.
            pack_mass_jac(&mut mass, &mut jac, k, bn, bm, zp, &zp.q0);
            for r in 0..n {
                q0[k * bn + r] = zp.q0[r] as f32;
            }
            zp.eval_into(&zp.q0, cvals.as_vec());
            for r in 0..m {
                c0[k * bm + r] = cvals[r] as f32;
            }
        }
        let outs = self.runtime.call_f32(name, &[&mass[..], &jac[..], &c0[..], &q0[..]])?;
        let (qs, lams) = (&outs[0], &outs[1]);
        let mut res = Vec::with_capacity(chunk.len());
        for (k, &i) in chunk.iter().enumerate() {
            let zp = problems[i];
            let n = zp.n;
            let m = zp.constraints.len();
            let q: Vec<f64> = (0..n).map(|c| qs[k * bn + c] as f64).collect();
            let lambda: Vec<f64> = (0..m).map(|r| (lams[k * bm + r] as f64).max(0.0)).collect();
            // Feasibility is judged natively (f64) so the converged flag
            // means the same thing on every path.
            let viol = zp.eval(&q).iter().map(|&x| (-x).max(0.0)).fold(0.0, f64::max);
            res.push(ZoneSolution {
                q,
                lambda,
                converged: viol < 1e-6,
                outer_iters: 0,
                gn_iters: 0,
                max_violation: viol,
            });
        }
        Ok(res)
    }

    /// Batched rigid vertex transform + Jacobian through the Pallas-
    /// kernel artifact. `q` repeated per vertex, `p0` body-frame points.
    /// Returns (world positions, 3×6 Jacobians row-major).
    #[allow(clippy::type_complexity)]
    pub fn rigid_transform_batch(
        &self,
        q: &[[f64; 6]],
        p0: &[[f64; 3]],
    ) -> Result<(Vec<[f64; 3]>, Vec<[[f64; 6]; 3]>)> {
        assert_eq!(q.len(), p0.len());
        let n = q.len();
        let bucket = self
            .runtime
            .rigid_batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .unwrap_or_else(|| *self.runtime.rigid_batches.iter().max().unwrap_or(&128));
        let mut xs = Vec::with_capacity(n);
        let mut jacs = Vec::with_capacity(n);
        let mut start = 0;
        let mut qbuf = scratch::f32s(0, 0.0);
        let mut pbuf = scratch::f32s(0, 0.0);
        while start < n {
            let take = (n - start).min(bucket);
            qbuf.refill(bucket * 6, 0.0);
            pbuf.refill(bucket * 3, 0.0);
            for k in 0..take {
                for c in 0..6 {
                    qbuf[k * 6 + c] = q[start + k][c] as f32;
                }
                for c in 0..3 {
                    pbuf[k * 3 + c] = p0[start + k][c] as f32;
                }
            }
            let name = format!("rigid_transform_b{bucket}");
            let outs = self.runtime.call_f32(&name, &[&qbuf[..], &pbuf[..]])?;
            let (xf, jf) = (&outs[0], &outs[1]);
            for k in 0..take {
                xs.push([
                    xf[k * 3] as f64,
                    xf[k * 3 + 1] as f64,
                    xf[k * 3 + 2] as f64,
                ]);
                let mut j = [[0.0f64; 6]; 3];
                for r in 0..3 {
                    for c in 0..6 {
                        j[r][c] = jf[k * 18 + r * 6 + c] as f64;
                    }
                }
                jacs.push(j);
            }
            let mut m = self.metrics.lock().unwrap();
            m.rigid_pjrt_calls += 1;
            m.rigid_items += take;
            m.rigid_slots += bucket;
            drop(m);
            obs_add("coord.rigid_pjrt_calls", 1);
            obs_add("coord.rigid_items", take);
            obs_add("coord.rigid_slots", bucket);
            start += take;
        }
        Ok((xs, jacs))
    }

    /// Dense-mode batched backward (the "W/o FD" ablation run through the
    /// native dense path — exported for parity in experiments).
    pub fn zone_backward_native_dense(&self, items: &[ZoneBwItem<'_>]) -> Vec<Vec<f64>> {
        items
            .iter()
            .map(|it| backward_dense(it.problem, it.solution, it.grad_z).grad_q)
            .collect()
    }
}

/// Mirror a [`CoordMetrics`] increment into the process-wide telemetry
/// registry under `coord.<field>` (skipping zero adds so unused metrics
/// never intern a counter). The mutex-guarded struct stays the
/// per-coordinator source of truth; the registry aggregates across
/// coordinators for [`crate::util::telemetry::summary`].
fn obs_add(name: &str, n: usize) {
    if n > 0 {
        crate::util::telemetry::counter(name).add(n as u64);
    }
}

/// Artifact name of a zone-backward bucket.
fn zone_backward_name(b: ZoneBucket) -> String {
    format!("zone_backward_n{}_m{}_b{}", b.n, b.m, b.batch)
}

/// Artifact name of a forward zone-solve bucket.
fn zone_solve_name(b: ZoneBucket) -> String {
    format!("zone_solve_n{}_m{}_b{}", b.n, b.m, b.batch)
}

/// Set identity diagonals in every slot of a zeroed padded bucket mass
/// buffer, so empty batch slots keep the batched solves well posed.
fn fill_identity_padded_mass(mass: &mut [f32], bb: usize, bn: usize) {
    for k in 0..bb {
        for r in 0..bn {
            mass[k * bn * bn + r * bn + r] = 1.0;
        }
    }
}

/// Pack one zone's mass block and its constraint Jacobian (linearized
/// at `at`) into slot `k` of the padded bucket buffers — shared between
/// the forward and backward bucket calls so the padding scheme cannot
/// silently diverge.
fn pack_mass_jac(
    mass: &mut [f32],
    jac: &mut [f32],
    k: usize,
    bn: usize,
    bm: usize,
    zp: &ZoneProblem,
    at: &[f64],
) {
    let n = zp.n;
    let m = zp.constraints.len();
    for r in 0..n {
        for c in 0..n {
            mass[k * bn * bn + r * bn + c] = zp.mass[(r, c)] as f32;
        }
    }
    let mut jrows = scratch::mat(0, 0);
    zp.jacobian_into(at, &mut jrows);
    for r in 0..m {
        for c in 0..n {
            jac[k * bm * bn + r * bn + c] = jrows[(r, c)] as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_choice_minimizes_padded_cost() {
        let table = vec![
            ZoneBucket { n: 6, m: 64, batch: 8 },
            ZoneBucket { n: 12, m: 8, batch: 8 },
            ZoneBucket { n: 24, m: 24, batch: 4 },
        ];
        // (6, 4) fits all three. The old lexicographic (n, m) min picked
        // (6, 64) — cost 6² + 64·6 = 420 — over the near-exact (12, 8)
        // at 12² + 8·12 = 240.
        let b = Coordinator::bucket_for_in(&table, 6, 4).expect("fits");
        assert_eq!((b.n, b.m), (12, 8));
        // Near-exact fit wins outright.
        let b = Coordinator::bucket_for_in(&table, 10, 8).expect("fits");
        assert_eq!((b.n, b.m), (12, 8));
        // Many constraints force the wide bucket.
        let b = Coordinator::bucket_for_in(&table, 4, 40).expect("fits");
        assert_eq!((b.n, b.m), (6, 64));
        // Oversize in either dimension: no bucket.
        assert!(Coordinator::bucket_for_in(&table, 25, 1).is_none());
        assert!(Coordinator::bucket_for_in(&table, 1, 65).is_none());
        // Exact tie on cost breaks deterministically on (n, m).
        let tied = vec![
            ZoneBucket { n: 8, m: 8, batch: 4 },
            ZoneBucket { n: 8, m: 8, batch: 2 },
        ];
        let b = Coordinator::bucket_for_in(&tied, 8, 8).expect("fits");
        assert_eq!((b.n, b.m, b.batch), (8, 8, 4), "first listed of equal keys");
    }

    #[test]
    fn solve_name_matches_export_convention() {
        assert_eq!(
            zone_solve_name(ZoneBucket { n: 12, m: 8, batch: 16 }),
            "zone_solve_n12_m8_b16"
        );
    }
}
