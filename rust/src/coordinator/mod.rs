//! The L3 coordinator: routes batched numeric work from the engine onto
//! the AOT-compiled PJRT executables.
//!
//! Zone shapes are dynamic but HLO shapes are static, so the coordinator
//! resolves the mismatch with *size buckets* (pad each zone's (n, m) up
//! to the smallest exported bucket) and *batching* (all zones sharing a
//! bucket go out in one PJRT call). Zones exceeding every bucket fall
//! back to the native rust path. The same strategy a serving router uses
//! for sequence-length buckets.

pub mod metrics;

use crate::diff::implicit::{backward_dense, backward_qr};
use crate::runtime::{Runtime, ZoneBucket};
use crate::solver::zone_solver::{ZoneProblem, ZoneSolution};
use anyhow::Result;
use metrics::CoordMetrics;
use std::sync::{Arc, Mutex};

/// One zone-backward work item.
pub struct ZoneBwItem<'a> {
    pub problem: &'a ZoneProblem,
    pub solution: &'a ZoneSolution,
    pub grad_z: &'a [f64],
}

pub struct Coordinator {
    pub runtime: Arc<Runtime>,
    pub metrics: Mutex<CoordMetrics>,
}

impl Coordinator {
    pub fn new(runtime: Arc<Runtime>) -> Coordinator {
        Coordinator { runtime, metrics: Mutex::new(CoordMetrics::default()) }
    }

    /// Smallest exported bucket fitting (n, m), if any.
    fn bucket_for(&self, n: usize, m: usize) -> Option<ZoneBucket> {
        self.runtime
            .zone_buckets
            .iter()
            .copied()
            .filter(|b| b.n >= n && b.m >= m)
            .min_by_key(|b| (b.n, b.m))
    }

    /// Batched zone-backward over independent zones: groups by bucket,
    /// pads, one PJRT call per bucket-batch; oversize zones run native.
    /// Returns ∂L/∂q per item (same order).
    pub fn zone_backward_batch(&self, items: &[ZoneBwItem<'_>]) -> Vec<Vec<f64>> {
        let mut out: Vec<Vec<f64>> = items.iter().map(|_| Vec::new()).collect();
        // Group item indices by bucket.
        let mut groups: std::collections::HashMap<(usize, usize), Vec<usize>> =
            std::collections::HashMap::new();
        for (i, it) in items.iter().enumerate() {
            let n = it.problem.n;
            let m = it.problem.constraints.len();
            match self.bucket_for(n, m) {
                Some(b) => groups.entry((b.n, b.m)).or_default().push(i),
                None => {
                    // Native fallback for oversize zones.
                    self.metrics.lock().unwrap().zone_native_fallback += 1;
                    let bw = backward_qr(it.problem, it.solution, it.grad_z);
                    out[i] = bw.grad_q;
                }
            }
        }
        for ((bn, bm), idxs) in groups {
            let bucket = self
                .runtime
                .zone_buckets
                .iter()
                .copied()
                .find(|b| b.n == bn && b.m == bm)
                .expect("bucket vanished");
            let name = format!("zone_backward_n{}_m{}_b{}", bucket.n, bucket.m, bucket.batch);
            for chunk in idxs.chunks(bucket.batch) {
                match self.call_zone_bucket(&name, bucket, chunk, items) {
                    Ok(grads) => {
                        for (k, &i) in chunk.iter().enumerate() {
                            out[i] = grads[k].clone();
                        }
                        let mut m = self.metrics.lock().unwrap();
                        m.zone_pjrt_calls += 1;
                        m.zone_items += chunk.len();
                        m.zone_slots += bucket.batch;
                    }
                    Err(e) => {
                        // PJRT trouble: degrade to native, keep running.
                        crate::warnlog!("pjrt zone backward failed ({e:#}); native fallback");
                        let mut m = self.metrics.lock().unwrap();
                        m.zone_native_fallback += chunk.len();
                        drop(m);
                        for &i in chunk {
                            let it = &items[i];
                            out[i] = backward_qr(it.problem, it.solution, it.grad_z).grad_q;
                        }
                    }
                }
            }
        }
        out
    }

    fn call_zone_bucket(
        &self,
        name: &str,
        bucket: ZoneBucket,
        chunk: &[usize],
        items: &[ZoneBwItem<'_>],
    ) -> Result<Vec<Vec<f64>>> {
        let (bn, bm, bb) = (bucket.n, bucket.m, bucket.batch);
        let mut mass = vec![0.0f32; bb * bn * bn];
        let mut jac = vec![0.0f32; bb * bm * bn];
        let mut lam = vec![0.0f32; bb * bm];
        let mut g = vec![0.0f32; bb * bn];
        // Empty batch slots get identity mass so the batched CG stays
        // well posed.
        for k in 0..bb {
            for r in 0..bn {
                mass[k * bn * bn + r * bn + r] = 1.0;
            }
        }
        for k in chunk.len()..bb {
            let _ = k; // (slots already identity)
        }
        for (k, &i) in chunk.iter().enumerate() {
            let it = &items[i];
            let zp = it.problem;
            let n = zp.n;
            let m = zp.constraints.len();
            for r in 0..n {
                for c in 0..n {
                    mass[k * bn * bn + r * bn + c] = zp.mass[(r, c)] as f32;
                }
                if zp.mass[(r, r)] != 0.0 {
                    // (diagonal was pre-set to 1; real value overwrites)
                }
            }
            let jrows = zp.jacobian(&it.solution.q);
            for r in 0..m {
                for c in 0..n {
                    jac[k * bm * bn + r * bn + c] = jrows[(r, c)] as f32;
                }
                lam[k * bm + r] = it.solution.lambda[r] as f32;
            }
            for c in 0..n {
                g[k * bn + c] = it.grad_z[c] as f32;
            }
        }
        let outs = self.runtime.call_f32(name, &[&mass, &jac, &lam, &g])?;
        let grad = &outs[0];
        let mut res = Vec::with_capacity(chunk.len());
        for (k, &i) in chunk.iter().enumerate() {
            let n = items[i].problem.n;
            res.push((0..n).map(|c| grad[k * bn + c] as f64).collect());
        }
        Ok(res)
    }

    /// Batched rigid vertex transform + Jacobian through the Pallas-
    /// kernel artifact. `q` repeated per vertex, `p0` body-frame points.
    /// Returns (world positions, 3×6 Jacobians row-major).
    #[allow(clippy::type_complexity)]
    pub fn rigid_transform_batch(
        &self,
        q: &[[f64; 6]],
        p0: &[[f64; 3]],
    ) -> Result<(Vec<[f64; 3]>, Vec<[[f64; 6]; 3]>)> {
        assert_eq!(q.len(), p0.len());
        let n = q.len();
        let bucket = self
            .runtime
            .rigid_batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .unwrap_or_else(|| *self.runtime.rigid_batches.iter().max().unwrap_or(&128));
        let mut xs = Vec::with_capacity(n);
        let mut jacs = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let take = (n - start).min(bucket);
            let mut qbuf = vec![0.0f32; bucket * 6];
            let mut pbuf = vec![0.0f32; bucket * 3];
            for k in 0..take {
                for c in 0..6 {
                    qbuf[k * 6 + c] = q[start + k][c] as f32;
                }
                for c in 0..3 {
                    pbuf[k * 3 + c] = p0[start + k][c] as f32;
                }
            }
            let name = format!("rigid_transform_b{bucket}");
            let outs = self.runtime.call_f32(&name, &[&qbuf, &pbuf])?;
            let (xf, jf) = (&outs[0], &outs[1]);
            for k in 0..take {
                xs.push([
                    xf[k * 3] as f64,
                    xf[k * 3 + 1] as f64,
                    xf[k * 3 + 2] as f64,
                ]);
                let mut j = [[0.0f64; 6]; 3];
                for r in 0..3 {
                    for c in 0..6 {
                        j[r][c] = jf[k * 18 + r * 6 + c] as f64;
                    }
                }
                jacs.push(j);
            }
            let mut m = self.metrics.lock().unwrap();
            m.rigid_pjrt_calls += 1;
            m.rigid_items += take;
            m.rigid_slots += bucket;
            start += take;
        }
        Ok((xs, jacs))
    }

    /// Dense-mode batched backward (the "W/o FD" ablation run through the
    /// native dense path — exported for parity in experiments).
    pub fn zone_backward_native_dense(&self, items: &[ZoneBwItem<'_>]) -> Vec<Vec<f64>> {
        items
            .iter()
            .map(|it| backward_dense(it.problem, it.solution, it.grad_z).grad_q)
            .collect()
    }
}
