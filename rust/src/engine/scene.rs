//! Scene construction from JSON configs — the engine's config system.
//!
//! ```json
//! {
//!   "dt": 0.00667, "gravity": [0, -9.8, 0], "thickness": 0.001,
//!   "bodies": [
//!     {"type": "ground", "y": 0.0, "half_extent": 10.0},
//!     {"type": "box", "half": [0.5, 0.5, 0.5], "pos": [0, 1, 0],
//!      "density": 1.0, "vel": [0, 0, 0]},
//!     {"type": "sphere", "radius": 0.3, "pos": [0, 2, 0], "subdiv": 2},
//!     {"type": "bunny", "radius": 0.5, "pos": [0, 1, 0]},
//!     {"type": "cloth", "res": [16, 16], "size": [2, 2], "pos": [0, 1, 0],
//!      "density": 0.2, "k_stretch": 1000, "k_bend": 1, "damping": 1,
//!      "pins": [0, 16]}
//!   ]
//! }
//! ```

use crate::bodies::{Cloth, RigidBody, System};
use crate::engine::{SimConfig, Simulation};
use crate::math::Vec3;
use crate::mesh::primitives;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

fn vec3_of(j: Option<&Json>, default: Vec3) -> Vec3 {
    match j.and_then(Json::as_arr) {
        Some(a) if a.len() == 3 => Vec3::new(
            a[0].as_f64().unwrap_or(default.x),
            a[1].as_f64().unwrap_or(default.y),
            a[2].as_f64().unwrap_or(default.z),
        ),
        _ => default,
    }
}

/// Build a `Simulation` from a JSON scene description.
pub fn build_scene(config: &Json) -> Result<Simulation> {
    let mut cfg = SimConfig {
        dt: config.f64_or("dt", 1.0 / 150.0),
        thickness: config.f64_or("thickness", 1e-3),
        gravity: vec3_of(config.get("gravity"), Vec3::new(0.0, -9.8, 0.0)),
        record_tape: config.bool_or("record_tape", false),
        workers: config.usize_or("workers", 1),
        ..Default::default()
    };
    if config.str_or("diff_mode", "qr") == "dense" {
        cfg.diff_mode = crate::engine::DiffMode::Dense;
    }
    if config.str_or("collision_mode", "local") == "global" {
        cfg.collision_mode = crate::engine::CollisionMode::Global;
    }
    let mut sys = System::new();
    let bodies = config
        .get("bodies")
        .and_then(Json::as_arr)
        .context("scene config needs a 'bodies' array")?;
    for (i, b) in bodies.iter().enumerate() {
        let ty = b.str_or("type", "?").to_string();
        let pos = vec3_of(b.get("pos"), Vec3::default());
        let vel = vec3_of(b.get("vel"), Vec3::default());
        let density = b.f64_or("density", 1.0);
        match ty.as_str() {
            "ground" => {
                let he = b.f64_or("half_extent", 10.0);
                let body = RigidBody::frozen_from_mesh(primitives::box_mesh(Vec3::new(
                    he,
                    0.5,
                    he,
                )))
                .with_position(Vec3::new(0.0, b.f64_or("y", 0.0) - 0.5, 0.0));
                sys.add_rigid(body);
            }
            "box" => {
                let half = vec3_of(b.get("half"), Vec3::splat(0.5));
                let mut body = RigidBody::from_mesh(primitives::box_mesh(half), density)
                    .with_position(pos)
                    .with_velocity(vel)
                    .with_rotation(vec3_of(b.get("rot"), Vec3::default()));
                body.frozen = b.bool_or("frozen", false);
                sys.add_rigid(body);
            }
            "sphere" => {
                let body = RigidBody::from_mesh(
                    primitives::icosphere(b.f64_or("radius", 0.5), b.usize_or("subdiv", 2)),
                    density,
                )
                .with_position(pos)
                .with_velocity(vel);
                sys.add_rigid(body);
            }
            "cylinder" => {
                let body = RigidBody::from_mesh(
                    primitives::cylinder(
                        b.f64_or("radius", 0.1),
                        b.f64_or("height", 1.0),
                        b.usize_or("segments", 12),
                    ),
                    density,
                )
                .with_position(pos)
                .with_velocity(vel);
                sys.add_rigid(body);
            }
            "bunny" | "armadillo" => {
                let mesh = if ty == "bunny" {
                    primitives::bunny(b.f64_or("radius", 0.5), b.usize_or("subdiv", 2))
                } else {
                    primitives::armadillo(b.f64_or("radius", 0.5), b.usize_or("subdiv", 2))
                };
                let body =
                    RigidBody::from_mesh(mesh, density).with_position(pos).with_velocity(vel);
                sys.add_rigid(body);
            }
            "obj" => {
                let path = b.str_or("path", "");
                let mesh = crate::mesh::obj::load_obj(std::path::Path::new(path))?;
                let body =
                    RigidBody::from_mesh(mesh, density).with_position(pos).with_velocity(vel);
                sys.add_rigid(body);
            }
            "cloth" => {
                let res = b.get("res").and_then(Json::as_arr);
                let (nx, nz) = match res {
                    Some(r) if r.len() == 2 => (
                        r[0].as_usize().unwrap_or(8),
                        r[1].as_usize().unwrap_or(8),
                    ),
                    _ => (8, 8),
                };
                let size = b.get("size").and_then(Json::as_arr);
                let (sx, sz) = match size {
                    Some(s) if s.len() == 2 => {
                        (s[0].as_f64().unwrap_or(1.0), s[1].as_f64().unwrap_or(1.0))
                    }
                    _ => (1.0, 1.0),
                };
                let mesh = primitives::cloth_grid(nx, nz, sx, sz).translated(pos);
                let mut cloth = Cloth::from_grid(
                    mesh,
                    b.f64_or("density", 0.2),
                    b.f64_or("k_stretch", 1000.0),
                    b.f64_or("k_bend", 1.0),
                    b.f64_or("damping", 1.0),
                );
                if let Some(pins) = b.get("pins").and_then(Json::as_arr) {
                    for p in pins {
                        if let Some(i) = p.as_usize() {
                            cloth.pin(i);
                        }
                    }
                }
                sys.add_cloth(cloth);
            }
            other => bail!("body {i}: unknown type '{other}'"),
        }
    }
    Ok(Simulation::new(sys, cfg))
}

/// Parse and build from a JSON string.
pub fn build_scene_str(text: &str) -> Result<Simulation> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("scene json: {e}"))?;
    build_scene(&j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_mixed_scene() {
        let sim = build_scene_str(
            r#"{
              "dt": 0.01, "gravity": [0, -5, 0],
              "bodies": [
                {"type": "ground"},
                {"type": "box", "pos": [0, 1, 0], "density": 2.0},
                {"type": "sphere", "radius": 0.3, "pos": [2, 1, 0]},
                {"type": "cloth", "res": [4, 4], "size": [1, 1], "pos": [0, 2, 0],
                 "pins": [0, 4]}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(sim.sys.rigids.len(), 3);
        assert_eq!(sim.sys.cloths.len(), 1);
        assert!(sim.sys.rigids[0].frozen);
        assert_eq!(sim.cfg.dt, 0.01);
        assert_eq!(sim.cfg.gravity.y, -5.0);
        assert!(sim.sys.cloths[0].pinned[0]);
        assert!(sim.sys.cloths[0].pinned[4]);
    }

    #[test]
    fn rejects_unknown_body() {
        assert!(build_scene_str(r#"{"bodies": [{"type": "wormhole"}]}"#).is_err());
        assert!(build_scene_str(r#"{"no_bodies": 1}"#).is_err());
    }

    #[test]
    fn figurines_and_modes() {
        let sim = build_scene_str(
            r#"{
              "diff_mode": "dense", "collision_mode": "global",
              "bodies": [
                {"type": "bunny", "radius": 0.4, "pos": [0, 1, 0], "subdiv": 1},
                {"type": "armadillo", "radius": 0.4, "pos": [2, 1, 0], "subdiv": 1}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(sim.cfg.diff_mode, crate::engine::DiffMode::Dense);
        assert_eq!(sim.cfg.collision_mode, crate::engine::CollisionMode::Global);
        assert_eq!(sim.sys.rigids.len(), 2);
    }
}
