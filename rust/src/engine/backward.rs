//! Reverse pass over the step tape: backpropagates a loss on the final
//! state to initial conditions, per-step control forces, and rigid-body
//! masses — the gradient flows the paper's applications (§7.4) use.
//!
//! The per-step adjoint is factored into stages ([`begin_step`] → zone
//! groups → [`finish_step`]) so [`crate::batch`] can walk many scenes'
//! tapes in lockstep and route every scene's zone backwards through one
//! coordinator call per (step, pass) level.

use super::Simulation;
use crate::coordinator::ZoneBwItem;
use crate::diff::dynamics_grad::adjoint_solve;
use crate::diff::implicit::{backward_dense, backward_qr};
use crate::diff::tape::{Grads, StepRecord, ZoneRec};
use crate::engine::DiffMode;
use crate::math::Vec3;

/// Seed gradients ∂L/∂(final state).
#[derive(Clone, Debug, Default)]
pub struct LossGrad {
    pub rigid_q: Vec<[f64; 6]>,
    pub rigid_v: Vec<[f64; 6]>,
    pub cloth_x: Vec<Vec<Vec3>>,
    pub cloth_v: Vec<Vec<Vec3>>,
}

impl LossGrad {
    /// Zero seed shaped like the system.
    pub fn zeros(sim: &Simulation) -> LossGrad {
        LossGrad {
            rigid_q: vec![[0.0; 6]; sim.sys.rigids.len()],
            rigid_v: vec![[0.0; 6]; sim.sys.rigids.len()],
            cloth_x: sim.sys.cloths.iter().map(|c| vec![Vec3::default(); c.n_nodes()]).collect(),
            cloth_v: sim.sys.cloths.iter().map(|c| vec![Vec3::default(); c.n_nodes()]).collect(),
        }
    }
}

/// Running adjoint state: ∂L/∂(state) at the current tape position.
pub(crate) struct Adjoint {
    pub gq_r: Vec<[f64; 6]>,
    pub gv_r: Vec<[f64; 6]>,
    pub gx_c: Vec<Vec<Vec3>>,
    pub gv_c: Vec<Vec<Vec3>>,
}

/// Within-step intermediates, alive between the commit adjoint and the
/// candidate adjoint; zone-group backwards read and rewrite the `*bar`
/// entries.
pub(crate) struct StepWork {
    pub gqbar_r: Vec<[f64; 6]>,
    pub gq0_r: Vec<[f64; 6]>,
    pub gxbar_c: Vec<Vec<Vec3>>,
    pub gx0_c: Vec<Vec<Vec3>>,
}

/// Zeroed gradient accumulator shaped like `sim` with `steps` records.
pub(crate) fn grads_zeros(sim: &Simulation, steps: usize) -> Grads {
    let nr = sim.sys.rigids.len();
    Grads {
        rigid_q0: vec![[0.0; 6]; nr],
        rigid_v0: vec![[0.0; 6]; nr],
        cloth_x0: sim.sys.cloths.iter().map(|c| vec![Vec3::default(); c.n_nodes()]).collect(),
        cloth_v0: sim.sys.cloths.iter().map(|c| vec![Vec3::default(); c.n_nodes()]).collect(),
        rigid_force: vec![vec![Vec3::default(); nr]; steps],
        cloth_force: (0..steps)
            .map(|_| sim.sys.cloths.iter().map(|c| vec![Vec3::default(); c.n_nodes()]).collect())
            .collect(),
        rigid_mass: vec![0.0; nr],
    }
}

/// Zero-out adjoint entries of fixed DOFs (frozen bodies, pinned nodes).
fn clamp_fixed(sim: &Simulation, adj: &mut Adjoint) {
    for (b, body) in sim.sys.rigids.iter().enumerate() {
        if body.frozen {
            adj.gq_r[b] = [0.0; 6];
            adj.gv_r[b] = [0.0; 6];
        }
    }
    for (c, cloth) in sim.sys.cloths.iter().enumerate() {
        for i in 0..cloth.n_nodes() {
            if cloth.pinned[i] {
                adj.gx_c[c][i] = Vec3::default();
                adj.gv_c[c][i] = Vec3::default();
            }
        }
    }
}

/// Initial adjoint from the loss seed (with fixed DOFs clamped).
pub(crate) fn seed_adjoint(sim: &Simulation, seed: &LossGrad) -> Adjoint {
    let mut adj = Adjoint {
        gq_r: seed.rigid_q.clone(),
        gv_r: seed.rigid_v.clone(),
        gx_c: seed.cloth_x.clone(),
        gv_c: seed.cloth_v.clone(),
    };
    clamp_fixed(sim, &mut adj);
    adj
}

/// Commit adjoint of one step: q₁ = q̄′, v₁ = (q₁ − q₀)/h gives
/// ḡ_q̄′ = ḡ_q₁ + ḡ_v₁/h and ḡ_q₀ −= ḡ_v₁/h.
pub(crate) fn begin_step(sim: &Simulation, rec: &StepRecord, adj: &Adjoint) -> StepWork {
    let h = rec.h;
    let nr = sim.sys.rigids.len();
    let nc = sim.sys.cloths.len();
    let gqbar_r: Vec<[f64; 6]> = (0..nr)
        .map(|b| {
            let mut g = adj.gq_r[b];
            for k in 0..6 {
                g[k] += adj.gv_r[b][k] / h;
            }
            g
        })
        .collect();
    let gq0_r: Vec<[f64; 6]> = (0..nr)
        .map(|b| {
            let mut g = [0.0; 6];
            for k in 0..6 {
                g[k] = -adj.gv_r[b][k] / h;
            }
            g
        })
        .collect();
    let gxbar_c: Vec<Vec<Vec3>> = (0..nc)
        .map(|c| (0..adj.gx_c[c].len()).map(|i| adj.gx_c[c][i] + adj.gv_c[c][i] / h).collect())
        .collect();
    let gx0_c: Vec<Vec<Vec3>> = (0..nc)
        .map(|c| (0..adj.gx_c[c].len()).map(|i| -adj.gv_c[c][i] / h).collect())
        .collect();
    StepWork { gqbar_r, gq0_r, gxbar_c, gx0_c }
}

/// Gather ∂L/∂z for every zone in a (single fail-safe pass) group.
pub(crate) fn gather_zone_grads(group: &[ZoneRec], w: &StepWork) -> Vec<Vec<f64>> {
    group
        .iter()
        .map(|zr| {
            let zp = &zr.problem;
            let mut grad_z = vec![0.0; zp.n];
            for (k, e) in zp.entities.iter().enumerate() {
                let off = zp.offsets[k];
                match e {
                    crate::collision::zones::Entity::Rigid(b) => {
                        grad_z[off..off + 6].copy_from_slice(&w.gqbar_r[*b as usize]);
                    }
                    crate::collision::zones::Entity::ClothNode(c, i) => {
                        let g = w.gxbar_c[*c as usize][*i as usize];
                        grad_z[off] = g.x;
                        grad_z[off + 1] = g.y;
                        grad_z[off + 2] = g.z;
                    }
                }
            }
            grad_z
        })
        .collect()
}

/// Scatter a solved zone group's ∂L/∂q back into the step intermediates
/// and accumulate the mass-parameter gradients.
pub(crate) fn apply_zone_grads(
    sim: &Simulation,
    group: &[ZoneRec],
    grads_q: &[Vec<f64>],
    w: &mut StepWork,
    out: &mut Grads,
) {
    for (zr, grad_q) in group.iter().zip(grads_q) {
        let zp = &zr.problem;
        // Mass-parameter gradient through the zone's M̂ (uniform
        // density: ∂M̂_b/∂m = M̂_b/m). Using grad_q = M̂·u_z:
        //   ∂L/∂m += −u_zᵀ·(M̂_b/m)·(z*−q)|_b = −grad_q·(z*−q)|_b / m.
        for (k, e) in zp.entities.iter().enumerate() {
            if let crate::collision::zones::Entity::Rigid(b) = e {
                let body = &sim.sys.rigids[*b as usize];
                if body.frozen {
                    continue;
                }
                let off = zp.offsets[k];
                let mut dot = 0.0;
                for i in 0..6 {
                    dot += grad_q[off + i] * (zr.solution.q[off + i] - zp.q0[off + i]);
                }
                out.rigid_mass[*b as usize] += -dot / body.mass;
            }
        }
        // Scatter ∂L/∂q back (replacing the entries).
        for (k, e) in zp.entities.iter().enumerate() {
            let off = zp.offsets[k];
            match e {
                crate::collision::zones::Entity::Rigid(b) => {
                    w.gqbar_r[*b as usize].copy_from_slice(&grad_q[off..off + 6]);
                }
                crate::collision::zones::Entity::ClothNode(c, i) => {
                    w.gxbar_c[*c as usize][*i as usize] =
                        Vec3::new(grad_q[off], grad_q[off + 1], grad_q[off + 2]);
                }
            }
        }
    }
}

/// Zone-group backward dispatch by diff mode. `DiffMode::Pjrt` without a
/// coordinator (e.g. the `pjrt` feature or artifacts are absent)
/// degrades to the QR path with a logged warning instead of panicking.
pub(crate) fn dispatch_zone_backward(
    sim: &Simulation,
    items: &[ZoneBwItem<'_>],
) -> Vec<Vec<f64>> {
    let native_qr = |items: &[ZoneBwItem<'_>]| -> Vec<Vec<f64>> {
        items.iter().map(|it| backward_qr(it.problem, it.solution, it.grad_z).grad_q).collect()
    };
    match sim.cfg.diff_mode {
        DiffMode::Qr => native_qr(items),
        DiffMode::Dense => items
            .iter()
            .map(|it| backward_dense(it.problem, it.solution, it.grad_z).grad_q)
            .collect(),
        DiffMode::Pjrt => match &sim.coordinator {
            Some(coord) => coord.zone_backward_batch(items),
            None => {
                // Warn once, not once per zone group: a single backward
                // hits this for every (step, pass) level.
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    crate::warnlog!(
                        "DiffMode::Pjrt without a coordinator (pjrt feature/artifacts \
                         unavailable); falling back to the QR backward"
                    );
                });
                native_qr(items)
            }
        },
    }
}

/// Contiguous (pass, index-range) groups of a step's zone records, in
/// recorded (ascending-pass) order. Zones within one group are
/// independent; groups must be back-propagated last-to-first.
pub(crate) fn pass_groups(zones: &[ZoneRec]) -> Vec<(usize, std::ops::Range<usize>)> {
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < zones.len() {
        let pass = zones[lo].pass;
        let mut hi = lo + 1;
        while hi < zones.len() && zones[hi].pass == pass {
            hi += 1;
        }
        out.push((pass, lo..hi));
        lo = hi;
    }
    out
}

/// Candidate adjoint (q̄ = q₀ + h·(v₀ + Δv)) plus the rigid/cloth solve
/// adjoints of one step; rolls `adj` to the previous step's state.
pub(crate) fn finish_step(
    sim: &Simulation,
    s: usize,
    rec: &StepRecord,
    w: StepWork,
    adj: &mut Adjoint,
    out: &mut Grads,
) {
    let h = rec.h;
    let nr = sim.sys.rigids.len();
    let nc = sim.sys.cloths.len();
    let StepWork { gqbar_r, mut gq0_r, gxbar_c, mut gx0_c } = w;

    let mut gv0_r: Vec<[f64; 6]> = vec![[0.0; 6]; nr];
    let mut gdv_r: Vec<[f64; 6]> = vec![[0.0; 6]; nr];
    for b in 0..nr {
        if sim.sys.rigids[b].frozen {
            continue;
        }
        for k in 0..6 {
            gq0_r[b][k] += gqbar_r[b][k];
            // v₁ = (q₁−q₀)/h: v₀ and Δv act only through q̄ (gv/h is
            // already folded into gqbar above).
            gv0_r[b][k] = h * gqbar_r[b][k];
            gdv_r[b][k] = h * gqbar_r[b][k];
        }
    }
    let mut gv0_c: Vec<Vec<Vec3>> =
        (0..nc).map(|c| vec![Vec3::default(); sim.sys.cloths[c].n_nodes()]).collect();
    let mut gdv_c: Vec<Vec<Vec3>> = gv0_c.clone();
    for c in 0..nc {
        for i in 0..sim.sys.cloths[c].n_nodes() {
            if sim.sys.cloths[c].pinned[i] {
                continue;
            }
            gx0_c[c][i] += gxbar_c[c][i];
            gv0_c[c][i] = gxbar_c[c][i] * h;
            gdv_c[c][i] = gxbar_c[c][i] * h;
        }
    }

    // --- Rigid velocity update adjoint: Δq̇ = h·M̂⁻¹·Q. ---
    for (b, rs) in rec.rigid_solves.iter().enumerate() {
        if sim.sys.rigids[b].frozen {
            continue;
        }
        let u = rs
            .mass
            .lu_solve(&gdv_r[b])
            .unwrap_or_else(|| vec![0.0; 6]);
        // ∂L/∂f_ext (world force): translation rows of ḡ_Q = h·u.
        out.rigid_force[s][b] = Vec3::new(h * u[3], h * u[4], h * u[5]);
        // ∂L/∂m: −ḡ_Δq̇·Δq̇/m + h·u·[0; g] (gyro-term/m dropped).
        let mut d = 0.0;
        for k in 0..6 {
            d -= gdv_r[b][k] * rs.dqdot[k];
        }
        let g = sim.cfg.gravity;
        out.rigid_mass[b] +=
            d / sim.sys.rigids[b].mass + h * (u[3] * g.x + u[4] * g.y + u[5] * g.z);
    }

    // --- Cloth implicit solve adjoint. ---
    for (c, cs) in rec.cloth_solves.iter().enumerate() {
        let nnodes = sim.sys.cloths[c].n_nodes();
        let mut gflat = vec![0.0; 3 * nnodes];
        for i in 0..nnodes {
            gflat[3 * i] = gdv_c[c][i].x;
            gflat[3 * i + 1] = gdv_c[c][i].y;
            gflat[3 * i + 2] = gdv_c[c][i].z;
        }
        let u = adjoint_solve(&cs.a, &gflat);
        // b = h·(f₀ + h·Jx·v₀):
        //   ∂L/∂ext_force_i = h·u_i
        //   ∂L/∂x₀ += h·Jxᵀ·u   (∂f₀/∂x = Jx; higher-order dropped)
        //   ∂L/∂v₀ += h·(∂f/∂v)ᵀ·u + h²·Jxᵀ·u
        let jtu = cs.jx.matvec(&u); // Jx symmetric by construction
        for i in 0..nnodes {
            if sim.sys.cloths[c].pinned[i] {
                continue;
            }
            let ui = Vec3::new(u[3 * i], u[3 * i + 1], u[3 * i + 2]);
            let jti = Vec3::new(jtu[3 * i], jtu[3 * i + 1], jtu[3 * i + 2]);
            out.cloth_force[s][c][i] = ui * h;
            gx0_c[c][i] += jti * h;
            gv0_c[c][i] += ui * (h * cs.dfdv[i]) + jti * (h * h);
        }
    }

    // Roll to the previous step.
    adj.gq_r = gq0_r;
    adj.gv_r = gv0_r;
    adj.gx_c = gx0_c;
    adj.gv_c = gv0_c;
    clamp_fixed(sim, adj);
}

/// Run the backward pass over `sim`'s tape.
pub fn backward(sim: &Simulation, seed: &LossGrad) -> Grads {
    let steps = sim.tape.len();
    let mut out = grads_zeros(sim, steps);
    let mut adj = seed_adjoint(sim, seed);
    for (s, rec) in sim.tape.iter().enumerate().rev() {
        let mut w = begin_step(sim, rec, &adj);
        // Zone resolutions, reversed by fail-safe pass. Zones within one
        // pass are independent (disjoint entities) so their backwards are
        // computed together — which is exactly what the PJRT coordinator
        // batches.
        for (_pass, r) in pass_groups(&rec.zones).iter().rev() {
            let group = &rec.zones[r.clone()];
            let grad_zs = gather_zone_grads(group, &w);
            let items: Vec<ZoneBwItem<'_>> = group
                .iter()
                .zip(&grad_zs)
                .map(|(zr, g)| ZoneBwItem {
                    problem: &zr.problem,
                    solution: &zr.solution,
                    grad_z: g,
                })
                .collect();
            let grads_q = dispatch_zone_backward(sim, &items);
            apply_zone_grads(sim, group, &grads_q, &mut w, &mut out);
        }
        finish_step(sim, s, rec, w, &mut adj, &mut out);
    }
    out.rigid_q0 = adj.gq_r;
    out.rigid_v0 = adj.gv_r;
    out.cloth_x0 = adj.gx_c;
    out.cloth_v0 = adj.gv_c;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{Cloth, RigidBody, System};
    use crate::engine::{SimConfig, Simulation};
    use crate::mesh::primitives::{box_mesh, cloth_grid, unit_box};

    fn ground() -> RigidBody {
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0))
    }

    fn taped_cfg() -> SimConfig {
        SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() }
    }

    #[test]
    fn free_fall_position_gradient_exact() {
        // y_T = y₀ + Σ v_s·h with v updated by gravity only:
        // ∂y_T/∂y₀ = 1, ∂y_T/∂v₀ = T·h.
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 50.0, 0.0)),
        );
        let mut sim = Simulation::new(sys, taped_cfg());
        let n = 20;
        sim.run(n);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[0][4] = 1.0; // L = final y
        let g = backward(&sim, &seed);
        assert!((g.rigid_q0[0][4] - 1.0).abs() < 1e-10, "dq0 = {}", g.rigid_q0[0][4]);
        assert!(
            (g.rigid_v0[0][4] - n as f64 * sim.cfg.dt).abs() < 1e-9,
            "dv0 = {} want {}",
            g.rigid_v0[0][4],
            n as f64 * sim.cfg.dt
        );
    }

    #[test]
    fn control_force_gradient_matches_fd() {
        // Push a cube horizontally in zero gravity; L = final x.
        // ∂L/∂f_x at step s = h·(T−s)·h/m (force → Δv → position).
        let build = |fx: f64| -> f64 {
            let mut sys = System::new();
            sys.add_rigid(RigidBody::from_mesh(unit_box(), 2.0));
            let mut sim = Simulation::new(
                sys,
                SimConfig {
                    record_tape: true,
                    gravity: Vec3::default(),
                    dt: 1.0 / 100.0,
                    ..Default::default()
                },
            );
            for _ in 0..10 {
                sim.sys.rigids[0].ext_force = Vec3::new(fx, 0.0, 0.0);
                sim.step();
            }
            sim.sys.rigids[0].translation().x
        };
        let mut sys = System::new();
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 2.0));
        let mut sim = Simulation::new(
            sys,
            SimConfig {
                record_tape: true,
                gravity: Vec3::default(),
                dt: 1.0 / 100.0,
                ..Default::default()
            },
        );
        for _ in 0..10 {
            sim.sys.rigids[0].ext_force = Vec3::new(1.0, 0.0, 0.0);
            sim.step();
        }
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[0][3] = 1.0;
        let g = backward(&sim, &seed);
        // FD over a shared force scale: dL/dscale = Σ_s f·∂L/∂f_s.
        let eps = 1e-5;
        let fd = (build(1.0 + eps) - build(1.0 - eps)) / (2.0 * eps);
        let analytic: f64 = (0..10).map(|s| g.rigid_force[s][0].x).sum();
        assert!(
            (analytic - fd).abs() < 1e-6 * (1.0 + fd.abs()),
            "analytic {analytic} vs fd {fd}"
        );
    }

    #[test]
    fn contact_kills_normal_gradient() {
        // Cube dropped onto the ground; L = final y. Once resting, the
        // initial height has (almost) no influence — the contact
        // projection absorbs it.
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.7, 0.0)),
        );
        let mut sim = Simulation::new(sys, taped_cfg());
        sim.run(120); // long enough to settle
        assert!((sim.sys.rigids[1].translation().y - 0.5).abs() < 0.02);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[1][4] = 1.0;
        let g = backward(&sim, &seed);
        assert!(
            g.rigid_q0[1][4].abs() < 0.05,
            "normal-direction gradient should be absorbed: {}",
            g.rigid_q0[1][4]
        );
    }

    #[test]
    fn tangential_gradient_survives_contact() {
        // Same scene, L = final x: frictionless contact leaves
        // tangential motion unconstrained ⇒ ∂x_T/∂x₀ = 1.
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.7, 0.0)),
        );
        let mut sim = Simulation::new(sys, taped_cfg());
        sim.run(80);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[1][3] = 1.0;
        let g = backward(&sim, &seed);
        assert!(
            (g.rigid_q0[1][3] - 1.0).abs() < 0.05,
            "tangential gradient: {}",
            g.rigid_q0[1][3]
        );
    }

    #[test]
    fn mass_gradient_matches_fd_under_applied_force() {
        // Zero gravity, constant force: x_T ∝ 1/m, so ∂x_T/∂m < 0.
        let run = |m_density: f64| -> (Simulation, f64) {
            let mut sys = System::new();
            sys.add_rigid(RigidBody::from_mesh(unit_box(), m_density));
            let mut sim = Simulation::new(
                sys,
                SimConfig {
                    record_tape: true,
                    gravity: Vec3::default(),
                    dt: 1.0 / 100.0,
                    ..Default::default()
                },
            );
            for _ in 0..15 {
                sim.sys.rigids[0].ext_force = Vec3::new(3.0, 0.0, 0.0);
                sim.step();
            }
            let x = sim.sys.rigids[0].translation().x;
            (sim, x)
        };
        let (sim, _) = run(1.0);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[0][3] = 1.0;
        let g = backward(&sim, &seed);
        let eps = 1e-5;
        let fd = (run(1.0 + eps).1 - run(1.0 - eps).1) / (2.0 * eps);
        assert!(
            (g.rigid_mass[0] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
            "mass grad {} vs fd {fd}",
            g.rigid_mass[0]
        );
        assert!(g.rigid_mass[0] < 0.0);
    }

    #[test]
    fn cloth_force_gradient_matches_fd() {
        let run = |scale: f64| -> (Simulation, f64) {
            let mut sys = System::new();
            let mut cloth =
                Cloth::from_grid(cloth_grid(3, 3, 1.0, 1.0), 0.3, 100.0, 1.0, 0.2);
            cloth.pin(0);
            cloth.pin(12);
            sys.add_cloth(cloth);
            let mut sim = Simulation::new(
                sys,
                SimConfig {
                    record_tape: true,
                    gravity: Vec3::new(0.0, -2.0, 0.0),
                    dt: 1.0 / 100.0,
                    ..Default::default()
                },
            );
            for _ in 0..8 {
                sim.sys.cloths[0].ext_force[8] = Vec3::new(scale, 0.0, 0.0);
                sim.step();
            }
            let x = sim.sys.cloths[0].x[8].x;
            (sim, x)
        };
        let (sim, _) = run(0.5);
        let mut seed = LossGrad::zeros(&sim);
        seed.cloth_x[0][8].x = 1.0;
        let g = backward(&sim, &seed);
        let analytic: f64 = (0..8).map(|s| g.cloth_force[s][0][8].x).sum();
        let eps = 1e-5;
        let fd = (run(0.5 + eps).1 - run(0.5 - eps).1) / (2.0 * eps);
        // First-order adjoint drops force-Hessian terms: allow ~1%.
        assert!(
            (analytic - fd).abs() < 2e-2 * (1.0 + fd.abs()),
            "analytic {analytic} vs fd {fd}"
        );
    }

    #[test]
    fn initial_velocity_gradient_through_collision() {
        // Cube A slides into cube B (zero-g); L = B's final x. ∂L/∂v_A
        // must be positive (A pushes B further) — checked against FD.
        let run = |v0: f64| -> (Simulation, f64) {
            let mut sys = System::new();
            sys.add_rigid(
                RigidBody::from_mesh(unit_box(), 1.0)
                    .with_position(Vec3::new(-1.2, 0.02, 0.05))
                    .with_velocity(Vec3::new(v0, 0.0, 0.0)),
            );
            sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
            let mut sim = Simulation::new(
                sys,
                SimConfig {
                    record_tape: true,
                    gravity: Vec3::default(),
                    dt: 1.0 / 100.0,
                    ..Default::default()
                },
            );
            sim.run(40);
            let x = sim.sys.rigids[1].translation().x;
            (sim, x)
        };
        let (sim, _) = run(2.0);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[1][3] = 1.0;
        let g = backward(&sim, &seed);
        // Wide central difference: the forward map is only piecewise
        // smooth (contact events shift between runs), so tiny eps
        // measures event noise rather than the slope.
        let eps = 2e-2;
        let fd = (run(2.0 + eps).1 - run(2.0 - eps).1) / (2.0 * eps);
        assert!(g.rigid_v0[0][3] > 0.01, "gradient should be positive: {}", g.rigid_v0[0][3]);
        assert!(
            (g.rigid_v0[0][3] - fd).abs() < 0.25 * (1.0 + fd.abs()),
            "analytic {} vs fd {fd}",
            g.rigid_v0[0][3]
        );
    }
}
