//! The simulation engine (paper §3, Fig. 1): time integration → continuous
//! collision detection → impact-zone resolution, with a tape for
//! end-to-end backpropagation ([`backward`]). [`Simulation`] drives the
//! staged step primitives documented on [`StepState`]; scene JSON
//! loading lives in [`scene`]. Per-step buffers come from the scene's
//! [`BatchArena`] (disabled/plain for standalone scenes, shared across
//! a [`crate::batch::SceneBatch`]) with logical-byte accounting in
//! [`crate::util::memory`].
pub mod backward;
pub mod scene;

use crate::bodies::System;
use crate::collision::zones::{build_zones, zones_bytes};
use crate::collision::{
    detect_in, detect_incremental, surfaces_from_system, CacheCounters, CollisionState,
    DetectStats, WarmStarts,
};
use crate::diff::tape::{ClothSolveRec, RigidSolveRec, StepRecord, ZoneRec};
use crate::math::sparse::Triplets;
use crate::math::{euler, Vec3};
use crate::solver::implicit_euler::{cloth_implicit_step, cloth_implicit_step_in, rigid_step_damped};
use crate::solver::lcp::merge_zones;
use crate::solver::zone_solver::{SolveOpts, ZoneProblem, ZoneSolution};
use crate::util::arena::BatchArena;
use crate::util::json::Json;
use crate::util::memory::MemCategory;
use crate::util::pool::Pool;
use crate::util::telemetry::{self, Trace};
// lint:allow-file(wallclock: Instant reads live in obs_begin/obs_end,
// are telemetry-gated (None when the registry is disabled), and feed
// only stage-duration traces — never simulation numerics)
use std::sync::Mutex;
use std::time::Instant;

/// A contained per-scene failure: what went wrong stepping one scene,
/// and at which step. This is the error type the fault-containment
/// layer threads from the solver up through [`Simulation::try_step`],
/// the lockstep batch, and the pipelined paths, so
/// [`crate::batch::SceneBatch`] can quarantine the failed scene while
/// healthy scenes finish (see [`crate::batch::FaultPolicy`]).
#[derive(Clone, Debug, PartialEq)]
pub enum SceneError {
    /// A state quantity (integrated velocity, candidate or resolved
    /// coordinates) became non-finite. The failed step was rolled back;
    /// the committed state is still the last good one.
    NonFinite { what: &'static str, step: usize },
    /// A zone solve produced a divergent solution (non-finite
    /// coordinates or violation) at the given fail-safe pass.
    ZoneDivergence { step: usize, pass: usize, zones: usize },
    /// Collision detection / zoning produced non-finite contact data,
    /// so the zone problems cannot be solved soundly.
    CcdFailure { step: usize },
    /// A worker panicked while stepping the scene; the payload is the
    /// panic message when it was a string.
    WorkerPanic { payload: String },
}

impl std::fmt::Display for SceneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SceneError::NonFinite { what, step } => {
                write!(f, "non-finite {what} at step {step}")
            }
            SceneError::ZoneDivergence { step, pass, zones } => {
                write!(f, "zone solve diverged at step {step} pass {pass} ({zones} zone(s))")
            }
            SceneError::CcdFailure { step } => {
                write!(f, "collision detection produced non-finite contact data at step {step}")
            }
            SceneError::WorkerPanic { payload } => write!(f, "worker panicked: {payload}"),
        }
    }
}

impl std::error::Error for SceneError {}

impl SceneError {
    /// Convert a caught panic payload (from `catch_unwind`) into the
    /// typed error, preserving string messages.
    pub fn from_panic(payload: &(dyn std::any::Any + Send)) -> SceneError {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "non-string panic payload".to_string());
        SceneError::WorkerPanic { payload: msg }
    }
}

/// How zone-solve backward passes are computed (§6 / Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffMode {
    /// The paper's QR fast path (Eqs. 14–15).
    Qr,
    /// Dense (n+m)³ KKT solve — the "W/o FD" ablation.
    Dense,
    /// Batched through the AOT PJRT artifacts via the coordinator
    /// (requires `Simulation::coordinator`).
    Pjrt,
}

/// Collision-handling strategy (§5 / Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollisionMode {
    /// Localized impact zones (ours).
    LocalZones,
    /// Merge everything into one global optimization (LCP-style baseline).
    Global,
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub dt: f64,
    pub gravity: Vec3,
    /// Contact thickness δ.
    pub thickness: f64,
    pub diff_mode: DiffMode,
    pub collision_mode: CollisionMode,
    /// Fail-safe re-detection passes per step.
    pub max_resolve_passes: usize,
    pub record_tape: bool,
    /// Worker threads for independent zone solves.
    pub workers: usize,
    /// Rigid-body angular damping (s⁻¹). Small default prevents
    /// frictionless resting stacks from accumulating spin creep.
    pub angular_damping: f64,
    /// Fail-safe ladder rungs [`Simulation::step_recovering`] may climb
    /// after a failed step: 1 = boosted re-solve, 2 = + half-dt
    /// substeps. 0 disables recovery (a failed step is returned as-is).
    pub recovery_budget: usize,
    /// Persist collision state across steps: surfaces (and their BVHs)
    /// survive commit, so step N+1 refits instead of rebuilding, and
    /// broad-phase candidate lists are cached across steps. Detection
    /// output is bitwise-identical either way — the refit-vs-rebuild
    /// oracle in `tests/integration_refit.rs` holds it to that.
    pub incremental_collision: bool,
    /// Rebuild a surface's BVH (instead of refitting) once refits have
    /// inflated its summed node surface area past this ratio of the
    /// value at the last build ([`crate::collision::bvh::Bvh::quality`]).
    pub bvh_degrade_ratio: f64,
    /// Padding on the cross-step broad-phase cull snapshot: larger
    /// values keep cached candidate lists valid across more motion at
    /// the cost of longer (superset) lists for the narrow phase's exact
    /// filter to discard.
    pub cull_pad: f64,
    /// Seed each zone solve from the previous step's parked multipliers
    /// when the zone's (sorted) entity set matches. Changes solver
    /// iterates — *not* bitwise-neutral — so it is opt-in; default off.
    pub warm_start_zones: bool,
    /// Math-kernel implementation selector
    /// ([`crate::math::simd::SimdMode`]). `None` (the default) leaves
    /// the process-wide mode alone — the `DIFFSIM_SIMD` environment
    /// variable or the compile-time default decides. `Some(mode)` is
    /// applied process-wide at [`Simulation::new`] *and* on entry to
    /// every step driver, so the scene constructed/stepped last wins;
    /// mixing scenes that pin different modes in one process is a
    /// configuration error. `Scalar`/`Ordered` trajectories are
    /// bitwise-identical; `Fast` is ULP-bounded per kernel (see the
    /// [`crate::math::simd`] module docs).
    pub simd: Option<crate::math::simd::SimdMode>,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            dt: 1.0 / 150.0,
            gravity: Vec3::new(0.0, -9.8, 0.0),
            thickness: 1e-3,
            diff_mode: DiffMode::Qr,
            collision_mode: CollisionMode::LocalZones,
            max_resolve_passes: 8,
            record_tape: false,
            workers: 1,
            angular_damping: 0.2,
            recovery_budget: 2,
            incremental_collision: true,
            bvh_degrade_ratio: 4.0,
            cull_pad: 0.05,
            warm_start_zones: false,
            simd: None,
        }
    }
}

/// Per-step metrics (coordinator telemetry; E11). `PartialEq` so the
/// refit-vs-rebuild parity oracle can compare whole per-step records.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepStats {
    pub impacts: usize,
    pub zones: usize,
    pub max_zone_dofs: usize,
    pub max_zone_constraints: usize,
    pub resolve_passes: usize,
    pub detect: DetectStats,
    pub cg_iters: usize,
    /// Accepted Gauss–Newton steps summed over every zone solve of
    /// every fail-safe pass this step (solver-side ground truth the
    /// telemetry trace is checked against).
    pub gn_iters: usize,
    /// Zone solves this step that finished with `converged: false`
    /// (their solutions were still applied — the fail-safe loop's
    /// re-detection is the backstop). Mirrored into the
    /// `solver.zone_nonconverged` obs counter with a rate-limited
    /// warning; a sustained non-zero rate is a solver-health signal.
    pub zone_nonconverged: usize,
}

/// The simulation: owns the system, steps it forward, records the tape.
pub struct Simulation {
    pub sys: System,
    pub cfg: SimConfig,
    pub tape: Vec<StepRecord>,
    pub steps: usize,
    pub last_stats: StepStats,
    pool: Pool,
    /// Buffer source for per-step contact/solver/tape allocations:
    /// [`BatchArena::disabled`] (plain allocation) for standalone
    /// scenes; [`crate::batch::SceneBatch`] installs one shared pooled
    /// arena across its scenes. Content-neutral either way.
    arena: BatchArena,
    /// Cross-step collision state, parked between steps: taken at pass 0
    /// of the next step's detection (when `cfg.incremental_collision`
    /// and the cached surfaces still match the system), returned at
    /// commit. `None` between steps means the next step rebuilds — step
    /// states dropped on error or rollback invalidate the cache for
    /// free. A mutex (not a cell) because lockstep batch drivers run the
    /// detection stage through `&Simulation` from worker threads.
    collision_cache: Mutex<Option<CollisionState>>,
    /// Lifetime totals of the per-step cache counters, rolled up at each
    /// commit (benches and tests read these; telemetry publishes the
    /// same numbers as `collision.*` counters).
    collision_counters: CacheCounters,
    /// Optional external zone-solver hook; receives the problems and
    /// returns solutions (testing / alternative solvers).
    #[allow(clippy::type_complexity)]
    pub zone_hook: Option<Box<dyn Fn(&[ZoneProblem]) -> Vec<ZoneSolution> + Send + Sync>>,
    /// PJRT coordinator (batched zone backwards / vertex transforms).
    pub coordinator: Option<std::sync::Arc<crate::coordinator::Coordinator>>,
    /// Per-rollout JSONL trace sink: when set, every staged step
    /// primitive writes one schema-versioned event per call. Installed
    /// via [`Simulation::set_trace`] (or inherited from
    /// [`telemetry::install_global_trace`] at construction, which is
    /// how `--trace` reaches driver-built scenes). Purely
    /// observational — trajectories are bitwise-unchanged.
    trace: Option<Trace>,
}

/// In-flight state of one staged forward step, produced by
/// [`Simulation::integrate`] and consumed by [`Simulation::commit`].
///
/// The step is factored into reusable stages —
/// `integrate → candidates → (detect_and_zone → solve_zones → scatter)*
/// → commit` — mirroring the backward's
/// `begin_step/gather/apply/finish_step` decomposition, so
/// [`crate::batch`] can advance many scenes in lockstep and pool every
/// scene's zone problems at each fail-safe pass into one batched solve.
/// [`Simulation::step`] drives the stages sequentially; single-scene
/// behavior is identical to the pre-staged monolith.
pub struct StepState {
    stats: StepStats,
    rigid_recs: Vec<RigidSolveRec>,
    cloth_recs: Vec<ClothSolveRec>,
    cloth_ext: Vec<Vec<Vec3>>,
    rigid_vhalf: Vec<[f64; 6]>,
    cloth_vhalf: Vec<Vec<Vec3>>,
    rigid_qbar: Vec<[f64; 6]>,
    cloth_xbar: Vec<Vec<Vec3>>,
    zone_recs: Vec<ZoneRec>,
    /// The persistent collision state while the step is in flight:
    /// adopted from the scene's parked cache (or freshly built) at
    /// pass 0, refreshed in place each later pass, handed back to the
    /// cache at commit. Dropping the step state without committing
    /// leaves the parked slot empty, so a failed or abandoned step can
    /// never leak stale surfaces into the next one.
    surfs: Option<CollisionState>,
    /// (zone entity set → multiplier rows) captured at scatter; promoted
    /// wholesale to the cache's warm-start store at commit when
    /// `cfg.warm_start_zones` is on.
    warm_pending: WarmStarts,
}

impl StepState {
    /// Are all integrated velocities and candidate coordinates finite?
    /// The fallible step paths' commit gate: checked (pure observation,
    /// no numeric effect) before [`Simulation::commit`] so a poisoned
    /// step is rolled back instead of committed. Empty stages (e.g.
    /// before [`Simulation::candidates`]) count as finite.
    pub fn is_finite(&self) -> bool {
        all_finite_6(&self.rigid_vhalf)
            && all_finite_v3(&self.cloth_vhalf)
            && all_finite_6(&self.rigid_qbar)
            && all_finite_v3(&self.cloth_xbar)
    }
}

/// Committed-state snapshot for the retry ladder's multi-commit
/// remedies: enough to roll a substep pair back as a unit
/// (coordinates, velocities, external forces, counters, tape length).
struct Checkpoint {
    rigid: Vec<([f64; 6], [f64; 6], Vec3)>,
    cloth: Vec<(Vec<Vec3>, Vec<Vec3>, Vec<Vec3>)>,
    steps: usize,
    last_stats: StepStats,
    tape_len: usize,
}

fn all_finite_6(v: &[[f64; 6]]) -> bool {
    v.iter().all(|a| a.iter().all(|x| x.is_finite()))
}

fn all_finite_v3(v: &[Vec<Vec3>]) -> bool {
    v.iter().all(|c| c.iter().all(|p| p.is_finite()))
}

/// Rate-limited "zone solve(s) finished non-converged" warning: logs the
/// first occurrence, then only when the process-wide running total
/// crosses a power of two — O(log N) lines for N events.
fn warn_nonconverged(n: usize) {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEEN: AtomicU64 = AtomicU64::new(0);
    let prev = SEEN.fetch_add(n as u64, Ordering::Relaxed);
    let now = prev + n as u64;
    if prev == 0 || now.ilog2() > prev.max(1).ilog2() {
        crate::warnlog!(
            "{n} zone solve(s) finished non-converged ({now} total); \
             solutions applied, fail-safe re-detection is the backstop"
        );
    }
}

impl Simulation {
    pub fn new(sys: System, cfg: SimConfig) -> Simulation {
        // Handle to the process-wide persistent worker runtime, budgeted
        // at cfg.workers — per-pass zone solves share one worker set
        // with batch stepping and gradient gathers, and no OS threads
        // are spawned on the stepping hot path.
        let pool = Pool::shared(cfg.workers);
        if let Some(mode) = cfg.simd {
            crate::math::simd::set_mode(mode);
        }
        Simulation {
            sys,
            cfg,
            tape: Vec::new(),
            steps: 0,
            last_stats: StepStats::default(),
            pool,
            arena: BatchArena::disabled(),
            collision_cache: Mutex::new(None),
            collision_counters: CacheCounters::default(),
            zone_hook: None,
            coordinator: None,
            trace: telemetry::default_trace(),
        }
    }

    /// Install (or remove) this scene's JSONL trace sink. Every staged
    /// step primitive then writes one event per call (span close) with
    /// its duration and stage payload; see
    /// [`crate::util::telemetry::Trace`]. Passing `None` drops the
    /// handle, which flushes the file once the last clone goes.
    pub fn set_trace(&mut self, trace: Option<Trace>) {
        self.trace = trace;
    }

    /// The trace sink currently installed, if any.
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// Clock origin for an instrumented stage: `Some` only when this
    /// call will be reported (a trace sink is installed or the registry
    /// is enabled) — disabled-mode cost is this one check.
    fn obs_begin(&self) -> Option<Instant> {
        if self.trace.is_some() || telemetry::enabled() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close an instrumented stage: record the duration into the
    /// registry histogram `step.<stage>` (when enabled) and write one
    /// trace event (when a sink is installed), letting `fill` attach
    /// the stage payload.
    fn obs_end(&self, stage: &str, t0: Option<Instant>, fill: impl FnOnce(&mut Json)) {
        let t0 = match t0 {
            Some(t) => t,
            None => return,
        };
        let dur = t0.elapsed().as_secs_f64();
        if telemetry::enabled() {
            telemetry::hist(&format!("step.{stage}")).record(dur);
        }
        if let Some(tr) = &self.trace {
            let mut ev = Json::obj();
            ev.set("span", stage).set("step", self.steps).set("dur_s", dur);
            fill(&mut ev);
            tr.write_event(ev);
        }
    }

    /// Replace this scene's worker pool (injection point for dedicated
    /// or baseline pools; benches compare spawn-per-call vs persistent).
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = pool;
    }

    /// Replace this scene's buffer arena (cross-scene pooling when the
    /// same arena is shared by a batch; [`BatchArena::tracked`] for
    /// accounting without pooling; [`BatchArena::disabled`] to restore
    /// the plain-allocation default). Trajectories and gradients are
    /// bitwise-identical in every mode. Swapping arenas mid-tape is
    /// harmless for correctness but splits the tape's recycling and
    /// accounting across arenas — do it between rollouts.
    pub fn set_arena(&mut self, arena: BatchArena) {
        self.arena = arena;
    }

    /// The buffer arena this scene checks per-step allocations out of.
    pub fn arena(&self) -> &BatchArena {
        &self.arena
    }

    /// Drop the parked cross-step collision state: the next step
    /// rebuilds surfaces from scratch. Detection output is
    /// cache-independent, so this is never *required* for soundness —
    /// topology/body-set changes are caught by
    /// [`CollisionState::matches`] and positions are re-rolled from
    /// committed state every step — but it is the explicit hook for
    /// tests and for callers that want a guaranteed cold pipeline.
    pub fn invalidate_collision_cache(&self) {
        *self.collision_cache.lock().expect("collision cache lock poisoned") = None;
    }

    /// Lifetime totals of the incremental-collision counters (refits,
    /// rebuilds, cull-cache hits/misses, warm-start hits/misses), rolled
    /// up from the per-step state at each commit.
    pub fn collision_counters(&self) -> CacheCounters {
        self.collision_counters
    }

    /// Structural audit of every parked BVH
    /// ([`crate::collision::bvh::Bvh::check_invariants`]); panics on a
    /// malformed tree, no-op when nothing is parked. Test/debug hook —
    /// the scenario-fuzz lane runs it between steps with the incremental
    /// pipeline on.
    pub fn check_collision_cache_invariants(&self) {
        let guard = self.collision_cache.lock().expect("collision cache lock poisoned");
        if let Some(cs) = guard.as_ref() {
            for s in &cs.surfs {
                s.bvh.check_invariants();
            }
        }
    }

    /// Re-assert this scene's pinned kernel mode (if any) — the mode is
    /// process-global, so a scene constructed since our last step may
    /// have switched it.
    #[inline]
    fn apply_simd(&self) {
        if let Some(mode) = self.cfg.simd {
            crate::math::simd::set_mode(mode);
        }
    }

    /// Advance one step of length `cfg.dt`: the thin sequential driver
    /// over the staged primitives (see [`StepState`]).
    pub fn step(&mut self) {
        self.apply_simd();
        let mut st = self.integrate();
        self.candidates(&mut st);
        // Fail-safe collision resolution over impact zones.
        for pass in 0..self.cfg.max_resolve_passes {
            let problems = self.detect_and_zone(&mut st, pass);
            if problems.is_empty() {
                break;
            }
            let solutions = self.solve_zones(&problems);
            let max_disp = self.scatter(&mut st, problems, solutions, pass);
            // Proximity contacts re-fire at gap ≈ δ with negligible
            // corrections; don't burn the remaining passes on no-ops.
            if max_disp < 1e-9 {
                break;
            }
        }
        self.commit(st);
    }

    /// One fallible, *transactional* step attempt: the staged loop of
    /// [`Simulation::step`] with cheap soundness gates between stages —
    /// non-finite integrated velocities or candidates, non-finite zone
    /// problem data (CCD failure), divergent zone solutions, non-finite
    /// resolved coordinates. On `Err` nothing was committed: the
    /// coordinates, velocities, forces, tape, and step counter are
    /// exactly as before the call (the implicit last-good checkpoint),
    /// so the caller can retry ([`Simulation::step_recovering`]) or
    /// quarantine the scene ([`crate::batch::FaultPolicy`]).
    ///
    /// The gates are pure observation (reads only), so on the `Ok` path
    /// the committed state is bitwise-identical to [`Simulation::step`].
    pub fn try_step(&mut self) -> Result<(), SceneError> {
        self.try_step_with(&SolveOpts::default())
    }

    /// [`Simulation::try_step`] with explicit zone-solve tuning — the
    /// retry ladder's entry point for boosted re-solves.
    pub fn try_step_with(&mut self, opts: &SolveOpts) -> Result<(), SceneError> {
        self.apply_simd();
        let step = self.steps;
        let mut st = self.integrate();
        if !(all_finite_6(&st.rigid_vhalf) && all_finite_v3(&st.cloth_vhalf)) {
            return Err(SceneError::NonFinite { what: "integrated velocity", step });
        }
        self.candidates(&mut st);
        if !(all_finite_6(&st.rigid_qbar) && all_finite_v3(&st.cloth_xbar)) {
            return Err(SceneError::NonFinite { what: "candidate positions", step });
        }
        for pass in 0..self.cfg.max_resolve_passes {
            let problems = self.detect_and_zone(&mut st, pass);
            if problems.is_empty() {
                break;
            }
            if problems.iter().any(|p| !p.is_finite()) {
                self.abandon_pass(problems, Vec::new());
                return Err(SceneError::CcdFailure { step });
            }
            let solutions = self.solve_zones_with(&problems, opts);
            if solutions.iter().any(|s| !s.is_finite()) {
                let zones = problems.len();
                self.abandon_pass(problems, solutions);
                return Err(SceneError::ZoneDivergence { step, pass, zones });
            }
            let max_disp = self.scatter(&mut st, problems, solutions, pass);
            if max_disp < 1e-9 {
                break;
            }
        }
        if !st.is_finite() {
            return Err(SceneError::NonFinite { what: "resolved coordinates", step });
        }
        self.commit(st);
        Ok(())
    }

    /// Hand an aborted pass's zone buffers back to the arena. Solutions
    /// (when present) were never scattered; problems were never retired.
    /// Earlier committed-to-tape passes of the aborted step are dropped
    /// with the `StepState` — their Solver charges were already released
    /// at scatter and never re-charged to Tape, so accounting balances.
    pub(crate) fn abandon_pass(&self, problems: Vec<ZoneProblem>, solutions: Vec<ZoneSolution>) {
        for zp in problems {
            zp.retire(&self.arena);
        }
        for sol in solutions {
            self.arena.park_vec(sol.q);
            self.arena.park_vec(sol.lambda);
        }
    }

    /// [`Simulation::try_step`] plus the solver fail-safe ladder: on a
    /// failed attempt the step is rolled back to the last-good state
    /// and retried with escalating remedies, bounded by
    /// `cfg.recovery_budget` rungs —
    ///
    /// 1. re-solve the step with a boosted AL penalty and extra
    ///    Tikhonov regularization ([`SolveOpts`]), and
    /// 2. re-run the step as two half-`dt` substeps with the boosted
    ///    solver (a recovered substep pair advances `steps` by 2 and,
    ///    when taping, pushes two `h/2` records — the backward handles
    ///    per-record `h`).
    ///
    /// Every escalation is counted in obs: `fault.rollbacks`,
    /// `fault.retries`, `fault.mu_boosts`, `fault.substeps`,
    /// `fault.recovered`, `fault.giveups`.
    pub fn step_recovering(&mut self) -> Result<(), SceneError> {
        match self.try_step() {
            Ok(()) => Ok(()),
            Err(e) => self.recover(e),
        }
    }

    fn recover(&mut self, mut last: SceneError) -> Result<(), SceneError> {
        fn bump(name: &str) {
            if telemetry::enabled() {
                telemetry::counter(name).incr();
            }
        }
        bump("fault.rollbacks");
        let boosted = SolveOpts { mu_scale: 100.0, extra_reg: 1e-6 };
        let budget = self.cfg.recovery_budget;
        // Rung 1 — boosted re-solve at full dt.
        if budget >= 1 {
            bump("fault.retries");
            bump("fault.mu_boosts");
            match self.try_step_with(&boosted) {
                Ok(()) => {
                    bump("fault.recovered");
                    return Ok(());
                }
                Err(e) => {
                    bump("fault.rollbacks");
                    last = e;
                }
            }
        }
        // Rung 2 — two half-dt substeps with the boosted solver. The
        // first substep commits, so an explicit checkpoint guards the
        // pair: if the second fails, both are rolled back.
        if budget >= 2 {
            bump("fault.retries");
            bump("fault.substeps");
            let ck = self.checkpoint();
            let dt = self.cfg.dt;
            self.cfg.dt = 0.5 * dt;
            let mut ok = true;
            for _ in 0..2 {
                if let Err(e) = self.try_step_with(&boosted) {
                    last = e;
                    ok = false;
                    break;
                }
            }
            self.cfg.dt = dt;
            if ok {
                bump("fault.recovered");
                return Ok(());
            }
            bump("fault.rollbacks");
            self.restore(ck);
        }
        bump("fault.giveups");
        Err(last)
    }

    /// Snapshot the committed dynamic state (coordinates, velocities,
    /// external forces, counters, tape length) so a multi-commit remedy
    /// can be rolled back as a unit.
    fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            rigid: self.sys.rigids.iter().map(|b| (b.q, b.qdot, b.ext_force)).collect(),
            cloth: self
                .sys
                .cloths
                .iter()
                .map(|c| (c.x.clone(), c.v.clone(), c.ext_force.clone()))
                .collect(),
            steps: self.steps,
            last_stats: self.last_stats,
            tape_len: self.tape.len(),
        }
    }

    /// Restore a [`Simulation::checkpoint`]: dynamic state and counters
    /// roll back, and tape records pushed since are popped and recycled.
    fn restore(&mut self, ck: Checkpoint) {
        for (b, (q, qdot, f)) in self.sys.rigids.iter_mut().zip(&ck.rigid) {
            b.q = *q;
            b.qdot = *qdot;
            b.ext_force = *f;
        }
        for (c, (x, v, f)) in self.sys.cloths.iter_mut().zip(&ck.cloth) {
            c.x.clone_from(x);
            c.v.clone_from(v);
            c.ext_force.clone_from(f);
        }
        self.steps = ck.steps;
        self.last_stats = ck.last_stats;
        // The parked surfaces' x0/warm-start rows came from steps that
        // are being rolled back; drop them so the rolled-back state
        // restarts the pipeline cold. (Adoption re-rolls x0 from
        // committed state anyway — this keeps rollback observably
        // identical to a fresh scene rather than relying on that.)
        self.invalidate_collision_cache();
        while self.tape.len() > ck.tape_len {
            if let Some(rec) = self.tape.pop() {
                self.arena.uncharge(MemCategory::Tape, rec.bytes);
                rec.recycle(&self.arena);
            }
        }
    }

    /// Run `n` steps through [`Simulation::step_recovering`], stopping
    /// at the first unrecovered failure (returned with the 0-based
    /// iteration it happened on; earlier steps remain committed).
    pub fn try_run(&mut self, n: usize) -> Result<(), (usize, SceneError)> {
        for k in 0..n {
            self.step_recovering().map_err(|e| (k, e))?;
        }
        Ok(())
    }

    /// Stage 1 — unconstrained velocity update (Eq. 3).
    pub fn integrate(&self) -> StepState {
        let t0 = self.obs_begin();
        let h = self.cfg.dt;
        let g = self.cfg.gravity;
        let mut stats = StepStats::default();
        let mut rigid_recs = Vec::with_capacity(self.sys.rigids.len());
        let mut rigid_vhalf: Vec<[f64; 6]> = Vec::with_capacity(self.sys.rigids.len());
        for b in &self.sys.rigids {
            let dqdot = rigid_step_damped(b, h, g, self.cfg.angular_damping);
            let mut v = b.qdot;
            for k in 0..6 {
                v[k] += dqdot[k];
            }
            rigid_vhalf.push(v);
            if self.cfg.record_tape {
                rigid_recs.push(RigidSolveRec {
                    mass: b.mass_matrix(),
                    dqdot,
                    q_gen: b.generalized_force(g),
                    ext_force: b.ext_force,
                });
            }
        }
        let mut cloth_recs = Vec::with_capacity(self.sys.cloths.len());
        let mut cloth_vhalf: Vec<Vec<Vec3>> = Vec::with_capacity(self.sys.cloths.len());
        let mut cloth_ext: Vec<Vec<Vec3>> = Vec::new();
        for c in &self.sys.cloths {
            // Taped solves loan their retained buffers (system CSR, Δv)
            // from the scene's arena; `StepRecord::recycle` hands them
            // back at `clear_tape`, so repeated rollouts re-fill warm
            // CSR storage. Untaped solves retain nothing — plain
            // allocation stays the right call there.
            let solve = if self.cfg.record_tape {
                cloth_implicit_step_in(c, h, g, &self.arena)
            } else {
                cloth_implicit_step(c, h, g)
            };
            stats.cg_iters += solve.iters;
            let v: Vec<Vec3> = (0..c.n_nodes())
                .map(|i| if c.pinned[i] { Vec3::default() } else { c.v[i] + solve.dv[i] })
                .collect();
            cloth_vhalf.push(v);
            if self.cfg.record_tape {
                let dim = 3 * c.n_nodes();
                let mut jx_t = Triplets::new(dim, dim);
                let dfdv = c.force_jacobian(&mut jx_t, 0, false);
                let jnnz = jx_t.nnz();
                let jx = jx_t.to_csr_into(
                    self.arena.loan_vec(jnnz),
                    self.arena.loan_vec(jnnz),
                    self.arena.loan_vec(dim + 1),
                );
                cloth_recs.push(ClothSolveRec { a: solve.a, jx, dfdv, dv: solve.dv });
                cloth_ext.push(c.ext_force.clone());
            }
        }
        if telemetry::enabled() {
            telemetry::counter("solver.cg_iters").add(stats.cg_iters as u64);
        }
        self.obs_end("integrate", t0, |ev| {
            ev.set("cg_iters", stats.cg_iters);
        });
        StepState {
            stats,
            rigid_recs,
            cloth_recs,
            cloth_ext,
            rigid_vhalf,
            cloth_vhalf,
            rigid_qbar: Vec::new(),
            cloth_xbar: Vec::new(),
            // Taped steps accumulate zone records; reuse a parked list
            // so repeated rollouts don't regrow it from scratch.
            zone_recs: if self.cfg.record_tape { self.arena.loan_vec(0) } else { Vec::new() },
            surfs: None,
            warm_pending: WarmStarts::default(),
        }
    }

    /// Stage 2 — candidate positions q̄ = q₀ + h·q̇₁.
    pub fn candidates(&self, st: &mut StepState) {
        let t0 = self.obs_begin();
        let h = self.cfg.dt;
        st.rigid_qbar = self
            .sys
            .rigids
            .iter()
            .zip(&st.rigid_vhalf)
            .map(|(b, v)| {
                let mut q = b.q;
                if !b.frozen {
                    for k in 0..6 {
                        q[k] += h * v[k];
                    }
                }
                q
            })
            .collect();
        st.cloth_xbar = self
            .sys
            .cloths
            .iter()
            .zip(&st.cloth_vhalf)
            .map(|(c, v)| {
                (0..c.n_nodes())
                    .map(|i| if c.pinned[i] { c.x[i] } else { c.x[i] + v[i] * h })
                    .collect()
            })
            .collect();
        self.obs_end("candidates", t0, |_| {});
    }

    /// Stage 3 — one fail-safe pass of continuous collision detection and
    /// impact-zone construction at the current candidates. Returns the
    /// built zone problems; empty means the resolution loop is finished.
    pub fn detect_and_zone(&self, st: &mut StepState, pass: usize) -> Vec<ZoneProblem> {
        let t0 = self.obs_begin();
        let rigid_x1: Vec<Vec<Vec3>> = self
            .sys
            .rigids
            .iter()
            .zip(&st.rigid_qbar)
            .map(|(b, q)| {
                let r = euler::rotation(Vec3::new(q[0], q[1], q[2]));
                let t = Vec3::new(q[3], q[4], q[5]);
                b.mesh0.verts.iter().map(|&p| r * p + t).collect()
            })
            .collect();
        let mut just_built = false;
        if st.surfs.is_none() {
            // Pass 0: adopt the scene's parked collision state when it
            // still describes this system; otherwise build from scratch.
            let cached = if self.cfg.incremental_collision {
                self.collision_cache
                    .lock()
                    .expect("collision cache lock poisoned")
                    .take()
                    .filter(|cs| cs.matches(&self.sys))
            } else {
                None
            };
            st.surfs = Some(match cached {
                Some(mut cs) => {
                    // Roll x0 ← committed state: exactly the positions a
                    // fresh build would start from (`world_verts` is
                    // r·p + t over the same inputs, so the roll is
                    // bitwise), written into the retained buffers. This
                    // also makes rollback sound — whatever q the system
                    // holds now is what detection sweeps from.
                    let nr = self.sys.rigids.len();
                    for (i, b) in self.sys.rigids.iter().enumerate() {
                        let r = b.rotation();
                        let t = b.translation();
                        for (k, &p) in b.mesh0.verts.iter().enumerate() {
                            cs.surfs[i].x0[k] = r * p + t;
                        }
                    }
                    for (c, cl) in self.sys.cloths.iter().enumerate() {
                        cs.surfs[nr + c].x0.copy_from_slice(&cl.x);
                    }
                    cs
                }
                None => {
                    let mut cs = CollisionState::new(surfaces_from_system(
                        &self.sys,
                        &rigid_x1,
                        &st.cloth_xbar,
                        self.cfg.thickness,
                    ));
                    cs.counters.rebuilds += cs.surfs.len() as u64;
                    just_built = true;
                    cs
                }
            });
        }
        // lint:allow(no-bare-unwrap: the is_none branch above just built it)
        let cs = st.surfs.as_mut().expect("collision state built above");
        if !just_built {
            // Refresh candidates in place: O(n) BVH refits instead of
            // fresh builds, with a rebuild for any tree the refits have
            // degraded past the quality threshold.
            let nr = self.sys.rigids.len();
            for (i, x1) in rigid_x1.iter().enumerate() {
                cs.surfs[i].update_candidates(x1, self.cfg.thickness);
            }
            for (c, x1) in st.cloth_xbar.iter().enumerate() {
                cs.surfs[nr + c].update_candidates(x1, self.cfg.thickness);
            }
            let mut rebuilt = 0u64;
            for s in cs.surfs.iter_mut() {
                if s.rebuild_if_degraded(self.cfg.bvh_degrade_ratio) {
                    rebuilt += 1;
                }
            }
            cs.counters.refits += cs.surfs.len() as u64 - rebuilt;
            cs.counters.rebuilds += rebuilt;
        }
        // Candidate/contact lists come from (and return to) the scene's
        // arena; impacts are bitwise-identical to plain `detect` in
        // both modes (the parity oracle in `tests/integration_refit.rs`
        // compares whole trajectories).
        let (impacts, dstats) = if self.cfg.incremental_collision {
            detect_incremental(cs, self.cfg.thickness, self.cfg.cull_pad, &self.arena)
        } else {
            detect_in(&cs.surfs, self.cfg.thickness, &self.arena)
        };
        if pass == 0 {
            st.stats.detect = dstats;
            st.stats.impacts = impacts.len();
        }
        let mut zones = build_zones(&self.sys, &impacts);
        if self.cfg.collision_mode == CollisionMode::Global {
            zones = merge_zones(&zones).into_iter().collect();
        }
        if zones.is_empty() {
            self.obs_end("detect_and_zone", t0, |ev| {
                ev.set("pass", pass).set("impacts", impacts.len()).set("zones", 0usize);
            });
            return Vec::new();
        }
        st.stats.resolve_passes = pass + 1;
        if pass == 0 {
            st.stats.zones = zones.len();
            st.stats.max_zone_dofs = zones.iter().map(|z| z.n_dofs()).max().unwrap_or(0);
            st.stats.max_zone_constraints =
                zones.iter().map(|z| z.n_constraints()).max().unwrap_or(0);
        }
        // The zones' impact/entity copies live only for this pass; count
        // them while the problems are being built.
        let zbytes = zones_bytes(&zones);
        self.arena.charge(MemCategory::Contacts, zbytes);
        let mut problems: Vec<ZoneProblem> = zones
            .iter()
            .map(|z| {
                ZoneProblem::build_in(
                    &self.sys,
                    z,
                    &st.rigid_qbar,
                    &st.cloth_xbar,
                    self.cfg.thickness,
                    &self.arena,
                )
            })
            .collect();
        self.arena.uncharge(MemCategory::Contacts, zbytes);
        if self.cfg.warm_start_zones {
            // Seed λ₀ from the previous step's parked multipliers when
            // the zone's sorted entity set matches; constraints are
            // matched by their impact node quadruple (first fit, each
            // parked row consumed at most once). Unmatched constraints
            // start at 0 — the cold value.
            for zp in &mut problems {
                match cs.warm.get(&zp.entities) {
                    Some(rows) => {
                        cs.counters.warmstart_hits += 1;
                        let mut used = vec![false; rows.len()];
                        let lam: Vec<f64> = zp
                            .constraints
                            .iter()
                            .map(|c| {
                                for (k, (nodes, l)) in rows.iter().enumerate() {
                                    if !used[k] && *nodes == c.nodes {
                                        used[k] = true;
                                        return *l;
                                    }
                                }
                                0.0
                            })
                            .collect();
                        zp.warm_lambda = Some(lam);
                    }
                    None => cs.counters.warmstart_misses += 1,
                }
            }
        }
        self.obs_end("detect_and_zone", t0, |ev| {
            ev.set("pass", pass).set("impacts", impacts.len()).set("zones", problems.len());
        });
        problems
    }

    /// Stage 4 — solve a pass's zone problems independently (zone hook,
    /// or the scene's thread pool). Batch callers substitute a
    /// cross-scene batched solve here instead.
    pub fn solve_zones(&self, problems: &[ZoneProblem]) -> Vec<ZoneSolution> {
        self.solve_zones_with(problems, &SolveOpts::default())
    }

    /// [`Simulation::solve_zones`] with explicit [`SolveOpts`] — the
    /// retry ladder passes boosted opts here. A zone hook, when
    /// installed, takes precedence and ignores the opts (it owns its
    /// own solver configuration).
    pub fn solve_zones_with(
        &self,
        problems: &[ZoneProblem],
        opts: &SolveOpts,
    ) -> Vec<ZoneSolution> {
        let t0 = self.obs_begin();
        let sols = if let Some(hook) = &self.zone_hook {
            hook(problems)
        } else {
            self.pool.map(problems.len(), |i| problems[i].solve_with(opts))
        };
        if t0.is_some() {
            let contacts: usize = problems.iter().map(|p| p.constraints.len()).sum();
            let gn: usize = sols.iter().map(|s| s.gn_iters).sum();
            self.obs_end("solve_zones", t0, |ev| {
                ev.set("zones", problems.len()).set("contacts", contacts).set("gn_iters", gn);
            });
        }
        sols
    }

    /// Stage 5 — scatter a pass's resolved coordinates back into the
    /// candidates (and the tape when recording). Returns the largest
    /// per-DOF displacement the pass produced, for the no-op early exit.
    pub fn scatter(
        &self,
        st: &mut StepState,
        problems: Vec<ZoneProblem>,
        solutions: Vec<ZoneSolution>,
        pass: usize,
    ) -> f64 {
        let t0 = self.obs_begin();
        let (obs_zones, obs_contacts) = if t0.is_some() {
            (problems.len(), problems.iter().map(|p| p.constraints.len()).sum::<usize>())
        } else {
            (0, 0)
        };
        let mut pass_gn = 0usize;
        let mut pass_nonconv = 0usize;
        let mut max_disp: f64 = 0.0;
        for (zp, sol) in problems.into_iter().zip(solutions) {
            pass_gn += sol.gn_iters;
            if !sol.converged {
                pass_nonconv += 1;
            }
            for (a, b) in sol.q.iter().zip(&zp.q0) {
                max_disp = max_disp.max((a - b).abs());
            }
            zp.scatter(&sol, &mut st.rigid_qbar, &mut st.cloth_xbar);
            if self.cfg.warm_start_zones {
                // Park (nodes, λ) rows for next step's seeding; a later
                // fail-safe pass for the same entity set overwrites —
                // the last solve is the one worth warm-starting from.
                let rows: Vec<([crate::bodies::NodeRef; 4], f64)> = zp
                    .constraints
                    .iter()
                    .zip(&sol.lambda)
                    .map(|(c, &l)| (c.nodes, l))
                    .collect();
                st.warm_pending.insert(zp.entities.clone(), rows);
            }
            if self.cfg.record_tape {
                // The record keeps the solver buffers alive: the Solver
                // charge transfers to the Tape category at commit, and
                // the loan itself is handed back by `clear_tape`.
                self.arena.uncharge(MemCategory::Solver, zp.loaned_bytes());
                st.zone_recs.push(ZoneRec { problem: zp, solution: sol, pass });
            } else {
                zp.retire(&self.arena);
                let ZoneSolution { q, lambda, .. } = sol;
                self.arena.park_vec(q);
                self.arena.park_vec(lambda);
            }
        }
        st.stats.gn_iters += pass_gn;
        if pass_nonconv > 0 {
            // Non-converged solutions used to vanish silently; surface
            // them (StepStats + obs + rate-limited warning) without
            // changing what is done with them.
            st.stats.zone_nonconverged += pass_nonconv;
            if telemetry::enabled() {
                telemetry::counter("solver.zone_nonconverged").add(pass_nonconv as u64);
            }
            warn_nonconverged(pass_nonconv);
        }
        if telemetry::enabled() {
            telemetry::counter("solver.gn_iters").add(pass_gn as u64);
            telemetry::counter("solver.zones_solved").add(obs_zones as u64);
            telemetry::counter("solver.contacts").add(obs_contacts as u64);
            telemetry::counter("solver.failsafe_passes").incr();
        }
        self.obs_end("scatter", t0, |ev| {
            ev.set("pass", pass)
                .set("zones", obs_zones)
                .set("contacts", obs_contacts)
                .set("gn_iters", pass_gn)
                .set("max_disp", max_disp);
        });
        max_disp
    }

    /// Stage 6 — commit: q₁ = q̄′, q̇₁ = (q₁ − q₀)/h, with an inelastic
    /// energy clamp on the resolution's velocity correction; pushes the
    /// tape record and rolls the per-step counters.
    ///
    /// The projection is position-level; committing v = (q₁−q₀)/h can
    /// *inject* kinetic energy when deep corrections route through
    /// rotation (cheap in the mass metric — e.g. a sphere picking up
    /// violent spin from a single-vertex contact). The impact-zone
    /// response is inelastic: post-resolution KE must not exceed
    /// pre-resolution KE, so Δ = v_new − v_half is scaled back when it
    /// would. (Not applied while taping: the clamp is off the gradient
    /// chain; taped episodes use gentle contacts.)
    pub fn commit(&mut self, st: StepState) {
        let t0 = self.obs_begin();
        let h = self.cfg.dt;
        let StepState {
            stats,
            rigid_recs,
            cloth_recs,
            cloth_ext,
            rigid_vhalf,
            cloth_vhalf,
            rigid_qbar,
            cloth_xbar,
            zone_recs,
            surfs,
            warm_pending,
        } = st;
        // Return the collision state to the parked slot: drain the
        // step's cache counters into telemetry + lifetime totals, swap
        // in the step's parked multipliers, park the surfaces for the
        // next step's refit (only when the incremental pipeline is on —
        // otherwise the state dies here and every step rebuilds).
        if let Some(mut cs) = surfs {
            let c = std::mem::take(&mut cs.counters);
            self.collision_counters.absorb(c);
            if telemetry::enabled() {
                telemetry::counter("collision.refits").add(c.refits);
                telemetry::counter("collision.rebuilds").add(c.rebuilds);
                telemetry::counter("collision.cull_cache_hits").add(c.cull_cache_hits);
                telemetry::counter("collision.cull_cache_misses").add(c.cull_cache_misses);
                telemetry::counter("collision.warmstart_hits").add(c.warmstart_hits);
                telemetry::counter("collision.warmstart_misses").add(c.warmstart_misses);
            }
            if self.cfg.warm_start_zones {
                cs.warm = warm_pending;
            } else {
                cs.warm.clear();
            }
            if self.cfg.incremental_collision {
                *self.collision_cache.lock().expect("collision cache lock poisoned") = Some(cs);
            }
        }
        let ke_of = |sys: &System, rv: &[[f64; 6]], cv: &[Vec<Vec3>]| -> f64 {
            let mut e = 0.0;
            for (i, b) in sys.rigids.iter().enumerate() {
                if b.frozen {
                    continue;
                }
                let m = b.mass_matrix();
                let v = rv[i].to_vec();
                e += 0.5 * crate::math::dense::dot(&v, &m.matvec(&v));
            }
            for (c, cl) in sys.cloths.iter().enumerate() {
                for i in 0..cl.n_nodes() {
                    if !cl.pinned[i] {
                        e += 0.5 * cl.node_mass[i] * cv[c][i].norm2();
                    }
                }
            }
            e
        };
        let rigid_vnew: Vec<[f64; 6]> = self
            .sys
            .rigids
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let mut v = [0.0; 6];
                if !b.frozen {
                    for k in 0..6 {
                        v[k] = (rigid_qbar[i][k] - b.q[k]) / h;
                    }
                }
                v
            })
            .collect();
        let cloth_vnew: Vec<Vec<Vec3>> = self
            .sys
            .cloths
            .iter()
            .enumerate()
            .map(|(c, cl)| {
                (0..cl.n_nodes())
                    .map(|i| {
                        if cl.pinned[i] {
                            Vec3::default()
                        } else {
                            (cloth_xbar[c][i] - cl.x[i]) / h
                        }
                    })
                    .collect()
            })
            .collect();
        let mut scale = 1.0;
        if stats.resolve_passes > 0 && !self.cfg.record_tape {
            let ke_half = ke_of(&self.sys, &rigid_vhalf, &cloth_vhalf);
            let ke_new = ke_of(&self.sys, &rigid_vnew, &cloth_vnew);
            if ke_new > ke_half * (1.0 + 1e-9) + 1e-12 {
                // KE(v_half + s·Δ) is quadratic in s: bisect on [0,1].
                let ke_at = |s: f64| {
                    let rv: Vec<[f64; 6]> = rigid_vhalf
                        .iter()
                        .zip(&rigid_vnew)
                        .map(|(a, b)| {
                            let mut v = [0.0; 6];
                            for k in 0..6 {
                                v[k] = a[k] + s * (b[k] - a[k]);
                            }
                            v
                        })
                        .collect();
                    let cv: Vec<Vec<Vec3>> = cloth_vhalf
                        .iter()
                        .zip(&cloth_vnew)
                        .map(|(a, b)| a.iter().zip(b).map(|(x, y)| x.lerp(*y, s)).collect())
                        .collect();
                    ke_of(&self.sys, &rv, &cv)
                };
                let (mut lo, mut hi) = (0.0, 1.0);
                for _ in 0..30 {
                    let mid = 0.5 * (lo + hi);
                    if ke_at(mid) > ke_half {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                scale = lo;
            }
        }
        for (i, b) in self.sys.rigids.iter_mut().enumerate() {
            if b.frozen {
                continue;
            }
            for k in 0..6 {
                b.qdot[k] = rigid_vhalf[i][k] + scale * (rigid_vnew[i][k] - rigid_vhalf[i][k]);
            }
            b.q = rigid_qbar[i];
            b.clear_forces();
        }
        for (ci, c) in self.sys.cloths.iter_mut().enumerate() {
            for i in 0..c.n_nodes() {
                if !c.pinned[i] {
                    c.v[i] =
                        cloth_vhalf[ci][i] + scale * (cloth_vnew[ci][i] - cloth_vhalf[ci][i]);
                    c.x[i] = cloth_xbar[ci][i];
                }
            }
            c.clear_forces();
        }
        // Re-parameterize any rigid body drifting toward gimbal lock.
        // (Not done while taping: re-basing would break the gradient
        // chain; taped episodes are short and rotation-bounded.)
        if !self.cfg.record_tape {
            for b in &mut self.sys.rigids {
                if !b.frozen && b.near_gimbal_lock() {
                    canonicalize_rotation(b);
                }
            }
        }

        if self.cfg.record_tape {
            let mut rec = StepRecord {
                h,
                rigid_solves: rigid_recs,
                cloth_solves: cloth_recs,
                cloth_ext,
                zones: zone_recs,
                bytes: 0,
            };
            rec.bytes = rec.estimate_bytes();
            // Fig-3 accounting: the record's bytes are retained until
            // `clear_tape` (uniform for standalone and batched scenes).
            self.arena.charge(MemCategory::Tape, rec.bytes);
            self.tape.push(rec);
        }
        if telemetry::enabled() {
            telemetry::counter("engine.steps").incr();
        }
        self.obs_end("commit", t0, |ev| {
            ev.set("impacts", stats.impacts)
                .set("zones", stats.zones)
                .set("passes", stats.resolve_passes)
                .set("cg_iters", stats.cg_iters)
                .set("gn_iters", stats.gn_iters);
        });
        self.steps += 1;
        self.last_stats = stats;
    }

    /// Run `n` steps.
    pub fn run(&mut self, n: usize) {
        for _ in 0..n {
            self.step();
        }
    }

    /// Total bytes retained by the tape (Fig. 3 memory accounting).
    pub fn tape_bytes(&self) -> usize {
        self.tape.iter().map(|r| r.bytes).sum()
    }

    /// Drop the tape, releasing its [`MemCategory::Tape`] bytes and
    /// returning the records' reusable zone buffers to the arena.
    pub fn clear_tape(&mut self) {
        for rec in self.tape.drain(..) {
            self.arena.uncharge(MemCategory::Tape, rec.bytes);
            rec.recycle(&self.arena);
        }
    }
}

/// Re-express a body's orientation with a canonical Euler triple
/// (|θ| ≤ π/2) preserving the rotation matrix and world angular velocity.
fn canonicalize_rotation(b: &mut crate::bodies::RigidBody) {
    let rm = b.rotation();
    let omega = b.omega();
    let m = rm.m;
    // R = Rz(ψ)Ry(θ)Rx(φ) ⇒ θ = −asin(R₃₁), ψ = atan2(R₂₁,R₁₁), φ = atan2(R₃₂,R₃₃).
    let theta = (-m[2][0]).clamp(-1.0, 1.0).asin();
    let psi = m[1][0].atan2(m[0][0]);
    let phi = m[2][1].atan2(m[2][2]);
    b.q[0] = phi;
    b.q[1] = theta;
    b.q[2] = psi;
    // ṙ = T⁻¹ ω.
    let t = euler::omega_transform(Vec3::new(phi, theta, psi));
    let rdot = t.inverse() * omega;
    b.qdot[0] = rdot.x;
    b.qdot[1] = rdot.y;
    b.qdot[2] = rdot.z;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{Cloth, RigidBody};
    use crate::mesh::primitives::{box_mesh, cloth_grid, unit_box};

    fn ground() -> RigidBody {
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0))
    }

    #[test]
    fn cube_falls_and_rests_on_ground() {
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 1.0, 0.0)),
        );
        let mut sim = Simulation::new(sys, SimConfig::default());
        sim.run(300);
        let b = &sim.sys.rigids[1];
        // Settles with bottom at the ground (center at ~0.5 + δ).
        assert!((b.translation().y - 0.5).abs() < 0.02, "y = {}", b.translation().y);
        assert!(b.linear_velocity().norm() < 0.1, "v = {:?}", b.linear_velocity());
        // Never penetrated.
        let ymin = b.world_verts().iter().map(|p| p.y).fold(f64::MAX, f64::min);
        assert!(ymin > -5e-3, "penetration: ymin = {ymin}");
    }

    #[test]
    fn two_cubes_stack() {
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.6, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.07, 1.9, 0.03)),
        );
        let mut sim = Simulation::new(sys, SimConfig::default());
        sim.run(400);
        let y1 = sim.sys.rigids[1].translation().y;
        let y2 = sim.sys.rigids[2].translation().y;
        assert!((y1 - 0.5).abs() < 0.03, "bottom cube y = {y1}");
        assert!((y2 - 1.5).abs() < 0.08, "top cube y = {y2}");
    }

    #[test]
    fn cloth_drapes_on_cube_without_penetrating() {
        let mut sys = System::new();
        sys.add_rigid(RigidBody::frozen_from_mesh(unit_box()));
        let cloth = Cloth::from_grid(
            cloth_grid(8, 8, 2.0, 2.0).translated(Vec3::new(0.0, 0.8, 0.0)),
            0.2,
            1000.0,
            1.0,
            2.0,
        );
        sys.add_cloth(cloth);
        let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 200.0, ..Default::default() });
        sim.run(200);
        // The cloth's center region must stay on/above the cube top.
        let c = &sim.sys.cloths[0];
        let center = c.x[c.x.len() / 2];
        assert!(center.y > 0.49, "cloth center fell through: {center:?}");
        for p in &c.x {
            assert!(p.is_finite());
            // Nothing deep inside the cube.
            let inside = p.x.abs() < 0.45 && p.y < 0.45 && p.y > -0.45 && p.z.abs() < 0.45;
            assert!(!inside, "cloth node inside cube: {p:?}");
        }
    }

    #[test]
    fn momentum_conserved_in_free_collision() {
        // Two equal cubes colliding head-on in zero gravity: the zone
        // projection conserves linear momentum.
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(-1.0, 0.0, 0.0))
                .with_velocity(Vec3::new(2.0, 0.0, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 3.0)
                .with_position(Vec3::new(1.0, 0.04, 0.06))
                .with_velocity(Vec3::new(-2.0, 0.0, 0.0)),
        );
        let mut sim = Simulation::new(
            sys,
            SimConfig { gravity: Vec3::default(), dt: 1.0 / 100.0, ..Default::default() },
        );
        let p0 = sim.sys.linear_momentum();
        sim.run(120);
        let p1 = sim.sys.linear_momentum();
        assert!((p1 - p0).norm() < 1e-3 * (1.0 + p0.norm()), "Δp = {:?}", p1 - p0);
        // They did collide (velocities changed).
        assert!((sim.sys.rigids[0].linear_velocity().x - 2.0).abs() > 0.5);
    }

    #[test]
    fn try_step_trajectory_is_bitwise_step() {
        // The soundness gates are reads only: a healthy scene stepped
        // through the fallible path must match the infallible one bit
        // for bit.
        let build = || {
            let mut sys = System::new();
            sys.add_rigid(ground());
            sys.add_rigid(
                RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.8, 0.0)),
            );
            Simulation::new(sys, SimConfig::default())
        };
        let mut a = build();
        let mut b = build();
        for _ in 0..120 {
            a.step();
            b.try_step().expect("healthy scene");
        }
        for k in 0..6 {
            assert_eq!(a.sys.rigids[1].q[k].to_bits(), b.sys.rigids[1].q[k].to_bits());
            assert_eq!(a.sys.rigids[1].qdot[k].to_bits(), b.sys.rigids[1].qdot[k].to_bits());
        }
        assert_eq!(a.steps, b.steps);
    }

    #[test]
    fn try_step_rolls_back_on_nonfinite_and_ladder_gives_up() {
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 1.0, 0.0)),
        );
        let mut sim = Simulation::new(sys, SimConfig::default());
        sim.run(3);
        let q_before = sim.sys.rigids[1].q;
        let qdot_before = sim.sys.rigids[1].qdot;
        let steps_before = sim.steps;
        // Poison the external force: every integrate now produces
        // non-finite velocities, so no remedy can help.
        sim.sys.rigids[1].ext_force = Vec3::new(f64::NAN, 0.0, 0.0);
        let err = sim.try_step().expect_err("NaN force must fail the step");
        assert!(matches!(err, SceneError::NonFinite { step, .. } if step == steps_before));
        // Nothing committed: state and counters are the last good ones.
        assert_eq!(sim.sys.rigids[1].q, q_before);
        assert_eq!(sim.sys.rigids[1].qdot, qdot_before);
        assert_eq!(sim.steps, steps_before);
        // The full ladder also fails (the poison persists), still
        // without committing anything.
        let err = sim.step_recovering().expect_err("ladder cannot fix a poisoned input");
        assert!(matches!(err, SceneError::NonFinite { .. }));
        assert_eq!(sim.sys.rigids[1].q, q_before);
        assert_eq!(sim.steps, steps_before);
        // Clearing the poison makes the same scene step again.
        sim.sys.rigids[1].ext_force = Vec3::default();
        sim.step_recovering().expect("healthy again");
        assert_eq!(sim.steps, steps_before + 1);
    }

    #[test]
    fn tape_records_steps_and_bytes() {
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.55, 0.0)),
        );
        let mut sim = Simulation::new(sys, SimConfig { record_tape: true, ..Default::default() });
        sim.run(20);
        assert_eq!(sim.tape.len(), 20);
        assert!(sim.tape_bytes() > 0);
        // Contact steps recorded zones.
        assert!(sim.tape.iter().any(|r| !r.zones.is_empty()));
    }

    #[test]
    fn canonicalize_preserves_rotation_and_omega() {
        let mut b = RigidBody::from_mesh(unit_box(), 1.0);
        b.q[0] = 2.8;
        b.q[1] = 1.2;
        b.q[2] = -2.1;
        b.qdot[0] = 0.5;
        b.qdot[1] = -0.3;
        b.qdot[2] = 0.7;
        let r0 = b.rotation();
        let w0 = b.omega();
        canonicalize_rotation(&mut b);
        assert!((b.rotation() - r0).fro() < 1e-9);
        assert!((b.omega() - w0).norm() < 1e-9);
        assert!(b.q[1].abs() <= std::f64::consts::FRAC_PI_2 + 1e-9);
    }
}
