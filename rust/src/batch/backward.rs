//! Batched backward over many scenes' tapes.
//!
//! Two strategies:
//!
//! * **Scene-parallel** (native QR/Dense modes): each scene's full
//!   backward is independent, so they run concurrently on the batch
//!   pool. This is the throughput path when zone backwards are cheap.
//! * **Lockstep** (`DiffMode::Pjrt` on every scene): all tapes are
//!   walked in reverse together and, at each (step, fail-safe-pass)
//!   level, every scene's zone items go out in a *single*
//!   `Coordinator::zone_backward_batch` call — PJRT bucket occupancy
//!   then amortizes across the whole batch instead of within one scene
//!   (zones per pass per scene are few; zones per pass per *batch* fill
//!   buckets). Passes stay sequential within a scene because a pass
//!   group's scatter feeds the next group's gather.
//!
//! Either strategy walks tapes read-only: tape records (and their
//! arena-loaned zone buffers) are only released afterwards, by
//! `Simulation::clear_tape` at the start of the next
//! [`crate::batch::SceneBatch::rollout_grad`]. Gradients are
//! bitwise-identical whether the tapes were recorded with pooled or
//! plain buffers (asserted in `rust/tests/integration_batch.rs`).

use crate::coordinator::ZoneBwItem;
use crate::diff::tape::Grads;
use crate::engine::backward::{self as eb, LossGrad};
use crate::engine::{DiffMode, Simulation};
use crate::util::pool::Pool;

/// Backward for a batch of scenes with per-scene loss seeds. Returns
/// per-scene gradients in scene order.
pub fn backward_batch(pool: &Pool, sims: &[Simulation], seeds: &[LossGrad]) -> Vec<Grads> {
    assert_eq!(sims.len(), seeds.len());
    if sims.is_empty() {
        return Vec::new();
    }
    // Lockstep requires one SHARED coordinator: all scenes' zone items
    // go out through sims[0]'s, so distinct runtimes would mis-bucket.
    // Anything else takes the scene-parallel path, where each scene's
    // backward uses its own coordinator.
    let lockstep = sims
        .iter()
        .all(|s| s.cfg.diff_mode == DiffMode::Pjrt && s.coordinator.is_some())
        && sims.windows(2).all(|w| w[0].tape.len() == w[1].tape.len())
        && sims.windows(2).all(|w| {
            match (&w[0].coordinator, &w[1].coordinator) {
                (Some(a), Some(b)) => std::sync::Arc::ptr_eq(a, b),
                _ => false,
            }
        });
    if lockstep {
        backward_lockstep(sims, seeds)
    } else {
        pool.map(sims.len(), |i| eb::backward(&sims[i], &seeds[i]))
    }
}

/// Lockstep PJRT backward: one coordinator call per (step, pass) level
/// covering every scene's zone group at that level.
fn backward_lockstep(sims: &[Simulation], seeds: &[LossGrad]) -> Vec<Grads> {
    // lint:allow(no-bare-unwrap: backward_batch's lockstep gate checked is_some)
    let coord = sims[0].coordinator.as_ref().expect("lockstep requires a coordinator");
    backward_lockstep_with(sims, seeds, &|items| coord.zone_backward_batch(items))
}

/// Lockstep walk with an injected zone-backward dispatch. Factored out
/// so the span/offset bookkeeping is testable without PJRT artifacts
/// (tests drive it with a native-QR dispatch).
pub(crate) fn backward_lockstep_with(
    sims: &[Simulation],
    seeds: &[LossGrad],
    dispatch: &(dyn Fn(&[ZoneBwItem<'_>]) -> Vec<Vec<f64>> + '_),
) -> Vec<Grads> {
    let steps = sims[0].tape.len();
    let mut outs: Vec<Grads> =
        sims.iter().map(|sim| eb::grads_zeros(sim, sim.tape.len())).collect();
    let mut adjs: Vec<eb::Adjoint> =
        sims.iter().zip(seeds).map(|(sim, seed)| eb::seed_adjoint(sim, seed)).collect();
    for s in (0..steps).rev() {
        let mut works: Vec<eb::StepWork> = sims
            .iter()
            .zip(&adjs)
            .map(|(sim, adj)| eb::begin_step(sim, &sim.tape[s], adj))
            .collect();
        let groups: Vec<Vec<(usize, std::ops::Range<usize>)>> =
            sims.iter().map(|sim| eb::pass_groups(&sim.tape[s].zones)).collect();
        let max_pass =
            groups.iter().flat_map(|g| g.iter().map(|(p, _)| *p + 1)).max().unwrap_or(0);
        for pass in (0..max_pass).rev() {
            // Gather ∂L/∂z from every scene that resolved zones in this
            // pass; scenes that broke out earlier simply skip it.
            let mut spans: Vec<(usize, std::ops::Range<usize>)> = Vec::new();
            let mut grad_zs: Vec<Vec<Vec<f64>>> = Vec::new();
            for (i, sim) in sims.iter().enumerate() {
                if let Some((_, r)) = groups[i].iter().find(|(p, _)| *p == pass) {
                    let group = &sim.tape[s].zones[r.clone()];
                    grad_zs.push(eb::gather_zone_grads(group, &works[i]));
                    spans.push((i, r.clone()));
                }
            }
            if spans.is_empty() {
                continue;
            }
            let mut items: Vec<ZoneBwItem<'_>> = Vec::new();
            for ((i, r), gz) in spans.iter().zip(&grad_zs) {
                for (zr, g) in sims[*i].tape[s].zones[r.clone()].iter().zip(gz) {
                    items.push(ZoneBwItem {
                        problem: &zr.problem,
                        solution: &zr.solution,
                        grad_z: g,
                    });
                }
            }
            // One bucket-batched dispatch for the whole batch.
            let grads_q = dispatch(&items);
            let mut off = 0;
            for ((i, r), gz) in spans.iter().zip(&grad_zs) {
                let group = &sims[*i].tape[s].zones[r.clone()];
                eb::apply_zone_grads(
                    &sims[*i],
                    group,
                    &grads_q[off..off + gz.len()],
                    &mut works[*i],
                    &mut outs[*i],
                );
                off += gz.len();
            }
        }
        for (i, work) in works.into_iter().enumerate() {
            eb::finish_step(&sims[i], s, &sims[i].tape[s], work, &mut adjs[i], &mut outs[i]);
        }
    }
    for (out, adj) in outs.iter_mut().zip(adjs) {
        out.rigid_q0 = adj.gq_r;
        out.rigid_v0 = adj.gv_r;
        out.cloth_x0 = adj.gx_c;
        out.cloth_v0 = adj.gv_c;
    }
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{RigidBody, System};
    use crate::diff::implicit::backward_qr;
    use crate::engine::SimConfig;
    use crate::math::Vec3;
    use crate::mesh::primitives::{box_mesh, unit_box};

    fn taped_drop(vx: f64) -> Simulation {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(0.0, 0.8, 0.0))
                .with_velocity(Vec3::new(vx, 0.0, 0.0)),
        );
        let mut sim = Simulation::new(
            sys,
            SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() },
        );
        sim.run(40);
        sim
    }

    #[test]
    fn lockstep_span_bookkeeping_matches_per_scene_backward() {
        // Drive the lockstep walk with a native-QR dispatch: the cross-
        // scene gather/offset-split/scatter must reproduce each scene's
        // independent backward exactly (scenes have different contact
        // histories, so pass counts differ across the batch).
        let sims: Vec<Simulation> = [0.0, 0.6, -1.1].iter().map(|&vx| taped_drop(vx)).collect();
        let seeds: Vec<LossGrad> = sims
            .iter()
            .map(|sim| {
                let mut seed = LossGrad::zeros(sim);
                seed.rigid_q[1][3] = 1.0;
                seed.rigid_v[1][4] = 0.5;
                seed
            })
            .collect();
        let lockstep = backward_lockstep_with(&sims, &seeds, &|items| {
            items.iter().map(|it| backward_qr(it.problem, it.solution, it.grad_z).grad_q).collect()
        });
        for (i, sim) in sims.iter().enumerate() {
            let solo = eb::backward(sim, &seeds[i]);
            for k in 0..6 {
                assert!(
                    lockstep[i].rigid_q0[1][k] == solo.rigid_q0[1][k],
                    "scene {i} q0[{k}]: lockstep {} vs solo {}",
                    lockstep[i].rigid_q0[1][k],
                    solo.rigid_q0[1][k]
                );
                assert!(
                    lockstep[i].rigid_v0[1][k] == solo.rigid_v0[1][k],
                    "scene {i} v0[{k}]: lockstep {} vs solo {}",
                    lockstep[i].rigid_v0[1][k],
                    solo.rigid_v0[1][k]
                );
            }
            assert!(lockstep[i].rigid_mass[1] == solo.rigid_mass[1], "scene {i} mass grad");
            for s in 0..sim.tape.len() {
                assert!(
                    (lockstep[i].rigid_force[s][1] - solo.rigid_force[s][1]).norm() == 0.0,
                    "scene {i} step {s} force grad"
                );
            }
        }
    }
}
