//! Batched multi-scene simulation: N independent [`Simulation`]s stepped
//! in parallel on one persistent worker pool, with batched forward
//! rollouts and a batched backward that gathers per-scene ∂L/∂θ into one
//! contiguous buffer for [`crate::ml::adam`].
//!
//! This is the throughput layer for the paper's learning loops: inverse
//! problems (Fig. 7) evaluate CMA-ES populations, control learning
//! (Fig. 8) rolls out minibatches of episodes, and parameter estimation
//! (Fig. 9) advances many gradient chains — all embarrassingly parallel
//! across scenes. Scenes are the unit of parallelism (each scene's inner
//! zone pool is forced to one worker), so a batch of B scenes on W cores
//! costs ~max(B/W)·(one scene) wall-clock and trajectories stay
//! bitwise-identical to sequential runs.
//!
//! When every scene uses `DiffMode::Pjrt` with a coordinator, the
//! backward walks all tapes in lockstep and routes every scene's zone
//! items at each (step, pass) level through a *single*
//! `Coordinator::zone_backward_batch` call, so PJRT bucket-batching
//! amortizes across scenes instead of within one (see [`backward`]).
//!
//! The forward has the same lockstep option ([`SceneBatch::run_lockstep`]
//! / [`SceneBatch::step_lockstep`], see [`forward`]): scenes advance
//! through the staged step primitives with a barrier at the zone-solve
//! level, and each fail-safe pass's zones from *all* scenes are solved
//! together — one `Coordinator::zone_solve_batch` call per (step, pass)
//! level under a shared coordinator, or one cross-scene pool map
//! otherwise. Native-solver trajectories stay bitwise-identical to
//! sequential per-scene stepping.
//!
//! # Async pipelining
//!
//! The lockstep entry points are *blocking*: the submitting thread
//! waits for every scene before it can evaluate a single loss or build
//! the next generation. [`pipeline::BatchPipeline`] is the asynchronous
//! alternative — per-scene rollouts stream through a bounded in-flight
//! window (finished scenes' losses are evaluated on the submitter while
//! slower scenes still step) and population drivers double-buffer
//! generations (generation *k+1*'s scene construction overlaps
//! generation *k*'s stepping, with a drain barrier only at
//! gradient-consuming boundaries). It sits on the pool's detached-job
//! API ([`crate::util::pool::Pool::submit`]) and is bitwise-identical
//! to the synchronous paths; the fig7 CMA-ES and fig8 BPTT drivers use
//! it, keeping the lockstep entry points as the synchronous fallback.
//!
//! # Memory
//!
//! Every batch installs one shared
//! [`BatchArena`](crate::util::arena::BatchArena) across its scenes, so
//! the per-step contact lists, zone solver state, and (between
//! rollouts) tape buffers are checked out of a common pool instead of
//! being allocated per scene: a warm batch holds roughly one buffer set
//! per *concurrently stepping* scene — bounded by the pool's worker
//! budget, not the population size — where independent scenes would
//! hold `n_scenes × worst_case`. Pooling is content-neutral
//! (bitwise-identical trajectories and gradients, asserted in
//! `rust/tests/integration_batch.rs`); [`SceneBatch::set_arena`] swaps
//! in a disabled/tracked/per-scene configuration for baselines, and the
//! `batch_memory` bench reports the peaks to `BENCH_memory.json`.

pub mod backward;
pub mod forward;
pub mod pipeline;

pub use pipeline::{BatchPipeline, Generation};

use crate::bodies::System;
use crate::diff::tape::Grads;
use crate::engine::backward::LossGrad;
use crate::engine::{SceneError, SimConfig, Simulation};
use crate::util::arena::BatchArena;
use crate::util::pool::Pool;
use crate::util::telemetry;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// How a batch responds when one scene's step fails (a worker panic,
/// non-finite state, CCD failure, or zone-solve divergence — see
/// [`SceneError`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Propagate: a scene failure unwinds out of the batch call, exactly
    /// as before fault containment existed. The default.
    #[default]
    FailFast,
    /// Contain: the failing scene is quarantined with its error and step
    /// index while healthy scenes keep stepping. The failed step never
    /// commits, so the quarantined scene rests at its last good state.
    Isolate,
    /// Contain, but first run the engine's fail-safe ladder
    /// ([`Simulation::step_recovering`]) on the failing scene; the scene
    /// is quarantined only if the ladder also gives up.
    Retry,
}

/// Why and when a scene was pulled from its batch.
#[derive(Clone, Debug, PartialEq)]
pub struct Quarantined {
    /// The failure that ended the scene's participation.
    pub error: SceneError,
    /// The scene's committed step count at quarantine time (the failing
    /// step rolled back, so this is the last good step).
    pub step: usize,
}

/// A batch of independent scenes advanced in lockstep.
pub struct SceneBatch {
    sims: Vec<Simulation>,
    pool: Pool,
    arena: BatchArena,
    policy: FaultPolicy,
    quarantine: Vec<Option<Quarantined>>,
}

/// Result of a taped batch rollout: per-scene losses, gradients, and the
/// per-scene controller state threaded through the rollout.
pub struct BatchRollout<S> {
    pub losses: Vec<f64>,
    pub grads: Vec<Grads>,
    pub states: Vec<S>,
}

impl<S> BatchRollout<S> {
    pub fn total_loss(&self) -> f64 {
        self.losses.iter().sum()
    }

    pub fn mean_loss(&self) -> f64 {
        if self.losses.is_empty() {
            0.0
        } else {
            self.total_loss() / self.losses.len() as f64
        }
    }

    /// Gather per-scene parameter gradients into one contiguous buffer
    /// (scene-major: scene i owns `[i·per_scene, (i+1)·per_scene)`),
    /// ready for a single `ml::adam::Adam::step` over the whole
    /// population. `fill(i, grads, slice)` extracts scene i's ∂L/∂θ.
    pub fn gather_param_grads<F>(&self, per_scene: usize, fill: F) -> Vec<f64>
    where
        F: Fn(usize, &Grads, &mut [f64]),
    {
        let mut buf = vec![0.0; self.grads.len() * per_scene];
        for (i, g) in self.grads.iter().enumerate() {
            fill(i, g, &mut buf[i * per_scene..(i + 1) * per_scene]);
        }
        buf
    }
}

impl SceneBatch {
    /// Wrap pre-built simulations; `workers` budgets the batch's handle
    /// to the process-wide persistent worker pool ([`Pool::shared`]).
    /// Installs one shared [`BatchArena`] across the scenes (replacing
    /// any arena they held) — pooling is content-neutral, so this never
    /// changes trajectories; use [`SceneBatch::set_arena`] to opt out.
    pub fn new(sims: Vec<Simulation>, workers: usize) -> SceneBatch {
        let quarantine = (0..sims.len()).map(|_| None).collect();
        let mut sb = SceneBatch {
            sims,
            pool: Pool::shared(workers),
            arena: BatchArena::new(),
            policy: FaultPolicy::default(),
            quarantine,
        };
        let arena = sb.arena.clone();
        for sim in &mut sb.sims {
            sim.set_arena(arena.clone());
        }
        sb
    }

    /// Replace the batch's pool handle (e.g. a dedicated [`Pool::new`]
    /// for isolation, or the [`Pool::scoped`] spawn-per-call baseline in
    /// the perf benches).
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = pool;
    }

    /// The pool handle this batch steps on.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Install `arena` on every scene (and remember it as the batch's):
    /// [`BatchArena::disabled`] restores plain per-scene allocation,
    /// [`BatchArena::tracked`] keeps accounting without pooling. For
    /// per-scene arenas (the `n_scenes × worst_case` baseline the
    /// `batch_memory` bench measures), set arenas directly through
    /// [`SceneBatch::sims_mut`] + `Simulation::set_arena` instead.
    pub fn set_arena(&mut self, arena: BatchArena) {
        for sim in &mut self.sims {
            sim.set_arena(arena.clone());
        }
        self.arena = arena;
    }

    /// The arena installed by the batch (scenes may have been re-pointed
    /// individually via `Simulation::set_arena`).
    pub fn arena(&self) -> &BatchArena {
        &self.arena
    }

    /// Install a JSONL trace sink on every scene: scene `i` writes its
    /// events tagged `scene: i` (via [`Trace::for_scene`]), so one file
    /// carries the whole batch and per-scene streams are separable by
    /// filtering. `None` removes all sinks (flushing the file once the
    /// last handle drops). Purely observational — see
    /// [`Simulation::set_trace`].
    pub fn set_trace(&mut self, trace: Option<crate::util::telemetry::Trace>) {
        for (i, sim) in self.sims.iter_mut().enumerate() {
            sim.set_trace(trace.as_ref().map(|t| t.for_scene(i)));
        }
    }

    /// Clone one scene config into `n` scenes, applying a per-scene
    /// override (parameter perturbations, population candidates, …).
    /// `cfg.workers` sizes the *batch* pool; each scene's own zone pool
    /// is forced to one worker so scenes, not zones, are the unit of
    /// parallelism — which also keeps batch trajectories bitwise
    /// identical to sequential single-scene runs.
    pub fn from_scene<F>(base: &System, cfg: &SimConfig, n: usize, customize: F) -> SceneBatch
    where
        F: Fn(usize, &mut System),
    {
        let workers = cfg.workers.max(1);
        let sims = (0..n)
            .map(|i| {
                let mut sys = base.clone();
                customize(i, &mut sys);
                let cfg_i = SimConfig { workers: 1, ..cfg.clone() };
                Simulation::new(sys, cfg_i)
            })
            .collect();
        SceneBatch::new(sims, workers)
    }

    pub fn len(&self) -> usize {
        self.sims.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sims.is_empty()
    }

    pub fn sim(&self, i: usize) -> &Simulation {
        &self.sims[i]
    }

    pub fn sims(&self) -> &[Simulation] {
        &self.sims
    }

    pub fn sims_mut(&mut self) -> &mut [Simulation] {
        &mut self.sims
    }

    /// Toggle taping on every scene.
    pub fn set_record_tape(&mut self, on: bool) {
        for sim in &mut self.sims {
            sim.cfg.record_tape = on;
        }
    }

    /// Install one SHARED coordinator on every scene and switch them to
    /// `DiffMode::Pjrt`. Sharing matters: the batched backward only
    /// takes the lockstep path (all scenes' zone items in one
    /// `Coordinator::zone_backward_batch` call per (step, pass) level)
    /// when every scene holds the same coordinator.
    pub fn set_coordinator(&mut self, coord: std::sync::Arc<crate::coordinator::Coordinator>) {
        for sim in &mut self.sims {
            sim.coordinator = Some(coord.clone());
            sim.cfg.diff_mode = crate::engine::DiffMode::Pjrt;
        }
    }

    /// Set how the batch responds to per-scene failures. Under
    /// [`FaultPolicy::FailFast`] (the default) every stepping entry
    /// point runs its original, unguarded body — bitwise-identical
    /// behavior and cost. `Isolate`/`Retry` switch `step`, `run`,
    /// `step_lockstep`, `run_lockstep`, `rollout`, and
    /// `rollout_lockstep` to fault-contained variants; the
    /// gradient paths (`rollout_grad*`) always fail fast, since a
    /// half-taped population has no usable batched gradient.
    pub fn set_fault_policy(&mut self, policy: FaultPolicy) {
        self.policy = policy;
    }

    /// The batch's current [`FaultPolicy`].
    pub fn fault_policy(&self) -> FaultPolicy {
        self.policy
    }

    /// Scenes currently quarantined, as `(scene index, record)` pairs.
    pub fn quarantined(&self) -> impl Iterator<Item = (usize, &Quarantined)> + '_ {
        self.quarantine.iter().enumerate().filter_map(|(i, q)| q.as_ref().map(|r| (i, r)))
    }

    /// Is scene `i` quarantined?
    pub fn is_quarantined(&self, i: usize) -> bool {
        self.quarantine[i].is_some()
    }

    /// Release scene `i` from quarantine (after repairing it through
    /// [`SceneBatch::sims_mut`], say) and return its record. The scene
    /// rejoins stepping on the next call.
    pub fn clear_quarantine(&mut self, i: usize) -> Option<Quarantined> {
        let rec = self.quarantine[i].take();
        self.update_quarantine_gauge();
        rec
    }

    fn quarantine_scene(&mut self, i: usize, error: SceneError) {
        if self.quarantine[i].is_none() {
            self.quarantine[i] = Some(Quarantined { error, step: self.sims[i].steps });
        }
        self.update_quarantine_gauge();
    }

    fn update_quarantine_gauge(&self) {
        if telemetry::enabled() {
            let n = self.quarantine.iter().filter(|q| q.is_some()).count();
            telemetry::gauge("batch.quarantined").set(n as i64);
        }
    }

    /// Advance every scene one step, in parallel.
    pub fn step(&mut self) {
        match self.policy {
            FaultPolicy::FailFast => self.pool.map_mut(&mut self.sims, |_, sim| sim.step()),
            _ => self.step_guarded(1),
        }
    }

    /// Advance every scene `steps` steps. Scenes are independent, so
    /// each worker runs its scenes' full horizon without barriers.
    pub fn run(&mut self, steps: usize) {
        match self.policy {
            FaultPolicy::FailFast => self.pool.map_mut(&mut self.sims, |_, sim| sim.run(steps)),
            _ => self.step_guarded(steps),
        }
    }

    /// Scene-parallel stepping with per-scene containment: quarantined
    /// scenes sit out, panics are caught on the worker, and a scene
    /// that fails (after the retry ladder, under [`FaultPolicy::Retry`])
    /// is quarantined at its last committed step while the rest of the
    /// batch finishes its horizon.
    fn step_guarded(&mut self, steps: usize) {
        let retry = self.policy == FaultPolicy::Retry;
        let skip: Vec<bool> = self.quarantine.iter().map(|q| q.is_some()).collect();
        let errs: Vec<Option<SceneError>> = {
            let skip_ref: &[bool] = &skip;
            self.pool.map_mut(&mut self.sims, |i, sim| {
                if skip_ref[i] {
                    return None;
                }
                for _ in 0..steps {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        if retry {
                            sim.step_recovering()
                        } else {
                            sim.try_step()
                        }
                    }));
                    match r {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => return Some(e),
                        Err(p) => return Some(SceneError::from_panic(p.as_ref())),
                    }
                }
                None
            })
        };
        for (i, e) in errs.into_iter().enumerate() {
            if let Some(e) = e {
                self.quarantine_scene(i, e);
            }
        }
    }

    /// The coordinator every scene shares, if they all hold the same
    /// `Arc` — the condition for both lockstep dispatch paths (forward
    /// `zone_solve_batch`, backward `zone_backward_batch`).
    pub fn shared_coordinator(&self) -> Option<std::sync::Arc<crate::coordinator::Coordinator>> {
        forward::shared_coordinator(&self.sims)
    }

    /// Advance every scene one step in lockstep: all scenes move through
    /// the staged step primitives together and each fail-safe pass's
    /// zone problems are pooled across the batch — one
    /// `Coordinator::zone_solve_batch` call per pass level when all
    /// scenes share a coordinator, one cross-scene pool map otherwise
    /// (better load balance than scene-granularity stepping when zone
    /// counts are skewed). With the native solver, trajectories are
    /// bitwise-identical to [`SceneBatch::step`] and sequential
    /// single-scene stepping.
    pub fn step_lockstep(&mut self) {
        match self.policy {
            FaultPolicy::FailFast => forward::step_lockstep(&self.pool, &mut self.sims),
            _ => self.step_lockstep_guarded(),
        }
    }

    /// Lockstep stepping with per-scene containment (see
    /// [`forward::try_step_lockstep`]): quarantined scenes sit out, and
    /// a scene that fails a stage rolls back without committing. Under
    /// [`FaultPolicy::Retry`] the failed scene then runs the engine's
    /// solo fail-safe ladder — legitimate because the rolled-back state
    /// is exactly what the lockstep step started from, and solo vs
    /// batched native zone solves are bitwise-identical.
    fn step_lockstep_guarded(&mut self) {
        let skip: Vec<bool> = self.quarantine.iter().map(|q| q.is_some()).collect();
        let errs = forward::try_step_lockstep(&self.pool, &mut self.sims, &skip);
        let retry = self.policy == FaultPolicy::Retry;
        for (i, e) in errs.into_iter().enumerate() {
            let Some(e) = e else { continue };
            let final_err = if retry {
                let sim = &mut self.sims[i];
                match catch_unwind(AssertUnwindSafe(|| sim.step_recovering())) {
                    Ok(Ok(())) => None,
                    Ok(Err(e2)) => Some(e2),
                    Err(p) => Some(SceneError::from_panic(p.as_ref())),
                }
            } else {
                Some(e)
            };
            if let Some(e) = final_err {
                self.quarantine_scene(i, e);
            }
        }
    }

    /// Advance every scene `steps` steps in lockstep (see
    /// [`SceneBatch::step_lockstep`]).
    pub fn run_lockstep(&mut self, steps: usize) {
        for _ in 0..steps {
            self.step_lockstep();
        }
    }

    /// Forward rollout with per-scene controller state: for scene i,
    /// `state = init(i)`, then `steps` iterations of
    /// `control(&mut state, i, step, sim); sim.step()`. Returns the
    /// final states in scene order.
    pub fn rollout<S, I, C>(&mut self, steps: usize, init: I, control: C) -> Vec<S>
    where
        S: Send,
        I: Fn(usize) -> S + Sync,
        C: Fn(&mut S, usize, usize, &mut Simulation) + Sync,
    {
        if self.policy == FaultPolicy::FailFast {
            return self.pool.map_mut(&mut self.sims, |i, sim| {
                let mut state = init(i);
                for s in 0..steps {
                    control(&mut state, i, s, sim);
                    sim.step();
                }
                state
            });
        }
        // Guarded: a scene that fails (controller panic or step error,
        // post-ladder under Retry) stops rolling out and is quarantined;
        // its state is returned as of the failure. Quarantined scenes
        // return `init(i)` untouched.
        let retry = self.policy == FaultPolicy::Retry;
        let skip: Vec<bool> = self.quarantine.iter().map(|q| q.is_some()).collect();
        let results: Vec<(S, Option<SceneError>)> = {
            let skip_ref: &[bool] = &skip;
            self.pool.map_mut(&mut self.sims, |i, sim| {
                let mut state = init(i);
                if skip_ref[i] {
                    return (state, None);
                }
                let mut err = None;
                for s in 0..steps {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        control(&mut state, i, s, sim);
                        if retry {
                            sim.step_recovering()
                        } else {
                            sim.try_step()
                        }
                    }));
                    match r {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            err = Some(e);
                            break;
                        }
                        Err(p) => {
                            err = Some(SceneError::from_panic(p.as_ref()));
                            break;
                        }
                    }
                }
                (state, err)
            })
        };
        let mut states = Vec::with_capacity(results.len());
        for (i, (state, e)) in results.into_iter().enumerate() {
            states.push(state);
            if let Some(e) = e {
                self.quarantine_scene(i, e);
            }
        }
        states
    }

    /// Lockstep variant of [`SceneBatch::rollout`]: the per-scene
    /// controller state is threaded identically, but scenes advance one
    /// step at a time through [`SceneBatch::step_lockstep`] so zone
    /// solves batch across the whole population at each fail-safe pass.
    /// Control callbacks still run on the worker pool (policy networks
    /// are real per-step work); each scene's state slot is touched by
    /// exactly one worker, so the mutexes are uncontended.
    pub fn rollout_lockstep<S, I, C>(&mut self, steps: usize, init: I, control: C) -> Vec<S>
    where
        S: Send,
        I: Fn(usize) -> S + Sync,
        C: Fn(&mut S, usize, usize, &mut Simulation) + Sync,
    {
        let guarded = self.policy != FaultPolicy::FailFast;
        let slots: Vec<std::sync::Mutex<S>> =
            (0..self.sims.len()).map(|i| std::sync::Mutex::new(init(i))).collect();
        for s in 0..steps {
            {
                let slots = &slots;
                let control = &control;
                if guarded {
                    // Contained controller pass: quarantined scenes are
                    // skipped, a panicking controller quarantines its
                    // scene (state as of the last completed call).
                    let skip: Vec<bool> =
                        self.quarantine.iter().map(|q| q.is_some()).collect();
                    let skip_ref: &[bool] = &skip;
                    let errs: Vec<Option<SceneError>> =
                        self.pool.map_mut(&mut self.sims, |i, sim| {
                            if skip_ref[i] {
                                return None;
                            }
                            let mut state =
                                slots[i].lock().unwrap_or_else(|e| e.into_inner());
                            catch_unwind(AssertUnwindSafe(|| control(&mut *state, i, s, sim)))
                                .err()
                                .map(|p| SceneError::from_panic(p.as_ref()))
                        });
                    for (i, e) in errs.into_iter().enumerate() {
                        if let Some(e) = e {
                            self.quarantine_scene(i, e);
                        }
                    }
                } else {
                    self.pool.map_mut(&mut self.sims, |i, sim| {
                        let mut state = slots[i].lock().unwrap_or_else(|e| e.into_inner());
                        control(&mut *state, i, s, sim);
                    });
                }
            }
            self.step_lockstep();
        }
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()))
            .collect()
    }

    /// Taped batch rollout + batched backward. Tapes are cleared, taping
    /// is enabled, the controlled forward runs in parallel, then
    /// `loss(i, sim, state)` seeds each scene's adjoint and the backward
    /// runs batched (lockstep + shared coordinator calls under
    /// `DiffMode::Pjrt`, scene-parallel native otherwise).
    pub fn rollout_grad<S, I, C, L>(
        &mut self,
        steps: usize,
        init: I,
        control: C,
        loss: L,
    ) -> BatchRollout<S>
    where
        S: Send + Sync,
        I: Fn(usize) -> S + Sync,
        C: Fn(&mut S, usize, usize, &mut Simulation) + Sync,
        L: Fn(usize, &Simulation, &S) -> (f64, LossGrad) + Sync,
    {
        self.rollout_grad_impl(steps, init, control, loss, false)
    }

    /// [`SceneBatch::rollout_grad`] with a *lockstep* forward
    /// ([`SceneBatch::rollout_lockstep`]): forward zone solves batch
    /// across scenes at each (step, pass) level, matching the batched
    /// backward's lockstep granularity. With the native zone solver the
    /// forward trajectory is bitwise the same, so gradients are
    /// identical to [`SceneBatch::rollout_grad`]; with a shared
    /// coordinator and real `zone_solve_*` artifacts the forward runs
    /// f32 PJRT solves and trajectories (hence gradients) differ within
    /// solver tolerance.
    pub fn rollout_grad_lockstep<S, I, C, L>(
        &mut self,
        steps: usize,
        init: I,
        control: C,
        loss: L,
    ) -> BatchRollout<S>
    where
        S: Send + Sync,
        I: Fn(usize) -> S + Sync,
        C: Fn(&mut S, usize, usize, &mut Simulation) + Sync,
        L: Fn(usize, &Simulation, &S) -> (f64, LossGrad) + Sync,
    {
        self.rollout_grad_impl(steps, init, control, loss, true)
    }

    fn rollout_grad_impl<S, I, C, L>(
        &mut self,
        steps: usize,
        init: I,
        control: C,
        loss: L,
        lockstep: bool,
    ) -> BatchRollout<S>
    where
        S: Send + Sync,
        I: Fn(usize) -> S + Sync,
        C: Fn(&mut S, usize, usize, &mut Simulation) + Sync,
        L: Fn(usize, &Simulation, &S) -> (f64, LossGrad) + Sync,
    {
        // Tape only for the duration of this call: prior record_tape
        // flags are restored afterwards so a later forward-only
        // `run()` on the same batch doesn't grow tapes unboundedly.
        // (The rollout's tapes themselves are kept for inspection;
        // the next rollout_grad clears them.)
        let prior_tape: Vec<bool> = self.sims.iter().map(|s| s.cfg.record_tape).collect();
        for sim in &mut self.sims {
            sim.cfg.record_tape = true;
            sim.clear_tape();
        }
        // Gradient rollouts always fail fast — a half-taped population
        // has no usable batched gradient, so containment is forced off
        // for the duration of the forward.
        let prior_policy = self.policy;
        self.policy = FaultPolicy::FailFast;
        let states = if lockstep {
            self.rollout_lockstep(steps, init, control)
        } else {
            self.rollout(steps, init, control)
        };
        self.policy = prior_policy;
        let pool = &self.pool;
        let sims = &self.sims;
        let seeded: Vec<(f64, LossGrad)> =
            pool.map(sims.len(), |i| loss(i, &sims[i], &states[i]));
        let mut losses = Vec::with_capacity(seeded.len());
        let mut seeds = Vec::with_capacity(seeded.len());
        for (l, s) in seeded {
            losses.push(l);
            seeds.push(s);
        }
        let grads = backward::backward_batch(pool, sims, &seeds);
        for (sim, on) in self.sims.iter_mut().zip(prior_tape) {
            sim.cfg.record_tape = on;
        }
        BatchRollout { losses, grads, states }
    }
}
