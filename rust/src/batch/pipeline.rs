//! Async pipelined batch stepping: latency-hiding on top of the
//! persistent [`Pool`] runtime's detached jobs
//! ([`Pool::submit`](crate::util::pool::Pool::submit) /
//! [`JobHandle`]).
//!
//! The lockstep entry points ([`super::SceneBatch::run_lockstep`],
//! [`super::SceneBatch::rollout_grad_lockstep`]) advance all scenes
//! with a *blocking* pool: the submitting thread cannot evaluate a
//! finished scene's loss, or build the next generation's scenes, until
//! every scene of the current call has finished. Per-scene completion
//! times are uneven exactly because impact zones are localized (a
//! contact-rich scene resolves several fail-safe passes while a
//! ballistic one resolves none), so the submitter idles on the slowest
//! scene. [`BatchPipeline`] hides that latency two ways:
//!
//! * **Streaming** ([`BatchPipeline::map_windowed`] /
//!   [`BatchPipeline::stream`]): per-scene rollout jobs flow through a
//!   bounded in-flight *window*. Finished scenes are consumed on the
//!   submitter — loss evaluation, scoring, logging — while slower
//!   scenes still step on the workers. Handles are waited in submission
//!   order, so consumption is in scene order and the output is
//!   identical to the sequential loop.
//! * **Generation double-buffering** ([`BatchPipeline::prepare`] /
//!   [`BatchPipeline::generations`]): population-style drivers (CMA-ES
//!   fig7, minibatched BPTT fig8) build generation *k+1*'s scenes —
//!   construction, perturbation, untaped settling — as detached jobs
//!   while generation *k* is still stepping. The *drain barrier* sits
//!   only at gradient-consuming boundaries: a generation's seeds are
//!   waited right before its own rollout, and gradients are always
//!   produced and consumed synchronously on the submitter, never
//!   overlapped with each other.
//!
//! # Dataflow
//!
//! ```text
//! submitter                               pool workers (budget w)
//! ─────────────────────────────────────────────────────────────────
//! prepare(gen k+1) ──submit──▶ [build 0][build 1]…   (overlaps gen k)
//! stream(gen k):
//!   wait seed i ──submit──▶ [work i: step scene i … done]
//!   window full → wait oldest ──▶ consume(i−W)   (loss, on submitter)
//!   …
//!   drain: wait remaining in-flight          ◀── barrier before the
//!                                                results are consumed
//! ```
//!
//! # Invariants
//!
//! * **Determinism / bitwise parity.** Jobs are waited in submission
//!   order and `consume` runs only on the submitter, so outputs are in
//!   scene order and bitwise-independent of worker scheduling. A
//!   pipelined driver that runs the same per-scene code as the
//!   sequential path produces bitwise-identical trajectories, losses,
//!   and gradients (asserted in `rust/tests/integration_pipeline.rs`).
//!   On a 1-worker pool every `submit` degenerates to synchronous
//!   execution, so the pipeline *is* the sequential loop.
//! * **Bounded window.** At most `window` scenes of a stream are
//!   in flight (submitted, not yet consumed) at once, and the pool's
//!   budget gate additionally caps how many execute concurrently —
//!   which is what keeps a shared
//!   [`BatchArena`](crate::util::arena::BatchArena)'s live checkout
//!   count (and hence warm buffer memory) bounded when scenes step as
//!   detached jobs.
//! * **Panic-at-wait.** A panic in one scene's job surfaces when that
//!   handle is waited (in scene order). Before it propagates out of the
//!   pipeline call, every other in-flight job is drained
//!   ([`JobHandle`]'s drop blocks), so the pool is never poisoned and
//!   no job outlives the borrows it captured.
//! * **No nested waits.** Pipeline jobs never wait on other detached
//!   jobs (see the pool docs' "never block on a handle from inside a
//!   pool task" rule); nested `map`s inside a scene job remain fine.

// lint:allow-file(wallclock: Instant reads are telemetry-gated — zero
// clock calls with the registry disabled — and only feed latency
// histograms, never simulation numerics)
use super::FaultPolicy;
use crate::engine::SceneError;
use crate::util::pool::{JobHandle, Pool};
use crate::util::telemetry;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

/// Erase the borrow lifetime of a scene job so it can be submitted as a
/// detached pool job.
///
/// SAFETY: sound only because every caller drains its in-flight handles
/// on every exit path — [`JobHandle`]'s drop blocks until the job has
/// finished — so the closure (and everything it borrows) outlives the
/// job even when the submitter unwinds.
unsafe fn erase_job<'a, T>(
    job: Box<dyn FnOnce() -> T + Send + 'a>,
) -> Box<dyn FnOnce() -> T + Send + 'static> {
    // SAFETY: lifetime erasure only (same layout); the caller upholds
    // the drain contract in this function's doc.
    unsafe { std::mem::transmute(job) }
}

/// A generation of scene seeds being built ahead of time by detached
/// pool jobs (see [`BatchPipeline::prepare`]). Waiting it — explicitly
/// via [`Generation::wait_all`], implicitly via [`BatchPipeline::stream`],
/// or by dropping it — is the drain barrier for the construction jobs.
pub struct Generation<S> {
    handles: Vec<JobHandle<S>>,
}

impl<S> Generation<S> {
    /// Scenes in this generation.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Drain barrier: block until every seed is built, returning them
    /// in scene order.
    pub fn wait_all(self) -> Vec<S> {
        self.handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Keep only the first `n` seeds (a truncated final CMA-ES
    /// generation); the dropped construction jobs are drained.
    pub fn truncate(&mut self, n: usize) {
        self.handles.truncate(n);
    }
}

/// Scheduler for asynchronous, windowed batch stepping (module docs).
/// Cheap to construct; holds a [`Pool`] handle and a window size.
pub struct BatchPipeline {
    pool: Pool,
    window: usize,
    policy: FaultPolicy,
}

impl BatchPipeline {
    /// Pipeline on the process-wide shared runtime with a `workers`
    /// budget ([`Pool::shared`]); the in-flight window defaults to the
    /// budget (a wider window cannot add concurrency, only queueing).
    pub fn new(workers: usize) -> BatchPipeline {
        let w = workers.max(1);
        BatchPipeline { pool: Pool::shared(w), window: w, policy: FaultPolicy::default() }
    }

    /// Pipeline over an explicit pool handle (dedicated [`Pool::new`]
    /// runtimes for isolation, [`Pool::scoped`] for bench baselines);
    /// the window defaults to the handle's budget.
    pub fn with_pool(pool: Pool) -> BatchPipeline {
        let w = pool.workers().max(1);
        BatchPipeline { pool, window: w, policy: FaultPolicy::default() }
    }

    /// Builder-style fault-policy override (see
    /// [`BatchPipeline::set_fault_policy`]).
    pub fn with_fault_policy(mut self, policy: FaultPolicy) -> BatchPipeline {
        self.policy = policy;
        self
    }

    /// Set how the `*_checked` streaming entry points respond to a
    /// panicking scene job. Under [`FaultPolicy::FailFast`] (the
    /// default) they behave exactly like their unchecked twins — the
    /// panic drains the window and rethrows. `Isolate` and `Retry` both
    /// contain the panic and hand `consume` an `Err(SceneError)` in the
    /// failing scene's slot; the pipeline cannot re-run an opaque job
    /// (its side effects are unknown), so retry semantics live inside
    /// the scene closure — roll out with
    /// [`Simulation::step_recovering`](crate::engine::Simulation::step_recovering)
    /// or under [`super::SceneBatch`]'s `Retry` policy there.
    pub fn set_fault_policy(&mut self, policy: FaultPolicy) {
        self.policy = policy;
    }

    /// The pipeline's current [`FaultPolicy`].
    pub fn fault_policy(&self) -> FaultPolicy {
        self.policy
    }

    /// Builder-style window override (clamped to ≥ 1).
    pub fn with_window(mut self, window: usize) -> BatchPipeline {
        self.set_window(window);
        self
    }

    /// Set the bounded in-flight window: at most this many scenes of a
    /// stream are submitted-but-unconsumed at once.
    pub fn set_window(&mut self, window: usize) {
        self.window = window.max(1);
    }

    /// The configured in-flight window.
    pub fn window(&self) -> usize {
        self.window
    }

    /// The pool handle jobs are submitted on.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Kick off construction of `n` scene seeds as detached jobs and
    /// return immediately — generation *k+1*'s `prepare` overlaps
    /// generation *k*'s stepping. `build` must be candidate-independent
    /// (that is what makes the overlap legal) and is typically scene
    /// cloning, perturbation, or untaped settling.
    pub fn prepare<S, B>(&self, n: usize, build: B) -> Generation<S>
    where
        S: Send + 'static,
        B: Fn(usize) -> S + Send + Sync + 'static,
    {
        let build = Arc::new(build);
        Generation {
            handles: (0..n)
                .map(|i| {
                    let b = build.clone();
                    self.pool.submit(move || b(i))
                })
                .collect(),
        }
    }

    /// The one bounded-window driver both streaming entry points share:
    /// `submit_next(i)` submits scene `i`'s job (waiting its seed first,
    /// for [`BatchPipeline::stream`]), the oldest in-flight handle is
    /// waited whenever the window is full, and `consume` runs on the
    /// submitter in scene order.
    ///
    /// This is also the drain guarantee the callers' `erase_job` safety
    /// arguments rest on: `inflight` is waited or blocking-dropped on
    /// every exit path (including unwinds out of `wait`/`consume`), so
    /// no submitted job outlives the caller's borrowed closures.
    fn drive_window<T, R, F, C>(&self, n: usize, mut submit_next: F, mut consume: C) -> Vec<R>
    where
        F: FnMut(usize) -> JobHandle<T>,
        C: FnMut(usize, T) -> R,
    {
        let mut out = Vec::with_capacity(n);
        // Each in-flight entry carries its submission time when the
        // telemetry registry is enabled (None otherwise), feeding the
        // `pipeline.submit_to_consume` latency histogram without any
        // clock reads in disabled mode.
        let mut inflight: VecDeque<(JobHandle<T>, Option<Instant>)> = VecDeque::new();
        let mut consume_front =
            |inflight: &mut VecDeque<(JobHandle<T>, Option<Instant>)>, out: &mut Vec<R>| {
                // lint:allow(no-bare-unwrap: callers only consume while inflight is non-empty)
                let (h, t0) = inflight.pop_front().expect("window >= 1");
                let t = h.wait();
                if let Some(t0) = t0 {
                    telemetry::hist("pipeline.submit_to_consume")
                        .record(t0.elapsed().as_secs_f64());
                }
                let done = out.len();
                let r = consume(done, t);
                out.push(r);
            };
        for i in 0..n {
            if inflight.len() >= self.window {
                consume_front(&mut inflight, &mut out);
            }
            let enabled = telemetry::enabled();
            let t0 = if enabled { Some(Instant::now()) } else { None };
            inflight.push_back((submit_next(i), t0));
            if enabled {
                telemetry::counter("pipeline.scenes").incr();
                telemetry::hist("pipeline.window_occupancy").record(inflight.len() as f64);
            }
        }
        while !inflight.is_empty() {
            consume_front(&mut inflight, &mut out);
        }
        out
    }

    /// Stream `n` scenes through the bounded window: `work(i)` runs on
    /// a pool worker (build + roll out scene `i`), `consume(i, t)` runs
    /// on the submitting thread in scene order while later scenes still
    /// step. Returns the consumed results in scene order. Bitwise
    /// equivalent to `(0..n).map(|i| consume(i, work(i))).collect()`.
    pub fn map_windowed<T, R, W, C>(&self, n: usize, work: W, consume: C) -> Vec<R>
    where
        T: Send + 'static,
        W: Fn(usize) -> T + Sync,
        C: FnMut(usize, T) -> R,
    {
        let work_ref: &(dyn Fn(usize) -> T + Sync) = &work;
        self.drive_window(
            n,
            |i| {
                let job: Box<dyn FnOnce() -> T + Send + '_> = Box::new(move || work_ref(i));
                // SAFETY: `drive_window` drains every submitted handle
                // on every exit path, so `work` outlives every job.
                let job = unsafe { erase_job(job) };
                self.pool.submit(job)
            },
            consume,
        )
    }

    /// [`BatchPipeline::map_windowed`] over a prepared generation:
    /// seed `i` (waited in scene order — usually already built, since
    /// its construction overlapped the previous generation) is handed
    /// to `work(i, seed)` on a worker, and `consume(i, t)` runs on the
    /// submitter. The generation's drain barrier is this call.
    pub fn stream<S, T, R, W, C>(
        &self,
        generation: Generation<S>,
        work: W,
        consume: C,
    ) -> Vec<R>
    where
        S: Send + 'static,
        T: Send + 'static,
        W: Fn(usize, S) -> T + Sync,
        C: FnMut(usize, T) -> R,
    {
        let work_ref: &(dyn Fn(usize, S) -> T + Sync) = &work;
        let n = generation.handles.len();
        let mut seeds = generation.handles.into_iter();
        self.drive_window(
            n,
            |i| {
                // lint:allow(no-bare-unwrap: drive_window submits exactly n = handles.len())
                let seed = seeds.next().expect("one seed handle per scene").wait();
                let job: Box<dyn FnOnce() -> T + Send + '_> =
                    Box::new(move || work_ref(i, seed));
                // SAFETY: `drive_window` drains every submitted handle
                // on every exit path (and the remaining seed handles'
                // drops block too), so `work` outlives every job.
                let job = unsafe { erase_job(job) };
                self.pool.submit(job)
            },
            consume,
        )
    }

    /// Fault-contained [`BatchPipeline::map_windowed`]: `consume` gets
    /// `Ok(t)` for scenes whose job completed and — when the policy is
    /// not [`FaultPolicy::FailFast`] — `Err(e)` for scenes whose job
    /// panicked, with the payload recovered via
    /// [`SceneError::from_panic`]. A contained panic costs nothing to
    /// its neighbors: the window keeps flowing and every other scene is
    /// consumed normally. Under `FailFast` this is exactly
    /// `map_windowed` (the panic drains and rethrows).
    pub fn map_windowed_checked<T, R, W, C>(&self, n: usize, work: W, mut consume: C) -> Vec<R>
    where
        T: Send + 'static,
        W: Fn(usize) -> T + Sync,
        C: FnMut(usize, Result<T, SceneError>) -> R,
    {
        if self.policy == FaultPolicy::FailFast {
            return self.map_windowed(n, work, |i, t| consume(i, Ok(t)));
        }
        let work_ref = &work;
        self.map_windowed(
            n,
            move |i| catch_unwind(AssertUnwindSafe(|| work_ref(i))),
            |i, r| consume(i, r.map_err(|p| SceneError::from_panic(p.as_ref()))),
        )
    }

    /// Fault-contained [`BatchPipeline::stream`]: like
    /// [`BatchPipeline::map_windowed_checked`], but over a prepared
    /// [`Generation`] of seeds. Seed *construction* jobs are not
    /// contained (they run before the policy applies — wait the
    /// generation explicitly if builders can fail); the per-scene
    /// `work` jobs are.
    pub fn stream_checked<S, T, R, W, C>(
        &self,
        generation: Generation<S>,
        work: W,
        mut consume: C,
    ) -> Vec<R>
    where
        S: Send + 'static,
        T: Send + 'static,
        W: Fn(usize, S) -> T + Sync,
        C: FnMut(usize, Result<T, SceneError>) -> R,
    {
        if self.policy == FaultPolicy::FailFast {
            return self.stream(generation, work, |i, t| consume(i, Ok(t)));
        }
        let work_ref = &work;
        self.stream(
            generation,
            move |i, seed| catch_unwind(AssertUnwindSafe(|| work_ref(i, seed))),
            |i, r| consume(i, r.map_err(|p| SceneError::from_panic(p.as_ref()))),
        )
    }

    /// Double-buffered generation loop for population-style drivers:
    /// `build(g + 1)` runs on a pool worker while `run(g, state)`
    /// executes on the submitter, so the next generation's scene
    /// construction overlaps the current one's stepping. `run` is where
    /// rollouts execute and gradients are produced *and consumed* — the
    /// wait on `build(g)`'s handle is the only barrier, and it sits
    /// right at that gradient-consuming boundary, so results are
    /// bitwise-identical to the sequential
    /// `(0..n).map(|g| run(g, build(g)))` loop.
    pub fn generations<S, R, B, U>(&self, n: usize, build: B, mut run: U) -> Vec<R>
    where
        S: Send + 'static,
        B: Fn(usize) -> S + Send + Sync + 'static,
        U: FnMut(usize, S) -> R,
    {
        let build = Arc::new(build);
        let mut out = Vec::with_capacity(n);
        let mut next: Option<JobHandle<S>> = if n > 0 {
            let b = build.clone();
            Some(self.pool.submit(move || b(0)))
        } else {
            None
        };
        for g in 0..n {
            // lint:allow(no-bare-unwrap: loop refills `next` for every g < n)
            let state = next.take().expect("a handle exists for every generation").wait();
            if g + 1 < n {
                let b = build.clone();
                next = Some(self.pool.submit(move || b(g + 1)));
            }
            out.push(run(g, state));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn map_windowed_matches_sequential_in_order() {
        let pipe = BatchPipeline::new(4).with_window(2);
        let work = |i: usize| {
            let mut acc = 1.0f64;
            for k in 0..(i * 17 + 3) {
                acc = (acc * 1.0001 + k as f64).sin();
            }
            acc
        };
        let seq: Vec<(usize, f64)> = (0..12).map(|i| (i, work(i))).collect();
        let out = pipe.map_windowed(12, work, |i, v| (i, v));
        assert_eq!(out, seq, "pipelined output must be bitwise the sequential loop");
    }

    #[test]
    fn inline_pool_pipeline_is_the_sequential_loop() {
        // Budget 1 → submit degenerates to synchronous execution; the
        // consume callbacks interleave with work exactly like a loop.
        let pipe = BatchPipeline::new(1);
        let order = std::sync::Mutex::new(Vec::new());
        pipe.map_windowed(
            4,
            |i| {
                order.lock().unwrap().push(format!("work{i}"));
                i
            },
            |i, v| {
                assert_eq!(i, v);
                order.lock().unwrap().push(format!("consume{i}"));
            },
        );
        let o = order.lock().unwrap().clone();
        // All work happens before consumption begins only within the
        // window; at window=1 each scene's work precedes its consume.
        assert_eq!(o.iter().filter(|s| s.starts_with("work")).count(), 4);
        assert_eq!(o.iter().filter(|s| s.starts_with("consume")).count(), 4);
        assert!(o[0] == "work0");
    }

    #[test]
    fn window_bounds_in_flight_jobs() {
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let pipe = BatchPipeline::new(8).with_window(3);
        pipe.map_windowed(
            16,
            |i| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(2));
                live.fetch_sub(1, Ordering::SeqCst);
                i
            },
            |_i, v| v,
        );
        assert!(
            peak.load(Ordering::SeqCst) <= 3,
            "window 3 exceeded: peak {}",
            peak.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn stream_threads_prepared_seeds_in_order() {
        let pipe = BatchPipeline::new(3).with_window(2);
        let generation = pipe.prepare(6, |i| i * 10);
        assert_eq!(generation.len(), 6);
        let out = pipe.stream(generation, |i, seed| seed + i, |_i, v| v);
        assert_eq!(out, vec![0, 11, 22, 33, 44, 55]);
    }

    #[test]
    fn generation_wait_all_and_truncate() {
        let pipe = BatchPipeline::new(3);
        let mut generation = pipe.prepare(5, |i| i + 100);
        generation.truncate(3); // drains the dropped construction jobs
        assert_eq!(generation.wait_all(), vec![100, 101, 102]);
    }

    #[test]
    fn generations_double_buffer_matches_sequential() {
        let pipe = BatchPipeline::new(3);
        let built = AtomicUsize::new(0);
        let out = pipe.generations(
            5,
            move |g| g * 3,
            |g, s| {
                built.fetch_add(1, Ordering::SeqCst);
                assert_eq!(s, g * 3, "generation {g} got the wrong state");
                s + 1
            },
        );
        assert_eq!(out, vec![1, 4, 7, 10, 13]);
    }

    #[test]
    fn checked_stream_contains_two_panics_in_the_same_window() {
        // Scenes 2 and 3 land in the same in-flight window (window 2)
        // and both panic: both payloads must surface as per-scene
        // errors, every other scene must be consumed normally, and the
        // pool must stay usable afterwards.
        let pipe = BatchPipeline::new(4).with_window(2).with_fault_policy(FaultPolicy::Isolate);
        let out = pipe.map_windowed_checked(
            8,
            |i| {
                if i == 2 {
                    panic!("scene 2 exploded");
                }
                if i == 3 {
                    panic!("scene 3 exploded");
                }
                std::thread::sleep(Duration::from_millis(1));
                i * 10
            },
            |i, r| (i, r),
        );
        assert_eq!(out.len(), 8);
        for (i, r) in &out {
            match *i {
                2 | 3 => {
                    let Err(SceneError::WorkerPanic { payload }) = r else {
                        panic!("scene {i} should have a contained panic, got {r:?}");
                    };
                    assert!(
                        payload.contains(&format!("scene {i} exploded")),
                        "payload for scene {i}: {payload}"
                    );
                }
                _ => assert_eq!(r.as_ref().ok(), Some(&(i * 10)), "scene {i}"),
            }
        }
        // The pool is not poisoned and the pipeline is reusable.
        assert_eq!(pipe.pool().map(5, |i| i * 2), vec![0, 2, 4, 6, 8]);
        let again = pipe.map_windowed_checked(3, |i| i, |_i, r| r.is_ok());
        assert_eq!(again, vec![true, true, true]);
    }

    #[test]
    fn checked_under_fail_fast_is_the_unchecked_path() {
        let pipe = BatchPipeline::new(2);
        assert_eq!(pipe.fault_policy(), FaultPolicy::FailFast);
        let out = pipe.map_windowed_checked(4, |i| i + 1, |_i, r| r);
        assert_eq!(out.into_iter().collect::<Result<Vec<_>, _>>(), Ok(vec![1, 2, 3, 4]));
        let r = catch_unwind(AssertUnwindSafe(|| {
            pipe.map_windowed_checked(
                4,
                |i| {
                    if i == 1 {
                        panic!("boom");
                    }
                    i
                },
                |_i, r| r,
            )
        }));
        assert!(r.is_err(), "fail-fast checked must rethrow like the unchecked path");
        // Seeded generations flow through stream_checked the same way.
        let generation = pipe.prepare(3, |i| i * 7);
        let mut isolating = BatchPipeline::new(2);
        isolating.set_fault_policy(FaultPolicy::Retry);
        let out = isolating.stream_checked(
            generation,
            |i, seed| {
                if i == 1 {
                    panic!("seeded scene 1 exploded");
                }
                seed + 1
            },
            |_i, r| r,
        );
        assert_eq!(out[0], Ok(1));
        assert!(matches!(&out[1], Err(SceneError::WorkerPanic { .. })), "got {:?}", out[1]);
        assert_eq!(out[2], Ok(15));
    }

    #[test]
    fn panic_in_one_job_drains_and_rethrows_in_scene_order() {
        let pipe = BatchPipeline::new(4).with_window(2);
        let completed = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pipe.map_windowed(
                8,
                |i| {
                    if i == 3 {
                        panic!("scene 3 exploded");
                    }
                    std::thread::sleep(Duration::from_millis(1));
                    completed.fetch_add(1, Ordering::SeqCst);
                    i
                },
                |_i, v| v,
            )
        }));
        let payload = r.expect_err("the scene panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("scene 3 exploded"), "payload: {msg}");
        // Drained: nothing is still running after the unwind.
        let settled = completed.load(Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(completed.load(Ordering::SeqCst), settled, "jobs outlived the drain");
        // The pool is not poisoned.
        assert_eq!(pipe.pool().map(5, |i| i * 2), vec![0, 2, 4, 6, 8]);
    }
}
