//! Lockstep forward stepping over many scenes — the forward-side twin of
//! [`super::backward`]'s lockstep backward.
//!
//! Every scene advances through the staged step primitives
//! (`integrate → candidates → detect_and_zone → solve_zones → scatter →
//! commit`, see [`crate::engine::StepState`]) with a barrier at the
//! zone-solve level: at each fail-safe pass, every scene's
//! [`ZoneProblem`]s are pooled and solved together —
//!
//! * through a single [`Coordinator::zone_solve_batch`] call when all
//!   scenes share one PJRT coordinator, so bucket occupancy amortizes
//!   across the whole batch instead of within one scene (zones per pass
//!   per scene are few; zones per pass per *batch* fill buckets), or
//! * through one cross-scene [`Pool::map`] over the union of zones
//!   otherwise — better load balance than scene-granularity stepping
//!   when zone counts are skewed across the batch.
//!
//! With the native zone solver the pooled solve runs the exact same
//! per-zone code on the exact same problems in the exact same per-scene
//! order, so lockstep trajectories are bitwise-identical to sequential
//! per-scene [`crate::engine::Simulation::run`].
//!
//! The incremental collision pipeline composes transparently: each
//! scene's persistent [`crate::collision::CollisionState`] is adopted
//! inside its own `detect_and_zone` call (the parked slot is a per-scene
//! mutex precisely so this stage can run through `&Simulation` from
//! worker threads) and handed back at its `commit`. A scene that fails
//! any stage drops its step state — and with it the adopted cache — so
//! quarantined scenes restart detection cold, never from stale surfaces.
//!
//! Memory: each stage runs through the scene's own
//! [`crate::engine::Simulation`] primitives, so the batch's shared
//! [`BatchArena`](crate::util::arena::BatchArena) is exercised from
//! inside `detect_and_zone`/`scatter`/`commit` without this module
//! holding any buffers itself. At most `min(worker budget, n_scenes)`
//! scenes execute a stage concurrently, which is what bounds the
//! arena's live checkout count (and hence a warm batch's peak buffer
//! memory) regardless of population size. Panics from a scene's stage
//! propagate through the pool ([`Pool::map`] semantics) after the job
//! drains; arena guards return their buffers during unwinding, so the
//! arena stays consistent.

use crate::coordinator::Coordinator;
use crate::engine::{SceneError, Simulation, StepState};
use crate::solver::zone_solver::{ZoneProblem, ZoneSolution};
use crate::util::pool::Pool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

/// The one coordinator every scene shares, if they all hold the same
/// `Arc`. Distinct coordinators must not be pooled (different runtimes
/// would mis-bucket), so anything else returns `None`.
pub(crate) fn shared_coordinator(sims: &[Simulation]) -> Option<Arc<Coordinator>> {
    let first = sims.first()?.coordinator.clone()?;
    if sims[1..]
        .iter()
        .all(|s| s.coordinator.as_ref().is_some_and(|c| Arc::ptr_eq(c, &first)))
    {
        Some(first)
    } else {
        None
    }
}

/// Advance every scene one step in lockstep (see module docs).
pub(crate) fn step_lockstep(pool: &Pool, sims: &mut [Simulation]) {
    if sims.is_empty() {
        return;
    }
    let coord = shared_coordinator(sims);
    // Stages 1–2 per scene, in parallel.
    let mut states: Vec<StepState> = pool.map_mut(sims, |_, sim| {
        let mut st = sim.integrate();
        sim.candidates(&mut st);
        st
    });
    let n = sims.len();
    let max_passes = sims.iter().map(|s| s.cfg.max_resolve_passes).max().unwrap_or(0);
    let mut done = vec![false; n];
    for pass in 0..max_passes {
        // Stage 3 per scene, in parallel: CCD + zoning + problem build.
        // Scenes that broke out of the fail-safe loop skip the pass.
        let problems_per: Vec<Vec<ZoneProblem>> = {
            let sims_ref: &[Simulation] = sims;
            let done_ref: &[bool] = &done;
            pool.map_mut(&mut states, |i, st| {
                if done_ref[i] || pass >= sims_ref[i].cfg.max_resolve_passes {
                    Vec::new()
                } else {
                    sims_ref[i].detect_and_zone(st, pass)
                }
            })
        };
        for (i, probs) in problems_per.iter().enumerate() {
            if probs.is_empty() {
                done[i] = true;
            }
        }
        // Stage 4 — the lockstep barrier: pool every scene's zones at
        // this pass level into one batched solve. Scenes with a zone
        // hook keep their scene-local solver (the hook sees exactly the
        // problems it would see in a sequential step).
        let mut solutions_per: Vec<Vec<ZoneSolution>> = (0..n).map(|_| Vec::new()).collect();
        let mut union: Vec<(usize, usize)> = Vec::new(); // (scene, zone index)
        for (i, probs) in problems_per.iter().enumerate() {
            if probs.is_empty() {
                continue;
            }
            if sims[i].zone_hook.is_some() {
                solutions_per[i] = sims[i].solve_zones(probs);
            } else {
                for k in 0..probs.len() {
                    union.push((i, k));
                }
            }
        }
        if !union.is_empty() {
            let refs: Vec<&ZoneProblem> =
                union.iter().map(|&(i, k)| &problems_per[i][k]).collect();
            let sols: Vec<ZoneSolution> = match &coord {
                Some(c) => c.zone_solve_batch(&refs, pool),
                None => pool.map(refs.len(), |j| refs[j].solve()),
            };
            // Split back in (scene, zone) order — `union` is ascending,
            // so pushes land in each scene's original zone order.
            for (&(i, _), sol) in union.iter().zip(sols) {
                solutions_per[i].push(sol);
            }
        }
        // Stage 5 per scene: scatter into the candidates; scenes whose
        // pass was a no-op leave the fail-safe loop (same early exit as
        // the sequential driver).
        for (i, (probs, sols)) in problems_per.into_iter().zip(solutions_per).enumerate() {
            if probs.is_empty() {
                continue;
            }
            let max_disp = sims[i].scatter(&mut states[i], probs, sols, pass);
            if max_disp < 1e-9 {
                done[i] = true;
            }
        }
        if done.iter().all(|&d| d) {
            break;
        }
    }
    // Stage 6 per scene, in parallel. Each slot is consumed exactly
    // once; the per-scene mutexes are uncontended.
    let slots: Vec<Mutex<Option<StepState>>> =
        states.into_iter().map(|st| Mutex::new(Some(st))).collect();
    pool.map_mut(sims, |i, sim| {
        // lint:allow(no-bare-unwrap: fail-fast path — a worker panic here must abort)
        let st = slots[i].lock().unwrap().take().expect("step state consumed once");
        sim.commit(st);
    });
}

/// Fault-isolating variant of [`step_lockstep`]: scenes flagged in
/// `skip` sit the step out entirely, and a scene that fails any stage
/// — a worker panic, non-finite state, CCD garbage, or a divergent
/// zone solution — is dropped from the step without committing, so its
/// state stays exactly at the last good step (the staged step is
/// transactional: only `commit` mutates the simulation). Healthy
/// scenes are unaffected and commit normally. Returns one
/// `Option<SceneError>` slot per scene; `None` means the scene either
/// stepped cleanly or was skipped.
///
/// The lockstep barrier makes one stage genuinely shared: the batched
/// union zone solve. A panic inside it cannot be attributed to a
/// single scene, so every scene participating in that solve is failed
/// (each still rolls back untouched). Scene-attributable failures —
/// stage panics, per-scene finite checks — fail only their own scene.
pub(crate) fn try_step_lockstep(
    pool: &Pool,
    sims: &mut [Simulation],
    skip: &[bool],
) -> Vec<Option<SceneError>> {
    let n = sims.len();
    let mut errors: Vec<Option<SceneError>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return errors;
    }
    let coord = shared_coordinator(sims);
    // Stages 1–2 per scene, panics and non-finite states contained.
    let mut states: Vec<Option<StepState>> = Vec::with_capacity(n);
    {
        let skip_ref: &[bool] = skip;
        let staged: Vec<Option<Result<StepState, SceneError>>> = pool.map_mut(sims, |i, sim| {
            if skip_ref[i] {
                return None;
            }
            let step = sim.steps;
            Some(
                match catch_unwind(AssertUnwindSafe(|| {
                    let mut st = sim.integrate();
                    sim.candidates(&mut st);
                    st
                })) {
                    Ok(st) if st.is_finite() => Ok(st),
                    Ok(_) => Err(SceneError::NonFinite { what: "integrated candidates", step }),
                    Err(p) => Err(SceneError::from_panic(p.as_ref())),
                },
            )
        });
        for (i, r) in staged.into_iter().enumerate() {
            match r {
                None => states.push(None),
                Some(Ok(st)) => states.push(Some(st)),
                Some(Err(e)) => {
                    errors[i] = Some(e);
                    states.push(None);
                }
            }
        }
    }
    let max_passes = sims.iter().map(|s| s.cfg.max_resolve_passes).max().unwrap_or(0);
    let mut done: Vec<bool> = states.iter().map(|s| s.is_none()).collect();
    for pass in 0..max_passes {
        // Stage 3 per scene, contained. A failed build retires nothing
        // (the panic unwound before the problems existed); the scene's
        // candidate state is simply abandoned.
        let built: Vec<Result<Vec<ZoneProblem>, SceneError>> = {
            let sims_ref: &[Simulation] = sims;
            let done_ref: &[bool] = &done;
            pool.map_mut(&mut states, |i, slot| {
                let Some(st) = slot.as_mut() else { return Ok(Vec::new()) };
                if done_ref[i] || pass >= sims_ref[i].cfg.max_resolve_passes {
                    return Ok(Vec::new());
                }
                catch_unwind(AssertUnwindSafe(|| sims_ref[i].detect_and_zone(st, pass)))
                    .map_err(|p| SceneError::from_panic(p.as_ref()))
            })
        };
        let mut problems_per: Vec<Vec<ZoneProblem>> = Vec::with_capacity(n);
        for (i, r) in built.into_iter().enumerate() {
            match r {
                Ok(probs) => {
                    if probs.is_empty() {
                        done[i] = true;
                        problems_per.push(Vec::new());
                    } else if probs.iter().any(|p| !p.is_finite()) {
                        let step = sims[i].steps;
                        sims[i].abandon_pass(probs, Vec::new());
                        errors[i] = Some(SceneError::CcdFailure { step });
                        states[i] = None;
                        done[i] = true;
                        problems_per.push(Vec::new());
                    } else {
                        problems_per.push(probs);
                    }
                }
                Err(e) => {
                    errors[i] = Some(e);
                    states[i] = None;
                    done[i] = true;
                    problems_per.push(Vec::new());
                }
            }
        }
        // Stage 4 — the lockstep barrier, same pooling as the fail-fast
        // path, with the batched solve contained as a unit.
        let mut solutions_per: Vec<Vec<ZoneSolution>> = (0..n).map(|_| Vec::new()).collect();
        let mut union: Vec<(usize, usize)> = Vec::new();
        for (i, probs) in problems_per.iter().enumerate() {
            if probs.is_empty() {
                continue;
            }
            if sims[i].zone_hook.is_some() {
                match catch_unwind(AssertUnwindSafe(|| sims[i].solve_zones(probs))) {
                    Ok(sols) => solutions_per[i] = sols,
                    // Problems are retired in the verdict loop below.
                    Err(p) => errors[i] = Some(SceneError::from_panic(p.as_ref())),
                }
            } else {
                for k in 0..probs.len() {
                    union.push((i, k));
                }
            }
        }
        if !union.is_empty() {
            let refs: Vec<&ZoneProblem> =
                union.iter().map(|&(i, k)| &problems_per[i][k]).collect();
            let solved = catch_unwind(AssertUnwindSafe(|| match &coord {
                Some(c) => c.zone_solve_batch(&refs, pool),
                None => pool.map(refs.len(), |j| refs[j].solve()),
            }));
            match solved {
                Ok(sols) => {
                    for (&(i, _), sol) in union.iter().zip(sols) {
                        solutions_per[i].push(sol);
                    }
                }
                Err(p) => {
                    let e = SceneError::from_panic(p.as_ref());
                    for &(i, _) in &union {
                        if errors[i].is_none() {
                            errors[i] = Some(e.clone());
                        }
                    }
                }
            }
        }
        // Stage 5 per scene: verdict + scatter, contained.
        for (i, (probs, sols)) in problems_per.into_iter().zip(solutions_per).enumerate() {
            if probs.is_empty() {
                continue;
            }
            if errors[i].is_some() {
                sims[i].abandon_pass(probs, sols);
                states[i] = None;
                done[i] = true;
                continue;
            }
            if sols.len() != probs.len() || sols.iter().any(|s| !s.is_finite()) {
                let step = sims[i].steps;
                let zones = probs.len();
                sims[i].abandon_pass(probs, sols);
                errors[i] = Some(SceneError::ZoneDivergence { step, pass, zones });
                states[i] = None;
                done[i] = true;
                continue;
            }
            let Some(st) = states[i].as_mut() else { continue };
            match catch_unwind(AssertUnwindSafe(|| sims[i].scatter(st, probs, sols, pass))) {
                Ok(max_disp) => {
                    if max_disp < 1e-9 {
                        done[i] = true;
                    }
                }
                Err(p) => {
                    errors[i] = Some(SceneError::from_panic(p.as_ref()));
                    states[i] = None;
                    done[i] = true;
                }
            }
        }
        if done.iter().all(|&d| d) {
            break;
        }
    }
    // Stage 6 per scene: final finite gate, then commit. Only scenes
    // whose slot survived every stage reach this point.
    let slots: Vec<Mutex<Option<StepState>>> = states.into_iter().map(Mutex::new).collect();
    let committed: Vec<Option<Result<(), SceneError>>> = pool.map_mut(sims, |i, sim| {
        let st = slots[i].lock().unwrap_or_else(|e| e.into_inner()).take()?;
        let step = sim.steps;
        if !st.is_finite() {
            return Some(Err(SceneError::NonFinite { what: "resolved coordinates", step }));
        }
        Some(match catch_unwind(AssertUnwindSafe(|| sim.commit(st))) {
            Ok(()) => Ok(()),
            Err(p) => Err(SceneError::from_panic(p.as_ref())),
        })
    });
    for (i, r) in committed.into_iter().enumerate() {
        if let Some(Err(e)) = r {
            if errors[i].is_none() {
                errors[i] = Some(e);
            }
        }
    }
    errors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{RigidBody, System};
    use crate::engine::SimConfig;
    use crate::math::Vec3;
    use crate::mesh::primitives::{box_mesh, unit_box};

    fn drop_scene(vx: f64) -> Simulation {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(0.0, 0.8, 0.0))
                .with_velocity(Vec3::new(vx, 0.0, 0.0)),
        );
        Simulation::new(sys, SimConfig { dt: 1.0 / 100.0, ..Default::default() })
    }

    #[test]
    fn lockstep_step_matches_sequential_step() {
        // Different contact histories across the batch (one scene
        // airborne, one in contact) exercise the skewed-pass-count path.
        let mut sims: Vec<Simulation> = [0.0, 0.7].iter().map(|&vx| drop_scene(vx)).collect();
        // The shared persistent pool the batch layer actually steps on —
        // this doubles as the determinism assertion for lockstep
        // trajectories under the persistent runtime.
        let pool = Pool::global();
        for _ in 0..50 {
            step_lockstep(&pool, &mut sims);
        }
        for (i, &vx) in [0.0, 0.7].iter().enumerate() {
            let mut solo = drop_scene(vx);
            solo.run(50);
            for k in 0..6 {
                assert!(
                    sims[i].sys.rigids[1].q[k] == solo.sys.rigids[1].q[k],
                    "scene {i} q[{k}]: lockstep {} vs solo {}",
                    sims[i].sys.rigids[1].q[k],
                    solo.sys.rigids[1].q[k]
                );
                assert!(
                    sims[i].sys.rigids[1].qdot[k] == solo.sys.rigids[1].qdot[k],
                    "scene {i} qdot[{k}]",
                );
            }
            assert_eq!(sims[i].steps, solo.steps);
        }
    }

    #[test]
    fn try_step_lockstep_matches_step_lockstep_bitwise() {
        let mut guarded: Vec<Simulation> = [0.0, 0.7].iter().map(|&vx| drop_scene(vx)).collect();
        let mut plain: Vec<Simulation> = [0.0, 0.7].iter().map(|&vx| drop_scene(vx)).collect();
        let pool = Pool::global();
        let skip = vec![false; 2];
        for _ in 0..50 {
            let errs = try_step_lockstep(&pool, &mut guarded, &skip);
            assert!(errs.iter().all(|e| e.is_none()), "healthy scenes must not error: {errs:?}");
            step_lockstep(&pool, &mut plain);
        }
        for i in 0..2 {
            for k in 0..6 {
                assert_eq!(
                    guarded[i].sys.rigids[1].q[k].to_bits(),
                    plain[i].sys.rigids[1].q[k].to_bits(),
                    "scene {i} q[{k}] must be bitwise-identical"
                );
                assert_eq!(
                    guarded[i].sys.rigids[1].qdot[k].to_bits(),
                    plain[i].sys.rigids[1].qdot[k].to_bits(),
                    "scene {i} qdot[{k}] must be bitwise-identical"
                );
            }
        }
    }

    #[test]
    fn try_step_lockstep_isolates_a_poisoned_scene() {
        let mut sims: Vec<Simulation> = [0.0, 0.7].iter().map(|&vx| drop_scene(vx)).collect();
        let pool = Pool::global();
        let skip = vec![false; 2];
        for _ in 0..3 {
            try_step_lockstep(&pool, &mut sims, &skip);
        }
        let poisoned_q = sims[0].sys.rigids[1].q;
        sims[0].sys.rigids[1].ext_force = Vec3::new(f64::NAN, 0.0, 0.0);
        let errs = try_step_lockstep(&pool, &mut sims, &skip);
        assert!(
            matches!(errs[0], Some(SceneError::NonFinite { step: 3, .. })),
            "poisoned scene must fail its stage-2 finite gate: {errs:?}"
        );
        assert!(errs[1].is_none(), "healthy neighbor must step cleanly");
        assert_eq!(sims[0].steps, 3, "failed scene must not commit");
        assert_eq!(sims[1].steps, 4, "healthy scene must advance");
        for k in 0..6 {
            assert_eq!(
                sims[0].sys.rigids[1].q[k].to_bits(),
                poisoned_q[k].to_bits(),
                "failed scene's state must be untouched at q[{k}]"
            );
        }
        // A skipped scene sits the next step out entirely.
        sims[0].sys.rigids[1].ext_force = Vec3::new(0.0, 0.0, 0.0);
        let errs = try_step_lockstep(&pool, &mut sims, &[true, false]);
        assert!(errs.iter().all(|e| e.is_none()));
        assert_eq!(sims[0].steps, 3, "skipped scene must not advance");
        assert_eq!(sims[1].steps, 5);
    }

    #[test]
    fn shared_coordinator_requires_one_arc() {
        let sims: Vec<Simulation> = vec![drop_scene(0.0), drop_scene(0.1)];
        assert!(shared_coordinator(&sims).is_none(), "no coordinators installed");
        let mut sims = sims;
        let c = Arc::new(Coordinator::new(Arc::new(crate::runtime::Runtime::empty())));
        sims[0].coordinator = Some(c.clone());
        assert!(shared_coordinator(&sims).is_none(), "only one scene has it");
        sims[1].coordinator = Some(c.clone());
        assert!(shared_coordinator(&sims).is_some(), "both share the same Arc");
        sims[1].coordinator =
            Some(Arc::new(Coordinator::new(Arc::new(crate::runtime::Runtime::empty()))));
        assert!(shared_coordinator(&sims).is_none(), "distinct coordinators must not pool");
    }
}
