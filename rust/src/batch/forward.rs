//! Lockstep forward stepping over many scenes — the forward-side twin of
//! [`super::backward`]'s lockstep backward.
//!
//! Every scene advances through the staged step primitives
//! (`integrate → candidates → detect_and_zone → solve_zones → scatter →
//! commit`, see [`crate::engine::StepState`]) with a barrier at the
//! zone-solve level: at each fail-safe pass, every scene's
//! [`ZoneProblem`]s are pooled and solved together —
//!
//! * through a single [`Coordinator::zone_solve_batch`] call when all
//!   scenes share one PJRT coordinator, so bucket occupancy amortizes
//!   across the whole batch instead of within one scene (zones per pass
//!   per scene are few; zones per pass per *batch* fill buckets), or
//! * through one cross-scene [`Pool::map`] over the union of zones
//!   otherwise — better load balance than scene-granularity stepping
//!   when zone counts are skewed across the batch.
//!
//! With the native zone solver the pooled solve runs the exact same
//! per-zone code on the exact same problems in the exact same per-scene
//! order, so lockstep trajectories are bitwise-identical to sequential
//! per-scene [`crate::engine::Simulation::run`].
//!
//! Memory: each stage runs through the scene's own
//! [`crate::engine::Simulation`] primitives, so the batch's shared
//! [`BatchArena`](crate::util::arena::BatchArena) is exercised from
//! inside `detect_and_zone`/`scatter`/`commit` without this module
//! holding any buffers itself. At most `min(worker budget, n_scenes)`
//! scenes execute a stage concurrently, which is what bounds the
//! arena's live checkout count (and hence a warm batch's peak buffer
//! memory) regardless of population size. Panics from a scene's stage
//! propagate through the pool ([`Pool::map`] semantics) after the job
//! drains; arena guards return their buffers during unwinding, so the
//! arena stays consistent.

use crate::coordinator::Coordinator;
use crate::engine::{Simulation, StepState};
use crate::solver::zone_solver::{ZoneProblem, ZoneSolution};
use crate::util::pool::Pool;
use std::sync::{Arc, Mutex};

/// The one coordinator every scene shares, if they all hold the same
/// `Arc`. Distinct coordinators must not be pooled (different runtimes
/// would mis-bucket), so anything else returns `None`.
pub(crate) fn shared_coordinator(sims: &[Simulation]) -> Option<Arc<Coordinator>> {
    let first = sims.first()?.coordinator.clone()?;
    if sims[1..]
        .iter()
        .all(|s| s.coordinator.as_ref().is_some_and(|c| Arc::ptr_eq(c, &first)))
    {
        Some(first)
    } else {
        None
    }
}

/// Advance every scene one step in lockstep (see module docs).
pub(crate) fn step_lockstep(pool: &Pool, sims: &mut [Simulation]) {
    if sims.is_empty() {
        return;
    }
    let coord = shared_coordinator(sims);
    // Stages 1–2 per scene, in parallel.
    let mut states: Vec<StepState> = pool.map_mut(sims, |_, sim| {
        let mut st = sim.integrate();
        sim.candidates(&mut st);
        st
    });
    let n = sims.len();
    let max_passes = sims.iter().map(|s| s.cfg.max_resolve_passes).max().unwrap_or(0);
    let mut done = vec![false; n];
    for pass in 0..max_passes {
        // Stage 3 per scene, in parallel: CCD + zoning + problem build.
        // Scenes that broke out of the fail-safe loop skip the pass.
        let problems_per: Vec<Vec<ZoneProblem>> = {
            let sims_ref: &[Simulation] = sims;
            let done_ref: &[bool] = &done;
            pool.map_mut(&mut states, |i, st| {
                if done_ref[i] || pass >= sims_ref[i].cfg.max_resolve_passes {
                    Vec::new()
                } else {
                    sims_ref[i].detect_and_zone(st, pass)
                }
            })
        };
        for (i, probs) in problems_per.iter().enumerate() {
            if probs.is_empty() {
                done[i] = true;
            }
        }
        // Stage 4 — the lockstep barrier: pool every scene's zones at
        // this pass level into one batched solve. Scenes with a zone
        // hook keep their scene-local solver (the hook sees exactly the
        // problems it would see in a sequential step).
        let mut solutions_per: Vec<Vec<ZoneSolution>> = (0..n).map(|_| Vec::new()).collect();
        let mut union: Vec<(usize, usize)> = Vec::new(); // (scene, zone index)
        for (i, probs) in problems_per.iter().enumerate() {
            if probs.is_empty() {
                continue;
            }
            if sims[i].zone_hook.is_some() {
                solutions_per[i] = sims[i].solve_zones(probs);
            } else {
                for k in 0..probs.len() {
                    union.push((i, k));
                }
            }
        }
        if !union.is_empty() {
            let refs: Vec<&ZoneProblem> =
                union.iter().map(|&(i, k)| &problems_per[i][k]).collect();
            let sols: Vec<ZoneSolution> = match &coord {
                Some(c) => c.zone_solve_batch(&refs, pool),
                None => pool.map(refs.len(), |j| refs[j].solve()),
            };
            // Split back in (scene, zone) order — `union` is ascending,
            // so pushes land in each scene's original zone order.
            for (&(i, _), sol) in union.iter().zip(sols) {
                solutions_per[i].push(sol);
            }
        }
        // Stage 5 per scene: scatter into the candidates; scenes whose
        // pass was a no-op leave the fail-safe loop (same early exit as
        // the sequential driver).
        for (i, (probs, sols)) in problems_per.into_iter().zip(solutions_per).enumerate() {
            if probs.is_empty() {
                continue;
            }
            let max_disp = sims[i].scatter(&mut states[i], probs, sols, pass);
            if max_disp < 1e-9 {
                done[i] = true;
            }
        }
        if done.iter().all(|&d| d) {
            break;
        }
    }
    // Stage 6 per scene, in parallel. Each slot is consumed exactly
    // once; the per-scene mutexes are uncontended.
    let slots: Vec<Mutex<Option<StepState>>> =
        states.into_iter().map(|st| Mutex::new(Some(st))).collect();
    pool.map_mut(sims, |i, sim| {
        let st = slots[i].lock().unwrap().take().expect("step state consumed once");
        sim.commit(st);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{RigidBody, System};
    use crate::engine::SimConfig;
    use crate::math::Vec3;
    use crate::mesh::primitives::{box_mesh, unit_box};

    fn drop_scene(vx: f64) -> Simulation {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(0.0, 0.8, 0.0))
                .with_velocity(Vec3::new(vx, 0.0, 0.0)),
        );
        Simulation::new(sys, SimConfig { dt: 1.0 / 100.0, ..Default::default() })
    }

    #[test]
    fn lockstep_step_matches_sequential_step() {
        // Different contact histories across the batch (one scene
        // airborne, one in contact) exercise the skewed-pass-count path.
        let mut sims: Vec<Simulation> = [0.0, 0.7].iter().map(|&vx| drop_scene(vx)).collect();
        // The shared persistent pool the batch layer actually steps on —
        // this doubles as the determinism assertion for lockstep
        // trajectories under the persistent runtime.
        let pool = Pool::global();
        for _ in 0..50 {
            step_lockstep(&pool, &mut sims);
        }
        for (i, &vx) in [0.0, 0.7].iter().enumerate() {
            let mut solo = drop_scene(vx);
            solo.run(50);
            for k in 0..6 {
                assert!(
                    sims[i].sys.rigids[1].q[k] == solo.sys.rigids[1].q[k],
                    "scene {i} q[{k}]: lockstep {} vs solo {}",
                    sims[i].sys.rigids[1].q[k],
                    solo.sys.rigids[1].q[k]
                );
                assert!(
                    sims[i].sys.rigids[1].qdot[k] == solo.sys.rigids[1].qdot[k],
                    "scene {i} qdot[{k}]",
                );
            }
            assert_eq!(sims[i].steps, solo.steps);
        }
    }

    #[test]
    fn shared_coordinator_requires_one_arc() {
        let sims: Vec<Simulation> = vec![drop_scene(0.0), drop_scene(0.1)];
        assert!(shared_coordinator(&sims).is_none(), "no coordinators installed");
        let mut sims = sims;
        let c = Arc::new(Coordinator::new(Arc::new(crate::runtime::Runtime::empty())));
        sims[0].coordinator = Some(c.clone());
        assert!(shared_coordinator(&sims).is_none(), "only one scene has it");
        sims[1].coordinator = Some(c.clone());
        assert!(shared_coordinator(&sims).is_some(), "both share the same Arc");
        sims[1].coordinator =
            Some(Arc::new(Coordinator::new(Arc::new(crate::runtime::Runtime::empty()))));
        assert!(shared_coordinator(&sims).is_none(), "distinct coordinators must not pool");
    }
}
