//! DiffSim: scalable differentiable physics (ICML 2020 reproduction).
pub mod baselines;
pub mod batch;
pub mod bodies;
pub mod collision;
pub mod coordinator;
pub mod diff;
pub mod engine;
pub mod experiments;
pub mod math;
pub mod mesh;
pub mod ml;
pub mod runtime;
pub mod solver;
pub mod util;
