//! DiffSim: scalable differentiable physics (ICML 2020 reproduction).

// Raw operations inside `unsafe fn` bodies must sit in explicit
// `unsafe {}` blocks, each carrying its own `// SAFETY:` justification
// (the latter enforced tree-wide by `cargo xtask lint`). Also set via
// [workspace.lints] in Cargo.toml; stated here so the policy holds even
// for builds that bypass the workspace manifest.
#![deny(unsafe_op_in_unsafe_fn)]

// Execute the README's ```rust blocks as doctests (`cargo test --doc`),
// so the examples in it are run, not just rendered. Invisible to
// `cargo doc` (the cfg is only set during doctest collection).
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
#[allow(dead_code)]
struct ReadmeDoctests;

pub mod baselines;
pub mod batch;
pub mod bodies;
pub mod collision;
pub mod coordinator;
pub mod diff;
pub mod engine;
pub mod experiments;
pub mod math;
pub mod mesh;
pub mod ml;
pub mod runtime;
pub mod solver;
pub mod util;

/// Observability facade: the process-wide telemetry registry
/// ([`util::telemetry`]) under its conventional short name, so call
/// sites read `diffsim::obs::span("…")` / `diffsim::obs::counter("…")`
/// / `diffsim::obs::Trace`.
pub use util::telemetry as obs;
