//! Numerical substrates: small fixed-size linear algebra ([`Vec3`],
//! [`Mat3`]), dense factorizations ([`dense`]: LU, Cholesky, Householder
//! QR), CSR sparse matrices ([`sparse`]), conjugate gradients ([`cg`]),
//! the RPY Euler-angle kinematics from the paper's appendices A–C
//! ([`euler`]), and the explicit-lane kernel layer with its scalar
//! parity oracle ([`simd`]).
pub mod cg;
pub mod dense;
pub mod euler;
pub mod mat3;
pub mod simd;
pub mod sparse;
pub mod vec3;

pub use mat3::Mat3;
pub use vec3::Vec3;

/// Machine-ish tolerance used across solvers.
pub const EPS: f64 = 1e-12;
