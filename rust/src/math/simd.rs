//! Explicit-lane (`std::simd`-style) f64×4 math kernels with an
//! always-compiled scalar oracle.
//!
//! The step loop's wall-clock is dominated by many small data-parallel
//! kernels — Gauss–Newton residual/Jacobian evaluation, CG matvecs,
//! cloth implicit-Euler CSR row products — all f64 over contiguous
//! (structure-of-arrays) buffers. This module vectorizes them with an
//! explicit four-lane [`F64x4`] type (a plain `[f64; 4]` wrapper whose
//! lane-wise ops the compiler maps onto the target's vector unit; no
//! `unsafe`, no nightly `std::simd`) while keeping the original scalar
//! loops compiled in as the bitwise-parity oracle, the same baseline
//! discipline `Pool::scoped` and the refit-vs-rebuild oracle use.
//!
//! ## The reduction-order contract
//!
//! Every kernel is classified by whether vectorization preserves the
//! scalar summation order:
//!
//! * **Elementwise kernels** ([`axpy`], [`xpby`], [`mul_into`],
//!   [`sub_into`]) compute each output element with exactly the same
//!   floating-point ops as the scalar loop (one multiply, one add — no
//!   FMA contraction), so the lane versions are **bitwise identical**
//!   to the oracle in every mode.
//! * **Reduction kernels** ([`dot`], [`norm`], dense/CSR row products)
//!   in [`SimdMode::Fast`] accumulate four partial sums and combine
//!   them with the fixed tree `(l0+l1) + (l2+l3)`, then fold the
//!   `n % 4` remainder elements in scalar order. Reassociation changes
//!   rounding: for inputs whose elementwise products are `p_i`, both
//!   the scalar and the lane sum differ from the exact sum by at most
//!   `n·ε·Σ|p_i|` (standard recursive-summation analysis, ε = 2⁻⁵³),
//!   so the two paths agree to within **`2·n·ε·Σ|p_i|`** — the bound
//!   `tests/prop_math_kernels.rs` asserts. NaN/∞ propagation classes
//!   are preserved (a NaN or overflowing input poisons both paths).
//!
//! ## Mode selection
//!
//! The active [`SimdMode`] is a process-wide knob (one relaxed atomic
//! load per kernel call, not per element):
//!
//! * [`SimdMode::Scalar`] — oracle loops everywhere (the portable
//!   fallback; also what non-vector targets resolve to).
//! * [`SimdMode::Ordered`] — lane kernels only where the reduction
//!   order is preserved; trajectories stay **bitwise identical** to
//!   `Scalar` end-to-end.
//! * [`SimdMode::Fast`] — lane kernels everywhere (the default on
//!   x86-64/AArch64); reductions obey the ULP contract above.
//!
//! Selection priority: an explicit [`set_mode`] call (which
//! `SimConfig::simd` applies at `Simulation` construction and at every
//! step entry) beats the `DIFFSIM_SIMD` environment variable
//! (`scalar`/`off`/`0`, `ordered`, `fast`/`on`/`1`, `auto`), which
//! beats the compile-time default: [`SimdMode::Fast`] when the target
//! has a vector unit worth the lane shuffle ([`LANE_TARGET`]),
//! [`SimdMode::Scalar`] otherwise.
//!
//! ```
//! use diffsim::math::simd::{self, SimdMode};
//! let a = [1.0, 2.0, 3.0, 4.0, 5.0];
//! let fast = simd::dot_fast(&a, &a);
//! let oracle = simd::dot_scalar(&a, &a);
//! // Integer-valued inputs sum exactly: the lane tree agrees bitwise.
//! assert_eq!(fast.to_bits(), oracle.to_bits());
//! assert!(matches!(simd::mode(), SimdMode::Scalar | SimdMode::Ordered | SimdMode::Fast));
//! ```

use std::sync::atomic::{AtomicU8, Ordering};

/// Lane width of the explicit-SIMD kernels (f64×4 — one AVX2 register,
/// two NEON/SSE2 registers).
pub const LANES: usize = 4;

/// Compile-time gate: `true` on targets whose baseline ISA includes a
/// floating-point vector unit (x86-64 implies SSE2, AArch64 implies
/// NEON, wasm with `simd128`). On other targets the lane layout is a
/// pessimization, so [`default_mode`] resolves to [`SimdMode::Scalar`]
/// there; the lane kernels themselves are portable Rust and still
/// compile (and stay testable) everywhere.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64", target_feature = "simd128"))]
pub const LANE_TARGET: bool = true;
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64", target_feature = "simd128")))]
pub const LANE_TARGET: bool = false;

/// Which kernel implementations the math layer dispatches to. See the
/// [module docs](self) for the reduction-order contract per mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Scalar oracle loops everywhere (bitwise reference).
    Scalar,
    /// Lane kernels only where bitwise parity with `Scalar` holds.
    Ordered,
    /// Lane kernels everywhere; reductions reassociate (ULP-bounded).
    Fast,
}

impl SimdMode {
    /// Parse a `DIFFSIM_SIMD`-style selector. Accepts
    /// `scalar`/`off`/`0` → `Scalar`, `ordered`/`bitwise` → `Ordered`,
    /// `fast`/`on`/`simd`/`1` → `Fast`, and `auto` → the compile-time
    /// default. Unknown strings parse to `None` (callers keep the
    /// previous/default mode rather than guessing).
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" | "off" | "0" | "false" => Some(SimdMode::Scalar),
            "ordered" | "bitwise" => Some(SimdMode::Ordered),
            "fast" | "on" | "simd" | "1" | "true" => Some(SimdMode::Fast),
            "auto" | "" => Some(default_mode()),
            _ => None,
        }
    }
}

/// The compile-time default: [`SimdMode::Fast`] on [`LANE_TARGET`]s,
/// [`SimdMode::Scalar`] elsewhere.
pub fn default_mode() -> SimdMode {
    if LANE_TARGET {
        SimdMode::Fast
    } else {
        SimdMode::Scalar
    }
}

/// Process-wide mode cell. `UNSET` (the initial value) means "not yet
/// resolved": the first [`mode`] call folds in `DIFFSIM_SIMD` / the
/// compile-time default and stores the result.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);
const MODE_UNSET: u8 = u8::MAX;

fn encode(m: SimdMode) -> u8 {
    match m {
        SimdMode::Scalar => 0,
        SimdMode::Ordered => 1,
        SimdMode::Fast => 2,
    }
}

#[cold]
fn init_mode_from_env() -> SimdMode {
    let m = std::env::var("DIFFSIM_SIMD")
        .ok()
        .and_then(|s| SimdMode::parse(&s))
        .unwrap_or_else(default_mode);
    MODE.store(encode(m), Ordering::Relaxed);
    m
}

/// The currently selected [`SimdMode`] (one relaxed load; resolves the
/// `DIFFSIM_SIMD` environment override on first use).
#[inline]
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        0 => SimdMode::Scalar,
        1 => SimdMode::Ordered,
        2 => SimdMode::Fast,
        _ => init_mode_from_env(),
    }
}

/// Select the kernel mode process-wide. `SimConfig::simd` routes here
/// (at `Simulation::new` and on every step entry), so per-scene configs
/// win over the environment default. The knob is global — concurrently
/// stepping scenes that request *different* modes race benignly (last
/// store wins for subsequent kernel calls); batch drivers share one
/// mode by construction.
pub fn set_mode(m: SimdMode) {
    MODE.store(encode(m), Ordering::Relaxed);
}

/// `true` when reductions should use the lane path (`Fast` only).
#[inline]
pub fn reduce_lanes() -> bool {
    mode() == SimdMode::Fast
}

/// `true` when elementwise kernels should use the lane path
/// (`Ordered` and `Fast` — bitwise-neutral either way).
#[inline]
pub fn elementwise_lanes() -> bool {
    mode() != SimdMode::Scalar
}

/// Four f64 lanes with explicit elementwise ops — the `std::simd`
/// shape on stable Rust. All ops are plain per-lane mul/add/sub (no
/// FMA), so a lane op on element `i` rounds exactly like the scalar
/// loop's op on element `i`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct F64x4(pub [f64; 4]);

impl F64x4 {
    #[inline]
    pub fn zero() -> F64x4 {
        F64x4([0.0; 4])
    }

    #[inline]
    pub fn splat(v: f64) -> F64x4 {
        F64x4([v; 4])
    }

    /// Load lanes from the first four elements of `s` (`s.len() >= 4`).
    #[inline]
    pub fn load(s: &[f64]) -> F64x4 {
        F64x4([s[0], s[1], s[2], s[3]])
    }

    /// Store lanes into the first four elements of `out`.
    #[inline]
    pub fn store(self, out: &mut [f64]) {
        out[..4].copy_from_slice(&self.0);
    }

    /// Horizontal sum with the fixed tree `(l0+l1) + (l2+l3)` — the
    /// documented reduction order of every `Fast` kernel.
    #[inline]
    pub fn hsum(self) -> f64 {
        (self.0[0] + self.0[1]) + (self.0[2] + self.0[3])
    }
}

impl std::ops::Add for F64x4 {
    type Output = F64x4;
    #[inline]
    fn add(self, o: F64x4) -> F64x4 {
        let (a, b) = (self.0, o.0);
        F64x4([a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3]])
    }
}

impl std::ops::Sub for F64x4 {
    type Output = F64x4;
    #[inline]
    fn sub(self, o: F64x4) -> F64x4 {
        let (a, b) = (self.0, o.0);
        F64x4([a[0] - b[0], a[1] - b[1], a[2] - b[2], a[3] - b[3]])
    }
}

impl std::ops::Mul for F64x4 {
    type Output = F64x4;
    #[inline]
    fn mul(self, o: F64x4) -> F64x4 {
        let (a, b) = (self.0, o.0);
        F64x4([a[0] * b[0], a[1] * b[1], a[2] * b[2], a[3] * b[3]])
    }
}

// ---------------------------------------------------------------------
// Reduction kernels: dot products (dense rows, CSR rows, norms).
// ---------------------------------------------------------------------

/// Scalar-oracle dot product: strictly sequential left-to-right
/// accumulation from 0.0 (the seed tree's summation order).
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let mut s = 0.0;
    for i in 0..n {
        s += a[i] * b[i];
    }
    s
}

/// Lane dot product: four running partial sums over the `n - n % 4`
/// prefix, [`F64x4::hsum`]'s fixed tree, then the remainder elements in
/// scalar order. Differs from [`dot_scalar`] by at most
/// `2·n·ε·Σ|aᵢ·bᵢ|` (see the [module docs](self)).
#[inline]
pub fn dot_fast(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let main = n - n % LANES;
    let mut acc = F64x4::zero();
    let mut i = 0;
    while i < main {
        acc = acc + F64x4::load(&a[i..]) * F64x4::load(&b[i..]);
        i += LANES;
    }
    let mut s = acc.hsum();
    for k in main..n {
        s += a[k] * b[k];
    }
    s
}

/// Mode-dispatched dot product ([`dot_fast`] under [`SimdMode::Fast`],
/// [`dot_scalar`] otherwise — `Ordered` keeps reductions sequential to
/// preserve bitwise parity).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    if reduce_lanes() {
        dot_fast(a, b)
    } else {
        dot_scalar(a, b)
    }
}

/// Euclidean norm through the mode-dispatched [`dot`].
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Scalar-oracle CSR row product Σₖ vals[k]·x[cols[k]].
#[inline]
pub fn csr_row_dot_scalar(vals: &[f64], cols: &[u32], x: &[f64]) -> f64 {
    debug_assert_eq!(vals.len(), cols.len());
    let mut s = 0.0;
    for k in 0..vals.len() {
        s += vals[k] * x[cols[k] as usize];
    }
    s
}

/// Lane CSR row product: contiguous value lanes against four gathered
/// `x` entries, same reduction tree and remainder handling (and thus
/// the same ULP contract) as [`dot_fast`].
#[inline]
pub fn csr_row_dot_fast(vals: &[f64], cols: &[u32], x: &[f64]) -> f64 {
    debug_assert_eq!(vals.len(), cols.len());
    let n = vals.len();
    let main = n - n % LANES;
    let mut acc = F64x4::zero();
    let mut k = 0;
    while k < main {
        let xs = F64x4([
            x[cols[k] as usize],
            x[cols[k + 1] as usize],
            x[cols[k + 2] as usize],
            x[cols[k + 3] as usize],
        ]);
        acc = acc + F64x4::load(&vals[k..]) * xs;
        k += LANES;
    }
    let mut s = acc.hsum();
    for t in main..n {
        s += vals[t] * x[cols[t] as usize];
    }
    s
}

// ---------------------------------------------------------------------
// Elementwise kernels: bitwise-identical to their scalar oracles in
// every mode (each element sees exactly one mul and one add/sub).
// ---------------------------------------------------------------------

/// Scalar oracle for [`axpy`]: `y[i] += alpha * x[i]`.
#[inline]
pub fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len().min(y.len());
    for i in 0..n {
        y[i] += alpha * x[i];
    }
}

/// Lane version of [`axpy`] — bitwise-identical to [`axpy_scalar`]
/// (per-element `y[i] + alpha·x[i]`, no reduction, no FMA).
#[inline]
pub fn axpy_lanes(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len().min(y.len());
    let main = n - n % LANES;
    let av = F64x4::splat(alpha);
    let mut i = 0;
    while i < main {
        let r = F64x4::load(&y[i..]) + av * F64x4::load(&x[i..]);
        r.store(&mut y[i..]);
        i += LANES;
    }
    for k in main..n {
        y[k] += alpha * x[k];
    }
}

/// Mode-dispatched `y += alpha·x` (lane path in `Ordered` and `Fast`;
/// bitwise-neutral by the elementwise contract).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    if elementwise_lanes() {
        axpy_lanes(alpha, x, y)
    } else {
        axpy_scalar(alpha, x, y)
    }
}

/// Scalar oracle for [`xpby`]: `y[i] = x[i] + beta * y[i]` (the CG
/// direction update `p ← r + β·p`).
#[inline]
pub fn xpby_scalar(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len().min(y.len());
    for i in 0..n {
        y[i] = x[i] + beta * y[i];
    }
}

/// Lane version of [`xpby`] — bitwise-identical to [`xpby_scalar`].
#[inline]
pub fn xpby_lanes(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len().min(y.len());
    let main = n - n % LANES;
    let bv = F64x4::splat(beta);
    let mut i = 0;
    while i < main {
        let r = F64x4::load(&x[i..]) + bv * F64x4::load(&y[i..]);
        r.store(&mut y[i..]);
        i += LANES;
    }
    for k in main..n {
        y[k] = x[k] + beta * y[k];
    }
}

/// Mode-dispatched `y = x + beta·y`.
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    if elementwise_lanes() {
        xpby_lanes(x, beta, y)
    } else {
        xpby_scalar(x, beta, y)
    }
}

/// Scalar oracle for [`mul_into`]: `out[i] = a[i] * b[i]`.
#[inline]
pub fn mul_into_scalar(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len().min(b.len()).min(out.len());
    for i in 0..n {
        out[i] = a[i] * b[i];
    }
}

/// Lane version of [`mul_into`] — bitwise-identical to the oracle.
#[inline]
pub fn mul_into_lanes(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len().min(b.len()).min(out.len());
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        (F64x4::load(&a[i..]) * F64x4::load(&b[i..])).store(&mut out[i..]);
        i += LANES;
    }
    for k in main..n {
        out[k] = a[k] * b[k];
    }
}

/// Mode-dispatched Hadamard product `out = a ∘ b` (the Jacobi
/// preconditioner application `z = M⁻¹·r`).
#[inline]
pub fn mul_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    if elementwise_lanes() {
        mul_into_lanes(a, b, out)
    } else {
        mul_into_scalar(a, b, out)
    }
}

/// Scalar oracle for [`sub_into`]: `out[i] = a[i] - b[i]`.
#[inline]
pub fn sub_into_scalar(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len().min(b.len()).min(out.len());
    for i in 0..n {
        out[i] = a[i] - b[i];
    }
}

/// Lane version of [`sub_into`] — bitwise-identical to the oracle.
#[inline]
pub fn sub_into_lanes(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    let n = a.len().min(b.len()).min(out.len());
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        (F64x4::load(&a[i..]) - F64x4::load(&b[i..])).store(&mut out[i..]);
        i += LANES;
    }
    for k in main..n {
        out[k] = a[k] - b[k];
    }
}

/// Mode-dispatched elementwise difference `out = a − b` (the
/// Gauss–Newton displacement `dq = q − q₀`).
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    if elementwise_lanes() {
        sub_into_lanes(a, b, out)
    } else {
        sub_into_scalar(a, b, out)
    }
}

/// Distance between `a` and `b` in units in the last place, measured on
/// the monotone integer number line of IEEE-754 doubles (so it spans
/// zero and subnormals correctly). Returns 0 for `a == b` (including
/// `+0 == -0`), `u64::MAX` when either side is NaN. Test/diagnostic
/// helper for the reduction-kernel parity suites.
pub fn ulp_diff(a: f64, b: f64) -> u64 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    // Map the sign-magnitude bit pattern onto a monotone integer line:
    // nonnegative floats keep their bits, negative floats mirror below
    // zero. i128 arithmetic avoids overflow at the extremes.
    fn line(x: f64) -> i128 {
        let b = x.to_bits() as i64 as i128;
        if b < 0 {
            (i64::MIN as i128) - b
        } else {
            b
        }
    }
    let d = line(a) - line(b);
    u64::try_from(d.unsigned_abs()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_all_selectors() {
        assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("OFF"), Some(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("0"), Some(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("ordered"), Some(SimdMode::Ordered));
        assert_eq!(SimdMode::parse("bitwise"), Some(SimdMode::Ordered));
        assert_eq!(SimdMode::parse("fast"), Some(SimdMode::Fast));
        assert_eq!(SimdMode::parse(" on "), Some(SimdMode::Fast));
        assert_eq!(SimdMode::parse("1"), Some(SimdMode::Fast));
        assert_eq!(SimdMode::parse("auto"), Some(default_mode()));
        assert_eq!(SimdMode::parse("warp9"), None);
    }

    #[test]
    fn hsum_tree_order_is_fixed() {
        // (1 + 2^-53) + (2^-53 + 0) rounds differently than sequential
        // accumulation; pin the documented tree.
        let e = f64::EPSILON / 2.0;
        let v = F64x4([1.0, e, e, 0.0]);
        assert_eq!(v.hsum().to_bits(), ((1.0 + e) + (e + 0.0)).to_bits());
    }

    #[test]
    fn lane_ops_are_elementwise() {
        let a = F64x4([1.0, -2.0, 3.5, 0.0]);
        let b = F64x4([0.5, 4.0, -1.0, 9.0]);
        assert_eq!((a + b).0, [1.5, 2.0, 2.5, 9.0]);
        assert_eq!((a - b).0, [0.5, -6.0, 4.5, -9.0]);
        assert_eq!((a * b).0, [0.5, -8.0, -3.5, 0.0]);
        let mut out = [0.0; 4];
        F64x4::splat(7.0).store(&mut out);
        assert_eq!(out, [7.0; 4]);
        assert_eq!(F64x4::load(&[1.0, 2.0, 3.0, 4.0, 99.0]).0, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn exact_dots_agree_bitwise() {
        // Integer-valued data sums exactly in both orders: a cheap
        // witness that the fast path computes the same products.
        let a: Vec<f64> = (0..23).map(|i| (i % 7) as f64 - 3.0).collect();
        let b: Vec<f64> = (0..23).map(|i| (i % 5) as f64).collect();
        assert_eq!(dot_fast(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits());
        let cols: Vec<u32> = (0..23).rev().collect();
        assert_eq!(
            csr_row_dot_fast(&a, &cols, &b).to_bits(),
            csr_row_dot_scalar(&a, &cols, &b).to_bits()
        );
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(dot_fast(&[], &[]), 0.0);
        assert_eq!(dot_scalar(&[], &[]), 0.0);
        assert_eq!(dot_fast(&[2.0], &[3.0]), 6.0);
        assert_eq!(csr_row_dot_fast(&[], &[], &[1.0]), 0.0);
        let mut y: Vec<f64> = vec![];
        axpy_lanes(2.0, &[], &mut y);
        assert!(y.is_empty());
    }

    #[test]
    fn ulp_diff_basics() {
        assert_eq!(ulp_diff(1.0, 1.0), 0);
        assert_eq!(ulp_diff(0.0, -0.0), 0);
        assert_eq!(ulp_diff(1.0, 1.0 + f64::EPSILON), 1);
        assert_eq!(ulp_diff(-1.0, -1.0 - f64::EPSILON), 1);
        assert_eq!(
            ulp_diff(f64::MIN_POSITIVE, -f64::MIN_POSITIVE),
            ulp_diff(0.0, f64::MIN_POSITIVE) * 2
        );
        assert_eq!(ulp_diff(f64::NAN, 1.0), u64::MAX);
        assert!(ulp_diff(1.0, 2.0) > 1_000_000);
    }

    #[test]
    fn mode_cell_roundtrips() {
        // Unit tests share the process-global cell with other lib
        // tests; restore whatever was active when done.
        let saved = mode();
        set_mode(SimdMode::Ordered);
        assert_eq!(mode(), SimdMode::Ordered);
        assert!(elementwise_lanes());
        assert!(!reduce_lanes());
        set_mode(SimdMode::Fast);
        assert!(reduce_lanes());
        set_mode(SimdMode::Scalar);
        assert!(!elementwise_lanes());
        set_mode(saved);
    }
}
