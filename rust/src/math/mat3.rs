//! 3×3 matrix (row-major) for rotations and inertia tensors.

use super::vec3::Vec3;
use std::ops::{Add, Mul, Sub};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat3 {
    pub m: [[f64; 3]; 3],
}

impl Mat3 {
    pub const fn new(m: [[f64; 3]; 3]) -> Mat3 {
        Mat3 { m }
    }

    pub fn zeros() -> Mat3 {
        Mat3::new([[0.0; 3]; 3])
    }

    pub fn identity() -> Mat3 {
        Mat3::new([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
    }

    pub fn diag(d: Vec3) -> Mat3 {
        Mat3::new([[d.x, 0.0, 0.0], [0.0, d.y, 0.0], [0.0, 0.0, d.z]])
    }

    pub fn from_outer(o: [[f64; 3]; 3]) -> Mat3 {
        Mat3::new(o)
    }

    pub fn col(&self, j: usize) -> Vec3 {
        Vec3::new(self.m[0][j], self.m[1][j], self.m[2][j])
    }

    pub fn row(&self, i: usize) -> Vec3 {
        Vec3::new(self.m[i][0], self.m[i][1], self.m[i][2])
    }

    pub fn transpose(&self) -> Mat3 {
        let m = &self.m;
        Mat3::new([
            [m[0][0], m[1][0], m[2][0]],
            [m[0][1], m[1][1], m[2][1]],
            [m[0][2], m[1][2], m[2][2]],
        ])
    }

    pub fn det(&self) -> f64 {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    pub fn inverse(&self) -> Mat3 {
        let d = self.det();
        assert!(d.abs() > 1e-300, "Mat3::inverse of singular matrix");
        let m = &self.m;
        let inv = |a: f64, b: f64, c: f64, e: f64| (a * e - b * c) / d;
        Mat3::new([
            [
                inv(m[1][1], m[1][2], m[2][1], m[2][2]),
                inv(m[0][2], m[0][1], m[2][2], m[2][1]),
                inv(m[0][1], m[0][2], m[1][1], m[1][2]),
            ],
            [
                inv(m[1][2], m[1][0], m[2][2], m[2][0]),
                inv(m[0][0], m[0][2], m[2][0], m[2][2]),
                inv(m[0][2], m[0][0], m[1][2], m[1][0]),
            ],
            [
                inv(m[1][0], m[1][1], m[2][0], m[2][1]),
                inv(m[0][1], m[0][0], m[2][1], m[2][0]),
                inv(m[0][0], m[0][1], m[1][0], m[1][1]),
            ],
        ])
    }

    /// Skew-symmetric cross-product matrix: skew(v) · w = v × w.
    pub fn skew(v: Vec3) -> Mat3 {
        Mat3::new([[0.0, -v.z, v.y], [v.z, 0.0, -v.x], [-v.y, v.x, 0.0]])
    }

    pub fn trace(&self) -> f64 {
        self.m[0][0] + self.m[1][1] + self.m[2][2]
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.m.iter().flatten().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Solve 3×3 system A x = b via the explicit inverse (well-conditioned
    /// inertia blocks only).
    pub fn solve(&self, b: Vec3) -> Vec3 {
        self.inverse() * b
    }
}

impl Mul<Vec3> for Mat3 {
    type Output = Vec3;
    fn mul(self, v: Vec3) -> Vec3 {
        Vec3::new(self.row(0).dot(v), self.row(1).dot(v), self.row(2).dot(v))
    }
}

impl Mul<Mat3> for Mat3 {
    type Output = Mat3;
    fn mul(self, o: Mat3) -> Mat3 {
        let mut r = Mat3::zeros();
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += self.m[i][k] * o.m[k][j];
                }
                r.m[i][j] = s;
            }
        }
        r
    }
}

impl Mul<f64> for Mat3 {
    type Output = Mat3;
    fn mul(self, s: f64) -> Mat3 {
        let mut r = self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] *= s;
            }
        }
        r
    }
}

impl Add for Mat3 {
    type Output = Mat3;
    fn add(self, o: Mat3) -> Mat3 {
        let mut r = self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] += o.m[i][j];
            }
        }
        r
    }
}

impl Sub for Mat3 {
    type Output = Mat3;
    fn sub(self, o: Mat3) -> Mat3 {
        let mut r = self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] -= o.m[i][j];
            }
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::quick;

    fn random_mat(g: &mut crate::util::quick::Gen) -> Mat3 {
        let v = g.vec_normal(9);
        Mat3::new([[v[0], v[1], v[2]], [v[3], v[4], v[5]], [v[6], v[7], v[8]]])
    }

    #[test]
    fn identity_is_neutral() {
        quick("mat3-identity", 50, |g| {
            let a = random_mat(g);
            let i = Mat3::identity();
            assert!(((a * i) - a).fro() < 1e-12);
            assert!(((i * a) - a).fro() < 1e-12);
        });
    }

    #[test]
    fn inverse_roundtrip() {
        quick("mat3-inverse", 100, |g| {
            let a = random_mat(g) + Mat3::identity() * 3.0; // keep well-conditioned
            if a.det().abs() > 1e-3 {
                let prod = a * a.inverse();
                let err = (prod - Mat3::identity()).fro();
                assert!(err < 1e-8, "fro={err}");
            }
        });
    }

    #[test]
    fn skew_matches_cross() {
        quick("mat3-skew", 100, |g| {
            let v = Vec3::from_slice(&g.vec_normal(3));
            let w = Vec3::from_slice(&g.vec_normal(3));
            let lhs = Mat3::skew(v) * w;
            let rhs = v.cross(w);
            assert!((lhs - rhs).norm() < 1e-12);
        });
    }

    #[test]
    fn transpose_of_product() {
        quick("mat3-transpose", 50, |g| {
            let a = random_mat(g);
            let b = random_mat(g);
            let lhs = (a * b).transpose();
            let rhs = b.transpose() * a.transpose();
            assert!((lhs - rhs).fro() < 1e-10);
        });
    }

    #[test]
    fn det_of_diag() {
        let d = Mat3::diag(Vec3::new(2.0, 3.0, 4.0));
        assert!((d.det() - 24.0).abs() < 1e-12);
        assert!((d.trace() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn solve_small_system() {
        let a = Mat3::new([[4.0, 1.0, 0.0], [1.0, 3.0, 1.0], [0.0, 1.0, 2.0]]);
        let x = Vec3::new(1.0, -2.0, 3.0);
        let b = a * x;
        assert!((a.solve(b) - x).norm() < 1e-10);
    }
}
