//! Dense matrix substrate: row-major `Mat` with LU (partial pivoting),
//! Cholesky, and thin Householder QR — the three factorizations the
//! differentiation layer needs (§6 of the paper: the "W/o FD" baseline
//! solves the (n+m) KKT system by LU; the fast path QR-factors
//! √M̂⁻¹·∇fᵀ·Gᵀ).
//!
//! The BLAS-1 shapes (`dot`/`axpy`/`norm`, matvec rows) route through
//! the [`simd`] kernel layer; the factorizations stay scalar (their
//! inner loops are short, pivoted, and order-sensitive).

use crate::math::simd;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn diag(d: &[f64]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix–vector product. Each row is one [`simd::dot`]: sequential
    /// scalar accumulation under `Scalar`/`Ordered`, the four-lane
    /// reduction tree under `Fast` (per-row ULP bound as documented in
    /// [`simd`]).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = Vec::new();
        self.matvec_into(x, &mut y);
        y
    }

    /// Matrix–vector product into a caller-provided (scratch) buffer;
    /// bitwise-identical to [`Mat::matvec`].
    pub fn matvec_into(&self, x: &[f64], y: &mut Vec<f64>) {
        assert_eq!(x.len(), self.cols);
        y.clear();
        y.resize(self.rows, 0.0);
        if simd::reduce_lanes() {
            for i in 0..self.rows {
                y[i] = simd::dot_fast(self.row(i), x);
            }
        } else {
            for i in 0..self.rows {
                y[i] = simd::dot_scalar(self.row(i), x);
            }
        }
    }

    /// Resize to `rows × cols` and zero every entry, keeping the
    /// backing allocation (scratch-arena reuse).
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `o`, reusing this matrix's allocation.
    pub fn copy_from(&mut self, o: &Mat) {
        self.rows = o.rows;
        self.cols = o.cols;
        self.data.clear();
        self.data.extend_from_slice(&o.data);
    }

    /// Transposed matrix–vector product Aᵀx. Each row contributes one
    /// [`simd::axpy`] — elementwise, so bitwise-identical in every mode.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            simd::axpy(x[i], self.row(i), &mut y);
        }
        y
    }

    pub fn matmul(&self, o: &Mat) -> Mat {
        assert_eq!(self.cols, o.rows);
        let mut r = Mat::zeros(self.rows, o.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = o.row(k);
                let rrow = r.row_mut(i);
                for j in 0..o.cols {
                    rrow[j] += a * orow[j];
                }
            }
        }
        r
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|x| x * s).collect())
    }

    pub fn add(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&o.data).map(|(a, b)| a + b).collect(),
        )
    }

    pub fn sub(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&o.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Solve A·x = b by LU with partial pivoting. A must be square and
    /// nonsingular; returns None if (numerically) singular.
    pub fn lu_solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.rows;
        assert_eq!(self.cols, n);
        assert_eq!(b.len(), n);
        let mut a = self.data.clone();
        let mut x = b.to_vec();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Pivot.
            let mut pmax = a[piv[k] * n + k].abs();
            let mut prow = k;
            for i in k + 1..n {
                let v = a[piv[i] * n + k].abs();
                if v > pmax {
                    pmax = v;
                    prow = i;
                }
            }
            if pmax < 1e-300 {
                return None;
            }
            piv.swap(k, prow);
            let pk = piv[k];
            let akk = a[pk * n + k];
            for i in k + 1..n {
                let pi = piv[i];
                let l = a[pi * n + k] / akk;
                a[pi * n + k] = l;
                for j in k + 1..n {
                    a[pi * n + j] -= l * a[pk * n + j];
                }
            }
        }
        // Forward substitution (L has unit diagonal).
        let mut y = vec![0.0; n];
        for i in 0..n {
            let pi = piv[i];
            let mut s = x[pi];
            for j in 0..i {
                s -= a[pi * n + j] * y[j];
            }
            y[i] = s;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let pi = piv[i];
            let mut s = y[i];
            for j in i + 1..n {
                s -= a[pi * n + j] * x[j];
            }
            x[i] = s / a[pi * n + i];
        }
        Some(x)
    }

    /// Cholesky factor L (lower) with A = L·Lᵀ. Returns None if not SPD.
    pub fn cholesky(&self) -> Option<Mat> {
        let n = self.rows;
        assert_eq!(self.cols, n);
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(l)
    }

    /// Solve A·x = b for SPD A via Cholesky.
    pub fn chol_solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let l = self.cholesky()?;
        let n = self.rows;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= l[(i, k)] * y[k];
            }
            y[i] = s / l[(i, i)];
        }
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= l[(k, i)] * x[k];
            }
            x[i] = s / l[(i, i)];
        }
        Some(x)
    }

    /// Thin Householder QR of an `rows × cols` matrix with rows ≥ cols:
    /// returns (Q: rows×cols with orthonormal columns, R: cols×cols upper
    /// triangular) such that A = Q·R. Cost O(rows·cols²) — this is the
    /// paper's §6 acceleration workhorse.
    pub fn qr_thin(&self) -> (Mat, Mat) {
        let (m, n) = (self.rows, self.cols);
        assert!(m >= n, "qr_thin requires rows >= cols ({m} < {n})");
        let mut r = self.clone();
        // Householder vectors stored per column.
        let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
        for k in 0..n {
            // Build the Householder vector for column k.
            let mut norm = 0.0;
            for i in k..m {
                norm += r[(i, k)] * r[(i, k)];
            }
            let norm = norm.sqrt();
            let mut v = vec![0.0; m - k];
            if norm < 1e-300 {
                vs.push(v); // zero column: skip reflection
                continue;
            }
            let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
            for i in k..m {
                v[i - k] = r[(i, k)];
            }
            v[0] -= alpha;
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 < 1e-300 {
                vs.push(vec![0.0; m - k]);
                continue;
            }
            // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..].
            for j in k..n {
                let mut dot = 0.0;
                for i in k..m {
                    dot += v[i - k] * r[(i, j)];
                }
                let f = 2.0 * dot / vnorm2;
                for i in k..m {
                    r[(i, j)] -= f * v[i - k];
                }
            }
            vs.push(v);
        }
        // Extract upper-triangular R (n×n).
        let mut rr = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                rr[(i, j)] = r[(i, j)];
            }
        }
        // Form thin Q by applying reflections to the first n columns of I.
        let mut q = Mat::zeros(m, n);
        for j in 0..n {
            q[(j, j)] = 1.0;
        }
        for k in (0..n).rev() {
            let v = &vs[k];
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 < 1e-300 {
                continue;
            }
            for j in 0..n {
                let mut dot = 0.0;
                for i in k..m {
                    dot += v[i - k] * q[(i, j)];
                }
                let f = 2.0 * dot / vnorm2;
                for i in k..m {
                    q[(i, j)] -= f * v[i - k];
                }
            }
        }
        (q, rr)
    }

    /// Solve R·x = b with R upper triangular (from `qr_thin`).
    pub fn upper_solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.rows;
        assert_eq!(self.cols, n);
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self[(i, j)] * x[j];
            }
            let d = self[(i, i)];
            if d.abs() < 1e-300 {
                return None;
            }
            x[i] = s / d;
        }
        Some(x)
    }

    /// Solve Rᵀ·x = b with R upper triangular.
    pub fn upper_t_solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.rows;
        assert_eq!(self.cols, n);
        let mut x = b.to_vec();
        for i in 0..n {
            let mut s = x[i];
            for j in 0..i {
                s -= self[(j, i)] * x[j];
            }
            let d = self[(i, i)];
            if d.abs() < 1e-300 {
                return None;
            }
            x[i] = s / d;
        }
        Some(x)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product helper (mode-dispatched; see [`simd::dot`]).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    simd::dot(a, b)
}

/// y += alpha * x (elementwise — bitwise-identical in every mode).
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    simd::axpy(alpha, x, y)
}

/// Euclidean norm (mode-dispatched reduction).
pub fn norm(a: &[f64]) -> f64 {
    simd::norm(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{assert_close, quick};

    fn random_mat(g: &mut crate::util::quick::Gen, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, g.vec_normal(r * c))
    }

    #[test]
    fn matmul_identity_and_assoc() {
        quick("dense-matmul", 50, |g| {
            let n = g.usize(1, 8);
            let a = random_mat(g, n, n);
            let b = random_mat(g, n, n);
            let c = random_mat(g, n, n);
            assert!(a.matmul(&Mat::identity(n)).sub(&a).fro() < 1e-12);
            let lhs = a.matmul(&b).matmul(&c);
            let rhs = a.matmul(&b.matmul(&c));
            assert!(lhs.sub(&rhs).fro() < 1e-9 * (1.0 + lhs.fro()));
        });
    }

    #[test]
    fn lu_solves_random_systems() {
        quick("dense-lu", 100, |g| {
            let n = g.usize(1, 20);
            let a = random_mat(g, n, n).add(&Mat::identity(n).scale(3.0));
            let x: Vec<f64> = g.vec_normal(n);
            let b = a.matvec(&x);
            let xs = a.lu_solve(&b).expect("solvable");
            assert_close(&xs, &x, 1e-7, 1e-7, "lu solution");
        });
    }

    #[test]
    fn lu_detects_singular() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(a.lu_solve(&[1.0, 1.0]).is_none());
    }

    #[test]
    fn cholesky_spd_roundtrip() {
        quick("dense-chol", 100, |g| {
            let n = g.usize(1, 15);
            let b = random_mat(g, n, n);
            let a = b.transpose().matmul(&b).add(&Mat::identity(n).scale(0.5));
            let l = a.cholesky().expect("spd");
            let rec = l.matmul(&l.transpose());
            assert!(rec.sub(&a).fro() < 1e-9 * (1.0 + a.fro()));
            let x: Vec<f64> = g.vec_normal(n);
            let rhs = a.matvec(&x);
            let xs = a.chol_solve(&rhs).unwrap();
            assert_close(&xs, &x, 1e-7, 1e-6, "chol solution");
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(a.cholesky().is_none());
    }

    #[test]
    fn qr_reconstructs_and_is_orthonormal() {
        quick("dense-qr", 100, |g| {
            let n = g.usize(1, 10);
            let m = n + g.usize(0, 10);
            let a = random_mat(g, m, n);
            let (q, r) = a.qr_thin();
            // A = QR
            assert!(q.matmul(&r).sub(&a).fro() < 1e-9 * (1.0 + a.fro()));
            // QᵀQ = I
            let qtq = q.transpose().matmul(&q);
            assert!(qtq.sub(&Mat::identity(n)).fro() < 1e-10);
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert!(r[(i, j)].abs() < 1e-12);
                }
            }
        });
    }

    #[test]
    fn qr_handles_zero_columns() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[0.0, 2.0], &[0.0, 2.0]]);
        let (q, r) = a.qr_thin();
        assert!(q.matmul(&r).sub(&a).fro() < 1e-12);
    }

    #[test]
    fn triangular_solves() {
        quick("dense-tri", 100, |g| {
            let n = g.usize(1, 12);
            let a = random_mat(g, n + 2, n);
            let (_, r) = a.qr_thin();
            // Make sure diagonal is well away from zero.
            let mut r = r;
            for i in 0..n {
                if r[(i, i)].abs() < 0.1 {
                    r[(i, i)] += 1.0;
                }
            }
            let x: Vec<f64> = g.vec_normal(n);
            let b = r.matvec(&x);
            assert_close(&r.upper_solve(&b).unwrap(), &x, 1e-6, 1e-5, "upper");
            let bt = r.transpose().matvec(&x);
            assert_close(&r.upper_t_solve(&bt).unwrap(), &x, 1e-6, 1e-5, "upper-t");
        });
    }

    #[test]
    fn matvec_t_matches_transpose() {
        quick("dense-matvec-t", 50, |g| {
            let (m, n) = (g.usize(1, 10), g.usize(1, 10));
            let a = random_mat(g, m, n);
            let x: Vec<f64> = g.vec_normal(m);
            assert_close(&a.matvec_t(&x), &a.transpose().matvec(&x), 1e-12, 1e-12, "At x");
        });
    }

    #[test]
    fn blas1_helpers() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(2.0, &[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, 2.0, 1.0]);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
