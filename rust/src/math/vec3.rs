//! 3-vector used for mesh vertices, velocities, normals.

use std::ops::{Add, AddAssign, Div, Index, IndexMut, Mul, Neg, Sub, SubAssign};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

pub const ZERO3: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

impl Vec3 {
    pub const fn new(x: f64, y: f64, z: f64) -> Vec3 {
        Vec3 { x, y, z }
    }

    pub fn splat(v: f64) -> Vec3 {
        Vec3::new(v, v, v)
    }

    pub fn from_slice(s: &[f64]) -> Vec3 {
        Vec3::new(s[0], s[1], s[2])
    }

    pub fn to_array(self) -> [f64; 3] {
        [self.x, self.y, self.z]
    }

    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    pub fn norm2(self) -> f64 {
        self.dot(self)
    }

    pub fn norm(self) -> f64 {
        self.norm2().sqrt()
    }

    /// Unit vector; zero vector maps to zero (callers guard).
    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n <= 1e-30 {
            ZERO3
        } else {
            self / n
        }
    }

    pub fn min_c(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    pub fn max_c(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    pub fn abs(self) -> Vec3 {
        Vec3::new(self.x.abs(), self.y.abs(), self.z.abs())
    }

    pub fn max_component(self) -> f64 {
        self.x.max(self.y).max(self.z)
    }

    pub fn lerp(self, o: Vec3, t: f64) -> Vec3 {
        self + (o - self) * t
    }

    pub fn is_finite(self) -> bool {
        self.x.is_finite() && self.y.is_finite() && self.z.is_finite()
    }

    /// Outer product self · oᵀ as a row-major 3×3.
    pub fn outer(self, o: Vec3) -> [[f64; 3]; 3] {
        [
            [self.x * o.x, self.x * o.y, self.x * o.z],
            [self.y * o.x, self.y * o.y, self.y * o.z],
            [self.z * o.x, self.z * o.y, self.z * o.z],
        ]
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}
impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}
impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}
impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}
impl Mul<Vec3> for f64 {
    type Output = Vec3;
    fn mul(self, v: Vec3) -> Vec3 {
        v * self
    }
}
impl Div<f64> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}
impl AddAssign for Vec3 {
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}
impl SubAssign for Vec3 {
    fn sub_assign(&mut self, o: Vec3) {
        *self = *self - o;
    }
}
impl Index<usize> for Vec3 {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        match i {
            0 => &self.x,
            1 => &self.y,
            2 => &self.z,
            _ => panic!("Vec3 index {i}"),
        }
    }
}
impl IndexMut<usize> for Vec3 {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        match i {
            0 => &mut self.x,
            1 => &mut self.y,
            2 => &mut self.z,
            _ => panic!("Vec3 index {i}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::quick;

    #[test]
    fn basic_algebra() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, -5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, -3.0, 9.0));
        assert_eq!(a.dot(b), 12.0);
        assert_eq!((a * 2.0).norm2(), 4.0 * 14.0);
    }

    #[test]
    fn cross_is_orthogonal_and_anticommutative() {
        quick("cross", 100, |g| {
            let a = Vec3::from_slice(&g.vec_normal(3));
            let b = Vec3::from_slice(&g.vec_normal(3));
            let c = a.cross(b);
            assert!(c.dot(a).abs() < 1e-9 * (1.0 + a.norm() * b.norm() * a.norm()));
            assert!((c + b.cross(a)).norm() < 1e-12);
        });
    }

    #[test]
    fn normalized_has_unit_length() {
        quick("normalized", 100, |g| {
            let a = Vec3::from_slice(&g.vec_normal(3)) * g.f64(0.1, 10.0);
            if a.norm() > 1e-6 {
                assert!((a.normalized().norm() - 1.0).abs() < 1e-12);
            }
        });
        assert_eq!(ZERO3.normalized(), ZERO3);
    }

    #[test]
    fn lerp_endpoints() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-1.0, 0.0, 5.0);
        assert_eq!(a.lerp(b, 0.0), a);
        assert_eq!(a.lerp(b, 1.0), b);
        assert_eq!(a.lerp(b, 0.5), Vec3::new(0.0, 1.0, 4.0));
    }

    #[test]
    fn indexing_roundtrip() {
        let mut a = Vec3::new(1.0, 2.0, 3.0);
        a[1] = 7.0;
        assert_eq!(a[0], 1.0);
        assert_eq!(a[1], 7.0);
        assert_eq!(a[2], 3.0);
    }
}
