//! RPY Euler-angle kinematics from the paper's appendices.
//!
//! Convention (Appendix A): with r = (φ, θ, ψ), the body rotates about Z
//! by ψ, then about the new Y′ by θ, then the new X″ by φ — i.e. the
//! world-frame rotation matrix is R = Rz(ψ) · Ry(θ) · Rx(φ) (Appendix B).
//!
//! This module provides R, its per-angle derivatives (Appendix C, derived
//! analytically from the product structure rather than transcribing the
//! appendix, whose formulas contain typos), the angular-velocity transform
//! ω = T(r)·ṙ (Eq. 20), the generalized mass matrix M̂ (Eq. 22), and the
//! vertex map f(q) = R·p₀ + t with its 3×6 Jacobian ∇f (Eq. 24).

use super::mat3::Mat3;
use super::vec3::Vec3;

fn rx(phi: f64) -> Mat3 {
    let (s, c) = phi.sin_cos();
    Mat3::new([[1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c]])
}

fn ry(theta: f64) -> Mat3 {
    let (s, c) = theta.sin_cos();
    Mat3::new([[c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c]])
}

fn rz(psi: f64) -> Mat3 {
    let (s, c) = psi.sin_cos();
    Mat3::new([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
}

fn drx(phi: f64) -> Mat3 {
    let (s, c) = phi.sin_cos();
    Mat3::new([[0.0, 0.0, 0.0], [0.0, -s, -c], [0.0, c, -s]])
}

fn dry(theta: f64) -> Mat3 {
    let (s, c) = theta.sin_cos();
    Mat3::new([[-s, 0.0, c], [0.0, 0.0, 0.0], [-c, 0.0, -s]])
}

fn drz(psi: f64) -> Mat3 {
    let (s, c) = psi.sin_cos();
    Mat3::new([[-s, -c, 0.0], [c, -s, 0.0], [0.0, 0.0, 0.0]])
}

/// World-frame rotation matrix R(r) = Rz(ψ)·Ry(θ)·Rx(φ) (Appendix B).
pub fn rotation(r: Vec3) -> Mat3 {
    rz(r.z) * ry(r.y) * rx(r.x)
}

/// Per-angle derivatives [∂R/∂φ, ∂R/∂θ, ∂R/∂ψ].
pub fn rotation_derivs(r: Vec3) -> [Mat3; 3] {
    let (rxm, rym, rzm) = (rx(r.x), ry(r.y), rz(r.z));
    [rzm * rym * drx(r.x), rzm * dry(r.y) * rxm, drz(r.z) * rym * rxm]
}

/// T(r) with ω_world = T·ṙ (Eq. 20).
pub fn omega_transform(r: Vec3) -> Mat3 {
    let (st, ct) = r.y.sin_cos();
    let (sp, cp) = r.z.sin_cos();
    Mat3::new([[ct * cp, -sp, 0.0], [ct * sp, cp, 0.0], [-st, 0.0, 1.0]])
}

/// Euler-coordinate angular inertia Iₐ = Tᵀ·I′·T (Eq. 21), where I′ is
/// the world-frame inertia tensor.
pub fn angular_inertia(r: Vec3, i_world: Mat3) -> Mat3 {
    let t = omega_transform(r);
    t.transpose() * i_world * t
}

/// f(q): map a body-frame point p₀ to world coordinates (Eq. 23).
/// `q = [φ, θ, ψ, t_x, t_y, t_z]`.
pub fn transform_point(q: &[f64; 6], p0: Vec3) -> Vec3 {
    let r = rotation(Vec3::new(q[0], q[1], q[2]));
    r * p0 + Vec3::new(q[3], q[4], q[5])
}

/// ∇f: 3×6 Jacobian of `transform_point` w.r.t. q (Eq. 24 / Appendix C).
/// Rows = (x, y, z), columns = (φ, θ, ψ, t_x, t_y, t_z).
pub fn jacobian(q: &[f64; 6], p0: Vec3) -> [[f64; 6]; 3] {
    let derivs = rotation_derivs(Vec3::new(q[0], q[1], q[2]));
    let mut j = [[0.0; 6]; 3];
    for (a, d) in derivs.iter().enumerate() {
        let col = *d * p0;
        j[0][a] = col.x;
        j[1][a] = col.y;
        j[2][a] = col.z;
    }
    j[0][3] = 1.0;
    j[1][4] = 1.0;
    j[2][5] = 1.0;
    j
}

/// Rotate a world-frame inertia tensor taken at the reference orientation
/// into the current orientation: I′(r) = R I₀ Rᵀ.
pub fn rotate_inertia(r: Vec3, i_ref: Mat3) -> Mat3 {
    let rm = rotation(r);
    rm * i_ref * rm.transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::quick;

    #[test]
    fn rotation_is_orthonormal() {
        quick("euler-orthonormal", 200, |g| {
            let r = Vec3::new(g.f64(-3.0, 3.0), g.f64(-1.4, 1.4), g.f64(-3.0, 3.0));
            let m = rotation(r);
            let should_be_i = m * m.transpose();
            assert!((should_be_i - Mat3::identity()).fro() < 1e-12);
            assert!((m.det() - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn rotation_matches_appendix_b_entries() {
        quick("euler-appendix-b", 100, |g| {
            let (phi, theta, psi) = (g.f64(-3.0, 3.0), g.f64(-1.4, 1.4), g.f64(-3.0, 3.0));
            let m = rotation(Vec3::new(phi, theta, psi)).m;
            let (sp, cp) = phi.sin_cos();
            let (st, ct) = theta.sin_cos();
            let (ss, cs) = psi.sin_cos();
            let expect = [
                [ct * cs, -cp * ss + sp * st * cs, sp * ss + cp * st * cs],
                [ct * ss, cp * cs + sp * st * ss, -sp * cs + cp * st * ss],
                [-st, sp * ct, cp * ct],
            ];
            for i in 0..3 {
                for j in 0..3 {
                    assert!((m[i][j] - expect[i][j]).abs() < 1e-12, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn rotation_derivs_match_finite_differences() {
        quick("euler-dR", 100, |g| {
            let r = Vec3::new(g.f64(-3.0, 3.0), g.f64(-1.4, 1.4), g.f64(-3.0, 3.0));
            let d = rotation_derivs(r);
            let h = 1e-6;
            for a in 0..3 {
                let mut rp = r;
                let mut rm = r;
                rp[a] += h;
                rm[a] -= h;
                let fd = (rotation(rp) - rotation(rm)) * (0.5 / h);
                assert!((fd - d[a]).fro() < 1e-7, "angle {a}: err={}", (fd - d[a]).fro());
            }
        });
    }

    #[test]
    fn omega_transform_matches_fd_of_rotation() {
        // ω× = Ṙ Rᵀ with Ṙ = Σ ∂R/∂rᵢ ṙᵢ must equal skew(T·ṙ).
        quick("euler-omega", 100, |g| {
            let r = Vec3::new(g.f64(-3.0, 3.0), g.f64(-1.2, 1.2), g.f64(-3.0, 3.0));
            let rdot = Vec3::from_slice(&g.vec_normal(3));
            let d = rotation_derivs(r);
            let rdot_mat = d[0] * rdot.x + d[1] * rdot.y + d[2] * rdot.z;
            let omega_skew = rdot_mat * rotation(r).transpose();
            let omega = omega_transform(r) * rdot;
            assert!((omega_skew - Mat3::skew(omega)).fro() < 1e-9);
        });
    }

    #[test]
    fn jacobian_matches_finite_differences() {
        quick("euler-jacobian", 200, |g| {
            let q = [
                g.f64(-3.0, 3.0),
                g.f64(-1.4, 1.4),
                g.f64(-3.0, 3.0),
                g.f64(-2.0, 2.0),
                g.f64(-2.0, 2.0),
                g.f64(-2.0, 2.0),
            ];
            let p0 = Vec3::from_slice(&g.vec_normal(3));
            let jac = jacobian(&q, p0);
            let h = 1e-6;
            for c in 0..6 {
                let mut qp = q;
                let mut qm = q;
                qp[c] += h;
                qm[c] -= h;
                let fd = (transform_point(&qp, p0) - transform_point(&qm, p0)) * (0.5 / h);
                for row in 0..3 {
                    assert!(
                        (fd[row] - jac[row][c]).abs() < 1e-6,
                        "row {row} col {c}: fd={} analytic={}",
                        fd[row],
                        jac[row][c]
                    );
                }
            }
        });
    }

    #[test]
    fn angular_inertia_is_symmetric_psd() {
        quick("euler-inertia", 100, |g| {
            let r = Vec3::new(g.f64(-3.0, 3.0), g.f64(-1.2, 1.2), g.f64(-3.0, 3.0));
            // Random SPD world inertia.
            let v = g.vec_normal(9);
            let a = Mat3::new([[v[0], v[1], v[2]], [v[3], v[4], v[5]], [v[6], v[7], v[8]]]);
            let iw = a.transpose() * a + Mat3::identity() * 0.5;
            let ia = angular_inertia(r, iw);
            assert!((ia - ia.transpose()).fro() < 1e-10);
            // x^T Ia x > 0 for random x.
            let x = Vec3::from_slice(&g.vec_normal(3));
            if x.norm() > 1e-6 {
                assert!(x.dot(ia * x) > 0.0);
            }
        });
    }

    #[test]
    fn identity_rotation_at_zero() {
        let m = rotation(Vec3::new(0.0, 0.0, 0.0));
        assert!((m - Mat3::identity()).fro() < 1e-15);
        let q = [0.0; 6];
        let p = Vec3::new(1.0, 2.0, 3.0);
        assert!((transform_point(&q, p) - p).norm() < 1e-15);
    }
}
