//! Conjugate-gradient solvers for the implicit-Euler system (Eq. 3).
//!
//! Two entry points: a matrix-free CG over a linear operator closure
//! (used by the diff layer's adjoint solves) and a Jacobi-preconditioned
//! CG over a CSR matrix (the cloth stepper's hot path).

use super::dense::{axpy, dot, norm};
use super::sparse::Csr;

/// Result of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Matrix-free CG: solves A·x = b for SPD operator `apply(x, out)`.
pub fn cg_operator<F>(apply: F, b: &[f64], tol: f64, max_iter: usize) -> CgResult
where
    F: Fn(&[f64], &mut [f64]),
{
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let bnorm = norm(b).max(1e-300);
    let mut rs = dot(&r, &r);
    if rs.sqrt() / bnorm <= tol {
        return CgResult { x, iters: 0, residual: rs.sqrt() / bnorm, converged: true };
    }
    for it in 0..max_iter {
        apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            return CgResult { x, iters: it, residual: rs.sqrt() / bnorm, converged: false };
        }
        let alpha = rs / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        if rs_new.sqrt() / bnorm <= tol {
            return CgResult { x, iters: it + 1, residual: rs_new.sqrt() / bnorm, converged: true };
        }
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
    }
    CgResult { x, iters: max_iter, residual: rs.sqrt() / bnorm, converged: false }
}

/// Jacobi-preconditioned CG over a CSR matrix.
pub fn pcg_csr(a: &Csr, b: &[f64], tol: f64, max_iter: usize) -> CgResult {
    let n = b.len();
    assert_eq!(a.rows, n);
    let diag = a.diagonal();
    let minv: Vec<f64> = diag
        .iter()
        .map(|&d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 })
        .collect();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z: Vec<f64> = r.iter().zip(&minv).map(|(ri, mi)| ri * mi).collect();
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let bnorm = norm(b).max(1e-300);
    let mut rz = dot(&r, &z);
    if norm(&r) / bnorm <= tol {
        return CgResult { x, iters: 0, residual: norm(&r) / bnorm, converged: true };
    }
    for it in 0..max_iter {
        a.matvec_into(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            return CgResult { x, iters: it, residual: norm(&r) / bnorm, converged: false };
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rnorm = norm(&r);
        if rnorm / bnorm <= tol {
            return CgResult { x, iters: it + 1, residual: rnorm / bnorm, converged: true };
        }
        for i in 0..n {
            z[i] = r[i] * minv[i];
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    CgResult { x, iters: max_iter, residual: norm(&r) / bnorm, converged: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::dense::Mat;
    use crate::math::sparse::Triplets;
    use crate::util::quick::{assert_close, quick};

    fn random_spd(g: &mut crate::util::quick::Gen, n: usize) -> Mat {
        let b = Mat::from_vec(n, n, g.vec_normal(n * n));
        b.transpose().matmul(&b).add(&Mat::identity(n).scale(n as f64))
    }

    #[test]
    fn cg_operator_solves_spd() {
        quick("cg-operator", 50, |g| {
            let n = g.usize(1, 25);
            let a = random_spd(g, n);
            let xtrue = g.vec_normal(n);
            let b = a.matvec(&xtrue);
            let res = cg_operator(
                |x, out| out.copy_from_slice(&a.matvec(x)),
                &b,
                1e-12,
                10 * n + 10,
            );
            assert!(res.converged, "residual {}", res.residual);
            assert_close(&res.x, &xtrue, 1e-6, 1e-5, "cg solution");
        });
    }

    #[test]
    fn pcg_csr_solves_laplacian() {
        // 1-D Poisson: tridiag(-1, 2+eps, -1), SPD.
        let n = 64;
        let mut t = Triplets::new(n, n);
        for i in 0..n {
            t.push(i, i, 2.1);
            if i > 0 {
                t.push(i, i - 1, -1.0);
            }
            if i + 1 < n {
                t.push(i, i + 1, -1.0);
            }
        }
        let a = t.to_csr();
        let xtrue: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let b = a.matvec(&xtrue);
        let res = pcg_csr(&a, &b, 1e-12, 1000);
        assert!(res.converged);
        assert_close(&res.x, &xtrue, 1e-7, 1e-7, "pcg solution");
    }

    #[test]
    fn cg_zero_rhs_converges_instantly() {
        let res = cg_operator(|x, out| out.copy_from_slice(x), &[0.0, 0.0], 1e-10, 5);
        assert!(res.converged);
        assert_eq!(res.iters, 0);
        assert_eq!(res.x, vec![0.0, 0.0]);
    }

    #[test]
    fn pcg_matches_direct_solver() {
        quick("pcg-vs-direct", 30, |g| {
            let n = g.usize(2, 20);
            let dense = random_spd(g, n);
            let mut t = Triplets::new(n, n);
            for i in 0..n {
                for j in 0..n {
                    t.push(i, j, dense[(i, j)]);
                }
            }
            let a = t.to_csr();
            let b = g.vec_normal(n);
            let direct = dense.chol_solve(&b).unwrap();
            let iterative = pcg_csr(&a, &b, 1e-13, 100 * n).x;
            assert_close(&iterative, &direct, 1e-6, 1e-5, "pcg vs chol");
        });
    }
}
