//! Conjugate-gradient solvers for the implicit-Euler system (Eq. 3).
//!
//! Two entry points: a matrix-free CG over a linear operator closure
//! (used by the diff layer's adjoint solves) and a Jacobi-preconditioned
//! CG over a CSR matrix (the cloth stepper's hot path).
//!
//! ## Convergence and breakdown semantics
//!
//! Both solvers report the **relative** residual `‖r‖ / max(‖b‖,
//! 1e-300)` and converge when it drops to `tol`, checked before the
//! first iteration (so a zero/already-converged right-hand side returns
//! `iters == 0` without touching the operator) and after every `x`/`r`
//! update. Breakdown — a non-finite right-hand side, a non-finite or
//! (numerically) zero curvature `pᵀAp`, a vanished preconditioned
//! product `rᵀz`, or any non-finite residual mid-iteration — returns
//! `converged: false` with the iterate accumulated so far, never a
//! poisoned `x`: guards fire *before* the offending `alpha`/`beta`
//! would be applied. The solver-retry ladder keys off `converged`, so
//! breakdown must be reported, not masked.
//!
//! Inner-loop vector updates route through the [`simd`](super::simd)
//! kernel layer: `x`/`r`/`p`/`z` updates are elementwise (bitwise in
//! every mode); the `dot`/`norm` reductions follow the mode's
//! documented reduction-order contract.

use super::dense::{axpy, dot, norm};
use super::simd;
use super::sparse::Csr;

/// Result of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Matrix-free CG: solves A·x = b for SPD operator `apply(x, out)`.
pub fn cg_operator<F>(apply: F, b: &[f64], tol: f64, max_iter: usize) -> CgResult
where
    F: Fn(&[f64], &mut [f64]),
{
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let bnorm = norm(b).max(1e-300);
    let mut rs = dot(&r, &r);
    if !rs.is_finite() {
        // NaN/∞ in b: no finite residual exists; report breakdown
        // before the operator ever runs (x is still all-zero).
        return CgResult { x, iters: 0, residual: f64::INFINITY, converged: false };
    }
    if rs.sqrt() / bnorm <= tol {
        return CgResult { x, iters: 0, residual: rs.sqrt() / bnorm, converged: true };
    }
    for it in 0..max_iter {
        apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if !pap.is_finite() || pap.abs() < 1e-300 {
            // Curvature breakdown (singular/indefinite direction) or a
            // non-finite operator output: alpha would be inf/NaN.
            return CgResult { x, iters: it, residual: rs.sqrt() / bnorm, converged: false };
        }
        let alpha = rs / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        if !rs_new.is_finite() {
            return CgResult { x, iters: it + 1, residual: f64::INFINITY, converged: false };
        }
        if rs_new.sqrt() / bnorm <= tol {
            return CgResult { x, iters: it + 1, residual: rs_new.sqrt() / bnorm, converged: true };
        }
        let beta = rs_new / rs;
        rs = rs_new;
        simd::xpby(&r, beta, &mut p);
    }
    CgResult { x, iters: max_iter, residual: rs.sqrt() / bnorm, converged: false }
}

/// Jacobi-preconditioned CG over a CSR matrix.
pub fn pcg_csr(a: &Csr, b: &[f64], tol: f64, max_iter: usize) -> CgResult {
    let n = b.len();
    assert_eq!(a.rows, n);
    let diag = a.diagonal();
    let minv: Vec<f64> = diag
        .iter()
        .map(|&d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 })
        .collect();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = vec![0.0; n];
    simd::mul_into(&r, &minv, &mut z);
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let bnorm = norm(b).max(1e-300);
    let mut rz = dot(&r, &z);
    if !rz.is_finite() {
        return CgResult { x, iters: 0, residual: f64::INFINITY, converged: false };
    }
    if norm(&r) / bnorm <= tol {
        return CgResult { x, iters: 0, residual: norm(&r) / bnorm, converged: true };
    }
    for it in 0..max_iter {
        a.matvec_into(&p, &mut ap);
        let pap = dot(&p, &ap);
        if !pap.is_finite() || pap.abs() < 1e-300 || rz == 0.0 {
            // Curvature or preconditioner breakdown: alpha (rz/pap)
            // would be non-finite, or zero with r ≠ 0 (possible when
            // the lumped diagonal has mixed signs) — no progress.
            return CgResult { x, iters: it, residual: norm(&r) / bnorm, converged: false };
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rnorm = norm(&r);
        if !rnorm.is_finite() {
            return CgResult { x, iters: it + 1, residual: f64::INFINITY, converged: false };
        }
        if rnorm / bnorm <= tol {
            return CgResult { x, iters: it + 1, residual: rnorm / bnorm, converged: true };
        }
        simd::mul_into(&r, &minv, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        simd::xpby(&z, beta, &mut p);
    }
    CgResult { x, iters: max_iter, residual: norm(&r) / bnorm, converged: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::dense::Mat;
    use crate::math::sparse::Triplets;
    use crate::util::quick::{assert_close, quick};

    fn random_spd(g: &mut crate::util::quick::Gen, n: usize) -> Mat {
        let b = Mat::from_vec(n, n, g.vec_normal(n * n));
        b.transpose().matmul(&b).add(&Mat::identity(n).scale(n as f64))
    }

    #[test]
    fn cg_operator_solves_spd() {
        quick("cg-operator", 50, |g| {
            let n = g.usize(1, 25);
            let a = random_spd(g, n);
            let xtrue = g.vec_normal(n);
            let b = a.matvec(&xtrue);
            let res = cg_operator(
                |x, out| out.copy_from_slice(&a.matvec(x)),
                &b,
                1e-12,
                10 * n + 10,
            );
            assert!(res.converged, "residual {}", res.residual);
            assert_close(&res.x, &xtrue, 1e-6, 1e-5, "cg solution");
        });
    }

    #[test]
    fn pcg_csr_solves_laplacian() {
        // 1-D Poisson: tridiag(-1, 2+eps, -1), SPD.
        let n = 64;
        let mut t = Triplets::new(n, n);
        for i in 0..n {
            t.push(i, i, 2.1);
            if i > 0 {
                t.push(i, i - 1, -1.0);
            }
            if i + 1 < n {
                t.push(i, i + 1, -1.0);
            }
        }
        let a = t.to_csr();
        let xtrue: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let b = a.matvec(&xtrue);
        let res = pcg_csr(&a, &b, 1e-12, 1000);
        assert!(res.converged);
        assert_close(&res.x, &xtrue, 1e-7, 1e-7, "pcg solution");
    }

    #[test]
    fn cg_zero_rhs_converges_instantly() {
        let res = cg_operator(|x, out| out.copy_from_slice(x), &[0.0, 0.0], 1e-10, 5);
        assert!(res.converged);
        assert_eq!(res.iters, 0);
        assert_eq!(res.x, vec![0.0, 0.0]);
    }

    /// Dense-QR oracle for A·x = b: A = Q·R ⇒ x = R⁻¹·(Qᵀ·b).
    fn qr_oracle_solve(a: &Mat, b: &[f64]) -> Vec<f64> {
        let (q, r) = a.qr_thin();
        r.upper_solve(&q.matvec_t(b)).expect("SPD test matrix has full rank")
    }

    #[test]
    fn cg_operator_matches_qr_oracle() {
        quick("cg-vs-qr", 40, |g| {
            let n = g.usize(1, 24);
            let a = random_spd(g, n);
            let b = g.vec_normal(n);
            let oracle = qr_oracle_solve(&a, &b);
            let res = cg_operator(|x, out| out.copy_from_slice(&a.matvec(x)), &b, 1e-13, 20 * n);
            assert!(res.converged, "n={n} residual {}", res.residual);
            assert_close(&res.x, &oracle, 1e-7, 1e-6, "cg vs qr oracle");
        });
    }

    #[test]
    fn pcg_matches_qr_oracle() {
        quick("pcg-vs-qr", 40, |g| {
            let n = g.usize(2, 20);
            let dense = random_spd(g, n);
            let mut t = Triplets::new(n, n);
            for i in 0..n {
                for j in 0..n {
                    t.push(i, j, dense[(i, j)]);
                }
            }
            let a = t.to_csr();
            let b = g.vec_normal(n);
            let oracle = qr_oracle_solve(&dense, &b);
            let res = pcg_csr(&a, &b, 1e-13, 100 * n);
            assert!(res.converged, "n={n} residual {}", res.residual);
            assert_close(&res.x, &oracle, 1e-7, 1e-6, "pcg vs qr oracle");
        });
    }

    fn csr_identity(n: usize) -> Csr {
        let mut t = Triplets::new(n, n);
        for i in 0..n {
            t.push(i, i, 1.0);
        }
        t.to_csr()
    }

    #[test]
    fn cg_nonfinite_rhs_reports_breakdown() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let b = [1.0, bad, 0.5];
            let res = cg_operator(|x, out| out.copy_from_slice(x), &b, 1e-10, 10);
            assert!(!res.converged);
            assert_eq!(res.iters, 0, "operator must not run on a poisoned rhs");
            assert!(res.x.iter().all(|v| v.is_finite()), "iterate stays finite");
            let res = pcg_csr(&csr_identity(3), &b, 1e-10, 10);
            assert!(!res.converged);
            assert!(res.x.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn cg_nonfinite_operator_reports_breakdown() {
        // Operator emits NaN on the first application: pᵀAp is NaN, so
        // the guard must fire before alpha poisons x.
        let res = cg_operator(|_, out| out.fill(f64::NAN), &[1.0, 2.0], 1e-10, 10);
        assert!(!res.converged);
        assert_eq!(res.iters, 0);
        assert!(res.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cg_zero_curvature_reports_breakdown() {
        // The zero operator: pᵀAp = 0 exactly for the nonzero rhs.
        let res = cg_operator(|_, out| out.fill(0.0), &[1.0, -2.0], 1e-10, 10);
        assert!(!res.converged);
        assert_eq!(res.iters, 0);
        assert!(res.residual.is_finite());
    }

    #[test]
    fn pcg_zero_rhs_converges_instantly() {
        let res = pcg_csr(&csr_identity(4), &[0.0; 4], 1e-12, 10);
        assert!(res.converged);
        assert_eq!(res.iters, 0);
        assert_eq!(res.x, vec![0.0; 4]);
    }

    #[test]
    fn cg_exhausts_iterations_without_converging() {
        // A needs ~n iterations for an n-dim Krylov space; capping at 1
        // must report non-convergence with a finite residual, not panic.
        let n = 16;
        let raw: Vec<f64> = (0..n * n).map(|i| (i as f64 * 0.61).sin()).collect();
        let b_mat = Mat::from_vec(n, n, raw);
        let a = b_mat.transpose().matmul(&b_mat).add(&Mat::identity(n).scale(0.01));
        let b = vec![1.0; n];
        let res = cg_operator(|x, out| out.copy_from_slice(&a.matvec(x)), &b, 1e-14, 1);
        assert!(!res.converged);
        assert_eq!(res.iters, 1);
        assert!(res.residual.is_finite() && res.residual > 0.0);
    }

    #[test]
    fn pcg_matches_direct_solver() {
        quick("pcg-vs-direct", 30, |g| {
            let n = g.usize(2, 20);
            let dense = random_spd(g, n);
            let mut t = Triplets::new(n, n);
            for i in 0..n {
                for j in 0..n {
                    t.push(i, j, dense[(i, j)]);
                }
            }
            let a = t.to_csr();
            let b = g.vec_normal(n);
            let direct = dense.chol_solve(&b).unwrap();
            let iterative = pcg_csr(&a, &b, 1e-13, 100 * n).x;
            assert_close(&iterative, &direct, 1e-6, 1e-5, "pcg vs chol");
        });
    }
}
