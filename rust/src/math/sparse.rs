//! CSR sparse matrix substrate for the implicit-Euler system (Eq. 3):
//! the cloth force Jacobians ∂f/∂q, ∂f/∂q̇ are sparse (stencil = mesh
//! adjacency), so the h⁻¹M − ∂f/∂q̇ − h·∂f/∂q operator is assembled as a
//! CSR matrix and solved with (preconditioned) conjugate gradients.

/// Triplet accumulator; duplicates are summed on conversion.
#[derive(Clone, Debug, Default)]
pub struct Triplets {
    pub rows: usize,
    pub cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl Triplets {
    pub fn new(rows: usize, cols: usize) -> Triplets {
        Triplets { rows, cols, entries: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        if v != 0.0 {
            self.entries.push((i as u32, j as u32, v));
        }
    }

    /// Add a 3×3 block at block coordinates (bi, bj).
    pub fn push_block3(&mut self, bi: usize, bj: usize, b: &[[f64; 3]; 3]) {
        for r in 0..3 {
            for c in 0..3 {
                self.push(3 * bi + r, 3 * bj + c, b[r][c]);
            }
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn to_csr(self) -> Csr {
        self.to_csr_into(Vec::new(), Vec::new(), Vec::new())
    }

    /// [`Triplets::to_csr`] assembling into caller-provided buffers
    /// (cleared and refilled — contents are bitwise-identical to a
    /// fresh `to_csr`). The cloth solver loans these from the scene's
    /// [`crate::util::arena::BatchArena`] so taped steps reuse the
    /// previous rollout's CSR allocations instead of growing new ones;
    /// `StepRecord::recycle` hands them back.
    pub fn to_csr_into(
        mut self,
        mut indices: Vec<u32>,
        mut data: Vec<f64>,
        mut indptr: Vec<usize>,
    ) -> Csr {
        self.entries
            .sort_unstable_by_key(|&(i, j, _)| ((i as u64) << 32) | j as u64);
        indices.clear();
        indices.reserve(self.entries.len());
        data.clear();
        data.reserve(self.entries.len());
        indptr.clear();
        indptr.resize(self.rows + 1, 0);
        let mut iter = self.entries.drain(..).peekable();
        while let Some((i, j, mut v)) = iter.next() {
            // Merge consecutive duplicates (same i, j).
            while let Some(&(i2, j2, v2)) = iter.peek() {
                if i2 == i && j2 == j {
                    v += v2;
                    iter.next();
                } else {
                    break;
                }
            }
            indices.push(j);
            data.push(v);
            indptr[i as usize + 1] += 1;
        }
        // Per-row counts → row offsets.
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, data }
    }
}

/// Compressed sparse row matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub data: Vec<f64>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// y = A·x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A·x written into a caller buffer (hot path: no allocation).
    /// Each row is one CSR row product from the [`simd`](super::simd)
    /// layer: sequential under `Scalar`/`Ordered`, the four-lane
    /// value×gather reduction under `Fast` (per-row ULP bound as
    /// documented there).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        use super::simd;
        if simd::reduce_lanes() {
            for i in 0..self.rows {
                let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
                y[i] = simd::csr_row_dot_fast(&self.data[lo..hi], &self.indices[lo..hi], x);
            }
        } else {
            for i in 0..self.rows {
                let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
                y[i] = simd::csr_row_dot_scalar(&self.data[lo..hi], &self.indices[lo..hi], x);
            }
        }
    }

    /// Diagonal entries (0 where structurally missing) — Jacobi
    /// preconditioner for CG.
    pub fn diagonal(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.rows];
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                if self.indices[k] as usize == i {
                    d[i] += self.data[k];
                }
            }
        }
        d
    }

    /// Dense conversion (tests / small systems only).
    pub fn to_dense(&self) -> super::dense::Mat {
        let mut m = super::dense::Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                m[(i, self.indices[k] as usize)] += self.data[k];
            }
        }
        m
    }

    /// Estimated bytes held (for the memory experiments).
    pub fn bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 4 + self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{assert_close, quick};

    #[test]
    fn triplets_merge_duplicates() {
        let mut t = Triplets::new(3, 3);
        t.push(0, 0, 1.0);
        t.push(0, 0, 2.0);
        t.push(1, 2, 5.0);
        t.push(2, 1, -1.0);
        let a = t.to_csr();
        assert_eq!(a.nnz(), 3);
        let d = a.to_dense();
        assert_eq!(d[(0, 0)], 3.0);
        assert_eq!(d[(1, 2)], 5.0);
        assert_eq!(d[(2, 1)], -1.0);
    }

    #[test]
    fn to_csr_into_reuses_buffers_with_identical_contents() {
        let build = || {
            let mut t = Triplets::new(4, 4);
            t.push(2, 1, 3.0);
            t.push(0, 3, 1.0);
            t.push(2, 1, -0.5);
            t.push(3, 0, 2.0);
            t
        };
        let fresh = build().to_csr();
        // Dirty, wrongly-sized reused buffers must come out identical.
        let reused = build().to_csr_into(vec![9u32; 17], vec![7.5; 3], vec![42usize; 1]);
        assert_eq!(fresh.indptr, reused.indptr);
        assert_eq!(fresh.indices, reused.indices);
        assert_eq!(fresh.data, reused.data);
        assert_eq!((fresh.rows, fresh.cols), (reused.rows, reused.cols));
    }

    #[test]
    fn empty_rows_are_fine() {
        let mut t = Triplets::new(4, 4);
        t.push(3, 0, 2.0);
        let a = t.to_csr();
        assert_eq!(a.matvec(&[1.0, 0.0, 0.0, 0.0]), vec![0.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        quick("csr-matvec", 100, |g| {
            let n = g.usize(1, 30);
            let m = g.usize(1, 30);
            let mut t = Triplets::new(n, m);
            let nnz = g.usize(0, n * m);
            for _ in 0..nnz {
                t.push(g.usize(0, n - 1), g.usize(0, m - 1), g.f64(-2.0, 2.0));
            }
            let a = t.to_csr();
            let x = g.vec_normal(m);
            let want = a.to_dense().matvec(&x);
            assert_close(&a.matvec(&x), &want, 1e-10, 1e-10, "csr matvec");
        });
    }

    #[test]
    fn block3_assembly() {
        let mut t = Triplets::new(6, 6);
        let b = [[1.0, 2.0, 3.0], [4.0, 5.0, 6.0], [7.0, 8.0, 9.0]];
        t.push_block3(1, 0, &b);
        let a = t.to_csr().to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(a[(3 + r, c)], b[r][c]);
            }
        }
    }

    #[test]
    fn diagonal_extraction() {
        let mut t = Triplets::new(3, 3);
        t.push(0, 0, 4.0);
        t.push(1, 1, 5.0);
        t.push(1, 0, 9.0);
        let a = t.to_csr();
        assert_eq!(a.diagonal(), vec![4.0, 5.0, 0.0]);
    }
}
