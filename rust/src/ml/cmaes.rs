//! CMA-ES (Hansen 2016 tutorial, (μ/μ_w, λ) with rank-μ update) — the
//! derivative-free baseline of the paper's Fig. 7 inverse problem.

use crate::math::dense::Mat;
use crate::util::rng::Pcg32;

pub struct CmaEs {
    pub dim: usize,
    pub mean: Vec<f64>,
    pub sigma: f64,
    pub lambda: usize,
    #[allow(dead_code)]
    mu: usize,
    weights: Vec<f64>,
    mueff: f64,
    cc: f64,
    cs: f64,
    c1: f64,
    cmu: f64,
    damps: f64,
    pc: Vec<f64>,
    ps: Vec<f64>,
    /// Covariance (full matrix; dims here are small).
    c: Mat,
    /// Eigen-ish factor: we use Cholesky of C for sampling (refreshed
    /// each update; adequate for the modest generation counts used).
    a: Mat,
    pub generation: usize,
    chi_n: f64,
}

impl CmaEs {
    pub fn new(x0: &[f64], sigma: f64) -> CmaEs {
        let dim = x0.len();
        let lambda = 4 + (3.0 * (dim as f64).ln()).floor() as usize;
        Self::with_lambda(x0, sigma, lambda)
    }

    pub fn with_lambda(x0: &[f64], sigma: f64, lambda: usize) -> CmaEs {
        let dim = x0.len();
        let n = dim as f64;
        let mu = lambda / 2;
        let mut weights: Vec<f64> =
            (0..mu).map(|i| ((lambda as f64 + 1.0) / 2.0).ln() - ((i + 1) as f64).ln()).collect();
        let sum: f64 = weights.iter().sum();
        for w in &mut weights {
            *w /= sum;
        }
        let mueff = 1.0 / weights.iter().map(|w| w * w).sum::<f64>();
        let cc = (4.0 + mueff / n) / (n + 4.0 + 2.0 * mueff / n);
        let cs = (mueff + 2.0) / (n + mueff + 5.0);
        let c1 = 2.0 / ((n + 1.3) * (n + 1.3) + mueff);
        let cmu = (1.0 - c1)
            .min(2.0 * (mueff - 2.0 + 1.0 / mueff) / ((n + 2.0) * (n + 2.0) + mueff));
        let damps = 1.0 + 2.0 * (0.0f64).max(((mueff - 1.0) / (n + 1.0)).sqrt() - 1.0) + cs;
        CmaEs {
            dim,
            mean: x0.to_vec(),
            sigma,
            lambda,
            mu,
            weights,
            mueff,
            cc,
            cs,
            c1,
            cmu,
            damps,
            pc: vec![0.0; dim],
            ps: vec![0.0; dim],
            c: Mat::identity(dim),
            a: Mat::identity(dim),
            generation: 0,
            chi_n: n.sqrt() * (1.0 - 1.0 / (4.0 * n) + 1.0 / (21.0 * n * n)),
        }
    }

    /// Sample a population of λ candidates.
    pub fn ask(&mut self, rng: &mut Pcg32) -> Vec<Vec<f64>> {
        (0..self.lambda)
            .map(|_| {
                let z: Vec<f64> = rng.normal_vec(self.dim);
                let az = self.a.matvec(&z);
                (0..self.dim).map(|i| self.mean[i] + self.sigma * az[i]).collect()
            })
            .collect()
    }

    /// Update from (candidate, fitness) pairs; LOWER fitness is better.
    pub fn tell(&mut self, mut scored: Vec<(Vec<f64>, f64)>) {
        assert_eq!(scored.len(), self.lambda);
        // Total order, no NaN panic: a diverged rollout's NaN fitness
        // ranks strictly last regardless of its sign bit (raw
        // `total_cmp` would sort -NaN *first*, poisoning the mean), so
        // it can never enter the recombination weights. Formerly
        // `partial_cmp(..).unwrap()`, which panicked on the first NaN —
        // the float-ord xtask lint keeps that from coming back.
        scored.sort_by(|a, b| match (a.1.is_nan(), b.1.is_nan()) {
            (false, false) => a.1.total_cmp(&b.1),
            (true, true) => std::cmp::Ordering::Equal,
            (false, true) => std::cmp::Ordering::Less,
            (true, false) => std::cmp::Ordering::Greater,
        });
        let old_mean = self.mean.clone();
        // New mean.
        let mut new_mean = vec![0.0; self.dim];
        for (k, w) in self.weights.iter().enumerate() {
            for i in 0..self.dim {
                new_mean[i] += w * scored[k].0[i];
            }
        }
        // Evolution paths.
        let y: Vec<f64> =
            (0..self.dim).map(|i| (new_mean[i] - old_mean[i]) / self.sigma).collect();
        // C^{-1/2} y approximated via A⁻¹ y (A lower-triangular Cholesky).
        let cinv_y = lower_solve(&self.a, &y);
        let n = self.dim as f64;
        for i in 0..self.dim {
            self.ps[i] = (1.0 - self.cs) * self.ps[i]
                + (self.cs * (2.0 - self.cs) * self.mueff).sqrt() * cinv_y[i];
        }
        let ps_norm = self.ps.iter().map(|x| x * x).sum::<f64>().sqrt();
        let hsig = ps_norm
            / (1.0 - (1.0 - self.cs).powi(2 * (self.generation as i32 + 1))).sqrt()
            / self.chi_n
            < 1.4 + 2.0 / (n + 1.0);
        let h = if hsig { 1.0 } else { 0.0 };
        for i in 0..self.dim {
            self.pc[i] = (1.0 - self.cc) * self.pc[i]
                + h * (self.cc * (2.0 - self.cc) * self.mueff).sqrt() * y[i];
        }
        // Covariance update (rank-1 + rank-μ).
        let mut cnew = self.c.scale(1.0 - self.c1 - self.cmu);
        for i in 0..self.dim {
            for j in 0..self.dim {
                cnew[(i, j)] += self.c1 * self.pc[i] * self.pc[j];
            }
        }
        for (k, w) in self.weights.iter().enumerate() {
            let yk: Vec<f64> = (0..self.dim)
                .map(|i| (scored[k].0[i] - old_mean[i]) / self.sigma)
                .collect();
            for i in 0..self.dim {
                for j in 0..self.dim {
                    cnew[(i, j)] += self.cmu * w * yk[i] * yk[j];
                }
            }
        }
        self.c = cnew;
        // Step size.
        self.sigma *= ((self.cs / self.damps) * (ps_norm / self.chi_n - 1.0)).exp();
        self.sigma = self.sigma.clamp(1e-12, 1e6);
        self.mean = new_mean;
        self.generation += 1;
        // Refresh sampling factor (regularize if needed).
        self.a = match self.c.cholesky() {
            Some(a) => a,
            None => {
                let mut cr = self.c.clone();
                for i in 0..self.dim {
                    cr[(i, i)] += 1e-10 + 1e-8 * cr[(i, i)].abs();
                }
                cr.cholesky().unwrap_or_else(|| Mat::identity(self.dim))
            }
        };
    }
}

fn lower_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[(i, j)] * y[j];
        }
        let d = l[(i, i)];
        y[i] = if d.abs() > 1e-300 { s / d } else { 0.0 };
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimize<F: Fn(&[f64]) -> f64>(f: F, x0: &[f64], gens: usize) -> (Vec<f64>, f64) {
        let mut rng = Pcg32::new(3);
        let mut es = CmaEs::new(x0, 0.5);
        let mut best = (x0.to_vec(), f64::MAX);
        for _ in 0..gens {
            let pop = es.ask(&mut rng);
            let scored: Vec<(Vec<f64>, f64)> =
                pop.into_iter().map(|x| {
                    let v = f(&x);
                    (x, v)
                }).collect();
            for (x, v) in &scored {
                if *v < best.1 {
                    best = (x.clone(), *v);
                }
            }
            es.tell(scored);
        }
        best
    }

    #[test]
    fn solves_sphere() {
        let (x, v) = optimize(
            |x| x.iter().map(|a| a * a).sum(),
            &[2.0, -1.5, 3.0],
            120,
        );
        assert!(v < 1e-8, "best {v} at {x:?}");
    }

    #[test]
    fn solves_rosenbrock_2d() {
        let (x, v) = optimize(
            |x| {
                let (a, b) = (x[0], x[1]);
                (1.0 - a) * (1.0 - a) + 100.0 * (b - a * a) * (b - a * a)
            },
            &[-1.0, 1.0],
            400,
        );
        assert!(v < 1e-4, "best {v} at {x:?}");
    }

    #[test]
    fn sigma_shrinks_near_optimum() {
        let mut rng = Pcg32::new(5);
        let mut es = CmaEs::new(&[0.01, -0.01], 0.3);
        for _ in 0..80 {
            let pop = es.ask(&mut rng);
            let scored = pop
                .into_iter()
                .map(|x| {
                    let v = x.iter().map(|a| a * a).sum();
                    (x, v)
                })
                .collect();
            es.tell(scored);
        }
        assert!(es.sigma < 0.3, "sigma did not adapt: {}", es.sigma);
    }

    /// Regression for the `tell` ranking: NaN fitness (a diverged
    /// rollout) must neither panic — the old
    /// `partial_cmp(..).unwrap()` did — nor contaminate the update,
    /// whatever the NaN's sign bit (`total_cmp` alone ranks -NaN ahead
    /// of every finite value).
    #[test]
    fn tell_survives_nan_fitness() {
        for nan in [f64::NAN, -f64::NAN] {
            let mut rng = Pcg32::new(7);
            let mut es = CmaEs::with_lambda(&[0.2, -0.1, 0.3], 0.5, 8);
            let pop = es.ask(&mut rng);
            let scored: Vec<(Vec<f64>, f64)> = pop
                .into_iter()
                .enumerate()
                .map(|(k, x)| {
                    let fit = if k == 2 { nan } else { k as f64 };
                    (x, fit)
                })
                .collect();
            es.tell(scored);
            assert!(
                es.mean.iter().all(|m| m.is_finite()),
                "NaN fitness leaked into the mean: {:?}",
                es.mean
            );
            assert!(es.sigma.is_finite() && es.sigma > 0.0, "sigma corrupted: {}", es.sigma);
            // The optimizer keeps working after the bad generation.
            let pop = es.ask(&mut rng);
            assert!(pop.iter().flatten().all(|x| x.is_finite()));
        }
    }
}
