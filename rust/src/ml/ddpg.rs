//! DDPG (Lillicrap et al. 2016) — the model-free RL baseline of Fig. 8:
//! actor–critic MLPs with target networks, replay buffer, and OU
//! exploration noise. The paper's point is its sample-inefficiency
//! relative to gradient-through-simulation on short wall-clock budgets.

use crate::ml::adam::Adam;
use crate::ml::mlp::Mlp;
use crate::util::rng::Pcg32;

#[derive(Clone)]
pub struct Transition {
    pub state: Vec<f64>,
    pub action: Vec<f64>,
    pub reward: f64,
    pub next_state: Vec<f64>,
    pub done: bool,
}

pub struct Replay {
    buf: Vec<Transition>,
    cap: usize,
    next: usize,
}

impl Replay {
    pub fn new(cap: usize) -> Replay {
        Replay { buf: Vec::with_capacity(cap), cap, next: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.cap {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
            self.next = (self.next + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn sample<'a>(&'a self, rng: &mut Pcg32, n: usize) -> Vec<&'a Transition> {
        (0..n).map(|_| &self.buf[rng.below(self.buf.len())]).collect()
    }
}

pub struct DdpgConfig {
    pub gamma: f64,
    pub tau: f64,
    pub actor_lr: f64,
    pub critic_lr: f64,
    pub batch: usize,
    pub noise_theta: f64,
    pub noise_sigma: f64,
    pub action_scale: f64,
}

impl Default for DdpgConfig {
    fn default() -> DdpgConfig {
        DdpgConfig {
            gamma: 0.98,
            tau: 0.01,
            actor_lr: 1e-3,
            critic_lr: 1e-3,
            batch: 64,
            noise_theta: 0.15,
            noise_sigma: 0.2,
            action_scale: 1.0,
        }
    }
}

pub struct Ddpg {
    pub actor: Mlp,
    pub critic: Mlp,
    actor_target: Mlp,
    critic_target: Mlp,
    actor_opt: Adam,
    critic_opt: Adam,
    pub replay: Replay,
    pub cfg: DdpgConfig,
    noise: Vec<f64>,
    state_dim: usize,
    action_dim: usize,
}

impl Ddpg {
    pub fn new(state_dim: usize, action_dim: usize, cfg: DdpgConfig, rng: &mut Pcg32) -> Ddpg {
        // Same capacity class as the paper's controller (50, 200 hidden).
        let actor = Mlp::new(&[state_dim, 50, 200, action_dim], rng);
        let critic = Mlp::new(&[state_dim + action_dim, 50, 200, 1], rng);
        Ddpg {
            actor_target: actor.clone(),
            critic_target: critic.clone(),
            actor_opt: Adam::new(actor.n_params(), cfg.actor_lr),
            critic_opt: Adam::new(critic.n_params(), cfg.critic_lr),
            actor,
            critic,
            replay: Replay::new(100_000),
            noise: vec![0.0; action_dim],
            cfg,
            state_dim,
            action_dim,
        }
    }

    /// Deterministic policy action (tanh-squashed, scaled).
    pub fn act(&self, state: &[f64]) -> Vec<f64> {
        let (raw, _) = self.actor.forward(state);
        raw.iter().map(|a| a.tanh() * self.cfg.action_scale).collect()
    }

    /// Exploration action with Ornstein–Uhlenbeck noise.
    pub fn act_explore(&mut self, state: &[f64], rng: &mut Pcg32) -> Vec<f64> {
        let mut a = self.act(state);
        for i in 0..self.action_dim {
            self.noise[i] += -self.cfg.noise_theta * self.noise[i]
                + self.cfg.noise_sigma * rng.normal();
            a[i] = (a[i] + self.noise[i] * self.cfg.action_scale)
                .clamp(-self.cfg.action_scale, self.cfg.action_scale);
        }
        a
    }

    pub fn reset_noise(&mut self) {
        self.noise.iter_mut().for_each(|n| *n = 0.0);
    }

    /// One gradient update from the replay buffer.
    pub fn update(&mut self, rng: &mut Pcg32) {
        if self.replay.len() < self.cfg.batch {
            return;
        }
        let batch: Vec<Transition> =
            self.replay.sample(rng, self.cfg.batch).into_iter().cloned().collect();
        let inv = 1.0 / self.cfg.batch as f64;
        // --- Critic update: minimize (Q(s,a) − (r + γ·Q'(s', π'(s'))))². ---
        let mut cgrad = vec![0.0; self.critic.n_params()];
        for t in &batch {
            let mut target = t.reward;
            if !t.done {
                let (a_next_raw, _) = self.actor_target.forward(&t.next_state);
                let a_next: Vec<f64> = a_next_raw
                    .iter()
                    .map(|a| a.tanh() * self.cfg.action_scale)
                    .collect();
                let mut sa = t.next_state.clone();
                sa.extend_from_slice(&a_next);
                let (qn, _) = self.critic_target.forward(&sa);
                target += self.cfg.gamma * qn[0];
            }
            let mut sa = t.state.clone();
            sa.extend_from_slice(&t.action);
            let (q, tr) = self.critic.forward(&sa);
            let err = q[0] - target;
            self.critic.backward(&tr, &[2.0 * err * inv], &mut cgrad);
        }
        self.critic_opt.step(&mut self.critic.params, &cgrad);
        // --- Actor update: ascend Q(s, π(s)). ---
        let mut agrad = vec![0.0; self.actor.n_params()];
        for t in &batch {
            let (raw, atr) = self.actor.forward(&t.state);
            let action: Vec<f64> =
                raw.iter().map(|a| a.tanh() * self.cfg.action_scale).collect();
            let mut sa = t.state.clone();
            sa.extend_from_slice(&action);
            let (_, ctr) = self.critic.forward(&sa);
            // ∂(−Q)/∂(s,a); take the action part.
            let mut dummy = vec![0.0; self.critic.n_params()];
            let dsa = self.critic.backward(&ctr, &[-inv], &mut dummy);
            let dact = &dsa[self.state_dim..];
            // Chain through tanh scaling.
            let draw: Vec<f64> = dact
                .iter()
                .zip(&raw)
                .map(|(g, r)| g * self.cfg.action_scale * (1.0 - r.tanh() * r.tanh()))
                .collect();
            self.actor.backward(&atr, &draw, &mut agrad);
        }
        self.actor_opt.step(&mut self.actor.params, &agrad);
        // --- Soft target updates. ---
        let tau = self.cfg.tau;
        for (tp, p) in self.actor_target.params.iter_mut().zip(&self.actor.params) {
            *tp = (1.0 - tau) * *tp + tau * *p;
        }
        for (tp, p) in self.critic_target.params.iter_mut().zip(&self.critic.params) {
            *tp = (1.0 - tau) * *tp + tau * *p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_ring_buffer() {
        let mut r = Replay::new(4);
        for k in 0..6 {
            r.push(Transition {
                state: vec![k as f64],
                action: vec![],
                reward: 0.0,
                next_state: vec![],
                done: false,
            });
        }
        assert_eq!(r.len(), 4);
        // Oldest two were overwritten.
        let states: Vec<f64> = r.buf.iter().map(|t| t.state[0]).collect();
        assert!(states.contains(&4.0) && states.contains(&5.0));
        assert!(!states.contains(&0.0));
    }

    #[test]
    fn actions_bounded() {
        let mut rng = Pcg32::new(2);
        let cfg = DdpgConfig { action_scale: 0.7, ..Default::default() };
        let mut agent = Ddpg::new(3, 2, cfg, &mut rng);
        for _ in 0..50 {
            let s = rng.normal_vec(3);
            let a = agent.act_explore(&s, &mut rng);
            for ai in a {
                assert!(ai.abs() <= 0.7 + 1e-12);
            }
        }
    }

    #[test]
    fn learns_trivial_bandit() {
        // 1-step env: state = [x], reward = −(a − 0.5·sign(x))². DDPG
        // should learn a(x) ≈ 0.5·sign(x) — a smoke test that the
        // actor/critic plumbing optimizes in the right direction.
        let mut rng = Pcg32::new(8);
        let mut agent = Ddpg::new(
            1,
            1,
            DdpgConfig { gamma: 0.0, batch: 32, ..Default::default() },
            &mut rng,
        );
        for _ in 0..2500 {
            let x: f64 = if rng.next_u32() & 1 == 1 { 1.0 } else { -1.0 };
            let a = agent.act_explore(&[x], &mut rng)[0];
            let target = 0.5 * x.signum();
            let reward = -(a - target) * (a - target);
            agent.replay.push(Transition {
                state: vec![x],
                action: vec![a],
                reward,
                next_state: vec![x],
                done: true,
            });
            agent.update(&mut rng);
        }
        // DDPG's deterministic policy + bounded critic fit is coarse on
        // this budget; assert the learned *direction* per state (the
        // property Fig. 8 relies on is sample inefficiency, not final
        // precision).
        let a_pos = agent.act(&[1.0])[0];
        let a_neg = agent.act(&[-1.0])[0];
        assert!(a_pos > 0.15, "a(+1) = {a_pos}");
        assert!(a_neg < -0.15, "a(-1) = {a_neg}");
    }
}
