//! Learning components for the paper's §7.4 applications: a hand-rolled
//! MLP (the controller network of Fig. 8), Adam/SGD, and the two
//! baselines the paper compares against — CMA-ES (derivative-free,
//! Fig. 7) and DDPG (model-free RL, Fig. 8).
pub mod adam;
pub mod cmaes;
pub mod ddpg;
pub mod mlp;
