//! Learning components for the paper's §7.4 applications: a hand-rolled
//! MLP ([`mlp`], the controller network of Fig. 8), Adam/SGD
//! ([`adam`]), and the two baselines the paper compares against —
//! CMA-ES ([`cmaes`], derivative-free, Fig. 7) and DDPG ([`ddpg`],
//! model-free RL, Fig. 8). The gradient consumers are fed by
//! [`crate::batch::SceneBatch::rollout_grad`]'s contiguous scene-major
//! gradient buffers.
pub mod adam;
pub mod cmaes;
pub mod ddpg;
pub mod mlp;
