//! Multi-layer perceptron with manual reverse-mode — the controller
//! network of Fig. 8 ("an MLP with 50 nodes in the first layer and 200
//! nodes in the second, with ReLU activations"), trained end-to-end
//! through the differentiable simulator.

use crate::util::rng::Pcg32;

/// Fully-connected network with ReLU hidden activations and linear
/// output. Parameters are stored flat for optimizer simplicity.
#[derive(Clone)]
pub struct Mlp {
    pub sizes: Vec<usize>,
    /// Flat parameters: for each layer, weights (out×in) then biases.
    pub params: Vec<f64>,
}

/// Cached activations from a forward pass (needed for backward).
pub struct MlpTrace {
    /// Pre-activation inputs per layer (x, h1, h2, …).
    acts: Vec<Vec<f64>>,
}

impl Mlp {
    pub fn new(sizes: &[usize], rng: &mut Pcg32) -> Mlp {
        assert!(sizes.len() >= 2);
        let mut params = Vec::new();
        for l in 0..sizes.len() - 1 {
            let (fan_in, fan_out) = (sizes[l], sizes[l + 1]);
            let scale = (2.0 / fan_in as f64).sqrt(); // He init for ReLU
            for _ in 0..fan_in * fan_out {
                params.push(rng.normal() * scale);
            }
            for _ in 0..fan_out {
                params.push(0.0);
            }
        }
        Mlp { sizes: sizes.to_vec(), params }
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    fn layer_offsets(&self) -> Vec<(usize, usize, usize)> {
        // (offset, fan_in, fan_out) per layer.
        let mut offs = Vec::new();
        let mut off = 0;
        for l in 0..self.sizes.len() - 1 {
            offs.push((off, self.sizes[l], self.sizes[l + 1]));
            off += self.sizes[l] * self.sizes[l + 1] + self.sizes[l + 1];
        }
        offs
    }

    /// Forward pass; returns output and the trace for backward.
    pub fn forward(&self, x: &[f64]) -> (Vec<f64>, MlpTrace) {
        assert_eq!(x.len(), self.sizes[0]);
        let mut acts = vec![x.to_vec()];
        let offs = self.layer_offsets();
        let last = offs.len() - 1;
        for (l, &(off, fin, fout)) in offs.iter().enumerate() {
            let input = acts.last().unwrap().clone();
            let w = &self.params[off..off + fin * fout];
            let b = &self.params[off + fin * fout..off + fin * fout + fout];
            let mut out = vec![0.0; fout];
            for o in 0..fout {
                let mut s = b[o];
                for i in 0..fin {
                    s += w[o * fin + i] * input[i];
                }
                out[o] = if l < last { s.max(0.0) } else { s };
            }
            acts.push(out);
        }
        (acts.last().unwrap().clone(), MlpTrace { acts })
    }

    /// Backward pass: given ∂L/∂output, accumulate parameter gradients
    /// into `grad` (same layout as params) and return ∂L/∂input.
    pub fn backward(&self, trace: &MlpTrace, gout: &[f64], grad: &mut [f64]) -> Vec<f64> {
        assert_eq!(grad.len(), self.params.len());
        let offs = self.layer_offsets();
        let last = offs.len() - 1;
        let mut delta = gout.to_vec();
        for (l, &(off, fin, fout)) in offs.iter().enumerate().rev() {
            let input = &trace.acts[l];
            let output = &trace.acts[l + 1];
            // ReLU mask on hidden layers (output layer is linear).
            let mut d = delta.clone();
            if l < last {
                for o in 0..fout {
                    if output[o] <= 0.0 {
                        d[o] = 0.0;
                    }
                }
            }
            let w = &self.params[off..off + fin * fout];
            // Parameter grads.
            for o in 0..fout {
                for i in 0..fin {
                    grad[off + o * fin + i] += d[o] * input[i];
                }
                grad[off + fin * fout + o] += d[o];
            }
            // Input grads.
            let mut din = vec![0.0; fin];
            for o in 0..fout {
                for i in 0..fin {
                    din[i] += w[o * fin + i] * d[o];
                }
            }
            delta = din;
        }
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::quick;

    #[test]
    fn forward_shapes_and_relu() {
        let mut rng = Pcg32::new(1);
        let net = Mlp::new(&[3, 5, 2], &mut rng);
        let (y, tr) = net.forward(&[0.1, -0.2, 0.3]);
        assert_eq!(y.len(), 2);
        assert_eq!(tr.acts.len(), 3);
        for h in &tr.acts[1] {
            assert!(*h >= 0.0);
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        quick("mlp-grad", 10, |g| {
            let mut rng = Pcg32::new(g.rng.next_u64());
            let net = Mlp::new(&[4, 8, 6, 2], &mut rng);
            let x: Vec<f64> = rng.normal_vec(4);
            let gout: Vec<f64> = rng.normal_vec(2);
            let (_, tr) = net.forward(&x);
            let mut grad = vec![0.0; net.n_params()];
            let gin = net.backward(&tr, &gout, &mut grad);
            // Loss = gout · output. FD on a few random params + inputs.
            let loss = |n: &Mlp, xx: &[f64]| -> f64 {
                let (y, _) = n.forward(xx);
                y.iter().zip(&gout).map(|(a, b)| a * b).sum()
            };
            let h = 1e-6;
            for _ in 0..10 {
                let k = rng.below(net.n_params());
                let mut np = net.clone();
                np.params[k] += h;
                let mut nm = net.clone();
                nm.params[k] -= h;
                let fd = (loss(&np, &x) - loss(&nm, &x)) / (2.0 * h);
                assert!(
                    (fd - grad[k]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "param {k}: fd {fd} analytic {}",
                    grad[k]
                );
            }
            for k in 0..4 {
                let mut xp = x.clone();
                xp[k] += h;
                let mut xm = x.clone();
                xm[k] -= h;
                let fd = (loss(&net, &xp) - loss(&net, &xm)) / (2.0 * h);
                assert!(
                    (fd - gin[k]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "input {k}: fd {fd} analytic {}",
                    gin[k]
                );
            }
        });
    }

    #[test]
    fn can_fit_a_toy_function() {
        // Regression sanity: fit y = sin(2x) on [-1, 1] with Adam.
        use crate::ml::adam::Adam;
        let mut rng = Pcg32::new(7);
        let mut net = Mlp::new(&[1, 32, 32, 1], &mut rng);
        let mut opt = Adam::new(net.n_params(), 3e-3);
        let mut final_loss = f64::MAX;
        for _ in 0..800 {
            let mut grad = vec![0.0; net.n_params()];
            let mut loss = 0.0;
            for _ in 0..16 {
                let x = rng.range(-1.0, 1.0);
                let target = (2.0 * x).sin();
                let (y, tr) = net.forward(&[x]);
                let err = y[0] - target;
                loss += err * err;
                net.backward(&tr, &[2.0 * err / 16.0], &mut grad);
            }
            final_loss = loss / 16.0;
            opt.step(&mut net.params, &grad);
        }
        assert!(final_loss < 0.01, "did not fit: loss {final_loss}");
    }
}
