//! First-order optimizers: Adam (Kingma & Ba) and plain SGD.

pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    pub fn new(n: usize, lr: f64) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

pub struct Sgd {
    pub lr: f64,
}

impl Sgd {
    pub fn step(&self, params: &mut [f64], grad: &[f64]) {
        for (p, g) in params.iter_mut().zip(grad) {
            *p -= self.lr * g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // f(x) = Σ (x_i − i)², badly scaled.
        let mut x = vec![0.0; 5];
        let mut opt = Adam::new(5, 0.1);
        for _ in 0..1500 {
            let grad: Vec<f64> = x
                .iter()
                .enumerate()
                .map(|(i, &xi)| 2.0 * (i + 1) as f64 * (xi - i as f64))
                .collect();
            opt.step(&mut x, &grad);
        }
        for (i, xi) in x.iter().enumerate() {
            assert!((xi - i as f64).abs() < 1e-2, "x[{i}] = {xi}");
        }
    }

    #[test]
    fn sgd_descends() {
        let mut x = vec![10.0];
        let opt = Sgd { lr: 0.1 };
        for _ in 0..100 {
            let g = vec![2.0 * x[0]];
            opt.step(&mut x, &g);
        }
        assert!(x[0].abs() < 1e-4);
    }
}
