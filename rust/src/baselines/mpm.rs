//! MLS-MPM (Hu et al. 2018) 3-D simulator — the ChainQueen-style
//! particle/grid baseline of the paper's Fig. 3 scalability comparison.
//!
//! Differentiable-MPM frameworks backpropagate by storing the full
//! particle AND grid state of every step (DiffTaichi checkpoints the
//! whole tape); this implementation reproduces that cost structure with
//! a per-step tape byte counter, so the Fig. 3 memory series has the
//! same mechanism as the original: grid volume ∝ scene extent³, tape ∝
//! steps × (particles + grid).

use crate::math::{Mat3, Vec3};
use crate::util::memory::MemTracker;

#[derive(Clone)]
pub struct MpmConfig {
    /// Grid resolution per axis (n³ nodes over the domain).
    pub n_grid: usize,
    /// Domain edge length (world units); grid spacing = extent / n_grid.
    pub extent: f64,
    pub dt: f64,
    /// Young's modulus-ish stiffness (neo-Hookean λ≈μ simplification).
    pub e: f64,
    pub nu: f64,
    pub density: f64,
    pub gravity: f64,
    /// Record the per-step tape bytes (differentiable-MPM memory model).
    pub track_tape: bool,
}

impl Default for MpmConfig {
    fn default() -> MpmConfig {
        MpmConfig {
            n_grid: 32,
            extent: 1.0,
            dt: 1e-4,
            e: 1e4,
            nu: 0.3,
            density: 1000.0,
            gravity: -9.8,
            track_tape: true,
        }
    }
}

pub struct Mpm {
    pub cfg: MpmConfig,
    pub x: Vec<Vec3>,
    pub v: Vec<Vec3>,
    /// Affine velocity field (APIC C matrix).
    pub c: Vec<Mat3>,
    /// Deformation gradient.
    pub f: Vec<Mat3>,
    pub p_mass: f64,
    pub p_vol: f64,
    grid_m: Vec<f64>,
    grid_v: Vec<Vec3>,
    pub steps: usize,
    pub tape: MemTracker,
}

impl Mpm {
    pub fn new(cfg: MpmConfig) -> Mpm {
        let n = cfg.n_grid;
        let dx = cfg.extent / n as f64;
        // Standard MPM particle sizing: ~8 particles per cell volume.
        let p_vol = (dx * 0.5) * (dx * 0.5) * (dx * 0.5);
        Mpm {
            p_mass: cfg.density * p_vol,
            p_vol,
            x: Vec::new(),
            v: Vec::new(),
            c: Vec::new(),
            f: Vec::new(),
            grid_m: vec![0.0; n * n * n],
            grid_v: vec![Vec3::default(); n * n * n],
            steps: 0,
            tape: MemTracker::new(),
            cfg,
        }
    }

    /// Seed a box of particles (8 per cell) covering [lo, hi].
    pub fn add_box(&mut self, lo: Vec3, hi: Vec3, vel: Vec3) {
        let dx = self.cfg.extent / self.cfg.n_grid as f64;
        let spacing = dx * 0.5;
        let mut p = lo + Vec3::splat(spacing * 0.5);
        while p.x < hi.x {
            p.y = lo.y + spacing * 0.5;
            while p.y < hi.y {
                p.z = lo.z + spacing * 0.5;
                while p.z < hi.z {
                    self.x.push(p);
                    self.v.push(vel);
                    self.c.push(Mat3::zeros());
                    self.f.push(Mat3::identity());
                    p.z += spacing;
                }
                p.y += spacing;
            }
            p.x += spacing;
        }
    }

    pub fn n_particles(&self) -> usize {
        self.x.len()
    }

    /// Bytes of state a differentiable-MPM tape must retain per step:
    /// particle state (x, v, C, F = 3+3+9+9 f64) + active grid (m + v).
    pub fn step_tape_bytes(&self) -> usize {
        let particle = self.x.len() * (3 + 3 + 9 + 9) * 8;
        let grid = self.grid_m.len() * 4 * 8;
        particle + grid
    }

    /// One MLS-MPM step (P2G → grid ops → G2P).
    pub fn step(&mut self) {
        let n = self.cfg.n_grid;
        let dx = self.cfg.extent / n as f64;
        let inv_dx = 1.0 / dx;
        let mu = self.cfg.e / (2.0 * (1.0 + self.cfg.nu));
        let la = self.cfg.e * self.cfg.nu / ((1.0 + self.cfg.nu) * (1.0 - 2.0 * self.cfg.nu));
        let dt = self.cfg.dt;
        self.grid_m.iter_mut().for_each(|m| *m = 0.0);
        self.grid_v.iter_mut().for_each(|v| *v = Vec3::default());
        let idx = |i: usize, j: usize, k: usize| (i * n + j) * n + k;
        // --- P2G ---
        for p in 0..self.x.len() {
            let xp = self.x[p] * inv_dx;
            let base = Vec3::new(
                (xp.x - 0.5).floor(),
                (xp.y - 0.5).floor(),
                (xp.z - 0.5).floor(),
            );
            let fx = xp - base;
            // Quadratic B-spline weights.
            let w = [
                (Vec3::splat(1.5) - fx).to_array().map(|t| 0.5 * t * t),
                fx.to_array().map(|t| 0.75 - (t - 1.0) * (t - 1.0)),
                (fx - Vec3::splat(0.5)).to_array().map(|t| 0.5 * t * t),
            ];
            // Neo-Hookean (simplified fixed-corotated would need SVD;
            // NH P(F) = μ(F − F⁻ᵀ) + λ·ln(J)·F⁻ᵀ).
            let fm = self.f[p];
            let j = fm.det().max(0.05);
            let finv_t = fm.inverse().transpose();
            let pk = (fm - finv_t) * mu + finv_t * (la * j.ln());
            let stress = pk * fm.transpose() * (-dt * 4.0 * inv_dx * inv_dx * self.p_vol);
            let affine = stress + self.c[p] * self.p_mass;
            for a in 0..3usize {
                for b in 0..3usize {
                    for cc in 0..3usize {
                        let weight = w[a][0] * w[b][1] * w[cc][2];
                        let gi = base.x as isize + a as isize;
                        let gj = base.y as isize + b as isize;
                        let gk = base.z as isize + cc as isize;
                        if gi < 0
                            || gj < 0
                            || gk < 0
                            || gi >= n as isize
                            || gj >= n as isize
                            || gk >= n as isize
                        {
                            continue;
                        }
                        let dpos =
                            Vec3::new(a as f64 - fx.x, b as f64 - fx.y, cc as f64 - fx.z) * dx;
                        let gidx = idx(gi as usize, gj as usize, gk as usize);
                        let mv =
                            (self.v[p] * self.p_mass + affine * dpos) * weight;
                        self.grid_v[gidx] += mv;
                        self.grid_m[gidx] += weight * self.p_mass;
                    }
                }
            }
        }
        // --- Grid update ---
        let bound = 3;
        for i in 0..n {
            for jj in 0..n {
                for k in 0..n {
                    let g = idx(i, jj, k);
                    if self.grid_m[g] > 0.0 {
                        let mut v = self.grid_v[g] / self.grid_m[g];
                        v.y += dt * self.cfg.gravity;
                        // Sticky domain bounds (the "ground" and walls).
                        if i < bound && v.x < 0.0 {
                            v.x = 0.0;
                        }
                        if i >= n - bound && v.x > 0.0 {
                            v.x = 0.0;
                        }
                        if jj < bound && v.y < 0.0 {
                            v.y = 0.0;
                        }
                        if jj >= n - bound && v.y > 0.0 {
                            v.y = 0.0;
                        }
                        if k < bound && v.z < 0.0 {
                            v.z = 0.0;
                        }
                        if k >= n - bound && v.z > 0.0 {
                            v.z = 0.0;
                        }
                        self.grid_v[g] = v;
                    }
                }
            }
        }
        // --- G2P ---
        for p in 0..self.x.len() {
            let xp = self.x[p] * inv_dx;
            let base = Vec3::new(
                (xp.x - 0.5).floor(),
                (xp.y - 0.5).floor(),
                (xp.z - 0.5).floor(),
            );
            let fx = xp - base;
            let w = [
                (Vec3::splat(1.5) - fx).to_array().map(|t| 0.5 * t * t),
                fx.to_array().map(|t| 0.75 - (t - 1.0) * (t - 1.0)),
                (fx - Vec3::splat(0.5)).to_array().map(|t| 0.5 * t * t),
            ];
            let mut new_v = Vec3::default();
            let mut new_c = Mat3::zeros();
            for a in 0..3usize {
                for b in 0..3usize {
                    for cc in 0..3usize {
                        let gi = base.x as isize + a as isize;
                        let gj = base.y as isize + b as isize;
                        let gk = base.z as isize + cc as isize;
                        if gi < 0
                            || gj < 0
                            || gk < 0
                            || gi >= n as isize
                            || gj >= n as isize
                            || gk >= n as isize
                        {
                            continue;
                        }
                        let weight = w[a][0] * w[b][1] * w[cc][2];
                        let dpos = Vec3::new(a as f64 - fx.x, b as f64 - fx.y, cc as f64 - fx.z);
                        let gv = self.grid_v[idx(gi as usize, gj as usize, gk as usize)];
                        new_v += gv * weight;
                        let gv_w = gv * (4.0 * inv_dx * weight);
                        new_c = new_c + Mat3::from_outer(gv_w.outer(dpos * dx));
                    }
                }
            }
            self.v[p] = new_v;
            self.c[p] = new_c;
            self.x[p] += new_v * dt;
            // F update: F ← (I + dt·C)·F.
            self.f[p] = (Mat3::identity() + new_c * dt) * self.f[p];
        }
        if self.cfg.track_tape {
            self.tape.alloc(self.step_tape_bytes());
        }
        self.steps += 1;
    }

    /// Peak tape bytes so far (the Fig. 3 memory series for this method).
    pub fn tape_bytes(&self) -> usize {
        self.tape.peak()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Mpm {
        let mut m = Mpm::new(MpmConfig { n_grid: 16, dt: 2e-4, ..Default::default() });
        m.add_box(
            Vec3::new(0.4, 0.5, 0.4),
            Vec3::new(0.6, 0.7, 0.6),
            Vec3::default(),
        );
        m
    }

    #[test]
    fn particles_seeded() {
        let m = small();
        assert!(m.n_particles() > 100, "{}", m.n_particles());
    }

    #[test]
    fn block_falls_and_settles_in_domain() {
        let mut m = small();
        let y0: f64 = m.x.iter().map(|p| p.y).sum::<f64>() / m.n_particles() as f64;
        for _ in 0..3000 {
            m.step();
        }
        let y1: f64 = m.x.iter().map(|p| p.y).sum::<f64>() / m.n_particles() as f64;
        assert!(y1 < y0 - 0.1, "did not fall: {y0} -> {y1}");
        for p in &m.x {
            assert!(p.is_finite());
            assert!(p.x > -0.01 && p.x < 1.01 && p.y > -0.01 && p.z > -0.01);
        }
        // Settled on the domain floor (bound = 3 cells ≈ 0.19).
        let ymin = m.x.iter().map(|p| p.y).fold(f64::MAX, f64::min);
        assert!(ymin < 0.3, "ymin = {ymin}");
    }

    #[test]
    fn tape_grows_linearly_with_steps() {
        let mut m = small();
        m.step();
        let per = m.tape_bytes();
        for _ in 0..9 {
            m.step();
        }
        assert_eq!(m.tape_bytes(), per * 10);
    }

    #[test]
    fn grid_memory_scales_cubically() {
        let a = Mpm::new(MpmConfig { n_grid: 16, ..Default::default() });
        let b = Mpm::new(MpmConfig { n_grid: 32, ..Default::default() });
        assert_eq!(b.grid_m.len(), a.grid_m.len() * 8);
    }

    #[test]
    fn momentum_roughly_conserved_in_free_flight() {
        // No walls hit, short horizon: P2G/G2P transfer conserves
        // momentum up to gravity.
        let mut m =
            Mpm::new(MpmConfig { n_grid: 32, dt: 1e-4, gravity: 0.0, ..Default::default() });
        m.add_box(
            Vec3::new(0.4, 0.4, 0.4),
            Vec3::new(0.6, 0.6, 0.6),
            Vec3::new(0.2, 0.0, 0.0),
        );
        let p0: Vec3 = m.v.iter().fold(Vec3::default(), |a, &b| a + b) * m.p_mass;
        for _ in 0..50 {
            m.step();
        }
        let p1: Vec3 = m.v.iter().fold(Vec3::default(), |a, &b| a + b) * m.p_mass;
        assert!((p1 - p0).norm() < 0.05 * (1.0 + p0.norm()), "Δp = {:?}", p1 - p0);
    }
}
