//! Comparator systems built in-repo (DESIGN.md §6 substitutions):
//! an MLS-MPM particle/grid simulator standing in for ChainQueen /
//! DiffTaichi (Fig. 3), and a capsule-grid cloth standing in for
//! MuJoCo's cloth representation (Fig. 6 / Fig. 10).
pub mod capsule_cloth;
pub mod mpm;
