//! Comparator systems built in-repo (DESIGN.md §6 substitutions):
//! an MLS-MPM particle/grid simulator ([`mpm`]) standing in for
//! ChainQueen / DiffTaichi (Fig. 3), and a capsule-grid cloth
//! ([`capsule_cloth`]) standing in for MuJoCo's cloth representation
//! (Fig. 6 / Fig. 10). The MPM baseline reports its tape bytes through
//! an uncategorized [`crate::util::memory::MemTracker`], the quantity
//! the Fig-3 memory comparison plots against ours.
pub mod capsule_cloth;
pub mod mpm;
