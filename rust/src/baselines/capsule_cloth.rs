//! "MuJoCo-style" cloth: a grid of particles connected by stiff springs
//! whose collision geometry is SPHERES AT THE NODES ONLY (MuJoCo models
//! cloth as a 2-D grid of capsule/ellipsoid geoms; collisions happen
//! against those geoms, not against the continuum surface between them).
//!
//! This is the substitute comparator for Fig. 6 (a ball penetrates the
//! trampoline when the grid is sparse — the representation has holes)
//! and the non-differentiable "external simulator" of Fig. 10.

use crate::math::Vec3;

pub struct CapsuleClothConfig {
    pub nx: usize,
    pub nz: usize,
    pub size: f64,
    /// Collision radius of each node geom.
    pub geom_radius: f64,
    pub k_spring: f64,
    pub damping: f64,
    pub node_mass: f64,
    pub dt: f64,
    pub gravity: f64,
}

impl Default for CapsuleClothConfig {
    fn default() -> CapsuleClothConfig {
        CapsuleClothConfig {
            nx: 8,
            nz: 8,
            size: 2.0,
            geom_radius: 0.05,
            k_spring: 3000.0,
            damping: 2.0,
            node_mass: 0.02,
            dt: 1.0 / 500.0,
            gravity: -9.8,
        }
    }
}

/// A rigid ball interacting with the capsule-grid cloth.
pub struct Ball {
    pub pos: Vec3,
    pub vel: Vec3,
    pub radius: f64,
    pub mass: f64,
}

pub struct CapsuleCloth {
    pub cfg: CapsuleClothConfig,
    pub x: Vec<Vec3>,
    pub v: Vec<Vec3>,
    pub pinned: Vec<bool>,
    springs: Vec<(u32, u32, f64)>,
    pub steps: usize,
}

impl CapsuleCloth {
    pub fn new(cfg: CapsuleClothConfig, center: Vec3) -> CapsuleCloth {
        let (nx, nz) = (cfg.nx, cfg.nz);
        let mut x = Vec::new();
        for i in 0..=nx {
            for k in 0..=nz {
                x.push(
                    center
                        + Vec3::new(
                            cfg.size * (i as f64 / nx as f64 - 0.5),
                            0.0,
                            cfg.size * (k as f64 / nz as f64 - 0.5),
                        ),
                );
            }
        }
        let idx = |i: usize, k: usize| (i * (nz + 1) + k) as u32;
        let mut springs = Vec::new();
        let mut add = |a: u32, b: u32, xs: &[Vec3]| {
            springs.push((a, b, (xs[a as usize] - xs[b as usize]).norm()));
        };
        for i in 0..=nx {
            for k in 0..=nz {
                if i < nx {
                    add(idx(i, k), idx(i + 1, k), &x);
                }
                if k < nz {
                    add(idx(i, k), idx(i, k + 1), &x);
                }
                if i < nx && k < nz {
                    add(idx(i, k), idx(i + 1, k + 1), &x);
                    add(idx(i + 1, k), idx(i, k + 1), &x);
                }
            }
        }
        CapsuleCloth {
            v: vec![Vec3::default(); x.len()],
            pinned: vec![false; x.len()],
            x,
            springs,
            cfg,
            steps: 0,
        }
    }

    pub fn pin_boundary(&mut self) {
        let (nx, nz) = (self.cfg.nx, self.cfg.nz);
        for i in 0..=nx {
            for k in 0..=nz {
                if i == 0 || i == nx || k == 0 || k == nz {
                    self.pinned[i * (nz + 1) + k] = true;
                }
            }
        }
    }

    /// One symplectic-Euler step with node-sphere vs ball collision —
    /// the geom-level contact model. The *surface between nodes has no
    /// collision geometry*: a small ball passes through grid holes.
    pub fn step(&mut self, ball: &mut Ball) {
        let cfg = &self.cfg;
        let mut f = vec![Vec3::new(0.0, cfg.gravity * cfg.node_mass, 0.0); self.x.len()];
        for &(a, b, l0) in &self.springs {
            let d = self.x[b as usize] - self.x[a as usize];
            let l = d.norm().max(1e-9);
            let fs = d * (cfg.k_spring * (l - l0) / l);
            f[a as usize] += fs;
            f[b as usize] -= fs;
        }
        for i in 0..self.x.len() {
            f[i] -= self.v[i] * (cfg.damping * cfg.node_mass);
        }
        // Ball vs node geoms: impulse-free penalty push (MuJoCo-ish soft
        // contact), applied symmetrically.
        let contact_k = 5e4;
        let mut fb = Vec3::new(0.0, ball.mass * cfg.gravity, 0.0);
        for i in 0..self.x.len() {
            let d = self.x[i] - ball.pos;
            let dist = d.norm();
            let min_dist = ball.radius + cfg.geom_radius;
            if dist < min_dist && dist > 1e-9 {
                let pen = min_dist - dist;
                let push = d * (contact_k * pen / dist);
                f[i] += push;
                fb -= push;
            }
        }
        for i in 0..self.x.len() {
            if self.pinned[i] {
                self.v[i] = Vec3::default();
                continue;
            }
            self.v[i] += f[i] * (cfg.dt / cfg.node_mass);
            self.x[i] += self.v[i] * cfg.dt;
        }
        ball.vel += fb * (cfg.dt / ball.mass);
        ball.pos += ball.vel * cfg.dt;
        self.steps += 1;
    }

    /// Grid hole size: max gap between adjacent node geoms — a ball with
    /// diameter below this can pass straight through.
    pub fn hole_size(&self) -> f64 {
        let spacing = self.cfg.size / self.cfg.nx as f64;
        (spacing * std::f64::consts::SQRT_2 - 2.0 * self.cfg.geom_radius).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_ball_penetrates_sparse_grid() {
        // The Fig. 6 failure: ball smaller than the inter-geom hole
        // passes through the trampoline.
        let mut cloth = CapsuleCloth::new(
            CapsuleClothConfig { nx: 8, nz: 8, ..Default::default() },
            Vec3::new(0.0, 1.0, 0.0),
        );
        cloth.pin_boundary();
        let mut ball = Ball {
            pos: Vec3::new(0.12, 1.6, 0.12), // aimed at a grid hole
            vel: Vec3::new(0.0, -2.0, 0.0),
            radius: 0.08,
            mass: 0.5,
        };
        assert!(2.0 * ball.radius < cloth.hole_size(), "test setup: ball must fit the hole");
        let mut min_y = f64::MAX;
        for _ in 0..1500 {
            cloth.step(&mut ball);
            min_y = min_y.min(ball.pos.y);
        }
        assert!(min_y < 0.5, "ball should have fallen through: min_y = {min_y}");
    }

    #[test]
    fn big_ball_is_caught() {
        // A ball larger than the holes IS caught by the node geoms.
        let mut cloth = CapsuleCloth::new(
            CapsuleClothConfig { nx: 8, nz: 8, ..Default::default() },
            Vec3::new(0.0, 1.0, 0.0),
        );
        cloth.pin_boundary();
        let mut ball = Ball {
            pos: Vec3::new(0.0, 1.6, 0.0),
            vel: Vec3::new(0.0, -2.0, 0.0),
            radius: 0.3,
            mass: 0.5,
        };
        let mut min_y = f64::MAX;
        for _ in 0..2000 {
            cloth.step(&mut ball);
            min_y = min_y.min(ball.pos.y);
            assert!(ball.pos.is_finite());
        }
        assert!(min_y > 0.4, "big ball fell through: min_y = {min_y}");
    }

    #[test]
    fn pinned_boundary_stays() {
        let mut cloth = CapsuleCloth::new(CapsuleClothConfig::default(), Vec3::default());
        cloth.pin_boundary();
        let x0 = cloth.x[0];
        let mut ball =
            Ball { pos: Vec3::new(9.0, 9.0, 9.0), vel: Vec3::default(), radius: 0.1, mass: 1.0 };
        for _ in 0..200 {
            cloth.step(&mut ball);
        }
        assert!((cloth.x[0] - x0).norm() < 1e-12);
        // Interior sags under gravity.
        let mid = cloth.x[cloth.x.len() / 2];
        assert!(mid.y < -0.001);
    }

    #[test]
    fn hole_size_shrinks_with_resolution() {
        let sparse = CapsuleCloth::new(
            CapsuleClothConfig { nx: 6, nz: 6, ..Default::default() },
            Vec3::default(),
        );
        let dense = CapsuleCloth::new(
            CapsuleClothConfig { nx: 24, nz: 24, ..Default::default() },
            Vec3::default(),
        );
        assert!(dense.hole_size() < sparse.hole_size());
    }
}
