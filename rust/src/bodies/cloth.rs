//! Cloth: 3-DOF mesh nodes with stretching and bending internal forces
//! (paper §4; Narain et al. 2012-style elements simplified to a
//! mass-spring discretization — edge springs for stretch, opposite-vertex
//! springs across each interior edge for bending) and analytic force
//! Jacobians ∂f/∂q, ∂f/∂q̇ for the implicit Euler solve (Eq. 3).

use crate::math::sparse::Triplets;
use crate::math::Vec3;
use crate::mesh::topology::{build_topology, Topology};
use crate::mesh::TriMesh;

#[derive(Clone)]
pub struct Cloth {
    /// Node positions (world).
    pub x: Vec<Vec3>,
    /// Node velocities.
    pub v: Vec<Vec3>,
    pub faces: Vec<[u32; 3]>,
    pub topo: Topology,
    /// Rest length per topology edge (stretch springs).
    pub rest_len: Vec<f64>,
    /// Rest distance per bend pair (bending springs between opposite
    /// vertices of adjacent triangles).
    pub bend_rest: Vec<f64>,
    pub node_mass: Vec<f64>,
    pub k_stretch: f64,
    pub k_bend: f64,
    /// Mass-proportional drag coefficient (∂f/∂v = −damping·m·I).
    pub damping: f64,
    pub pinned: Vec<bool>,
    /// Per-node external force (control input), cleared each step.
    pub ext_force: Vec<Vec3>,
}

impl Cloth {
    /// Build from a triangle mesh with area density `rho` (kg/m²).
    pub fn from_grid(mesh: TriMesh, rho: f64, k_stretch: f64, k_bend: f64, damping: f64) -> Cloth {
        let topo = build_topology(&mesh);
        let n = mesh.verts.len();
        let mut node_mass = vec![0.0; n];
        for f in 0..mesh.faces.len() {
            let a = mesh.face_area(f) * rho / 3.0;
            for &vi in &mesh.faces[f] {
                node_mass[vi as usize] += a;
            }
        }
        let rest_len = topo
            .edges
            .iter()
            .map(|e| (mesh.verts[e.v[0] as usize] - mesh.verts[e.v[1] as usize]).norm())
            .collect();
        let bend_rest = topo
            .bend_pairs
            .iter()
            .map(|bp| (mesh.verts[bp.opp[0] as usize] - mesh.verts[bp.opp[1] as usize]).norm())
            .collect();
        Cloth {
            v: vec![Vec3::default(); n],
            ext_force: vec![Vec3::default(); n],
            pinned: vec![false; n],
            x: mesh.verts.clone(),
            faces: mesh.faces,
            topo,
            rest_len,
            bend_rest,
            node_mass,
            k_stretch,
            k_bend,
            damping,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.x.len()
    }

    pub fn pin(&mut self, node: usize) {
        self.pinned[node] = true;
    }

    /// Total force on every node: gravity + stretch + bend + drag + ext.
    pub fn forces(&self, gravity: Vec3) -> Vec<Vec3> {
        let mut f: Vec<Vec3> = (0..self.n_nodes())
            .map(|i| gravity * self.node_mass[i] + self.ext_force[i]
                - self.v[i] * (self.damping * self.node_mass[i]))
            .collect();
        self.accumulate_springs(&mut f);
        for i in 0..self.n_nodes() {
            if self.pinned[i] {
                f[i] = Vec3::default();
            }
        }
        f
    }

    fn accumulate_springs(&self, f: &mut [Vec3]) {
        for (e, &l0) in self.topo.edges.iter().zip(&self.rest_len) {
            spring_force(self.k_stretch, l0, e.v[0] as usize, e.v[1] as usize, &self.x, f);
        }
        for (bp, &l0) in self.topo.bend_pairs.iter().zip(&self.bend_rest) {
            spring_force(self.k_bend, l0, bp.opp[0] as usize, bp.opp[1] as usize, &self.x, f);
        }
    }

    /// Assemble ∂f/∂x into `dfdx` (3N×3N triplets at `offset`) and return
    /// the diagonal ∂f/∂v coefficient per node (drag). Pinned nodes get
    /// zero rows (their equations are replaced by identity upstream).
    ///
    /// With `spd_clamp` the compressed-spring lateral term is clamped at
    /// zero to keep the implicit-Euler system SPD (Choi & Ko 2002); the
    /// diff layer passes `false` for the exact Jacobian.
    pub fn force_jacobian(&self, dfdx: &mut Triplets, offset: usize, spd_clamp: bool) -> Vec<f64> {
        for (e, &l0) in self.topo.edges.iter().zip(&self.rest_len) {
            let (v0, v1) = (e.v[0] as usize, e.v[1] as usize);
            self.spring_jacobian(self.k_stretch, l0, v0, v1, dfdx, offset, spd_clamp);
        }
        for (bp, &l0) in self.topo.bend_pairs.iter().zip(&self.bend_rest) {
            let (o0, o1) = (bp.opp[0] as usize, bp.opp[1] as usize);
            self.spring_jacobian(self.k_bend, l0, o0, o1, dfdx, offset, spd_clamp);
        }
        (0..self.n_nodes())
            .map(|i| if self.pinned[i] { 0.0 } else { -self.damping * self.node_mass[i] })
            .collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn spring_jacobian(
        &self,
        k: f64,
        l0: f64,
        i: usize,
        j: usize,
        t: &mut Triplets,
        offset: usize,
        spd_clamp: bool,
    ) {
        let d = self.x[j] - self.x[i];
        let l = d.norm();
        if l < 1e-12 {
            return;
        }
        let dir = d / l;
        // J = k[(1−L0/l)(I − d̂d̂ᵀ) + d̂d̂ᵀ].
        let mut lateral = k * (1.0 - l0 / l);
        if spd_clamp {
            lateral = lateral.max(0.0);
        }
        let axial = k;
        let mut jm = [[0.0; 3]; 3];
        let o = dir.outer(dir);
        for r in 0..3 {
            for c in 0..3 {
                let id = if r == c { 1.0 } else { 0.0 };
                jm[r][c] = lateral * (id - o[r][c]) + axial * o[r][c];
            }
        }
        let (pi, pj) = (self.pinned[i], self.pinned[j]);
        let neg = |m: &[[f64; 3]; 3]| {
            let mut n = *m;
            for r in 0..3 {
                for c in 0..3 {
                    n[r][c] = -n[r][c];
                }
            }
            n
        };
        let (bi, bj) = (offset / 3 + i, offset / 3 + j);
        if !pi {
            t.push_block3(bi, bi, &neg(&jm));
            if !pj {
                t.push_block3(bi, bj, &jm);
            }
        }
        if !pj {
            t.push_block3(bj, bj, &neg(&jm));
            if !pi {
                t.push_block3(bj, bi, &jm);
            }
        }
    }

    /// Elastic potential energy (for energy-behaviour tests).
    pub fn elastic_energy(&self) -> f64 {
        let mut e = 0.0;
        for (ed, &l0) in self.topo.edges.iter().zip(&self.rest_len) {
            let l = (self.x[ed.v[1] as usize] - self.x[ed.v[0] as usize]).norm();
            e += 0.5 * self.k_stretch * (l - l0) * (l - l0);
        }
        for (bp, &l0) in self.topo.bend_pairs.iter().zip(&self.bend_rest) {
            let l = (self.x[bp.opp[1] as usize] - self.x[bp.opp[0] as usize]).norm();
            e += 0.5 * self.k_bend * (l - l0) * (l - l0);
        }
        e
    }

    pub fn clear_forces(&mut self) {
        for f in &mut self.ext_force {
            *f = Vec3::default();
        }
    }
}

fn spring_force(k: f64, l0: f64, i: usize, j: usize, x: &[Vec3], f: &mut [Vec3]) {
    let d = x[j] - x[i];
    let l = d.norm();
    if l < 1e-12 {
        return;
    }
    let fi = d * (k * (l - l0) / l);
    f[i] += fi;
    f[j] -= fi;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::primitives::cloth_grid;
    use crate::util::quick::quick;

    fn cloth() -> Cloth {
        Cloth::from_grid(cloth_grid(3, 3, 1.0, 1.0), 0.5, 200.0, 2.0, 0.0)
    }

    #[test]
    fn rest_state_has_no_internal_force() {
        let c = cloth();
        let f = c.forces(Vec3::default());
        for fi in f {
            assert!(fi.norm() < 1e-10, "{fi:?}");
        }
    }

    #[test]
    fn node_masses_sum_to_total() {
        let c = cloth();
        let total: f64 = c.node_mass.iter().sum();
        assert!((total - 0.5 * 1.0).abs() < 1e-9); // rho × area
    }

    #[test]
    fn stretched_edge_pulls_back() {
        let mut c = cloth();
        // Move node 0 outward along -x -z.
        c.x[0] += Vec3::new(-0.3, 0.0, -0.3);
        let f = c.forces(Vec3::default());
        // Force on node 0 points back toward the cloth (positive x,z).
        assert!(f[0].x > 0.0 && f[0].z > 0.0, "{:?}", f[0]);
    }

    #[test]
    fn momentum_conservation_of_internal_forces() {
        quick("cloth-momentum", 30, |g| {
            let mut c = cloth();
            for x in &mut c.x {
                *x += Vec3::new(g.f64(-0.1, 0.1), g.f64(-0.1, 0.1), g.f64(-0.1, 0.1));
            }
            let f = c.forces(Vec3::default());
            let total: Vec3 = f.iter().fold(Vec3::default(), |a, &b| a + b);
            assert!(total.norm() < 1e-8, "net internal force {total:?}");
        });
    }

    #[test]
    fn jacobian_matches_finite_differences() {
        quick("cloth-jacobian", 10, |g| {
            let mut c = cloth();
            for x in &mut c.x {
                *x += Vec3::new(g.f64(-0.05, 0.05), g.f64(-0.05, 0.05), g.f64(-0.05, 0.05));
            }
            let n = c.n_nodes();
            // Exact (unclamped) Jacobian vs central finite differences.
            let mut t = Triplets::new(3 * n, 3 * n);
            c.force_jacobian(&mut t, 0, false);
            let jac = t.to_csr().to_dense();
            let h = 1e-7;
            for _ in 0..5 {
                let col = g.usize(0, 3 * n - 1);
                let (node, comp) = (col / 3, col % 3);
                if c.pinned[node] {
                    continue;
                }
                let mut cp = c.clone();
                cp.x[node][comp] += h;
                let mut cm = c.clone();
                cm.x[node][comp] -= h;
                let fp = cp.forces(Vec3::default());
                let fm = cm.forces(Vec3::default());
                for row_node in 0..n {
                    for rc in 0..3 {
                        let fd = (fp[row_node][rc] - fm[row_node][rc]) / (2.0 * h);
                        let an = jac[(3 * row_node + rc, col)];
                        assert!(
                            (fd - an).abs() < 1e-4 * (1.0 + fd.abs()),
                            "row {} col {col}: fd={fd} analytic={an}",
                            3 * row_node + rc
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn pinned_nodes_have_zero_force_rows() {
        let mut c = cloth();
        c.pin(0);
        c.x[0] += Vec3::new(0.5, 0.5, 0.5);
        let f = c.forces(Vec3::new(0.0, -9.8, 0.0));
        assert_eq!(f[0], Vec3::default());
        let n = c.n_nodes();
        let mut t = Triplets::new(3 * n, 3 * n);
        c.force_jacobian(&mut t, 0, true);
        let jac = t.to_csr().to_dense();
        for col in 0..3 * n {
            for r in 0..3 {
                assert_eq!(jac[(r, col)], 0.0);
            }
        }
    }

    #[test]
    fn elastic_energy_zero_at_rest_positive_when_deformed() {
        let mut c = cloth();
        assert!(c.elastic_energy() < 1e-12);
        c.x[5] += Vec3::new(0.1, 0.2, 0.0);
        assert!(c.elastic_energy() > 1e-4);
    }
}
