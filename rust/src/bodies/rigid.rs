//! Rigid body with Euler-angle generalized coordinates (paper §4 +
//! Appendix A): `q = [φ, θ, ψ, t_x, t_y, t_z]`, generalized mass matrix
//! M̂ = diag(TᵀI′T, m·I₃), vertex map f(q) = R·p₀ + t.

use crate::math::dense::Mat;
use crate::math::{euler, Mat3, Vec3};
use crate::mesh::mass::mass_properties;
use crate::mesh::TriMesh;

#[derive(Clone, Debug)]
pub struct RigidBody {
    /// Mesh in the body frame: COM at origin, reference orientation.
    pub mesh0: TriMesh,
    /// Generalized coordinates [φ, θ, ψ, t_x, t_y, t_z].
    pub q: [f64; 6],
    /// Generalized velocities [φ̇, θ̇, ψ̇, ṫ_x, ṫ_y, ṫ_z].
    pub qdot: [f64; 6],
    pub mass: f64,
    /// Body-frame inertia about the COM at the reference orientation.
    pub inertia0: Mat3,
    /// Accumulated external force (world frame, this step).
    pub ext_force: Vec3,
    /// Accumulated external torque about the COM (world frame, this step).
    pub ext_torque: Vec3,
    /// Immovable (infinite mass): ground plane, walls, obstacles.
    pub frozen: bool,
}

impl RigidBody {
    /// Build from a closed mesh: computes mass properties and re-centers
    /// the mesh so the body-frame origin is the COM.
    pub fn from_mesh(mesh: TriMesh, density: f64) -> RigidBody {
        let props = mass_properties(&mesh, density);
        let mesh0 = mesh.translated(-props.com);
        RigidBody {
            mesh0,
            q: [0.0; 6],
            qdot: [0.0; 6],
            mass: props.mass,
            inertia0: props.inertia,
            ext_force: Vec3::default(),
            ext_torque: Vec3::default(),
            frozen: false,
        }
    }

    /// An immovable obstacle (infinite mass); mesh is used as-is in world
    /// coordinates relative to `q`'s translation.
    pub fn frozen_from_mesh(mesh: TriMesh) -> RigidBody {
        RigidBody {
            mesh0: mesh,
            q: [0.0; 6],
            qdot: [0.0; 6],
            mass: f64::INFINITY,
            inertia0: Mat3::identity(),
            ext_force: Vec3::default(),
            ext_torque: Vec3::default(),
            frozen: true,
        }
    }

    pub fn with_position(mut self, t: Vec3) -> RigidBody {
        self.q[3] = t.x;
        self.q[4] = t.y;
        self.q[5] = t.z;
        self
    }

    pub fn with_rotation(mut self, r: Vec3) -> RigidBody {
        self.q[0] = r.x;
        self.q[1] = r.y;
        self.q[2] = r.z;
        self
    }

    pub fn with_velocity(mut self, v: Vec3) -> RigidBody {
        self.qdot[3] = v.x;
        self.qdot[4] = v.y;
        self.qdot[5] = v.z;
        self
    }

    pub fn euler(&self) -> Vec3 {
        Vec3::new(self.q[0], self.q[1], self.q[2])
    }

    pub fn translation(&self) -> Vec3 {
        Vec3::new(self.q[3], self.q[4], self.q[5])
    }

    pub fn euler_rates(&self) -> Vec3 {
        Vec3::new(self.qdot[0], self.qdot[1], self.qdot[2])
    }

    pub fn linear_velocity(&self) -> Vec3 {
        Vec3::new(self.qdot[3], self.qdot[4], self.qdot[5])
    }

    pub fn rotation(&self) -> Mat3 {
        euler::rotation(self.euler())
    }

    /// World-frame angular velocity ω = T(r)·ṙ (Eq. 20).
    pub fn omega(&self) -> Vec3 {
        euler::omega_transform(self.euler()) * self.euler_rates()
    }

    /// World-frame inertia at the current orientation: I′ = R·I₀·Rᵀ.
    pub fn inertia_world(&self) -> Mat3 {
        euler::rotate_inertia(self.euler(), self.inertia0)
    }

    /// Generalized 6×6 mass matrix M̂ = diag(TᵀI′T, m·I₃) (Eq. 22).
    pub fn mass_matrix(&self) -> Mat {
        let ia = euler::angular_inertia(self.euler(), self.inertia_world());
        let mut m = Mat::zeros(6, 6);
        for i in 0..3 {
            for j in 0..3 {
                m[(i, j)] = ia.m[i][j];
            }
            m[(i + 3, i + 3)] = self.mass;
        }
        m
    }

    /// World position of body-frame vertex index `i`.
    pub fn world_vertex(&self, i: usize) -> Vec3 {
        euler::transform_point(&self.q, self.mesh0.verts[i])
    }

    /// All vertices in world coordinates.
    pub fn world_verts(&self) -> Vec<Vec3> {
        let r = self.rotation();
        let t = self.translation();
        self.mesh0.verts.iter().map(|&p| r * p + t).collect()
    }

    /// World velocity of vertex `i`: ẋ = ∇f(q)·q̇.
    pub fn vertex_velocity(&self, i: usize) -> Vec3 {
        let jac = euler::jacobian(&self.q, self.mesh0.verts[i]);
        let mut v = Vec3::default();
        for c in 0..6 {
            v.x += jac[0][c] * self.qdot[c];
            v.y += jac[1][c] * self.qdot[c];
            v.z += jac[2][c] * self.qdot[c];
        }
        v
    }

    /// ∇f at body-frame point p₀ (3×6 Jacobian, Appendix C).
    pub fn point_jacobian(&self, p0: Vec3) -> [[f64; 6]; 3] {
        euler::jacobian(&self.q, p0)
    }

    /// Kinetic energy ½ q̇ᵀ M̂ q̇ = ½ m|v|² + ½ ωᵀI′ω.
    pub fn kinetic_energy(&self) -> f64 {
        let v = self.linear_velocity();
        let w = self.omega();
        0.5 * self.mass * v.norm2() + 0.5 * w.dot(self.inertia_world() * w)
    }

    /// Generalized force vector from the accumulated world force/torque:
    /// Q = [Tᵀ·τ, f] (torque maps through ωᵀτ = ṙᵀTᵀτ).
    /// `angular_damping` adds τ −= c·I′·ω — a small default keeps
    /// frictionless resting stacks from accumulating spin creep.
    pub fn generalized_force_damped(&self, gravity: Vec3, angular_damping: f64) -> [f64; 6] {
        let t = euler::omega_transform(self.euler());
        // Gyroscopic torque -ω × (I′ω) treated explicitly.
        let w = self.omega();
        let tau_world = self.ext_torque
            - w.cross(self.inertia_world() * w)
            - (self.inertia_world() * w) * angular_damping;
        let tau_gen = t.transpose() * tau_world;
        let f = self.ext_force + gravity * self.mass;
        [tau_gen.x, tau_gen.y, tau_gen.z, f.x, f.y, f.z]
    }

    /// `generalized_force_damped` with zero damping.
    pub fn generalized_force(&self, gravity: Vec3) -> [f64; 6] {
        self.generalized_force_damped(gravity, 0.0)
    }

    pub fn clear_forces(&mut self) {
        self.ext_force = Vec3::default();
        self.ext_torque = Vec3::default();
    }

    /// Near gimbal lock (|θ| → π/2) the Euler parameterization degenerates
    /// (T loses rank). The stepper re-parameterizes when this is detected.
    pub fn near_gimbal_lock(&self) -> bool {
        (self.q[1].abs() - std::f64::consts::FRAC_PI_2).abs() < 0.1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::primitives::unit_box;
    use crate::util::quick::quick;

    fn body() -> RigidBody {
        RigidBody::from_mesh(unit_box(), 2.0)
    }

    #[test]
    fn from_mesh_centers_com() {
        let shifted = unit_box().translated(Vec3::new(3.0, -1.0, 2.0));
        let b = RigidBody::from_mesh(shifted, 1.0);
        let props = mass_properties(&b.mesh0, 1.0);
        assert!(props.com.norm() < 1e-9);
        assert!((b.mass - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mass_matrix_at_identity_is_block_diag() {
        let b = body();
        let m = b.mass_matrix();
        // Unit cube, density 2: mass 2, I = m(1+1)/12 = 1/3.
        for i in 0..3 {
            assert!((m[(i, i)] - 2.0 / 6.0).abs() < 1e-9);
            assert!((m[(i + 3, i + 3)] - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn kinetic_energy_quadratic_form_consistency() {
        quick("rigid-ke", 50, |g| {
            let mut b = body();
            b.q = [g.f64(-1.0, 1.0), g.f64(-0.9, 0.9), g.f64(-1.0, 1.0), 0.0, 0.0, 0.0];
            for k in 0..6 {
                b.qdot[k] = g.f64(-2.0, 2.0);
            }
            let m = b.mass_matrix();
            let qd = b.qdot.to_vec();
            let e_quad = 0.5 * crate::math::dense::dot(&qd, &m.matvec(&qd));
            assert!(
                (e_quad - b.kinetic_energy()).abs() < 1e-9 * (1.0 + e_quad.abs()),
                "quad={} direct={}",
                e_quad,
                b.kinetic_energy()
            );
        });
    }

    #[test]
    fn vertex_velocity_matches_finite_difference() {
        quick("rigid-vertvel", 50, |g| {
            let mut b = body();
            b.q = [
                g.f64(-1.0, 1.0),
                g.f64(-0.9, 0.9),
                g.f64(-1.0, 1.0),
                g.f64(-1.0, 1.0),
                g.f64(-1.0, 1.0),
                g.f64(-1.0, 1.0),
            ];
            for k in 0..6 {
                b.qdot[k] = g.f64(-1.0, 1.0);
            }
            let i = g.usize(0, 7);
            let v = b.vertex_velocity(i);
            let h = 1e-6;
            let mut bf = b.clone();
            for k in 0..6 {
                bf.q[k] = b.q[k] + h * b.qdot[k];
            }
            let fd = (bf.world_vertex(i) - b.world_vertex(i)) / h;
            assert!((v - fd).norm() < 1e-4, "v={v:?} fd={fd:?}");
        });
    }

    #[test]
    fn frozen_body_properties() {
        let g = RigidBody::frozen_from_mesh(unit_box());
        assert!(g.frozen);
        assert!(g.mass.is_infinite());
    }

    #[test]
    fn generalized_force_gravity_only_affects_translation_at_rest() {
        let b = body();
        let f = b.generalized_force(Vec3::new(0.0, -9.8, 0.0));
        assert_eq!(&f[0..3], &[0.0, 0.0, 0.0]);
        assert!((f[4] - (-9.8 * 2.0)).abs() < 1e-12);
    }
}
