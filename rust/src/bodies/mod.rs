//! Dynamic bodies (§4 of the paper): rigid bodies ([`RigidBody`]) with
//! 6-DOF generalized coordinates `q = [r, t]` and cloth ([`Cloth`]) with
//! 3-DOF nodes, plus the [`System`] container that packs all coordinates
//! into one state vector `q = [q₁ᵀ, …, qₙᵀ]ᵀ`. [`NodeRef`] names one
//! surface node — the unit the collision layer
//! ([`crate::collision`]) works in.
pub mod cloth;
pub mod rigid;

pub use cloth::Cloth;
pub use rigid::RigidBody;

use crate::math::Vec3;

/// Identifies one surface node in the system: either a vertex of a rigid
/// body's mesh or a cloth node. This is the unit of collision handling.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NodeRef {
    Rigid { body: u32, vert: u32 },
    Cloth { cloth: u32, node: u32 },
}

/// The whole simulated system. Rigid body `i` owns global DOFs
/// `[6i, 6i+6)`; cloth `c`'s node `j` owns `[rigid_dofs + off_c + 3j,
/// … + 3)`.
#[derive(Clone, Default)]
pub struct System {
    pub rigids: Vec<RigidBody>,
    pub cloths: Vec<Cloth>,
}

impl System {
    pub fn new() -> System {
        System::default()
    }

    pub fn add_rigid(&mut self, b: RigidBody) -> usize {
        self.rigids.push(b);
        self.rigids.len() - 1
    }

    pub fn add_cloth(&mut self, c: Cloth) -> usize {
        self.cloths.push(c);
        self.cloths.len() - 1
    }

    pub fn rigid_dofs(&self) -> usize {
        6 * self.rigids.len()
    }

    pub fn cloth_dof_offset(&self, cloth: usize) -> usize {
        let mut off = self.rigid_dofs();
        for c in 0..cloth {
            off += 3 * self.cloths[c].x.len();
        }
        off
    }

    pub fn total_dofs(&self) -> usize {
        self.rigid_dofs() + self.cloths.iter().map(|c| 3 * c.x.len()).sum::<usize>()
    }

    /// World position of a surface node.
    pub fn node_pos(&self, n: NodeRef) -> Vec3 {
        match n {
            NodeRef::Rigid { body, vert } => self.rigids[body as usize].world_vertex(vert as usize),
            NodeRef::Cloth { cloth, node } => self.cloths[cloth as usize].x[node as usize],
        }
    }

    /// World velocity of a surface node.
    pub fn node_vel(&self, n: NodeRef) -> Vec3 {
        match n {
            NodeRef::Rigid { body, vert } => {
                self.rigids[body as usize].vertex_velocity(vert as usize)
            }
            NodeRef::Cloth { cloth, node } => self.cloths[cloth as usize].v[node as usize],
        }
    }

    /// Is the node attached to an immovable entity (frozen body / pinned
    /// cloth node)?
    pub fn node_fixed(&self, n: NodeRef) -> bool {
        match n {
            NodeRef::Rigid { body, .. } => self.rigids[body as usize].frozen,
            NodeRef::Cloth { cloth, node } => self.cloths[cloth as usize].pinned[node as usize],
        }
    }

    /// Gather the full generalized state (positions) into a flat vector.
    pub fn gather_q(&self) -> Vec<f64> {
        let mut q = Vec::with_capacity(self.total_dofs());
        for b in &self.rigids {
            q.extend_from_slice(&b.q);
        }
        for c in &self.cloths {
            for x in &c.x {
                q.extend_from_slice(&x.to_array());
            }
        }
        q
    }

    /// Gather velocities.
    pub fn gather_qdot(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.total_dofs());
        for b in &self.rigids {
            v.extend_from_slice(&b.qdot);
        }
        for c in &self.cloths {
            for vv in &c.v {
                v.extend_from_slice(&vv.to_array());
            }
        }
        v
    }

    /// Scatter a flat state vector back into the bodies.
    pub fn scatter_q(&mut self, q: &[f64]) {
        assert_eq!(q.len(), self.total_dofs());
        let mut k = 0;
        for b in &mut self.rigids {
            b.q.copy_from_slice(&q[k..k + 6]);
            k += 6;
        }
        for c in &mut self.cloths {
            for x in &mut c.x {
                *x = Vec3::new(q[k], q[k + 1], q[k + 2]);
                k += 3;
            }
        }
    }

    pub fn scatter_qdot(&mut self, v: &[f64]) {
        assert_eq!(v.len(), self.total_dofs());
        let mut k = 0;
        for b in &mut self.rigids {
            b.qdot.copy_from_slice(&v[k..k + 6]);
            k += 6;
        }
        for c in &mut self.cloths {
            for vv in &mut c.v {
                *vv = Vec3::new(v[k], v[k + 1], v[k + 2]);
                k += 3;
            }
        }
    }

    /// Total linear momentum (world frame).
    pub fn linear_momentum(&self) -> Vec3 {
        let mut p = Vec3::default();
        for b in &self.rigids {
            if !b.frozen {
                p += b.linear_velocity() * b.mass;
            }
        }
        for c in &self.cloths {
            for (v, m) in c.v.iter().zip(&c.node_mass) {
                p += *v * *m;
            }
        }
        p
    }

    /// Total kinetic energy.
    pub fn kinetic_energy(&self) -> f64 {
        let mut e = 0.0;
        for b in &self.rigids {
            if !b.frozen {
                e += b.kinetic_energy();
            }
        }
        for c in &self.cloths {
            for (v, m) in c.v.iter().zip(&c.node_mass) {
                e += 0.5 * m * v.norm2();
            }
        }
        e
    }

    /// Logical bytes held by the state (for the Fig. 3 memory series).
    pub fn state_bytes(&self) -> usize {
        let mut b = 0;
        for r in &self.rigids {
            b += 8 * 12 + 24 * r.mesh0.verts.len() + 12 * r.mesh0.faces.len();
        }
        for c in &self.cloths {
            b += 48 * c.x.len() + 12 * c.faces.len();
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::primitives::{cloth_grid, unit_box};

    fn sample_system() -> System {
        let mut sys = System::new();
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 2.0));
        sys.add_cloth(Cloth::from_grid(cloth_grid(2, 2, 1.0, 1.0), 0.1, 100.0, 1.0, 0.1));
        sys
    }

    #[test]
    fn dof_bookkeeping() {
        let sys = sample_system();
        assert_eq!(sys.rigid_dofs(), 12);
        assert_eq!(sys.total_dofs(), 12 + 3 * 9);
        assert_eq!(sys.cloth_dof_offset(0), 12);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut sys = sample_system();
        sys.rigids[0].q = [0.1, 0.2, 0.3, 1.0, 2.0, 3.0];
        sys.cloths[0].x[4] = Vec3::new(9.0, 8.0, 7.0);
        let q = sys.gather_q();
        let v = sys.gather_qdot();
        let mut sys2 = sample_system();
        sys2.scatter_q(&q);
        sys2.scatter_qdot(&v);
        assert_eq!(sys2.gather_q(), q);
        assert_eq!(sys2.gather_qdot(), v);
        assert_eq!(sys2.rigids[0].q, sys.rigids[0].q);
        assert!((sys2.cloths[0].x[4] - sys.cloths[0].x[4]).norm() < 1e-15);
    }

    #[test]
    fn node_refs_resolve() {
        let mut sys = sample_system();
        sys.rigids[1].q[3] = 5.0;
        let n = NodeRef::Rigid { body: 1, vert: 0 };
        assert!((sys.node_pos(n).x - (5.0 - 0.5)).abs() < 1e-12);
        let c = NodeRef::Cloth { cloth: 0, node: 0 };
        assert!((sys.node_pos(c) - sys.cloths[0].x[0]).norm() < 1e-15);
    }

    #[test]
    fn momentum_sums_bodies() {
        let mut sys = sample_system();
        sys.rigids[0].qdot[3] = 1.0; // vx = 1, mass 1
        sys.rigids[1].qdot[4] = 2.0; // vy = 2, mass 2·vol
        let p = sys.linear_momentum();
        assert!((p.x - 1.0).abs() < 1e-12);
        assert!((p.y - 2.0 * sys.rigids[1].mass).abs() < 1e-12);
    }
}
