//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → HLO text + manifest.json), compiles them on
//! the PJRT CPU client once, and exposes a typed call interface. This is
//! the only place the `xla` crate is touched; Python is never on the
//! request path.
//!
//! The `xla` dependency sits behind the `pjrt` cargo feature (off by
//! default) so the default build works fully offline. Without the
//! feature, [`Runtime::load`] returns a clear error and the engine's
//! `DiffMode::Pjrt` degrades to the native QR backward (with a logged
//! warning) because no coordinator can be constructed.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Shape/dtype contract of one artifact (from manifest.json).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Zone-backward bucket exported by aot.py: (n dofs, m constraints, batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ZoneBucket {
    pub n: usize,
    pub m: usize,
    pub batch: usize,
}

/// The xla-owned state, isolated in its own type so the thread-safety
/// assertion below covers exactly the PJRT objects and nothing that may
/// be added to `Runtime` later.
#[cfg(feature = "pjrt")]
struct PjrtState {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT C API client and loaded executables are documented
// thread-safe; the `xla` wrappers just hold raw pointers and may not
// carry the auto traits. `Runtime` is shared behind `Arc` across the
// worker threads, and the executable cache has its own `Mutex`.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtState {}
// SAFETY: see `Send` above — shared references only expose the client
// and `&PjRtLoadedExecutable`, whose concurrent use the C API permits.
#[cfg(feature = "pjrt")]
unsafe impl Sync for PjrtState {}

/// The compiled-executable store.
#[allow(dead_code)]
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    pjrt: PjrtState,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    pub rigid_batches: Vec<usize>,
    pub zone_buckets: Vec<ZoneBucket>,
    /// Buckets exported for the *forward* zone solve
    /// (`zone_solve_n{n}_m{m}_b{b}` artifacts). Manifests that predate
    /// the forward path simply reuse `zone_buckets`; the coordinator
    /// still checks per-artifact presence before dispatching.
    pub zone_solve_buckets: Vec<ZoneBucket>,
    pub cloth_grids: Vec<(usize, usize)>,
    /// Executed-call counter per artifact (coordinator metrics).
    pub calls: Mutex<HashMap<String, usize>>,
}

/// Parse a `[[n, m, batch], ...]` bucket table from a manifest key.
/// Malformed entries (short arrays, non-integers) are skipped, not
/// panicked on — hand-edited manifests must fail soft.
fn parse_buckets(j: &Json, key: &str) -> Option<Vec<ZoneBucket>> {
    j.get(key).and_then(Json::as_arr).map(|v| {
        v.iter()
            .filter_map(|b| {
                let b = b.as_arr()?;
                Some(ZoneBucket {
                    n: b.first()?.as_usize()?,
                    m: b.get(1)?.as_usize()?,
                    batch: b.get(2)?.as_usize()?,
                })
            })
            .collect()
    })
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client. Compilation is
    /// lazy (first call per artifact) and cached. Without the `pjrt`
    /// feature this fails with an actionable error after validating the
    /// manifest (so a missing-artifacts message stays identical across
    /// builds).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} (run `make artifacts`)", manifest_path.display())
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let mut specs = HashMap::new();
        for a in j.get("artifacts").and_then(Json::as_arr).context("manifest: artifacts[]")? {
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .map(|io| {
                                io.get("shape")
                                    .and_then(Json::as_arr)
                                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let spec = ArtifactSpec {
                name: a.str_or("name", "").to_string(),
                path: a.str_or("path", "").to_string(),
                inputs: shapes("inputs"),
                outputs: shapes("outputs"),
            };
            specs.insert(spec.name.clone(), spec);
        }
        let rigid_batches = j
            .get("rigid_batches")
            .and_then(Json::as_arr)
            .map(|v| v.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        let zone_buckets = parse_buckets(&j, "zone_buckets").unwrap_or_default();
        let zone_solve_buckets =
            parse_buckets(&j, "zone_solve_buckets").unwrap_or_else(|| zone_buckets.clone());
        let cloth_grids = j
            .get("cloth_grids")
            .and_then(Json::as_arr)
            .map(|v| {
                v.iter()
                    .filter_map(|g| {
                        let g = g.as_arr()?;
                        Some((g[0].as_usize()?, g[1].as_usize()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        Runtime::finish_load(
            dir,
            specs,
            rigid_batches,
            zone_buckets,
            zone_solve_buckets,
            cloth_grids,
        )
    }

    #[cfg(feature = "pjrt")]
    fn finish_load(
        dir: &Path,
        specs: HashMap<String, ArtifactSpec>,
        rigid_batches: Vec<usize>,
        zone_buckets: Vec<ZoneBucket>,
        zone_solve_buckets: Vec<ZoneBucket>,
        cloth_grids: Vec<(usize, usize)>,
    ) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            pjrt: PjrtState { client, cache: Mutex::new(HashMap::new()) },
            dir: dir.to_path_buf(),
            specs,
            rigid_batches,
            zone_buckets,
            zone_solve_buckets,
            cloth_grids,
            calls: Mutex::new(HashMap::new()),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn finish_load(
        dir: &Path,
        _specs: HashMap<String, ArtifactSpec>,
        _rigid_batches: Vec<usize>,
        _zone_buckets: Vec<ZoneBucket>,
        _zone_solve_buckets: Vec<ZoneBucket>,
        _cloth_grids: Vec<(usize, usize)>,
    ) -> Result<Runtime> {
        bail!(
            "artifacts found at {} but this build has no PJRT runtime; \
             rebuild with `cargo build --features pjrt`",
            dir.display()
        )
    }

    /// An artifact-less runtime: no executables, no buckets, no manifest
    /// directory. Every coordinator call that consults it takes the
    /// native fallback path, so the coordinator's batching, fallback,
    /// and metrics logic can be exercised offline (tests, artifact-less
    /// deployments).
    #[cfg(not(feature = "pjrt"))]
    pub fn empty() -> Runtime {
        Runtime {
            dir: PathBuf::new(),
            specs: HashMap::new(),
            rigid_batches: Vec::new(),
            zone_buckets: Vec::new(),
            zone_solve_buckets: Vec::new(),
            cloth_grids: Vec::new(),
            calls: Mutex::new(HashMap::new()),
        }
    }

    /// An artifact-less runtime (see the non-`pjrt` variant). Still
    /// constructs the PJRT CPU client — in a `pjrt` build the client is
    /// assumed creatable (panics otherwise; this constructor is for
    /// tests/diagnostics, not the serving path).
    #[cfg(feature = "pjrt")]
    pub fn empty() -> Runtime {
        let client = xla::PjRtClient::cpu().expect("pjrt cpu client");
        Runtime {
            pjrt: PjrtState { client, cache: Mutex::new(HashMap::new()) },
            dir: PathBuf::new(),
            specs: HashMap::new(),
            rigid_batches: Vec::new(),
            zone_buckets: Vec::new(),
            zone_solve_buckets: Vec::new(),
            cloth_grids: Vec::new(),
            calls: Mutex::new(HashMap::new()),
        }
    }

    /// Load from the conventional `artifacts/` directory.
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(Path::new("artifacts"))
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn has(&self, name: &str) -> bool {
        self.specs.contains_key(name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    #[cfg(feature = "pjrt")]
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.pjrt.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.specs.get(name).with_context(|| format!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .pjrt
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.pjrt.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warmup).
    #[cfg(feature = "pjrt")]
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Pre-compile an artifact (warmup). Stub: the runtime cannot be
    /// constructed without the `pjrt` feature, so this is unreachable in
    /// practice but keeps the API uniform.
    #[cfg(not(feature = "pjrt"))]
    pub fn warmup(&self, name: &str) -> Result<()> {
        bail!("artifact '{name}': PJRT runtime disabled (build with `--features pjrt`)")
    }

    /// Execute artifact `name` with f32 inputs shaped per the manifest.
    /// Returns the flattened outputs in manifest order.
    #[cfg(feature = "pjrt")]
    pub fn call_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let spec = self.specs.get(name).with_context(|| format!("unknown artifact '{name}'"))?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (&data, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("{name}: input {k} has {} elements, want {want} {shape:?}", data.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("{name}: reshape input {k}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{name}: execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetch: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = out.to_tuple().map_err(|e| anyhow!("{name}: tuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for (k, p) in parts.into_iter().enumerate() {
            vecs.push(
                p.to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: output {k} to_vec: {e:?}"))?,
            );
        }
        *self.calls.lock().unwrap().entry(name.to_string()).or_insert(0) += 1;
        Ok(vecs)
    }

    /// Stub `call_f32`: always an error (see `warmup`).
    #[cfg(not(feature = "pjrt"))]
    pub fn call_f32(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!("artifact '{name}': PJRT runtime disabled (build with `--features pjrt`)")
    }

    /// Total PJRT calls made (metrics).
    pub fn total_calls(&self) -> usize {
        self.calls.lock().unwrap().values().sum()
    }
}

#[cfg(test)]
mod tests {
    // Tests needing real artifacts live in rust/tests/integration_runtime.rs
    // (they require `make artifacts` to have run).
    use super::*;

    #[test]
    fn missing_manifest_is_clean_error() {
        match Runtime::load(Path::new("/nonexistent/dir")) {
            Ok(_) => panic!("should fail"),
            Err(err) => assert!(format!("{err:#}").contains("make artifacts")),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_build_reports_disabled_runtime() {
        // With a readable manifest the stub must point at the feature flag.
        let dir = std::env::temp_dir().join("diffsim_stub_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
        match Runtime::load(&dir) {
            Ok(_) => panic!("stub build must not construct a runtime"),
            Err(err) => {
                let msg = format!("{err:#}");
                assert!(msg.contains("pjrt"), "unexpected error: {msg}");
            }
        }
    }
}
