//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → HLO text + manifest.json), compiles them on
//! the PJRT CPU client once, and exposes a typed call interface. This is
//! the only place the `xla` crate is touched; Python is never on the
//! request path.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Shape/dtype contract of one artifact (from manifest.json).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Zone-backward bucket exported by aot.py: (n dofs, m constraints, batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ZoneBucket {
    pub n: usize,
    pub m: usize,
    pub batch: usize,
}

/// The compiled-executable store.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    pub rigid_batches: Vec<usize>,
    pub zone_buckets: Vec<ZoneBucket>,
    pub cloth_grids: Vec<(usize, usize)>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Executed-call counter per artifact (coordinator metrics).
    pub calls: Mutex<HashMap<String, usize>>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client. Compilation is
    /// lazy (first call per artifact) and cached.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let mut specs = HashMap::new();
        for a in j.get("artifacts").and_then(Json::as_arr).context("manifest: artifacts[]")? {
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .map(|io| {
                                io.get("shape")
                                    .and_then(Json::as_arr)
                                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let spec = ArtifactSpec {
                name: a.str_or("name", "").to_string(),
                path: a.str_or("path", "").to_string(),
                inputs: shapes("inputs"),
                outputs: shapes("outputs"),
            };
            specs.insert(spec.name.clone(), spec);
        }
        let rigid_batches = j
            .get("rigid_batches")
            .and_then(Json::as_arr)
            .map(|v| v.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        let zone_buckets = j
            .get("zone_buckets")
            .and_then(Json::as_arr)
            .map(|v| {
                v.iter()
                    .filter_map(|b| {
                        let b = b.as_arr()?;
                        Some(ZoneBucket {
                            n: b[0].as_usize()?,
                            m: b[1].as_usize()?,
                            batch: b[2].as_usize()?,
                        })
                    })
                    .collect()
            })
            .unwrap_or_default();
        let cloth_grids = j
            .get("cloth_grids")
            .and_then(Json::as_arr)
            .map(|v| {
                v.iter()
                    .filter_map(|g| {
                        let g = g.as_arr()?;
                        Some((g[0].as_usize()?, g[1].as_usize()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            specs,
            rigid_batches,
            zone_buckets,
            cloth_grids,
            cache: Mutex::new(HashMap::new()),
            calls: Mutex::new(HashMap::new()),
        })
    }

    /// Load from the conventional `artifacts/` directory.
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(Path::new("artifacts"))
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn has(&self, name: &str) -> bool {
        self.specs.contains_key(name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.specs.get(name).with_context(|| format!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warmup).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute artifact `name` with f32 inputs shaped per the manifest.
    /// Returns the flattened outputs in manifest order.
    pub fn call_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let spec = self.specs.get(name).with_context(|| format!("unknown artifact '{name}'"))?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (&data, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("{name}: input {k} has {} elements, want {want} {shape:?}", data.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("{name}: reshape input {k}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{name}: execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetch: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = out.to_tuple().map_err(|e| anyhow!("{name}: tuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for (k, p) in parts.into_iter().enumerate() {
            vecs.push(
                p.to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: output {k} to_vec: {e:?}"))?,
            );
        }
        *self.calls.lock().unwrap().entry(name.to_string()).or_insert(0) += 1;
        Ok(vecs)
    }

    /// Total PJRT calls made (metrics).
    pub fn total_calls(&self) -> usize {
        self.calls.lock().unwrap().values().sum()
    }
}

#[cfg(test)]
mod tests {
    // Tests needing real artifacts live in rust/tests/integration_runtime.rs
    // (they require `make artifacts` to have run).
    use super::*;

    #[test]
    fn missing_manifest_is_clean_error() {
        match Runtime::load(Path::new("/nonexistent/dir")) {
            Ok(_) => panic!("should fail"),
            Err(err) => assert!(format!("{err:#}").contains("make artifacts")),
        }
    }
}
