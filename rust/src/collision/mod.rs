//! Scalable collision handling (paper §5): BVH broadphase ([`bvh`]) over
//! swept face bounds ([`aabb`]), continuous + proximity narrowphase
//! ([`ccd`]) producing [`Impact`]s (Eq. 4), grouped into independent
//! impact zones ([`zones`]).
//!
//! The detection pass's candidate/contact lists (broadphase face pairs,
//! raw and deduplicated impacts) dominate its transient memory. They can
//! be checked out from a cross-scene
//! [`BatchArena`](crate::util::arena::BatchArena) via [`detect_in`] so a
//! batch reuses one warm set of buffers instead of allocating per scene
//! per step; [`detect`] is the plain-allocation wrapper. Both produce
//! bitwise-identical impacts in identical order — pooling only changes
//! which allocation backs a list, never its contents.
pub mod aabb;
pub mod bvh;
pub mod ccd;
pub mod zones;

use crate::bodies::{NodeRef, System};
use crate::math::Vec3;
use crate::util::arena::{ArenaVec, BatchArena};
use crate::util::memory::MemCategory;
use aabb::Aabb;
use bvh::Bvh;
// lint:allow(hash-iter: membership-only HashSets (narrowphase dedup) — never iterated)
use std::collections::HashSet;

/// Which body a surface belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BodyId {
    Rigid(u32),
    Cloth(u32),
}

/// An impact: one VF or EE contact pair (paper Eq. 4), normalized to the
/// constraint form C(x) = n · Σᵢ wᵢ·xᵢ − δ ≥ 0 over four surface nodes.
#[derive(Clone, Copy, Debug)]
pub struct Impact {
    pub nodes: [NodeRef; 4],
    /// Signed weights: VF ⇒ [−α₁, −α₂, −α₃, 1]; EE ⇒ [−α₁, −α₂, α₃, α₄].
    pub w: [f64; 4],
    pub n: Vec3,
    /// Collision time within the step ([0,1]; 1 for proximity contacts).
    pub t: f64,
}

impl Impact {
    /// Evaluate C(x) + δ = n·Σwᵢxᵢ given node positions.
    pub fn gap(&self, pos: impl Fn(NodeRef) -> Vec3) -> f64 {
        let mut s = 0.0;
        for k in 0..4 {
            s += self.w[k] * self.n.dot(pos(self.nodes[k]));
        }
        s
    }
}

/// Per-body surface snapshot used by the collision pass: start-of-step
/// and candidate end-of-step world positions. Surfaces live in a
/// [`CollisionState`] that persists across steps: each step refits the
/// BVH in place ([`Surface::update_candidates`]) instead of rebuilding.
pub struct Surface {
    pub body: BodyId,
    pub faces: Vec<[u32; 3]>,
    pub edges: Vec<[u32; 2]>,
    pub x0: Vec<Vec3>,
    pub x1: Vec<Vec3>,
    pub fixed: bool,
    pub bvh: Bvh,
    aabbs: Vec<Aabb>,
    /// Edges per face (indices into `edges`) for EE dedup.
    face_edges: Vec<[u32; 3]>,
    /// Padded per-face AABB snapshot backing the cross-step cull cache.
    /// Candidate lists built against these bounds stay valid (as
    /// supersets) while every current AABB remains inside its snapshot.
    cull_bounds: Vec<Aabb>,
    /// Bumped whenever the snapshot is retaken; cached candidate lists
    /// are keyed by the epochs of both surfaces they were built from.
    epoch: u64,
    /// True iff the snapshot was retaken during the current validation
    /// round, i.e. `cull_bounds[f] == aabbs[f].inflated(pad)` right now —
    /// the only moment a padded BVH query equals a snapshot-bound query.
    fresh: bool,
}

impl Surface {
    pub fn new(
        body: BodyId,
        faces: Vec<[u32; 3]>,
        x0: Vec<Vec3>,
        x1: Vec<Vec3>,
        fixed: bool,
        thickness: f64,
    ) -> Surface {
        // Unique edges + face→edge map. The map is lookup-only: edge
        // ids and the `edges` list are assigned in face-scan order, so
        // hash order never reaches any output.
        // lint:allow(hash-iter: entry-lookup only, outputs are scan-ordered)
        let mut edge_map = std::collections::HashMap::new();
        let mut edges: Vec<[u32; 2]> = Vec::new();
        let mut face_edges = Vec::with_capacity(faces.len());
        for f in &faces {
            let mut fe = [0u32; 3];
            for k in 0..3 {
                let (a, b) = (f[k], f[(k + 1) % 3]);
                let key = if a < b { (a, b) } else { (b, a) };
                let id = *edge_map.entry(key).or_insert_with(|| {
                    edges.push([key.0, key.1]);
                    edges.len() - 1
                });
                fe[k] = id as u32;
            }
            face_edges.push(fe);
        }
        let aabbs: Vec<Aabb> = faces
            .iter()
            .map(|f| {
                Aabb::swept_tri(
                    x0[f[0] as usize],
                    x0[f[1] as usize],
                    x0[f[2] as usize],
                    x1[f[0] as usize],
                    x1[f[1] as usize],
                    x1[f[2] as usize],
                    thickness,
                )
            })
            .collect();
        let bvh = Bvh::build(&aabbs);
        Surface {
            body,
            faces,
            edges,
            x0,
            x1,
            fixed,
            bvh,
            aabbs,
            face_edges,
            cull_bounds: Vec::new(),
            epoch: 0,
            fresh: false,
        }
    }

    fn node_ref(&self, local: u32) -> NodeRef {
        match self.body {
            BodyId::Rigid(b) => NodeRef::Rigid { body: b, vert: local },
            BodyId::Cloth(c) => NodeRef::Cloth { cloth: c, node: local },
        }
    }

    pub fn root_aabb(&self) -> Aabb {
        self.bvh.root_aabb()
    }

    /// Update the candidate end-of-step positions (copied in place into
    /// the retained buffer — no per-pass allocation) and refit the BVH
    /// (topology unchanged) — O(n) instead of a fresh build. The
    /// per-step hot path: fail-safe passes re-detect after zone solves,
    /// and with the persistent cache every step after the first lands
    /// here instead of in [`Surface::new`].
    pub fn update_candidates(&mut self, x1: &[Vec3], thickness: f64) {
        assert_eq!(x1.len(), self.x1.len());
        self.x1.copy_from_slice(x1);
        for (f, bb) in self.faces.iter().zip(self.aabbs.iter_mut()) {
            *bb = Aabb::swept_tri(
                self.x0[f[0] as usize],
                self.x0[f[1] as usize],
                self.x0[f[2] as usize],
                self.x1[f[0] as usize],
                self.x1[f[1] as usize],
                self.x1[f[2] as usize],
                thickness,
            );
        }
        self.bvh.refit(&self.aabbs);
    }

    /// Rebuild the BVH in place (reusing its buffers) once refit
    /// inflation has degraded the tree past `ratio`; returns whether a
    /// rebuild happened. Tree shape never reaches the impact stream —
    /// candidate lists are sorted before the narrow phase — so rebuilds
    /// are bitwise-invisible and safe mid-flight.
    pub fn rebuild_if_degraded(&mut self, ratio: f64) -> bool {
        if self.bvh.quality() > ratio {
            self.bvh.rebuild(&self.aabbs);
            true
        } else {
            false
        }
    }

    /// Validate the cull snapshot against the current AABBs: if any face
    /// escaped its padded bound (or no snapshot exists yet), retake the
    /// snapshot and bump the epoch, invalidating cached candidate lists
    /// that involve this surface.
    fn validate_cull(&mut self, pad: f64) {
        let ok = self.cull_bounds.len() == self.aabbs.len()
            && self.aabbs.iter().zip(self.cull_bounds.iter()).all(|(bb, cb)| cb.contains(bb));
        if ok {
            self.fresh = false;
        } else {
            self.resnapshot(pad);
        }
    }

    /// Retake the padded snapshot from the current AABBs and bump the
    /// epoch. Always sound (every AABB is trivially inside its own
    /// inflation); marks the surface `fresh` for this validation round.
    fn resnapshot(&mut self, pad: f64) {
        self.cull_bounds.clear();
        self.cull_bounds.extend(self.aabbs.iter().map(|bb| bb.inflated(pad)));
        self.epoch += 1;
        self.fresh = true;
    }
}

/// Build surfaces from the system: `x1` come from candidate positions
/// provided per body (same layout as the body's vertices).
pub fn surfaces_from_system(
    sys: &System,
    rigid_x1: &[Vec<Vec3>],
    cloth_x1: &[Vec<Vec3>],
    thickness: f64,
) -> Vec<Surface> {
    let mut out = Vec::with_capacity(sys.rigids.len() + sys.cloths.len());
    for (i, b) in sys.rigids.iter().enumerate() {
        out.push(Surface::new(
            BodyId::Rigid(i as u32),
            b.mesh0.faces.clone(),
            b.world_verts(),
            rigid_x1[i].clone(),
            b.frozen,
            thickness,
        ));
    }
    for (c, cl) in sys.cloths.iter().enumerate() {
        out.push(Surface::new(
            BodyId::Cloth(c as u32),
            cl.faces.clone(),
            cl.x.clone(),
            cloth_x1[c].clone(),
            false,
            thickness,
        ));
    }
    out
}

/// Statistics from one detection pass (coordinator metrics / Fig. 2).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DetectStats {
    pub body_pairs: usize,
    pub face_pairs: usize,
    pub vf_tests: usize,
    pub ee_tests: usize,
    pub impacts: usize,
}

/// Full collision detection across all surfaces. Returns every VF and EE
/// impact between distinct bodies, plus cloth self-collisions.
pub fn detect(surfaces: &[Surface], thickness: f64) -> (Vec<Impact>, DetectStats) {
    let (impacts, stats) = detect_in(surfaces, thickness, &BatchArena::disabled());
    (impacts.into_inner(), stats)
}

/// [`detect`] with the candidate/contact lists checked out from a
/// [`BatchArena`]: the face-pair buffer, the raw impact accumulator, and
/// the returned deduplicated impact list all come from (and return to)
/// `arena`, charged to [`MemCategory::Contacts`]. With
/// [`BatchArena::disabled`] this *is* [`detect`]; impacts are
/// bitwise-identical in identical order in every mode.
pub fn detect_in(
    surfaces: &[Surface],
    thickness: f64,
    arena: &BatchArena,
) -> (ArenaVec<Impact>, DetectStats) {
    let mut raw: ArenaVec<Impact> = arena.vec(0, MemCategory::Contacts);
    let mut stats = DetectStats::default();
    let mut face_pairs: ArenaVec<(u32, u32)> = arena.vec(0, MemCategory::Contacts);
    let mut filtered: ArenaVec<(u32, u32)> = arena.vec(0, MemCategory::Contacts);
    for i in 0..surfaces.len() {
        for j in i + 1..surfaces.len() {
            let (a, b) = (&surfaces[i], &surfaces[j]);
            if a.fixed && b.fixed {
                continue;
            }
            if !a.root_aabb().overlaps(&b.root_aabb()) {
                continue;
            }
            stats.body_pairs += 1;
            face_pairs.clear();
            a.bvh.pairs_with(&b.bvh, &mut face_pairs);
            // Canonical order: BVH emission order depends on tree shape
            // (refit keeps the old topology, rebuild re-splits). Sorting
            // makes detection a pure function of the AABB set, so refit
            // and rebuild trees — and cached superset lists — feed the
            // narrow phase bitwise-identically.
            face_pairs.sort_unstable();
            narrowphase_pair(a, b, &face_pairs, thickness, &mut raw, &mut stats);
        }
    }
    // Cloth self-collision.
    for s in surfaces {
        if let BodyId::Cloth(_) = s.body {
            face_pairs.clear();
            s.bvh.self_pairs(&mut face_pairs);
            filtered.clear();
            filtered.extend(face_pairs.iter().copied().filter(|&(fa, fb)| {
                let (a, b) = (s.faces[fa as usize], s.faces[fb as usize]);
                !a.iter().any(|v| b.contains(v))
            }));
            filtered.sort_unstable();
            narrowphase_pair(s, s, &filtered, thickness, &mut raw, &mut stats);
        }
    }
    // Deduplicate VF impacts: a vertex near the shared edge of two
    // coplanar faces of the same body fires against both, producing
    // duplicated constraint rows that make the zone KKT system singular.
    // Keep one impact per (vertex, opposing body, quantized normal),
    // preferring the earliest collision time.
    let mut impacts: ArenaVec<Impact> = arena.vec(raw.len(), MemCategory::Contacts);
    dedup_vf_into(&raw, &mut impacts);
    raw.recharge();
    face_pairs.recharge();
    filtered.recharge();
    impacts.recharge();
    stats.impacts = impacts.len();
    (impacts, stats)
}

/// Per-cache event counters, drained into telemetry by the engine at
/// each commit. Deliberately *not* part of [`DetectStats`]: cache
/// internals must never leak into the stats the refit-vs-rebuild parity
/// oracle compares.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheCounters {
    /// BVH refits (one per surface per detect pass on the cached path).
    pub refits: u64,
    /// BVH (re)builds: initial cache builds plus degradation rebuilds.
    pub rebuilds: u64,
    /// Broad-phase candidate lists served from the cull cache.
    pub cull_cache_hits: u64,
    /// Candidate lists (re)built because a snapshot epoch moved on.
    pub cull_cache_misses: u64,
    /// Zone solves seeded from a previous step's parked multipliers.
    pub warmstart_hits: u64,
    /// Zone solves that fell back to a cold start (key or node mismatch).
    pub warmstart_misses: u64,
}

impl CacheCounters {
    /// Accumulate another snapshot into this one (per-step → lifetime
    /// rollup at commit).
    pub fn absorb(&mut self, o: CacheCounters) {
        self.refits += o.refits;
        self.rebuilds += o.rebuilds;
        self.cull_cache_hits += o.cull_cache_hits;
        self.cull_cache_misses += o.cull_cache_misses;
        self.warmstart_hits += o.warmstart_hits;
        self.warmstart_misses += o.warmstart_misses;
    }
}

/// A cached broad-phase candidate list for one surface pair (`a == b`
/// for cloth self-collision), valid while both surfaces' snapshot
/// epochs are unchanged.
#[derive(Default)]
struct CachedPairs {
    epoch_a: u64,
    epoch_b: u64,
    pairs: Vec<(u32, u32)>,
}

/// Parked per-constraint multipliers from the previous step's zone
/// solves, keyed by the zone's sorted entity list (the paper's localized
/// zones make that the natural identity). λ values are matched back to
/// the next step's constraints by their impact node quadruples. BTreeMap
/// keeps every lookup deterministic without hash-order caveats.
#[derive(Default)]
pub struct WarmStarts {
    map: std::collections::BTreeMap<Vec<zones::Entity>, Vec<([NodeRef; 4], f64)>>,
}

impl WarmStarts {
    /// Parked (nodes, λ) rows for the zone with this entity set, if the
    /// previous step solved one. A changed entity set misses — the
    /// caller falls back to a cold start.
    pub fn get(&self, key: &[zones::Entity]) -> Option<&[([NodeRef; 4], f64)]> {
        self.map.get(key).map(|v| v.as_slice())
    }

    pub fn insert(&mut self, key: Vec<zones::Entity>, rows: Vec<([NodeRef; 4], f64)>) {
        self.map.insert(key, rows);
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Collision state that persists across steps (owned by the engine's
/// `Simulation`, parked between steps): the per-body surfaces with their
/// BVHs, the cross-step broad-phase cull cache, and the warm-start store.
/// Everything here is an accelerator — detection output is bitwise
/// independent of cache history (see [`detect_incremental`]).
#[derive(Default)]
pub struct CollisionState {
    pub surfs: Vec<Surface>,
    /// Candidate lists keyed by surface-index pair; validated against
    /// the two surfaces' snapshot epochs. BTreeMap for determinism. The
    /// retained `pairs` buffers double as the list pool: a rebuild
    /// clears and refills in place.
    pair_cache: std::collections::BTreeMap<(u32, u32), CachedPairs>,
    pub warm: WarmStarts,
    pub counters: CacheCounters,
}

impl CollisionState {
    pub fn new(surfs: Vec<Surface>) -> CollisionState {
        CollisionState { surfs, ..Default::default() }
    }

    /// True iff the cached surfaces still describe `sys`: same body set
    /// in the same order, same mesh topology, same frozen flags. Pure
    /// motion (changed `q` / cloth `x`) matches — positions are re-rolled
    /// from committed state every step — but any topology or body-set
    /// change forces a rebuild.
    pub fn matches(&self, sys: &System) -> bool {
        let nr = sys.rigids.len();
        if self.surfs.len() != nr + sys.cloths.len() {
            return false;
        }
        for (i, b) in sys.rigids.iter().enumerate() {
            let s = &self.surfs[i];
            if s.body != BodyId::Rigid(i as u32)
                || s.fixed != b.frozen
                || s.x0.len() != b.mesh0.verts.len()
                || s.faces != b.mesh0.faces
            {
                return false;
            }
        }
        for (c, cl) in sys.cloths.iter().enumerate() {
            let s = &self.surfs[nr + c];
            if s.body != BodyId::Cloth(c as u32)
                || s.fixed
                || s.x0.len() != cl.x.len()
                || s.faces != cl.faces
            {
                return false;
            }
        }
        true
    }
}

/// [`detect_in`] over a persistent [`CollisionState`], reusing cached
/// broad-phase candidate lists across steps. A cached list is a padded
/// superset (built from snapshot bounds via [`Bvh::pairs_with_margin`])
/// and is valid while both surfaces' AABBs stay inside their snapshots;
/// the narrow phase's exact per-pair AABB filter reduces any such
/// superset to exactly the pairs a fresh query would test, in the same
/// (sorted) order — so impacts and [`DetectStats`] are bitwise-identical
/// to the uncached path, regardless of cache history.
pub fn detect_incremental(
    state: &mut CollisionState,
    thickness: f64,
    pad: f64,
    arena: &BatchArena,
) -> (ArenaVec<Impact>, DetectStats) {
    let CollisionState { surfs, pair_cache, counters, .. } = state;
    for s in surfs.iter_mut() {
        s.validate_cull(pad);
    }
    let mut raw: ArenaVec<Impact> = arena.vec(0, MemCategory::Contacts);
    let mut stats = DetectStats::default();
    let mut scratch: ArenaVec<(u32, u32)> = arena.vec(0, MemCategory::Contacts);
    let n = surfs.len();
    for i in 0..n {
        for j in i + 1..n {
            if surfs[i].fixed && surfs[j].fixed {
                continue;
            }
            if !surfs[i].root_aabb().overlaps(&surfs[j].root_aabb()) {
                continue;
            }
            stats.body_pairs += 1;
            let key = (i as u32, j as u32);
            let hit = pair_cache
                .get(&key)
                .is_some_and(|c| c.epoch_a == surfs[i].epoch && c.epoch_b == surfs[j].epoch);
            if hit {
                counters.cull_cache_hits += 1;
            } else {
                counters.cull_cache_misses += 1;
                // A padded list can only be built while snapshot bounds
                // equal current-bounds-inflated-by-pad; force-resnapshot
                // whichever side went stale. The epoch bumps invalidate
                // that surface's other lists, which rebuild the same way.
                if !surfs[i].fresh {
                    surfs[i].resnapshot(pad);
                }
                if !surfs[j].fresh {
                    surfs[j].resnapshot(pad);
                }
                let entry = pair_cache.entry(key).or_default();
                entry.pairs.clear();
                let (lo, hi) = surfs.split_at(j);
                lo[i].bvh.pairs_with_margin(&hi[0].bvh, 2.0 * pad, &mut entry.pairs);
                entry.pairs.sort_unstable();
                entry.epoch_a = surfs[i].epoch;
                entry.epoch_b = surfs[j].epoch;
            }
            narrowphase_pair(
                &surfs[i],
                &surfs[j],
                &pair_cache[&key].pairs,
                thickness,
                &mut raw,
                &mut stats,
            );
        }
    }
    // Cloth self-collision; the adjacency filter is topology-constant,
    // so it is applied once at list build time.
    for i in 0..n {
        if !matches!(surfs[i].body, BodyId::Cloth(_)) {
            continue;
        }
        let key = (i as u32, i as u32);
        let hit = pair_cache.get(&key).is_some_and(|c| c.epoch_a == surfs[i].epoch);
        if hit {
            counters.cull_cache_hits += 1;
        } else {
            counters.cull_cache_misses += 1;
            if !surfs[i].fresh {
                surfs[i].resnapshot(pad);
            }
            scratch.clear();
            surfs[i].bvh.self_pairs_margin(2.0 * pad, &mut scratch);
            let entry = pair_cache.entry(key).or_default();
            entry.pairs.clear();
            let faces = &surfs[i].faces;
            entry.pairs.extend(scratch.iter().copied().filter(|&(fa, fb)| {
                let (a, b) = (faces[fa as usize], faces[fb as usize]);
                !a.iter().any(|v| b.contains(v))
            }));
            entry.pairs.sort_unstable();
            entry.epoch_a = surfs[i].epoch;
            entry.epoch_b = surfs[i].epoch;
        }
        let s = &surfs[i];
        narrowphase_pair(s, s, &pair_cache[&key].pairs, thickness, &mut raw, &mut stats);
    }
    let mut impacts: ArenaVec<Impact> = arena.vec(raw.len(), MemCategory::Contacts);
    dedup_vf_into(&raw, &mut impacts);
    raw.recharge();
    scratch.recharge();
    impacts.recharge();
    stats.impacts = impacts.len();
    (impacts, stats)
}

fn body_of(n: NodeRef) -> BodyId {
    match n {
        NodeRef::Rigid { body, .. } => BodyId::Rigid(body),
        NodeRef::Cloth { cloth, .. } => BodyId::Cloth(cloth),
    }
}

/// One VF impact per (vertex, opposing body, ~normal); earliest t wins.
/// Writes into `out` (assumed empty) so the output list can be a reused
/// arena buffer.
fn dedup_vf_into(impacts: &[Impact], out: &mut Vec<Impact>) {
    // Entry-lookup only, never iterated: `out` keeps the input scan
    // order (first occurrence wins the slot; earliest t overwrites in
    // place), so hash order cannot reach the impact list.
    // lint:allow(hash-iter: entry-lookup only, out keeps input order)
    let mut best: std::collections::HashMap<(NodeRef, BodyId, [i64; 3]), usize> =
        // lint:allow(hash-iter: continuation of the annotated decl above)
        std::collections::HashMap::new();
    for &im in impacts {
        let is_vf = im.w[3] == 1.0;
        if !is_vf {
            out.push(im);
            continue;
        }
        let nq = [
            (im.n.x * 1e3).round() as i64,
            (im.n.y * 1e3).round() as i64,
            (im.n.z * 1e3).round() as i64,
        ];
        let key = (im.nodes[3], body_of(im.nodes[0]), nq);
        match best.entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => {
                let idx = *e.get();
                if im.t < out[idx].t {
                    out[idx] = im;
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(out.len());
                out.push(im);
            }
        }
    }
}

fn narrowphase_pair(
    a: &Surface,
    b: &Surface,
    face_pairs: &[(u32, u32)],
    thickness: f64,
    impacts: &mut Vec<Impact>,
    stats: &mut DetectStats,
) {
    let same = std::ptr::eq(a, b);
    // Membership probes only (impacts are emitted in face-pair scan
    // order); the sets are never iterated.
    // lint:allow(hash-iter: membership-only, never iterated)
    let mut vf_seen: HashSet<(u32, u32, bool)> = HashSet::new();
    // lint:allow(hash-iter: membership-only, never iterated)
    let mut ee_seen: HashSet<(u32, u32)> = HashSet::new();
    for &(fa, fb) in face_pairs {
        // Exact filter: candidate lists may be padded supersets from the
        // cull cache; only pairs whose current swept AABBs truly overlap
        // reach the tests (and the face_pairs stat), so every list mode
        // — fresh, refit, cached — yields identical downstream work.
        if !a.aabbs[fa as usize].overlaps(&b.aabbs[fb as usize]) {
            continue;
        }
        stats.face_pairs += 1;
        let tri_a = a.faces[fa as usize];
        let tri_b = b.faces[fb as usize];
        // Vertices of B against face of A.
        for &v in &tri_b {
            if same && tri_a.contains(&v) {
                continue;
            }
            if vf_seen.insert((fa, v, false)) {
                stats.vf_tests += 1;
                test_vf(a, tri_a, b, v, thickness, impacts);
            }
        }
        // Vertices of A against face of B.
        for &v in &tri_a {
            if same && tri_b.contains(&v) {
                continue;
            }
            if vf_seen.insert((fb, v, true)) {
                stats.vf_tests += 1;
                test_vf(b, tri_b, a, v, thickness, impacts);
            }
        }
        // Edge–edge.
        for &ea in &a.face_edges[fa as usize] {
            for &eb in &b.face_edges[fb as usize] {
                let e1 = a.edges[ea as usize];
                let e2 = b.edges[eb as usize];
                if same && (e1.contains(&e2[0]) || e1.contains(&e2[1])) {
                    continue;
                }
                if ee_seen.insert((ea, eb)) {
                    stats.ee_tests += 1;
                    test_ee(a, e1, b, e2, thickness, impacts);
                }
            }
        }
    }
}

fn test_vf(
    face_surf: &Surface,
    tri: [u32; 3],
    vert_surf: &Surface,
    v: u32,
    thickness: f64,
    impacts: &mut Vec<Impact>,
) {
    let x = [
        face_surf.x0[tri[0] as usize],
        face_surf.x0[tri[1] as usize],
        face_surf.x0[tri[2] as usize],
        vert_surf.x0[v as usize],
    ];
    let d = [
        face_surf.x1[tri[0] as usize] - x[0],
        face_surf.x1[tri[1] as usize] - x[1],
        face_surf.x1[tri[2] as usize] - x[2],
        vert_surf.x1[v as usize] - x[3],
    ];
    let hit = ccd::ccd_vertex_face(x, d, thickness).or_else(|| {
        let xe = [
            face_surf.x1[tri[0] as usize],
            face_surf.x1[tri[1] as usize],
            face_surf.x1[tri[2] as usize],
            vert_surf.x1[v as usize],
        ];
        ccd::proximity_vertex_face(xe, thickness)
    });
    if let Some(h) = hit {
        impacts.push(Impact {
            nodes: [
                face_surf.node_ref(tri[0]),
                face_surf.node_ref(tri[1]),
                face_surf.node_ref(tri[2]),
                vert_surf.node_ref(v),
            ],
            w: [-h.alpha[0], -h.alpha[1], -h.alpha[2], 1.0],
            n: h.n,
            t: h.t,
        });
    }
}

fn test_ee(
    sa: &Surface,
    e1: [u32; 2],
    sb: &Surface,
    e2: [u32; 2],
    thickness: f64,
    impacts: &mut Vec<Impact>,
) {
    let x = [
        sa.x0[e1[0] as usize],
        sa.x0[e1[1] as usize],
        sb.x0[e2[0] as usize],
        sb.x0[e2[1] as usize],
    ];
    let d = [
        sa.x1[e1[0] as usize] - x[0],
        sa.x1[e1[1] as usize] - x[1],
        sb.x1[e2[0] as usize] - x[2],
        sb.x1[e2[1] as usize] - x[3],
    ];
    let hit = ccd::ccd_edge_edge(x, d, thickness).or_else(|| {
        let xe = [
            sa.x1[e1[0] as usize],
            sa.x1[e1[1] as usize],
            sb.x1[e2[0] as usize],
            sb.x1[e2[1] as usize],
        ];
        ccd::proximity_edge_edge(xe, thickness)
    });
    if let Some(h) = hit {
        impacts.push(Impact {
            nodes: [
                sa.node_ref(e1[0]),
                sa.node_ref(e1[1]),
                sb.node_ref(e2[0]),
                sb.node_ref(e2[1]),
            ],
            w: [-h.alpha[0], -h.alpha[1], h.alpha[2], h.alpha[3]],
            n: h.n,
            t: h.t,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::RigidBody;
    use crate::mesh::primitives::{cloth_grid, unit_box};

    fn falling_box_system(height: f64) -> (System, Vec<Vec<Vec3>>, Vec<Vec<Vec3>>) {
        let mut sys = System::new();
        let ground = RigidBody::frozen_from_mesh(
            crate::mesh::primitives::box_mesh(Vec3::new(5.0, 0.5, 5.0)),
        )
        .with_position(Vec3::new(0.0, -0.5, 0.0));
        sys.add_rigid(ground);
        let cube = RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, height, 0.0));
        sys.add_rigid(cube);
        // Candidate positions: cube moves down by `height` (through floor).
        let r0 = sys.rigids[0].world_verts();
        let mut r1 = sys.rigids[1].world_verts();
        for v in &mut r1 {
            v.y -= height;
        }
        (sys.clone(), vec![r0, sys.rigids[1].world_verts()], vec![sys.rigids[0].world_verts(), r1])
    }

    #[test]
    fn falling_cube_hits_ground() {
        let (sys, _x0, x1) = falling_box_system(1.0);
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, stats) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty(), "stats: {stats:?}");
        // All impacts involve the cube (body 1) and the ground (body 0).
        for im in &impacts {
            let bodies: HashSet<_> = im
                .nodes
                .iter()
                .map(|n| match n {
                    NodeRef::Rigid { body, .. } => *body,
                    _ => 99,
                })
                .collect();
            assert!(bodies.contains(&1));
        }
        // The VF contacts with the ground's top face point up. (EE
        // impacts at cube corners legitimately have diagonal normals.)
        let up = impacts.iter().filter(|im| im.n.y > 0.7).count();
        assert!(up >= 1, "no upward-normal impacts");
    }

    #[test]
    fn separated_bodies_no_impacts() {
        let (sys, _x0, mut x1) = falling_box_system(3.0);
        // Candidate barely moves: no contact.
        for v in &mut x1[1] {
            v.y += 2.9; // ends at 2.9 above ground
        }
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(impacts.is_empty(), "found {} impacts", impacts.len());
    }

    #[test]
    fn cloth_vertex_hits_rigid_face() {
        let mut sys = System::new();
        let cube = RigidBody::frozen_from_mesh(unit_box());
        sys.add_rigid(cube);
        let cloth = crate::bodies::Cloth::from_grid(
            cloth_grid(4, 4, 1.0, 1.0).translated(Vec3::new(0.0, 1.0, 0.0)),
            0.1,
            100.0,
            1.0,
            0.0,
        );
        sys.add_cloth(cloth);
        let r1 = vec![sys.rigids[0].world_verts()];
        // Cloth falls 0.6 (through the cube top at y=0.5).
        let c1: Vec<Vec3> =
            sys.cloths[0].x.iter().map(|&p| p - Vec3::new(0.0, 0.6, 0.0)).collect();
        let surfs = surfaces_from_system(&sys, &r1, &[c1], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty());
        let has_cloth = impacts.iter().any(|im| {
            im.nodes.iter().any(|n| matches!(n, NodeRef::Cloth { .. }))
        });
        assert!(has_cloth);
    }

    #[test]
    fn impact_gap_sign_convention() {
        // A VF impact's gap should be positive when the vertex is on the
        // normal side, negative when penetrated.
        let (sys, _x0, x1) = falling_box_system(1.0);
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        let im = impacts[0];
        // Gap at start-of-step (cube above ground): positive.
        let gap0 = im.gap(|n| sys.node_pos(n));
        assert!(gap0 > 0.0, "gap0 = {gap0}");
    }

    fn assert_impacts_bits_eq(a: &[Impact], b: &[Impact], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: impact count");
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.nodes, y.nodes, "{what}: impact {k} nodes");
            for c in 0..4 {
                assert_eq!(x.w[c].to_bits(), y.w[c].to_bits(), "{what}: impact {k} w[{c}]");
            }
            assert_eq!(x.n.x.to_bits(), y.n.x.to_bits(), "{what}: impact {k} n.x");
            assert_eq!(x.n.y.to_bits(), y.n.y.to_bits(), "{what}: impact {k} n.y");
            assert_eq!(x.n.z.to_bits(), y.n.z.to_bits(), "{what}: impact {k} n.z");
            assert_eq!(x.t.to_bits(), y.t.to_bits(), "{what}: impact {k} t");
        }
    }

    #[test]
    fn incremental_detect_matches_plain_bitwise() {
        // Drive a persistent CollisionState through several pseudo-steps
        // of cube motion and compare against fresh surfaces + plain
        // detection each time: impacts and stats must be bit-identical
        // regardless of cache history (hits, misses, resnapshots).
        let (sys, _x0, x1) = falling_box_system(1.0);
        let mut cs = CollisionState::new(surfaces_from_system(&sys, &x1, &[], 1e-3));
        assert!(cs.matches(&sys));
        let arena = BatchArena::disabled();
        for step in 0..8 {
            let mut x1s = x1.clone();
            for v in &mut x1s[1] {
                v.y -= 0.02 * step as f64;
            }
            for (i, s) in cs.surfs.iter_mut().enumerate() {
                s.update_candidates(&x1s[i], 1e-3);
                s.rebuild_if_degraded(4.0);
            }
            let (inc, istats) = detect_incremental(&mut cs, 1e-3, 0.05, &arena);
            let fresh = surfaces_from_system(&sys, &x1s, &[], 1e-3);
            let (pl, pstats) = detect(&fresh, 1e-3);
            assert_eq!(istats, pstats, "step {step}");
            assert_impacts_bits_eq(&inc, &pl, &format!("step {step}"));
        }
        let c = cs.counters;
        assert!(c.cull_cache_hits > 0, "no cull-cache hits across steps: {c:?}");
        assert!(c.cull_cache_misses > 0, "first pass should miss: {c:?}");
    }

    #[test]
    fn collision_state_matches_detects_topology_changes() {
        let (sys, _x0, x1) = falling_box_system(1.0);
        let cs = CollisionState::new(surfaces_from_system(&sys, &x1, &[], 1e-3));
        assert!(cs.matches(&sys));
        // Pure motion still matches.
        let mut moved = sys.clone();
        moved.rigids[1].q[4] += 0.5;
        assert!(cs.matches(&moved));
        // Body-set change: rebuild.
        let mut grown = sys.clone();
        grown.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        assert!(!cs.matches(&grown));
        // Topology change on an existing body: rebuild.
        let mut retopo = sys.clone();
        retopo.rigids[1].mesh0.faces.swap(0, 1);
        assert!(!cs.matches(&retopo));
        // Frozen-flag change: rebuild.
        let mut thawed = sys.clone();
        thawed.rigids[0].frozen = false;
        assert!(!cs.matches(&thawed));
    }

    #[test]
    fn warm_starts_key_on_entity_set() {
        use zones::Entity;
        let mut w = WarmStarts::default();
        assert!(w.is_empty());
        let key = vec![Entity::Rigid(1), Entity::Rigid(2)];
        let nodes = [
            NodeRef::Rigid { body: 1, vert: 0 },
            NodeRef::Rigid { body: 1, vert: 1 },
            NodeRef::Rigid { body: 1, vert: 2 },
            NodeRef::Rigid { body: 2, vert: 0 },
        ];
        w.insert(key.clone(), vec![(nodes, 3.5)]);
        assert_eq!(w.len(), 1);
        assert_eq!(w.get(&key), Some(&[(nodes, 3.5)][..]));
        // A changed entity set misses — the caller cold-starts.
        let other = vec![Entity::Rigid(1), Entity::Rigid(3)];
        assert!(w.get(&other).is_none());
        assert!(w.get(&key[..1]).is_none());
        w.clear();
        assert!(w.get(&key).is_none());
    }

    #[test]
    fn fixed_fixed_pairs_skipped() {
        let mut sys = System::new();
        sys.add_rigid(RigidBody::frozen_from_mesh(unit_box()));
        sys.add_rigid(
            RigidBody::frozen_from_mesh(unit_box()).with_position(Vec3::new(0.2, 0.0, 0.0)),
        );
        let x1 = vec![sys.rigids[0].world_verts(), sys.rigids[1].world_verts()];
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, stats) = detect(&surfs, 1e-3);
        assert!(impacts.is_empty());
        assert_eq!(stats.body_pairs, 0);
    }
}
