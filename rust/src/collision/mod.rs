//! Scalable collision handling (paper §5): BVH broadphase ([`bvh`]) over
//! swept face bounds ([`aabb`]), continuous + proximity narrowphase
//! ([`ccd`]) producing [`Impact`]s (Eq. 4), grouped into independent
//! impact zones ([`zones`]).
//!
//! The detection pass's candidate/contact lists (broadphase face pairs,
//! raw and deduplicated impacts) dominate its transient memory. They can
//! be checked out from a cross-scene
//! [`BatchArena`](crate::util::arena::BatchArena) via [`detect_in`] so a
//! batch reuses one warm set of buffers instead of allocating per scene
//! per step; [`detect`] is the plain-allocation wrapper. Both produce
//! bitwise-identical impacts in identical order — pooling only changes
//! which allocation backs a list, never its contents.
pub mod aabb;
pub mod bvh;
pub mod ccd;
pub mod zones;

use crate::bodies::{NodeRef, System};
use crate::math::Vec3;
use crate::util::arena::{ArenaVec, BatchArena};
use crate::util::memory::MemCategory;
use aabb::Aabb;
use bvh::Bvh;
// lint:allow(hash-iter: membership-only HashSets (narrowphase dedup) — never iterated)
use std::collections::HashSet;

/// Which body a surface belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BodyId {
    Rigid(u32),
    Cloth(u32),
}

/// An impact: one VF or EE contact pair (paper Eq. 4), normalized to the
/// constraint form C(x) = n · Σᵢ wᵢ·xᵢ − δ ≥ 0 over four surface nodes.
#[derive(Clone, Copy, Debug)]
pub struct Impact {
    pub nodes: [NodeRef; 4],
    /// Signed weights: VF ⇒ [−α₁, −α₂, −α₃, 1]; EE ⇒ [−α₁, −α₂, α₃, α₄].
    pub w: [f64; 4],
    pub n: Vec3,
    /// Collision time within the step ([0,1]; 1 for proximity contacts).
    pub t: f64,
}

impl Impact {
    /// Evaluate C(x) + δ = n·Σwᵢxᵢ given node positions.
    pub fn gap(&self, pos: impl Fn(NodeRef) -> Vec3) -> f64 {
        let mut s = 0.0;
        for k in 0..4 {
            s += self.w[k] * self.n.dot(pos(self.nodes[k]));
        }
        s
    }
}

/// Per-body surface snapshot used by the collision pass: start-of-step
/// and candidate end-of-step world positions.
pub struct Surface {
    pub body: BodyId,
    pub faces: Vec<[u32; 3]>,
    pub edges: Vec<[u32; 2]>,
    pub x0: Vec<Vec3>,
    pub x1: Vec<Vec3>,
    pub fixed: bool,
    pub bvh: Bvh,
    aabbs: Vec<Aabb>,
    /// Edges per face (indices into `edges`) for EE dedup.
    face_edges: Vec<[u32; 3]>,
}

impl Surface {
    pub fn new(
        body: BodyId,
        faces: Vec<[u32; 3]>,
        x0: Vec<Vec3>,
        x1: Vec<Vec3>,
        fixed: bool,
        thickness: f64,
    ) -> Surface {
        // Unique edges + face→edge map. The map is lookup-only: edge
        // ids and the `edges` list are assigned in face-scan order, so
        // hash order never reaches any output.
        // lint:allow(hash-iter: entry-lookup only, outputs are scan-ordered)
        let mut edge_map = std::collections::HashMap::new();
        let mut edges: Vec<[u32; 2]> = Vec::new();
        let mut face_edges = Vec::with_capacity(faces.len());
        for f in &faces {
            let mut fe = [0u32; 3];
            for k in 0..3 {
                let (a, b) = (f[k], f[(k + 1) % 3]);
                let key = if a < b { (a, b) } else { (b, a) };
                let id = *edge_map.entry(key).or_insert_with(|| {
                    edges.push([key.0, key.1]);
                    edges.len() - 1
                });
                fe[k] = id as u32;
            }
            face_edges.push(fe);
        }
        let aabbs: Vec<Aabb> = faces
            .iter()
            .map(|f| {
                Aabb::swept_tri(
                    x0[f[0] as usize],
                    x0[f[1] as usize],
                    x0[f[2] as usize],
                    x1[f[0] as usize],
                    x1[f[1] as usize],
                    x1[f[2] as usize],
                    thickness,
                )
            })
            .collect();
        let bvh = Bvh::build(&aabbs);
        Surface { body, faces, edges, x0, x1, fixed, bvh, aabbs, face_edges }
    }

    fn node_ref(&self, local: u32) -> NodeRef {
        match self.body {
            BodyId::Rigid(b) => NodeRef::Rigid { body: b, vert: local },
            BodyId::Cloth(c) => NodeRef::Cloth { cloth: c, node: local },
        }
    }

    pub fn root_aabb(&self) -> Aabb {
        self.bvh.root_aabb()
    }

    /// Update the candidate end-of-step positions and refit the BVH in
    /// place (topology unchanged) — O(n) instead of a fresh build. The
    /// per-step hot path: fail-safe passes re-detect after zone solves.
    pub fn update_candidates(&mut self, x1: Vec<Vec3>, thickness: f64) {
        assert_eq!(x1.len(), self.x1.len());
        self.x1 = x1;
        for (f, bb) in self.faces.iter().zip(self.aabbs.iter_mut()) {
            *bb = Aabb::swept_tri(
                self.x0[f[0] as usize],
                self.x0[f[1] as usize],
                self.x0[f[2] as usize],
                self.x1[f[0] as usize],
                self.x1[f[1] as usize],
                self.x1[f[2] as usize],
                thickness,
            );
        }
        self.bvh.refit(&self.aabbs);
    }
}

/// Build surfaces from the system: `x1` come from candidate positions
/// provided per body (same layout as the body's vertices).
pub fn surfaces_from_system(
    sys: &System,
    rigid_x1: &[Vec<Vec3>],
    cloth_x1: &[Vec<Vec3>],
    thickness: f64,
) -> Vec<Surface> {
    let mut out = Vec::with_capacity(sys.rigids.len() + sys.cloths.len());
    for (i, b) in sys.rigids.iter().enumerate() {
        out.push(Surface::new(
            BodyId::Rigid(i as u32),
            b.mesh0.faces.clone(),
            b.world_verts(),
            rigid_x1[i].clone(),
            b.frozen,
            thickness,
        ));
    }
    for (c, cl) in sys.cloths.iter().enumerate() {
        out.push(Surface::new(
            BodyId::Cloth(c as u32),
            cl.faces.clone(),
            cl.x.clone(),
            cloth_x1[c].clone(),
            false,
            thickness,
        ));
    }
    out
}

/// Statistics from one detection pass (coordinator metrics / Fig. 2).
#[derive(Clone, Copy, Debug, Default)]
pub struct DetectStats {
    pub body_pairs: usize,
    pub face_pairs: usize,
    pub vf_tests: usize,
    pub ee_tests: usize,
    pub impacts: usize,
}

/// Full collision detection across all surfaces. Returns every VF and EE
/// impact between distinct bodies, plus cloth self-collisions.
pub fn detect(surfaces: &[Surface], thickness: f64) -> (Vec<Impact>, DetectStats) {
    let (impacts, stats) = detect_in(surfaces, thickness, &BatchArena::disabled());
    (impacts.into_inner(), stats)
}

/// [`detect`] with the candidate/contact lists checked out from a
/// [`BatchArena`]: the face-pair buffer, the raw impact accumulator, and
/// the returned deduplicated impact list all come from (and return to)
/// `arena`, charged to [`MemCategory::Contacts`]. With
/// [`BatchArena::disabled`] this *is* [`detect`]; impacts are
/// bitwise-identical in identical order in every mode.
pub fn detect_in(
    surfaces: &[Surface],
    thickness: f64,
    arena: &BatchArena,
) -> (ArenaVec<Impact>, DetectStats) {
    let mut raw: ArenaVec<Impact> = arena.vec(0, MemCategory::Contacts);
    let mut stats = DetectStats::default();
    let mut face_pairs: ArenaVec<(u32, u32)> = arena.vec(0, MemCategory::Contacts);
    for i in 0..surfaces.len() {
        for j in i + 1..surfaces.len() {
            let (a, b) = (&surfaces[i], &surfaces[j]);
            if a.fixed && b.fixed {
                continue;
            }
            if !a.root_aabb().overlaps(&b.root_aabb()) {
                continue;
            }
            stats.body_pairs += 1;
            face_pairs.clear();
            a.bvh.pairs_with(&b.bvh, &mut face_pairs);
            stats.face_pairs += face_pairs.len();
            narrowphase_pair(a, b, &face_pairs, thickness, &mut raw, &mut stats);
        }
    }
    // Cloth self-collision.
    for s in surfaces {
        if let BodyId::Cloth(_) = s.body {
            face_pairs.clear();
            s.bvh.self_pairs(&mut face_pairs);
            let filtered: Vec<(u32, u32)> = face_pairs
                .iter()
                .copied()
                .filter(|&(fa, fb)| {
                    let (a, b) = (s.faces[fa as usize], s.faces[fb as usize]);
                    !a.iter().any(|v| b.contains(v))
                })
                .collect();
            stats.face_pairs += filtered.len();
            narrowphase_pair(s, s, &filtered, thickness, &mut raw, &mut stats);
        }
    }
    // Deduplicate VF impacts: a vertex near the shared edge of two
    // coplanar faces of the same body fires against both, producing
    // duplicated constraint rows that make the zone KKT system singular.
    // Keep one impact per (vertex, opposing body, quantized normal),
    // preferring the earliest collision time.
    let mut impacts: ArenaVec<Impact> = arena.vec(raw.len(), MemCategory::Contacts);
    dedup_vf_into(&raw, &mut impacts);
    raw.recharge();
    face_pairs.recharge();
    impacts.recharge();
    stats.impacts = impacts.len();
    (impacts, stats)
}

fn body_of(n: NodeRef) -> BodyId {
    match n {
        NodeRef::Rigid { body, .. } => BodyId::Rigid(body),
        NodeRef::Cloth { cloth, .. } => BodyId::Cloth(cloth),
    }
}

/// One VF impact per (vertex, opposing body, ~normal); earliest t wins.
/// Writes into `out` (assumed empty) so the output list can be a reused
/// arena buffer.
fn dedup_vf_into(impacts: &[Impact], out: &mut Vec<Impact>) {
    // Entry-lookup only, never iterated: `out` keeps the input scan
    // order (first occurrence wins the slot; earliest t overwrites in
    // place), so hash order cannot reach the impact list.
    // lint:allow(hash-iter: entry-lookup only, out keeps input order)
    let mut best: std::collections::HashMap<(NodeRef, BodyId, [i64; 3]), usize> =
        // lint:allow(hash-iter: continuation of the annotated decl above)
        std::collections::HashMap::new();
    for &im in impacts {
        let is_vf = im.w[3] == 1.0;
        if !is_vf {
            out.push(im);
            continue;
        }
        let nq = [
            (im.n.x * 1e3).round() as i64,
            (im.n.y * 1e3).round() as i64,
            (im.n.z * 1e3).round() as i64,
        ];
        let key = (im.nodes[3], body_of(im.nodes[0]), nq);
        match best.entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => {
                let idx = *e.get();
                if im.t < out[idx].t {
                    out[idx] = im;
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(out.len());
                out.push(im);
            }
        }
    }
}

fn narrowphase_pair(
    a: &Surface,
    b: &Surface,
    face_pairs: &[(u32, u32)],
    thickness: f64,
    impacts: &mut Vec<Impact>,
    stats: &mut DetectStats,
) {
    let same = std::ptr::eq(a, b);
    // Membership probes only (impacts are emitted in face-pair scan
    // order); the sets are never iterated.
    // lint:allow(hash-iter: membership-only, never iterated)
    let mut vf_seen: HashSet<(u32, u32, bool)> = HashSet::new();
    // lint:allow(hash-iter: membership-only, never iterated)
    let mut ee_seen: HashSet<(u32, u32)> = HashSet::new();
    for &(fa, fb) in face_pairs {
        if !a.aabbs[fa as usize].overlaps(&b.aabbs[fb as usize]) {
            continue;
        }
        let tri_a = a.faces[fa as usize];
        let tri_b = b.faces[fb as usize];
        // Vertices of B against face of A.
        for &v in &tri_b {
            if same && tri_a.contains(&v) {
                continue;
            }
            if vf_seen.insert((fa, v, false)) {
                stats.vf_tests += 1;
                test_vf(a, tri_a, b, v, thickness, impacts);
            }
        }
        // Vertices of A against face of B.
        for &v in &tri_a {
            if same && tri_b.contains(&v) {
                continue;
            }
            if vf_seen.insert((fb, v, true)) {
                stats.vf_tests += 1;
                test_vf(b, tri_b, a, v, thickness, impacts);
            }
        }
        // Edge–edge.
        for &ea in &a.face_edges[fa as usize] {
            for &eb in &b.face_edges[fb as usize] {
                let e1 = a.edges[ea as usize];
                let e2 = b.edges[eb as usize];
                if same && (e1.contains(&e2[0]) || e1.contains(&e2[1])) {
                    continue;
                }
                if ee_seen.insert((ea, eb)) {
                    stats.ee_tests += 1;
                    test_ee(a, e1, b, e2, thickness, impacts);
                }
            }
        }
    }
}

fn test_vf(
    face_surf: &Surface,
    tri: [u32; 3],
    vert_surf: &Surface,
    v: u32,
    thickness: f64,
    impacts: &mut Vec<Impact>,
) {
    let x = [
        face_surf.x0[tri[0] as usize],
        face_surf.x0[tri[1] as usize],
        face_surf.x0[tri[2] as usize],
        vert_surf.x0[v as usize],
    ];
    let d = [
        face_surf.x1[tri[0] as usize] - x[0],
        face_surf.x1[tri[1] as usize] - x[1],
        face_surf.x1[tri[2] as usize] - x[2],
        vert_surf.x1[v as usize] - x[3],
    ];
    let hit = ccd::ccd_vertex_face(x, d, thickness).or_else(|| {
        let xe = [
            face_surf.x1[tri[0] as usize],
            face_surf.x1[tri[1] as usize],
            face_surf.x1[tri[2] as usize],
            vert_surf.x1[v as usize],
        ];
        ccd::proximity_vertex_face(xe, thickness)
    });
    if let Some(h) = hit {
        impacts.push(Impact {
            nodes: [
                face_surf.node_ref(tri[0]),
                face_surf.node_ref(tri[1]),
                face_surf.node_ref(tri[2]),
                vert_surf.node_ref(v),
            ],
            w: [-h.alpha[0], -h.alpha[1], -h.alpha[2], 1.0],
            n: h.n,
            t: h.t,
        });
    }
}

fn test_ee(
    sa: &Surface,
    e1: [u32; 2],
    sb: &Surface,
    e2: [u32; 2],
    thickness: f64,
    impacts: &mut Vec<Impact>,
) {
    let x = [
        sa.x0[e1[0] as usize],
        sa.x0[e1[1] as usize],
        sb.x0[e2[0] as usize],
        sb.x0[e2[1] as usize],
    ];
    let d = [
        sa.x1[e1[0] as usize] - x[0],
        sa.x1[e1[1] as usize] - x[1],
        sb.x1[e2[0] as usize] - x[2],
        sb.x1[e2[1] as usize] - x[3],
    ];
    let hit = ccd::ccd_edge_edge(x, d, thickness).or_else(|| {
        let xe = [
            sa.x1[e1[0] as usize],
            sa.x1[e1[1] as usize],
            sb.x1[e2[0] as usize],
            sb.x1[e2[1] as usize],
        ];
        ccd::proximity_edge_edge(xe, thickness)
    });
    if let Some(h) = hit {
        impacts.push(Impact {
            nodes: [
                sa.node_ref(e1[0]),
                sa.node_ref(e1[1]),
                sb.node_ref(e2[0]),
                sb.node_ref(e2[1]),
            ],
            w: [-h.alpha[0], -h.alpha[1], h.alpha[2], h.alpha[3]],
            n: h.n,
            t: h.t,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::RigidBody;
    use crate::mesh::primitives::{cloth_grid, unit_box};

    fn falling_box_system(height: f64) -> (System, Vec<Vec<Vec3>>, Vec<Vec<Vec3>>) {
        let mut sys = System::new();
        let ground = RigidBody::frozen_from_mesh(
            crate::mesh::primitives::box_mesh(Vec3::new(5.0, 0.5, 5.0)),
        )
        .with_position(Vec3::new(0.0, -0.5, 0.0));
        sys.add_rigid(ground);
        let cube = RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, height, 0.0));
        sys.add_rigid(cube);
        // Candidate positions: cube moves down by `height` (through floor).
        let r0 = sys.rigids[0].world_verts();
        let mut r1 = sys.rigids[1].world_verts();
        for v in &mut r1 {
            v.y -= height;
        }
        (sys.clone(), vec![r0, sys.rigids[1].world_verts()], vec![sys.rigids[0].world_verts(), r1])
    }

    #[test]
    fn falling_cube_hits_ground() {
        let (sys, _x0, x1) = falling_box_system(1.0);
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, stats) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty(), "stats: {stats:?}");
        // All impacts involve the cube (body 1) and the ground (body 0).
        for im in &impacts {
            let bodies: HashSet<_> = im
                .nodes
                .iter()
                .map(|n| match n {
                    NodeRef::Rigid { body, .. } => *body,
                    _ => 99,
                })
                .collect();
            assert!(bodies.contains(&1));
        }
        // The VF contacts with the ground's top face point up. (EE
        // impacts at cube corners legitimately have diagonal normals.)
        let up = impacts.iter().filter(|im| im.n.y > 0.7).count();
        assert!(up >= 1, "no upward-normal impacts");
    }

    #[test]
    fn separated_bodies_no_impacts() {
        let (sys, _x0, mut x1) = falling_box_system(3.0);
        // Candidate barely moves: no contact.
        for v in &mut x1[1] {
            v.y += 2.9; // ends at 2.9 above ground
        }
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(impacts.is_empty(), "found {} impacts", impacts.len());
    }

    #[test]
    fn cloth_vertex_hits_rigid_face() {
        let mut sys = System::new();
        let cube = RigidBody::frozen_from_mesh(unit_box());
        sys.add_rigid(cube);
        let cloth = crate::bodies::Cloth::from_grid(
            cloth_grid(4, 4, 1.0, 1.0).translated(Vec3::new(0.0, 1.0, 0.0)),
            0.1,
            100.0,
            1.0,
            0.0,
        );
        sys.add_cloth(cloth);
        let r1 = vec![sys.rigids[0].world_verts()];
        // Cloth falls 0.6 (through the cube top at y=0.5).
        let c1: Vec<Vec3> =
            sys.cloths[0].x.iter().map(|&p| p - Vec3::new(0.0, 0.6, 0.0)).collect();
        let surfs = surfaces_from_system(&sys, &r1, &[c1], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty());
        let has_cloth = impacts.iter().any(|im| {
            im.nodes.iter().any(|n| matches!(n, NodeRef::Cloth { .. }))
        });
        assert!(has_cloth);
    }

    #[test]
    fn impact_gap_sign_convention() {
        // A VF impact's gap should be positive when the vertex is on the
        // normal side, negative when penetrated.
        let (sys, _x0, x1) = falling_box_system(1.0);
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        let im = impacts[0];
        // Gap at start-of-step (cube above ground): positive.
        let gap0 = im.gap(|n| sys.node_pos(n));
        assert!(gap0 > 0.0, "gap0 = {gap0}");
    }

    #[test]
    fn fixed_fixed_pairs_skipped() {
        let mut sys = System::new();
        sys.add_rigid(RigidBody::frozen_from_mesh(unit_box()));
        sys.add_rigid(
            RigidBody::frozen_from_mesh(unit_box()).with_position(Vec3::new(0.2, 0.0, 0.0)),
        );
        let x1 = vec![sys.rigids[0].world_verts(), sys.rigids[1].world_verts()];
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, stats) = detect(&surfs, 1e-3);
        assert!(impacts.is_empty());
        assert_eq!(stats.body_pairs, 0);
    }
}
