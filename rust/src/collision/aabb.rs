//! Axis-aligned bounding boxes, including swept boxes over a timestep
//! (the CCD broadphase bounds motion from x₀ to x₁).

use crate::math::Vec3;

#[derive(Clone, Copy, Debug)]
pub struct Aabb {
    pub lo: Vec3,
    pub hi: Vec3,
}

impl Aabb {
    pub fn empty() -> Aabb {
        Aabb { lo: Vec3::splat(f64::INFINITY), hi: Vec3::splat(f64::NEG_INFINITY) }
    }

    pub fn point(p: Vec3) -> Aabb {
        Aabb { lo: p, hi: p }
    }

    pub fn from_points(ps: &[Vec3]) -> Aabb {
        let mut b = Aabb::empty();
        for &p in ps {
            b.grow(p);
        }
        b
    }

    #[inline]
    pub fn grow(&mut self, p: Vec3) {
        self.lo = self.lo.min_c(p);
        self.hi = self.hi.max_c(p);
    }

    #[inline]
    pub fn union(&self, o: &Aabb) -> Aabb {
        Aabb { lo: self.lo.min_c(o.lo), hi: self.hi.max_c(o.hi) }
    }

    /// Inflate uniformly by `m` on all sides (collision thickness).
    pub fn inflated(&self, m: f64) -> Aabb {
        Aabb { lo: self.lo - Vec3::splat(m), hi: self.hi + Vec3::splat(m) }
    }

    #[inline]
    pub fn overlaps(&self, o: &Aabb) -> bool {
        self.lo.x <= o.hi.x
            && o.lo.x <= self.hi.x
            && self.lo.y <= o.hi.y
            && o.lo.y <= self.hi.y
            && self.lo.z <= o.hi.z
            && o.lo.z <= self.hi.z
    }

    pub fn center(&self) -> Vec3 {
        (self.lo + self.hi) * 0.5
    }

    pub fn extent(&self) -> Vec3 {
        self.hi - self.lo
    }

    /// Index of the longest axis (0, 1, 2).
    pub fn longest_axis(&self) -> usize {
        let e = self.extent();
        if e.x >= e.y && e.x >= e.z {
            0
        } else if e.y >= e.z {
            1
        } else {
            2
        }
    }

    pub fn is_empty(&self) -> bool {
        self.lo.x > self.hi.x
    }

    /// Surface area (2·(xy + yz + zx)); 0 for empty boxes. The BVH quality
    /// heuristic sums these per node to track refit-induced inflation.
    pub fn surface_area(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let e = self.extent();
        2.0 * (e.x * e.y + e.y * e.z + e.z * e.x)
    }

    /// True iff `o` lies entirely inside `self` (empty `o` is contained
    /// everywhere). Used by the cull cache to validate padded snapshots.
    pub fn contains(&self, o: &Aabb) -> bool {
        o.is_empty()
            || (self.lo.x <= o.lo.x
                && self.lo.y <= o.lo.y
                && self.lo.z <= o.lo.z
                && o.hi.x <= self.hi.x
                && o.hi.y <= self.hi.y
                && o.hi.z <= self.hi.z)
    }

    /// Swept bounds of a triangle moving linearly from `a0,b0,c0` to
    /// `a1,b1,c1`, inflated by thickness `m`.
    #[allow(clippy::too_many_arguments)]
    pub fn swept_tri(a0: Vec3, b0: Vec3, c0: Vec3, a1: Vec3, b1: Vec3, c1: Vec3, m: f64) -> Aabb {
        let mut b = Aabb::empty();
        for p in [a0, b0, c0, a1, b1, c1] {
            b.grow(p);
        }
        b.inflated(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_and_overlap() {
        let a = Aabb::from_points(&[Vec3::new(0.0, 0.0, 0.0), Vec3::new(1.0, 1.0, 1.0)]);
        let b = Aabb::from_points(&[Vec3::new(2.0, 0.0, 0.0), Vec3::new(3.0, 1.0, 1.0)]);
        assert!(!a.overlaps(&b));
        assert!(!a.inflated(0.4).overlaps(&b)); // gap is 1.0
        assert!(a.inflated(1.1).overlaps(&b));
        let u = a.union(&b);
        assert_eq!(u.lo, Vec3::new(0.0, 0.0, 0.0));
        assert_eq!(u.hi, Vec3::new(3.0, 1.0, 1.0));
    }

    #[test]
    fn touching_boxes_overlap() {
        let a = Aabb::from_points(&[Vec3::new(0.0, 0.0, 0.0), Vec3::new(1.0, 1.0, 1.0)]);
        let b = Aabb::from_points(&[Vec3::new(1.0, 0.0, 0.0), Vec3::new(2.0, 1.0, 1.0)]);
        assert!(a.overlaps(&b));
    }

    #[test]
    fn longest_axis_and_center() {
        let a = Aabb::from_points(&[Vec3::new(0.0, 0.0, 0.0), Vec3::new(1.0, 5.0, 2.0)]);
        assert_eq!(a.longest_axis(), 1);
        assert_eq!(a.center(), Vec3::new(0.5, 2.5, 1.0));
    }

    #[test]
    fn empty_behaviour() {
        let e = Aabb::empty();
        assert!(e.is_empty());
        let a = Aabb::point(Vec3::new(1.0, 2.0, 3.0));
        assert!(!a.is_empty());
        assert!(!e.overlaps(&a));
        let u = e.union(&a);
        assert_eq!(u.lo, u.hi);
    }

    #[test]
    fn surface_area_and_contains() {
        let unit = Aabb::from_points(&[Vec3::new(0.0, 0.0, 0.0), Vec3::new(1.0, 1.0, 1.0)]);
        assert_eq!(unit.surface_area(), 6.0);
        assert_eq!(Aabb::empty().surface_area(), 0.0);
        let inner = Aabb::from_points(&[Vec3::new(0.2, 0.2, 0.2), Vec3::new(0.8, 0.8, 0.8)]);
        assert!(unit.contains(&inner));
        assert!(!inner.contains(&unit));
        assert!(unit.contains(&unit)); // boundary counts as inside
        let escaped = Aabb::from_points(&[Vec3::new(0.5, 0.5, 0.5), Vec3::new(1.5, 0.8, 0.8)]);
        assert!(!unit.contains(&escaped));
        assert!(unit.contains(&Aabb::empty()));
    }

    #[test]
    fn swept_tri_covers_both_ends() {
        let b = Aabb::swept_tri(
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(0.0, 0.0, 5.0),
            Vec3::new(1.0, 0.0, 5.0),
            Vec3::new(0.0, 1.0, 5.0),
            0.1,
        );
        assert!(b.lo.z <= -0.1 + 1e-15 && b.hi.z >= 5.1 - 1e-15);
    }
}
