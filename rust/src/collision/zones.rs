//! Impact zones (paper §5): "All the impacts in one connected component
//! are said to form an impact zone. Each impact zone is a local area that
//! can be treated independently."
//!
//! Connectivity is via shared *entities*: a rigid body is one entity (all
//! its vertices are tied through its 6 DOFs), a cloth node is one entity.
//! Fixed entities (frozen bodies, pinned nodes) never merge zones — they
//! contribute constraint geometry but no optimization variables.
//!
//! Zones copy their impacts out of the detection pass's contact list, so
//! they are part of the per-step contact memory the batch-extended Fig-3
//! accounting attributes to
//! [`MemCategory::Contacts`](crate::util::memory::MemCategory):
//! [`ImpactZone::bytes`]/[`zones_bytes`] report the logical bytes the
//! engine charges for the zones of one fail-safe pass.

use super::Impact;
use crate::bodies::{NodeRef, System};
// BTreeMap (not HashMap): zone grouping feeds the parallel dispatch
// order, so even intermediate containers iterate deterministically —
// the PR-2 `zone_backward_batch` bug class, now enforced tree-wide by
// `cargo xtask lint` (hash-iter).
use std::collections::BTreeMap;

/// Union–find with path compression + union by size.
#[derive(Clone, Debug, Default)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    pub fn find(&mut self, x: usize) -> usize {
        let mut root = x;
        while self.parent[root] as usize != root {
            root = self.parent[root] as usize;
        }
        // Path compression.
        let mut cur = x;
        while self.parent[cur] as usize != root {
            let next = self.parent[cur] as usize;
            self.parent[cur] = root as u32;
            cur = next;
        }
        root
    }

    pub fn union(&mut self, a: usize, b: usize) {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
    }

    pub fn same(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }
}

/// A movable entity participating in zone optimization.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Entity {
    /// Rigid body index — contributes 6 DOFs.
    Rigid(u32),
    /// (cloth, node) — contributes 3 DOFs.
    ClothNode(u32, u32),
}

impl Entity {
    pub fn dofs(&self) -> usize {
        match self {
            Entity::Rigid(_) => 6,
            Entity::ClothNode(..) => 3,
        }
    }
}

/// Movable entity owning a surface node (None if fixed).
pub fn entity_of(sys: &System, n: NodeRef) -> Option<Entity> {
    match n {
        NodeRef::Rigid { body, .. } => {
            if sys.rigids[body as usize].frozen {
                None
            } else {
                Some(Entity::Rigid(body))
            }
        }
        NodeRef::Cloth { cloth, node } => {
            if sys.cloths[cloth as usize].pinned[node as usize] {
                None
            } else {
                Some(Entity::ClothNode(cloth, node))
            }
        }
    }
}

/// One independent impact zone: its impacts and the movable entities
/// whose generalized coordinates are the optimization variables (Eq. 6).
#[derive(Clone, Debug)]
pub struct ImpactZone {
    pub impacts: Vec<Impact>,
    /// Sorted, deduplicated movable entities.
    pub entities: Vec<Entity>,
}

impl ImpactZone {
    /// Total DOF count n of the zone optimization.
    pub fn n_dofs(&self) -> usize {
        self.entities.iter().map(Entity::dofs).sum()
    }

    /// Constraint count m.
    pub fn n_constraints(&self) -> usize {
        self.impacts.len()
    }

    /// Logical bytes held by this zone's impact and entity lists
    /// (contact-memory accounting; capacity, not length, since that is
    /// what the allocator hands out).
    pub fn bytes(&self) -> usize {
        self.impacts.capacity() * std::mem::size_of::<Impact>()
            + self.entities.capacity() * std::mem::size_of::<Entity>()
    }
}

/// Total [`ImpactZone::bytes`] of one fail-safe pass's zones.
pub fn zones_bytes(zones: &[ImpactZone]) -> usize {
    zones.iter().map(|z| z.bytes()).sum()
}

/// Partition impacts into independent zones (union–find over shared
/// movable entities). Impacts touching only fixed entities are dropped.
pub fn build_zones(sys: &System, impacts: &[Impact]) -> Vec<ImpactZone> {
    // Map entity -> dense id.
    let mut ids: BTreeMap<Entity, usize> = BTreeMap::new();
    let mut ents: Vec<Entity> = Vec::new();
    let mut impact_entities: Vec<Vec<usize>> = Vec::with_capacity(impacts.len());
    for im in impacts {
        let mut list = Vec::with_capacity(4);
        for &n in &im.nodes {
            if let Some(e) = entity_of(sys, n) {
                let id = *ids.entry(e).or_insert_with(|| {
                    ents.push(e);
                    ents.len() - 1
                });
                if !list.contains(&id) {
                    list.push(id);
                }
            }
        }
        impact_entities.push(list);
    }
    let mut uf = UnionFind::new(ents.len());
    for list in &impact_entities {
        for w in list.windows(2) {
            uf.union(w[0], w[1]);
        }
    }
    // Group impacts by the root of their first movable entity; keyed
    // by dense root id, so `into_values` below already walks zones in
    // a scheduling-independent order before the final sort.
    let mut zones: BTreeMap<usize, ImpactZone> = BTreeMap::new();
    for (k, im) in impacts.iter().enumerate() {
        let Some(&first) = impact_entities[k].first() else {
            continue; // all-fixed impact: nothing to optimize
        };
        let root = uf.find(first);
        let z = zones.entry(root).or_insert_with(|| ImpactZone {
            impacts: Vec::new(),
            entities: Vec::new(),
        });
        z.impacts.push(*im);
        for &eid in &impact_entities[k] {
            z.entities.push(ents[eid]);
        }
    }
    let mut out: Vec<ImpactZone> = zones
        .into_values()
        .map(|mut z| {
            z.entities.sort();
            z.entities.dedup();
            z
        })
        .collect();
    // Deterministic order (largest zones first helps the pool balance).
    out.sort_by(|a, b| {
        b.impacts
            .len()
            .cmp(&a.impacts.len())
            .then_with(|| a.entities.cmp(&b.entities))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{Cloth, RigidBody, System};
    use crate::math::Vec3;
    use crate::mesh::primitives::{cloth_grid, unit_box};
    use crate::util::quick::quick;

    #[test]
    fn union_find_components() {
        quick("union-find", 50, |g| {
            let n = g.usize(2, 100);
            let mut uf = UnionFind::new(n);
            let mut naive: Vec<usize> = (0..n).collect();
            for _ in 0..g.usize(0, 2 * n) {
                let (a, b) = (g.usize(0, n - 1), g.usize(0, n - 1));
                uf.union(a, b);
                // Naive: relabel.
                let (la, lb) = (naive[a], naive[b]);
                if la != lb {
                    for x in naive.iter_mut() {
                        if *x == lb {
                            *x = la;
                        }
                    }
                }
            }
            for a in 0..n {
                for b in 0..n {
                    assert_eq!(uf.same(a, b), naive[a] == naive[b], "{a} {b}");
                }
            }
        });
    }

    fn make_impact(sys: &System, a: NodeRef, b: NodeRef) -> Impact {
        let _ = sys;
        Impact {
            nodes: [a, a, a, b],
            w: [-0.4, -0.3, -0.3, 1.0],
            n: Vec3::new(0.0, 1.0, 0.0),
            t: 0.5,
        }
    }

    #[test]
    fn zones_separate_disconnected_pairs() {
        let mut sys = System::new();
        for k in 0..4 {
            sys.add_rigid(
                RigidBody::from_mesh(unit_box(), 1.0)
                    .with_position(Vec3::new(3.0 * k as f64, 0.0, 0.0)),
            );
        }
        // Impacts: (0,1) and (2,3) — two independent zones.
        let impacts = vec![
            make_impact(
                &sys,
                NodeRef::Rigid { body: 0, vert: 0 },
                NodeRef::Rigid { body: 1, vert: 0 },
            ),
            make_impact(
                &sys,
                NodeRef::Rigid { body: 2, vert: 0 },
                NodeRef::Rigid { body: 3, vert: 0 },
            ),
        ];
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 2);
        for z in &zones {
            assert_eq!(z.entities.len(), 2);
            assert_eq!(z.n_dofs(), 12);
            assert_eq!(z.n_constraints(), 1);
        }
    }

    #[test]
    fn chain_merges_into_one_zone() {
        let mut sys = System::new();
        for _ in 0..4 {
            sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        }
        let impacts = vec![
            make_impact(
                &sys,
                NodeRef::Rigid { body: 0, vert: 0 },
                NodeRef::Rigid { body: 1, vert: 0 },
            ),
            make_impact(
                &sys,
                NodeRef::Rigid { body: 1, vert: 1 },
                NodeRef::Rigid { body: 2, vert: 0 },
            ),
            make_impact(
                &sys,
                NodeRef::Rigid { body: 2, vert: 1 },
                NodeRef::Rigid { body: 3, vert: 0 },
            ),
        ];
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 1);
        assert_eq!(zones[0].entities.len(), 4);
        assert_eq!(zones[0].n_dofs(), 24);
        assert_eq!(zones[0].n_constraints(), 3);
    }

    #[test]
    fn fixed_entities_do_not_merge() {
        let mut sys = System::new();
        let ground = RigidBody::frozen_from_mesh(unit_box());
        sys.add_rigid(ground);
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        // Both cubes touch only the ground: two zones, not one.
        let impacts = vec![
            make_impact(
                &sys,
                NodeRef::Rigid { body: 0, vert: 0 },
                NodeRef::Rigid { body: 1, vert: 0 },
            ),
            make_impact(
                &sys,
                NodeRef::Rigid { body: 0, vert: 1 },
                NodeRef::Rigid { body: 2, vert: 0 },
            ),
        ];
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 2);
        for z in &zones {
            assert_eq!(z.n_dofs(), 6);
        }
    }

    #[test]
    fn cloth_nodes_are_individual_entities() {
        let mut sys = System::new();
        let mut cloth = Cloth::from_grid(cloth_grid(2, 2, 1.0, 1.0), 0.1, 10.0, 1.0, 0.0);
        cloth.pin(0);
        sys.add_cloth(cloth);
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        let impacts = vec![
            // Pinned cloth node (fixed) against rigid 0 → zone of just the body.
            make_impact(
                &sys,
                NodeRef::Cloth { cloth: 0, node: 0 },
                NodeRef::Rigid { body: 0, vert: 0 },
            ),
            // Free cloth node against rigid 0 → merges into the body's zone.
            make_impact(
                &sys,
                NodeRef::Cloth { cloth: 0, node: 4 },
                NodeRef::Rigid { body: 0, vert: 1 },
            ),
        ];
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 1);
        let z = &zones[0];
        assert_eq!(z.n_constraints(), 2);
        assert_eq!(z.n_dofs(), 6 + 3);
        assert!(z.entities.contains(&Entity::Rigid(0)));
        assert!(z.entities.contains(&Entity::ClothNode(0, 4)));
    }

    #[test]
    fn all_fixed_impacts_dropped() {
        let mut sys = System::new();
        sys.add_rigid(RigidBody::frozen_from_mesh(unit_box()));
        sys.add_rigid(RigidBody::frozen_from_mesh(unit_box()));
        let impacts = vec![make_impact(
            &sys,
            NodeRef::Rigid { body: 0, vert: 0 },
            NodeRef::Rigid { body: 1, vert: 0 },
        )];
        assert!(build_zones(&sys, &impacts).is_empty());
    }

    /// `build_zones` must be a pure function of its inputs: zone
    /// grouping feeds the parallel dispatch order, so a container with
    /// nondeterministic iteration order anywhere inside it would
    /// reorder zone solves across runs (the PR-2 `zone_backward_batch`
    /// bug class). Repeated runs must agree exactly — with `HashMap`
    /// grouping this fails, because each instance draws a fresh random
    /// hash seed.
    #[test]
    fn build_zones_is_run_to_run_deterministic() {
        let mut sys = System::new();
        for k in 0..8 {
            sys.add_rigid(
                RigidBody::from_mesh(unit_box(), 1.0)
                    .with_position(Vec3::new(1.5 * k as f64, 0.0, 0.0)),
            );
        }
        // Unequal clusters — {0,1,2}, {3,4}, {5}, {6,7} — so grouping
        // and the size-major sort both have real decisions to make.
        let pairs = [(0, 1), (1, 2), (0, 2), (3, 4), (5, 5), (6, 7)];
        let impacts: Vec<Impact> = pairs
            .iter()
            .map(|&(a, b)| {
                make_impact(
                    &sys,
                    NodeRef::Rigid { body: a, vert: 0 },
                    NodeRef::Rigid { body: b, vert: 1 },
                )
            })
            .collect();
        let reference = format!("{:?}", build_zones(&sys, &impacts));
        for run in 0..32 {
            let again = format!("{:?}", build_zones(&sys, &impacts));
            assert_eq!(again, reference, "zone grouping diverged on run {run}");
        }
    }
}
