//! Binary bounding-volume hierarchy over face AABBs (paper §5: "we employ
//! a bounding volume hierarchy to localize and accelerate dynamic
//! collision detection"). Median-split build, in-place refit, pairwise
//! descent queries (inter-object and self with adjacency filtering).

use super::aabb::Aabb;

#[derive(Clone, Debug)]
struct Node {
    aabb: Aabb,
    /// Leaf: (first, count) into `order`; internal: left child = i+1,
    /// right child = `right`.
    right: u32,
    first: u32,
    count: u32,
}

#[derive(Clone, Debug, Default)]
pub struct Bvh {
    nodes: Vec<Node>,
    /// Primitive indices in tree order.
    order: Vec<u32>,
    /// Primitive AABBs (exact leaf-level filtering).
    prim_aabbs: Vec<Aabb>,
    /// Σ node surface area at the last (re)build — the quality baseline.
    built_sa: f64,
    /// Σ node surface area after the last refit (== `built_sa` at build).
    cur_sa: f64,
}

const LEAF_SIZE: usize = 4;

impl Bvh {
    /// Build over one AABB per primitive.
    pub fn build(aabbs: &[Aabb]) -> Bvh {
        let mut bvh = Bvh::default();
        bvh.rebuild(aabbs);
        bvh
    }

    /// Rebuild in place, reusing the node/order/AABB buffers from the
    /// previous build (the degradation-rebuild path allocates nothing
    /// once the tree has reached steady-state capacity).
    pub fn rebuild(&mut self, aabbs: &[Aabb]) {
        let n = aabbs.len();
        self.nodes.clear();
        self.nodes.reserve(2 * n.max(1));
        self.order.clear();
        self.order.extend(0..n as u32);
        self.prim_aabbs.clear();
        self.prim_aabbs.extend_from_slice(aabbs);
        self.built_sa = 0.0;
        self.cur_sa = 0.0;
        if n == 0 {
            return;
        }
        let centers: Vec<_> = aabbs.iter().map(|b| b.center()).collect();
        self.build_range(aabbs, &centers, 0, n);
        self.built_sa = self.nodes.iter().map(|nd| nd.aabb.surface_area()).sum();
        self.cur_sa = self.built_sa;
    }

    fn build_range(
        &mut self,
        aabbs: &[Aabb],
        centers: &[crate::math::Vec3],
        lo: usize,
        hi: usize,
    ) -> usize {
        let idx = self.nodes.len();
        let mut bb = Aabb::empty();
        for &p in &self.order[lo..hi] {
            bb = bb.union(&aabbs[p as usize]);
        }
        self.nodes.push(Node { aabb: bb, right: 0, first: lo as u32, count: 0 });
        if hi - lo <= LEAF_SIZE {
            self.nodes[idx].count = (hi - lo) as u32;
            return idx;
        }
        let axis = bb.longest_axis();
        let mid = (lo + hi) / 2;
        self.order[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
            // total_cmp: NaN centers (degenerate geometry) get a stable
            // order instead of collapsing to Equal and skewing the split.
            centers[a as usize][axis].total_cmp(&centers[b as usize][axis])
        });
        self.build_range(aabbs, centers, lo, mid);
        let right = self.build_range(aabbs, centers, mid, hi);
        self.nodes[idx].right = right as u32;
        idx
    }

    /// Refit node bounds bottom-up to updated primitive AABBs (topology
    /// unchanged). O(n), no reallocation — the per-step hot path.
    pub fn refit(&mut self, aabbs: &[Aabb]) {
        assert_eq!(aabbs.len(), self.prim_aabbs.len(), "refit with changed topology");
        self.prim_aabbs.copy_from_slice(aabbs);
        let mut sa = 0.0;
        for i in (0..self.nodes.len()).rev() {
            let node = &self.nodes[i];
            let bb = if node.count > 0 {
                let mut bb = Aabb::empty();
                for &p in &self.order[node.first as usize..(node.first + node.count) as usize] {
                    bb = bb.union(&aabbs[p as usize]);
                }
                bb
            } else {
                self.nodes[i + 1].aabb.union(&self.nodes[node.right as usize].aabb)
            };
            sa += bb.surface_area();
            self.nodes[i].aabb = bb;
        }
        self.cur_sa = sa;
    }

    /// Tree-quality ratio: Σ node surface area now vs at the last
    /// (re)build. 1.0 immediately after a build; grows as refits stretch
    /// a stale topology over scattered primitives. The engine rebuilds a
    /// surface's tree once this exceeds `SimConfig::bvh_degrade_ratio`.
    pub fn quality(&self) -> f64 {
        if self.built_sa > 0.0 {
            self.cur_sa / self.built_sa
        } else {
            1.0
        }
    }

    /// Structural invariants, panicking with a description on violation:
    /// a root-reachable traversal visits every node exactly once, every
    /// internal node's AABB contains both children, every leaf AABB
    /// contains its primitives, and every primitive index appears in
    /// exactly one leaf. Test/fuzz hook — O(n), not for the hot path.
    pub fn check_invariants(&self) {
        assert_eq!(self.order.len(), self.prim_aabbs.len(), "order/prim_aabbs length mismatch");
        if self.nodes.is_empty() {
            assert!(self.order.is_empty(), "empty tree over {} primitives", self.order.len());
            return;
        }
        let mut seen_node = vec![false; self.nodes.len()];
        let mut seen_prim = vec![false; self.prim_aabbs.len()];
        let mut stack = vec![0usize];
        while let Some(i) = stack.pop() {
            assert!(i < self.nodes.len(), "child index {i} out of range");
            assert!(!seen_node[i], "node {i} reachable twice");
            seen_node[i] = true;
            let node = &self.nodes[i];
            if node.count > 0 {
                for &p in self.leaf_prims(i) {
                    let p = p as usize;
                    assert!(p < self.prim_aabbs.len(), "primitive {p} out of range");
                    assert!(!seen_prim[p], "primitive {p} in two leaves");
                    seen_prim[p] = true;
                    assert!(
                        node.aabb.contains(&self.prim_aabbs[p]),
                        "leaf {i} does not contain primitive {p}"
                    );
                }
            } else {
                let (l, r) = (i + 1, node.right as usize);
                assert!(l < self.nodes.len() && r < self.nodes.len(), "node {i} child range");
                assert!(node.aabb.contains(&self.nodes[l].aabb), "node {i} excludes left child");
                assert!(node.aabb.contains(&self.nodes[r].aabb), "node {i} excludes right child");
                stack.push(l);
                stack.push(r);
            }
        }
        assert!(seen_node.iter().all(|&s| s), "unreachable nodes in tree");
        assert!(seen_prim.iter().all(|&s| s), "unreachable primitives in tree");
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn root_aabb(&self) -> Aabb {
        if self.is_empty() {
            Aabb::empty()
        } else {
            self.nodes[0].aabb
        }
    }

    fn leaf_prims(&self, node: usize) -> &[u32] {
        let n = &self.nodes[node];
        &self.order[n.first as usize..(n.first + n.count) as usize]
    }

    /// All primitive pairs (a from self, b from other) whose AABBs overlap.
    pub fn pairs_with(&self, other: &Bvh, out: &mut Vec<(u32, u32)>) {
        self.pairs_with_margin(other, 0.0, out);
    }

    /// [`Bvh::pairs_with`] with every `self`-side box inflated by
    /// `margin`: all pairs whose AABBs come within `margin` of touching.
    /// The cull cache snapshots this superset (margin = 2·pad covers
    /// both surfaces' pads) so it stays valid while motion is bounded.
    pub fn pairs_with_margin(&self, other: &Bvh, margin: f64, out: &mut Vec<(u32, u32)>) {
        if self.is_empty() || other.is_empty() {
            return;
        }
        let mut stack = vec![(0usize, 0usize)];
        while let Some((i, j)) = stack.pop() {
            let (a, b) = (&self.nodes[i], &other.nodes[j]);
            if !a.aabb.inflated(margin).overlaps(&b.aabb) {
                continue;
            }
            match (a.count > 0, b.count > 0) {
                (true, true) => {
                    for &pa in self.leaf_prims(i) {
                        for &pb in other.leaf_prims(j) {
                            if self.prim_aabbs[pa as usize]
                                .inflated(margin)
                                .overlaps(&other.prim_aabbs[pb as usize])
                            {
                                out.push((pa, pb));
                            }
                        }
                    }
                }
                (true, false) => {
                    stack.push((i, j + 1));
                    stack.push((i, b.right as usize));
                }
                (false, true) => {
                    stack.push((i + 1, j));
                    stack.push((a.right as usize, j));
                }
                (false, false) => {
                    stack.push((i + 1, j + 1));
                    stack.push((i + 1, b.right as usize));
                    stack.push((a.right as usize, j + 1));
                    stack.push((a.right as usize, b.right as usize));
                }
            }
        }
    }

    /// All unordered primitive pairs within this BVH whose AABBs overlap
    /// (cloth self-collision). Pairs are emitted with a < b.
    pub fn self_pairs(&self, out: &mut Vec<(u32, u32)>) {
        self.self_pairs_margin(0.0, out);
    }

    /// [`Bvh::self_pairs`] with one side of every test inflated by
    /// `margin` — the self-collision counterpart of
    /// [`Bvh::pairs_with_margin`].
    pub fn self_pairs_margin(&self, margin: f64, out: &mut Vec<(u32, u32)>) {
        if self.is_empty() {
            return;
        }
        self.self_pairs_node(0, margin, out);
    }

    fn self_pairs_node(&self, i: usize, m: f64, out: &mut Vec<(u32, u32)>) {
        let n = &self.nodes[i];
        if n.count > 0 {
            let prims = self.leaf_prims(i);
            for a in 0..prims.len() {
                for b in a + 1..prims.len() {
                    let (pa, pb) = (prims[a], prims[b]);
                    if self.prim_aabbs[pa as usize]
                        .inflated(m)
                        .overlaps(&self.prim_aabbs[pb as usize])
                    {
                        out.push((pa.min(pb), pa.max(pb)));
                    }
                }
            }
            return;
        }
        let (l, r) = (i + 1, n.right as usize);
        self.self_pairs_node(l, m, out);
        self.self_pairs_node(r, m, out);
        self.cross_pairs(l, r, m, out);
    }

    fn cross_pairs(&self, i: usize, j: usize, m: f64, out: &mut Vec<(u32, u32)>) {
        let (a, b) = (&self.nodes[i], &self.nodes[j]);
        if !a.aabb.inflated(m).overlaps(&b.aabb) {
            return;
        }
        match (a.count > 0, b.count > 0) {
            (true, true) => {
                for &pa in self.leaf_prims(i) {
                    for &pb in self.leaf_prims(j) {
                        if self.prim_aabbs[pa as usize]
                            .inflated(m)
                            .overlaps(&self.prim_aabbs[pb as usize])
                        {
                            out.push((pa.min(pb), pa.max(pb)));
                        }
                    }
                }
            }
            (true, false) => {
                self.cross_pairs(i, j + 1, m, out);
                self.cross_pairs(i, b.right as usize, m, out);
            }
            (false, true) => {
                self.cross_pairs(i + 1, j, m, out);
                self.cross_pairs(a.right as usize, j, m, out);
            }
            (false, false) => {
                self.cross_pairs(i + 1, j + 1, m, out);
                self.cross_pairs(i + 1, b.right as usize, m, out);
                self.cross_pairs(a.right as usize, j + 1, m, out);
                self.cross_pairs(a.right as usize, b.right as usize, m, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::util::quick::quick;
    use std::collections::HashSet;

    fn random_aabbs(g: &mut crate::util::quick::Gen, n: usize, extent: f64) -> Vec<Aabb> {
        (0..n)
            .map(|_| {
                let c = Vec3::new(g.f64(-5.0, 5.0), g.f64(-5.0, 5.0), g.f64(-5.0, 5.0));
                let e = Vec3::new(g.f64(0.01, extent), g.f64(0.01, extent), g.f64(0.01, extent));
                Aabb { lo: c - e, hi: c + e }
            })
            .collect()
    }

    fn brute_pairs(a: &[Aabb], b: &[Aabb]) -> HashSet<(u32, u32)> {
        let mut s = HashSet::new();
        for i in 0..a.len() {
            for j in 0..b.len() {
                if a[i].overlaps(&b[j]) {
                    s.insert((i as u32, j as u32));
                }
            }
        }
        s
    }

    #[test]
    fn pairs_match_brute_force() {
        quick("bvh-pairs", 25, |g| {
            let na = g.usize(1, 60);
            let nb = g.usize(1, 60);
            let a = random_aabbs(g, na, 1.0);
            let b = random_aabbs(g, nb, 1.0);
            let (ba, bb) = (Bvh::build(&a), Bvh::build(&b));
            let mut out = Vec::new();
            ba.pairs_with(&bb, &mut out);
            let got: HashSet<_> = out.into_iter().collect();
            assert_eq!(got, brute_pairs(&a, &b));
        });
    }

    #[test]
    fn self_pairs_match_brute_force() {
        quick("bvh-self-pairs", 25, |g| {
            let na = g.usize(2, 80);
            let a = random_aabbs(g, na, 0.8);
            let bvh = Bvh::build(&a);
            let mut out = Vec::new();
            bvh.self_pairs(&mut out);
            let got: HashSet<_> = out.into_iter().collect();
            let mut want = HashSet::new();
            for i in 0..a.len() {
                for j in i + 1..a.len() {
                    if a[i].overlaps(&a[j]) {
                        want.insert((i as u32, j as u32));
                    }
                }
            }
            assert_eq!(got, want);
        });
    }

    #[test]
    fn refit_tracks_motion() {
        quick("bvh-refit", 10, |g| {
            let mut a = random_aabbs(g, 40, 0.5);
            let mut bvh = Bvh::build(&a);
            // Move everything, refit, and re-query against a fresh build.
            for bb in &mut a {
                let d = Vec3::new(g.f64(-3.0, 3.0), g.f64(-3.0, 3.0), g.f64(-3.0, 3.0));
                bb.lo += d;
                bb.hi += d;
            }
            bvh.refit(&a);
            let fresh = Bvh::build(&a);
            let mut o1 = Vec::new();
            let mut o2 = Vec::new();
            bvh.self_pairs(&mut o1);
            fresh.self_pairs(&mut o2);
            let s1: HashSet<_> = o1.into_iter().collect();
            let s2: HashSet<_> = o2.into_iter().collect();
            assert_eq!(s1, s2);
        });
    }

    #[test]
    fn empty_and_single() {
        let e = Bvh::build(&[]);
        assert!(e.is_empty());
        e.check_invariants();
        let one = Bvh::build(&[Aabb::point(Vec3::default())]);
        one.check_invariants();
        let mut out = Vec::new();
        one.self_pairs(&mut out);
        assert!(out.is_empty());
        e.pairs_with(&one, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn margin_pairs_match_brute_force() {
        quick("bvh-margin-pairs", 15, |g| {
            let a = random_aabbs(g, g.usize(1, 50), 0.8);
            let b = random_aabbs(g, g.usize(1, 50), 0.8);
            let m = g.f64(0.0, 0.5);
            let (ba, bb) = (Bvh::build(&a), Bvh::build(&b));
            let mut out = Vec::new();
            ba.pairs_with_margin(&bb, m, &mut out);
            let got: HashSet<_> = out.into_iter().collect();
            let inflated: Vec<_> = a.iter().map(|x| x.inflated(m)).collect();
            assert_eq!(got, brute_pairs(&inflated, &b));
            // The margin set is a superset of the exact set.
            let mut exact = Vec::new();
            ba.pairs_with(&bb, &mut exact);
            assert!(exact.iter().all(|p| got.contains(p)));
        });
    }

    fn brute_self(a: &[Aabb]) -> HashSet<(u32, u32)> {
        let mut want = HashSet::new();
        for i in 0..a.len() {
            for j in i + 1..a.len() {
                if a[i].overlaps(&a[j]) {
                    want.insert((i as u32, j as u32));
                }
            }
        }
        want
    }

    #[test]
    fn invariants_after_build_and_refit_sequences() {
        quick("bvh-invariants", 15, |g| {
            let n = g.usize(1, 120);
            let mut a = random_aabbs(g, n, 0.8);
            let mut bvh = Bvh::build(&a);
            bvh.check_invariants();
            for _ in 0..g.usize(1, 4) {
                for bb in &mut a {
                    let d = Vec3::new(g.f64(-2.0, 2.0), g.f64(-2.0, 2.0), g.f64(-2.0, 2.0));
                    bb.lo += d;
                    bb.hi += d;
                }
                bvh.refit(&a);
                bvh.check_invariants();
            }
        });
    }

    #[test]
    fn invariants_through_degradation_rebuild_cycles() {
        quick("bvh-degrade-rebuild", 10, |g| {
            let n = g.usize(8, 80);
            let mut a = random_aabbs(g, n, 0.5);
            let mut bvh = Bvh::build(&a);
            assert!((bvh.quality() - 1.0).abs() < 1e-12);
            let mut rebuilt = false;
            for _ in 0..6 {
                // Scatter primitives far from their build positions so the
                // stale topology inflates and the quality ratio climbs.
                for bb in &mut a {
                    let d = Vec3::new(g.f64(-6.0, 6.0), g.f64(-6.0, 6.0), g.f64(-6.0, 6.0));
                    bb.lo += d;
                    bb.hi += d;
                }
                bvh.refit(&a);
                bvh.check_invariants();
                if bvh.quality() > 2.0 {
                    bvh.rebuild(&a);
                    bvh.check_invariants();
                    assert!((bvh.quality() - 1.0).abs() < 1e-12);
                    rebuilt = true;
                }
                // Queries stay exact through every refit/rebuild cycle.
                let mut out = Vec::new();
                bvh.self_pairs(&mut out);
                let got: HashSet<_> = out.into_iter().collect();
                assert_eq!(got, brute_self(&a));
            }
            assert!(rebuilt, "scatter never degraded the tree enough to trigger a rebuild");
        });
    }

    #[test]
    fn rebuild_matches_fresh_build_bitwise() {
        quick("bvh-rebuild-parity", 10, |g| {
            let n = g.usize(1, 90);
            let mut a = random_aabbs(g, n, 0.7);
            let mut reused = Bvh::build(&random_aabbs(g, n, 0.7));
            for bb in &mut a {
                let d = Vec3::new(g.f64(-3.0, 3.0), g.f64(-3.0, 3.0), g.f64(-3.0, 3.0));
                bb.lo += d;
                bb.hi += d;
            }
            reused.rebuild(&a);
            let fresh = Bvh::build(&a);
            // Identical trees ⇒ identical emission order, not just sets.
            let mut o1 = Vec::new();
            let mut o2 = Vec::new();
            reused.self_pairs(&mut o1);
            fresh.self_pairs(&mut o2);
            assert_eq!(o1, o2);
            assert_eq!(reused.quality().to_bits(), fresh.quality().to_bits());
        });
    }
}
