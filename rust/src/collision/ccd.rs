//! Continuous collision detection (paper §5): vertex–face and edge–edge
//! coplanarity tests over linear trajectories (Bridson et al. 2002),
//! plus static proximity tests used for resting contact. The paper uses
//! CCD specifically because "naive discrete-time impulse-based collision
//! response can lead to completely incorrect gradients" (Hu et al. 2020).

use crate::math::Vec3;

/// Roots of c₃t³ + c₂t² + c₁t + c₀ = 0 inside [0, 1], ascending.
/// Robust bracketed bisection/Newton on monotonic intervals.
///
/// Non-finite coefficients (degenerate/coplanar sweeps on exploding
/// trajectories overflow the cross products) yield no reliable roots:
/// they are rejected up front rather than allowed to poison the knot
/// sort or the bracketing signs mid-rollout, and every interval
/// endpoint is filtered to finite before use.
pub fn cubic_roots_01(c3: f64, c2: f64, c1: f64, c0: f64) -> Vec<f64> {
    if !(c3.is_finite() && c2.is_finite() && c1.is_finite() && c0.is_finite()) {
        return Vec::new();
    }
    // Named fault-injection site: an armed `ccd` firing drops the roots
    // (a conservative miss — the fail-safe re-detection passes and the
    // thickness margin are the backstops, which is exactly what the
    // chaos suite exercises). Constant `false` without the feature.
    if crate::util::faultinject::should_fire(crate::util::faultinject::site::CCD) {
        return Vec::new();
    }
    let f = |t: f64| ((c3 * t + c2) * t + c1) * t + c0;
    // Critical points of the cubic: roots of 3c₃t² + 2c₂t + c₁.
    let mut knots = vec![0.0, 1.0];
    let (a, b, c) = (3.0 * c3, 2.0 * c2, c1);
    if a.abs() > 1e-300 {
        let disc = b * b - 4.0 * a * c;
        if disc >= 0.0 {
            let s = disc.sqrt();
            for r in [(-b - s) / (2.0 * a), (-b + s) / (2.0 * a)] {
                if r.is_finite() && r > 0.0 && r < 1.0 {
                    knots.push(r);
                }
            }
        }
    } else if b.abs() > 1e-300 {
        let r = -c / b;
        if r.is_finite() && r > 0.0 && r < 1.0 {
            knots.push(r);
        }
    }
    knots.sort_by(f64::total_cmp);
    let mut roots = Vec::new();
    let eps = 1e-12;
    for w in knots.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        if hi - lo < 1e-15 {
            continue;
        }
        let (flo, fhi) = (f(lo), f(hi));
        if flo.abs() < eps {
            push_root(&mut roots, lo);
            continue;
        }
        if fhi.abs() < eps {
            push_root(&mut roots, hi);
            continue;
        }
        if flo * fhi > 0.0 {
            continue;
        }
        // Bisection (50 iterations ≈ 1e-15 precision on [0,1]).
        let (mut lo, mut hi, mut flo) = (lo, hi, flo);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            let fm = f(mid);
            if fm == 0.0 {
                lo = mid;
                hi = mid;
                break;
            }
            if flo * fm < 0.0 {
                hi = mid;
            } else {
                lo = mid;
                flo = fm;
            }
        }
        push_root(&mut roots, 0.5 * (lo + hi));
    }
    roots
}

fn push_root(roots: &mut Vec<f64>, r: f64) {
    if r.is_finite() && !roots.iter().any(|&x| (x - r).abs() < 1e-9) {
        roots.push(r);
    }
}

/// Coplanarity cubic for four linearly-moving points: returns the
/// coefficients of (p₂×p₃)·p₄ with pᵢ(t) = (xᵢ−x₁) + t(vᵢ−v₁).
fn coplanarity_cubic(
    x1: Vec3,
    x2: Vec3,
    x3: Vec3,
    x4: Vec3,
    v1: Vec3,
    v2: Vec3,
    v3: Vec3,
    v4: Vec3,
) -> (f64, f64, f64, f64) {
    let a2 = x2 - x1;
    let a3 = x3 - x1;
    let a4 = x4 - x1;
    let b2 = v2 - v1;
    let b3 = v3 - v1;
    let b4 = v4 - v1;
    let c0 = a2.cross(a3).dot(a4);
    let c1 = b2.cross(a3).dot(a4) + a2.cross(b3).dot(a4) + a2.cross(a3).dot(b4);
    let c2 = a2.cross(b3).dot(b4) + b2.cross(a3).dot(b4) + b2.cross(b3).dot(a4);
    let c3 = b2.cross(b3).dot(b4);
    (c3, c2, c1, c0)
}

/// Barycentric coordinates (α₁, α₂, α₃) of the closest point to `p` on
/// triangle (a, b, c), clamped to the triangle.
pub fn closest_point_triangle(p: Vec3, a: Vec3, b: Vec3, c: Vec3) -> (f64, f64, f64) {
    let ab = b - a;
    let ac = c - a;
    let ap = p - a;
    let d1 = ab.dot(ap);
    let d2 = ac.dot(ap);
    if d1 <= 0.0 && d2 <= 0.0 {
        return (1.0, 0.0, 0.0);
    }
    let bp = p - b;
    let d3 = ab.dot(bp);
    let d4 = ac.dot(bp);
    if d3 >= 0.0 && d4 <= d3 {
        return (0.0, 1.0, 0.0);
    }
    let vc = d1 * d4 - d3 * d2;
    if vc <= 0.0 && d1 >= 0.0 && d3 <= 0.0 {
        let v = d1 / (d1 - d3);
        return (1.0 - v, v, 0.0);
    }
    let cp = p - c;
    let d5 = ab.dot(cp);
    let d6 = ac.dot(cp);
    if d6 >= 0.0 && d5 <= d6 {
        return (0.0, 0.0, 1.0);
    }
    let vb = d5 * d2 - d1 * d6;
    if vb <= 0.0 && d2 >= 0.0 && d6 <= 0.0 {
        let w = d2 / (d2 - d6);
        return (1.0 - w, 0.0, w);
    }
    let va = d3 * d6 - d5 * d4;
    if va <= 0.0 && (d4 - d3) >= 0.0 && (d5 - d6) >= 0.0 {
        let w = (d4 - d3) / ((d4 - d3) + (d5 - d6));
        return (0.0, 1.0 - w, w);
    }
    let denom = 1.0 / (va + vb + vc);
    let v = vb * denom;
    let w = vc * denom;
    (1.0 - v - w, v, w)
}

/// Closest-point parameters (s, t) between segments (p1→p2) and (p3→p4),
/// both clamped to [0,1].
pub fn closest_segment_segment(p1: Vec3, p2: Vec3, p3: Vec3, p4: Vec3) -> (f64, f64) {
    let d1 = p2 - p1;
    let d2 = p4 - p3;
    let r = p1 - p3;
    let a = d1.norm2();
    let e = d2.norm2();
    let f = d2.dot(r);
    if a <= 1e-30 && e <= 1e-30 {
        return (0.0, 0.0);
    }
    if a <= 1e-30 {
        return (0.0, (f / e).clamp(0.0, 1.0));
    }
    let c = d1.dot(r);
    if e <= 1e-30 {
        return ((-c / a).clamp(0.0, 1.0), 0.0);
    }
    let b = d1.dot(d2);
    let denom = a * e - b * b;
    let mut s = if denom.abs() > 1e-30 { ((b * f - c * e) / denom).clamp(0.0, 1.0) } else { 0.0 };
    let mut t = (b * s + f) / e;
    if t < 0.0 {
        t = 0.0;
        s = (-c / a).clamp(0.0, 1.0);
    } else if t > 1.0 {
        t = 1.0;
        s = ((b - c) / a).clamp(0.0, 1.0);
    }
    (s, t)
}

/// A detected contact event, in the geometry of paper Eq. 4.
#[derive(Clone, Copy, Debug)]
pub struct Hit {
    /// Collision time within the step, in [0, 1] (1.0 for proximity).
    pub t: f64,
    /// VF: (α₁, α₂, α₃) of the contact point on the face, α₄ = 1 at the
    /// vertex. EE: (α₁, α₂) on edge 1, (α₃, α₄) on edge 2 packed as
    /// [α₁, α₂, α₃, α₄].
    pub alpha: [f64; 4],
    /// Contact normal, oriented so the constraint C ≥ 0 separates.
    pub n: Vec3,
    /// Signed distance along n at the *end* of the step.
    pub dist_end: f64,
}

const COPLANAR_TOL: f64 = 1e-6;

/// Continuous vertex–face test: face (x1, x2, x3) and vertex x4, each
/// moving by `d*` over the step. `thickness` is the contact offset δ.
pub fn ccd_vertex_face(
    x: [Vec3; 4],
    d: [Vec3; 4],
    thickness: f64,
) -> Option<Hit> {
    let (c3, c2, c1, c0) = coplanarity_cubic(x[0], x[1], x[2], x[3], d[0], d[1], d[2], d[3]);
    for t in cubic_roots_01(c3, c2, c1, c0) {
        let p: Vec<Vec3> = (0..4).map(|i| x[i] + d[i] * t).collect();
        let (a1, a2, a3) = closest_point_triangle(p[3], p[0], p[1], p[2]);
        let proj = p[0] * a1 + p[1] * a2 + p[2] * a3;
        let gap = (p[3] - proj).norm();
        // Inside the (slightly inflated) triangle and near the plane?
        if gap < thickness + COPLANAR_TOL {
            // Orient the normal toward the vertex's side at t = 0.
            let nf = (p[1] - p[0]).cross(p[2] - p[0]).normalized();
            if nf.norm2() < 0.5 {
                continue; // degenerate face
            }
            let side0 = {
                let (b1, b2, b3) = closest_point_triangle(x[3], x[0], x[1], x[2]);
                let proj0 = x[0] * b1 + x[1] * b2 + x[2] * b3;
                let n0 = (x[1] - x[0]).cross(x[2] - x[0]).normalized();
                n0.dot(x[3] - proj0)
            };
            let n = if side0 >= 0.0 { nf } else { -nf };
            // Signed end-of-step distance for the constraint RHS.
            let pe: Vec<Vec3> = (0..4).map(|i| x[i] + d[i]).collect();
            let proj_e = pe[0] * a1 + pe[1] * a2 + pe[2] * a3;
            let dist_end = n.dot(pe[3] - proj_e);
            return Some(Hit { t, alpha: [a1, a2, a3, 1.0], n, dist_end });
        }
    }
    None
}

/// Continuous edge–edge test: edge (x1→x2) and edge (x3→x4).
pub fn ccd_edge_edge(x: [Vec3; 4], d: [Vec3; 4], thickness: f64) -> Option<Hit> {
    let (c3, c2, c1, c0) = coplanarity_cubic(x[0], x[1], x[2], x[3], d[0], d[1], d[2], d[3]);
    for t in cubic_roots_01(c3, c2, c1, c0) {
        let p: Vec<Vec3> = (0..4).map(|i| x[i] + d[i] * t).collect();
        let (s, u) = closest_segment_segment(p[0], p[1], p[2], p[3]);
        // Interior contacts only: endpoint cases are covered by the VF
        // tests, and their cross-product normals are ill-defined (a
        // vertical edge grazing a face edge yields junk diagonals that
        // would wrongly constrain tangential motion).
        const END: f64 = 1e-4;
        if !(END..=1.0 - END).contains(&s) || !(END..=1.0 - END).contains(&u) {
            continue;
        }
        let q1 = p[0].lerp(p[1], s);
        let q2 = p[2].lerp(p[3], u);
        if (q2 - q1).norm() < thickness + COPLANAR_TOL {
            let n = (p[1] - p[0]).cross(p[3] - p[2]).normalized();
            if n.norm2() < 0.5 {
                // (Near-)parallel edges: the constraint direction is
                // ill-defined and the contact is covered by VF tests.
                continue;
            }
            let mut n = n;
            // Orient from edge-1 toward edge-2 at t = 0.
            let (s0, u0) = closest_segment_segment(x[0], x[1], x[2], x[3]);
            let w0 = x[2].lerp(x[3], u0) - x[0].lerp(x[1], s0);
            if n.dot(w0) < 0.0 {
                n = -n;
            }
            let pe: Vec<Vec3> = (0..4).map(|i| x[i] + d[i]).collect();
            let dist_end =
                n.dot(pe[2].lerp(pe[3], u) - pe[0].lerp(pe[1], s));
            return Some(Hit { t, alpha: [1.0 - s, s, 1.0 - u, u], n, dist_end });
        }
    }
    None
}

/// Static vertex–face proximity at the end-of-step positions; generates
/// resting/contact constraints before penetration happens.
pub fn proximity_vertex_face(x: [Vec3; 4], thickness: f64) -> Option<Hit> {
    let (a1, a2, a3) = closest_point_triangle(x[3], x[0], x[1], x[2]);
    let proj = x[0] * a1 + x[1] * a2 + x[2] * a3;
    let delta = x[3] - proj;
    let gap = delta.norm();
    if gap >= thickness || gap < 1e-12 {
        return None;
    }
    let nf = (x[1] - x[0]).cross(x[2] - x[0]).normalized();
    if nf.norm2() < 0.5 {
        return None;
    }
    let n = if nf.dot(delta) >= 0.0 { nf } else { -nf };
    Some(Hit { t: 1.0, alpha: [a1, a2, a3, 1.0], n, dist_end: n.dot(delta) })
}

/// Static edge–edge proximity.
pub fn proximity_edge_edge(x: [Vec3; 4], thickness: f64) -> Option<Hit> {
    let (s, u) = closest_segment_segment(x[0], x[1], x[2], x[3]);
    let q1 = x[0].lerp(x[1], s);
    let q2 = x[2].lerp(x[3], u);
    let delta = q2 - q1;
    let gap = delta.norm();
    if gap >= thickness || gap < 1e-12 {
        return None;
    }
    // Interior contacts only (see ccd_edge_edge): endpoint cases are the
    // VF tests' job and carry ill-defined normals.
    const END: f64 = 1e-4;
    if !(END..=1.0 - END).contains(&s) || !(END..=1.0 - END).contains(&u) {
        return None;
    }
    let mut n = (x[1] - x[0]).cross(x[3] - x[2]).normalized();
    if n.norm2() < 0.5 {
        return None; // near-parallel edges: VF covers this contact
    }
    if n.dot(delta) < 0.0 {
        n = -n;
    }
    Some(Hit { t: 1.0, alpha: [1.0 - s, s, 1.0 - u, u], n, dist_end: n.dot(delta) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::quick;

    #[test]
    fn cubic_roots_known() {
        // (t-0.25)(t-0.5)(t-0.75) = t³ -1.5t² +0.6875t -0.09375
        let r = cubic_roots_01(1.0, -1.5, 0.6875, -0.09375);
        assert_eq!(r.len(), 3);
        for (got, want) in r.iter().zip([0.25, 0.5, 0.75]) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // No roots in range.
        assert!(cubic_roots_01(1.0, 0.0, 0.0, 1.0).is_empty());
        // Linear case.
        let r = cubic_roots_01(0.0, 0.0, 2.0, -1.0);
        assert_eq!(r.len(), 1);
        assert!((r[0] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cubic_roots_random_polys() {
        quick("cubic-roots", 200, |g| {
            let roots_true: Vec<f64> = (0..3).map(|_| g.f64(-0.5, 1.5)).collect();
            let (r1, r2, r3) = (roots_true[0], roots_true[1], roots_true[2]);
            // (t-r1)(t-r2)(t-r3)
            let c2 = -(r1 + r2 + r3);
            let c1 = r1 * r2 + r1 * r3 + r2 * r3;
            let c0 = -r1 * r2 * r3;
            let got = cubic_roots_01(1.0, c2, c1, c0);
            // Every claimed root is a root; every true root in (0,1) is found.
            let f = |t: f64| ((t + c2) * t + c1) * t + c0;
            for &r in &got {
                assert!(f(r).abs() < 1e-7, "f({r}) = {}", f(r));
            }
            for &r in &roots_true {
                if r > 1e-6 && r < 1.0 - 1e-6
                    && roots_true.iter().all(|&o| o == r || (o - r).abs() > 1e-4)
                {
                    assert!(
                        got.iter().any(|&x| (x - r).abs() < 1e-6),
                        "missing root {r} in {got:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn cubic_roots_nonfinite_coefficients_yield_no_roots() {
        // Exploding trajectories overflow the coplanarity cross products
        // into inf/NaN coefficients; the solver must return cleanly (no
        // panicking knot sort, no fake bisection "roots").
        for (c3, c2, c1, c0) in [
            (f64::NAN, 0.0, 0.0, 0.0),
            (1.0, f64::NAN, -0.5, 0.25),
            (1.0, f64::INFINITY, -0.5, 0.25),
            (f64::NEG_INFINITY, f64::INFINITY, f64::NAN, 1.0),
            (0.0, 0.0, f64::INFINITY, f64::NAN),
        ] {
            assert!(
                cubic_roots_01(c3, c2, c1, c0).is_empty(),
                "non-finite cubic ({c3}, {c2}, {c1}, {c0}) must yield no roots"
            );
        }
        // Huge-but-finite coefficients: never panic, every claimed root
        // finite and inside [0, 1].
        for r in cubic_roots_01(1e300, -1.5e300, 0.6e300, -0.05e300) {
            assert!(r.is_finite() && (0.0..=1.0).contains(&r), "root {r}");
        }
    }

    #[test]
    fn degenerate_coplanar_vf_sweep_does_not_panic() {
        // All four points and all displacements lie in the y = 0 plane:
        // the coplanarity cubic is identically zero (every t is a
        // "root"), the historical breeding ground for NaN knots. The
        // sweep must complete and report either no hit or a sane one.
        let x = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.3, 0.0, 0.3),
        ];
        let d = [
            Vec3::default(),
            Vec3::default(),
            Vec3::default(),
            Vec3::new(0.5, 0.0, -0.1),
        ];
        if let Some(hit) = ccd_vertex_face(x, d, 1e-3) {
            assert!(hit.t.is_finite() && (0.0..=1.0).contains(&hit.t), "t = {}", hit.t);
            assert!(hit.n.is_finite(), "n = {:?}", hit.n);
        }
        // Fully degenerate: the vertex coincides with a face corner and
        // nothing moves — cubic ≡ 0 with a zero-area closest feature.
        let x = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.0, 0.0, 0.0),
        ];
        let d = [Vec3::default(); 4];
        let _ = ccd_vertex_face(x, d, 1e-3); // must not panic
        let _ = ccd_edge_edge(x, d, 1e-3); // must not panic
        // Non-finite sweep geometry (NaN candidate positions after a
        // solver blow-up) must not panic either.
        let x_bad = [
            Vec3::new(f64::NAN, 0.0, 0.0),
            Vec3::new(1.0, f64::INFINITY, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.3, 1.0, 0.3),
        ];
        let d_bad = [Vec3::default(), Vec3::default(), Vec3::default(), Vec3::new(0.0, -2.0, 0.0)];
        let _ = ccd_vertex_face(x_bad, d_bad, 1e-3);
        let _ = ccd_edge_edge(x_bad, d_bad, 1e-3);
    }

    #[test]
    fn vertex_falls_onto_triangle() {
        let x = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.25, 1.0, 0.25),
        ];
        let d = [Vec3::default(), Vec3::default(), Vec3::default(), Vec3::new(0.0, -2.0, 0.0)];
        let hit = ccd_vertex_face(x, d, 1e-4).expect("must hit");
        assert!((hit.t - 0.5).abs() < 1e-6, "t={}", hit.t);
        assert!(hit.n.dot(Vec3::new(0.0, 1.0, 0.0)) > 0.99, "n={:?}", hit.n);
        // Barycentric of (0.25, 0.25) in that triangle.
        assert!((hit.alpha[0] - 0.5).abs() < 1e-6);
        assert!((hit.alpha[1] - 0.25).abs() < 1e-6);
        assert!((hit.alpha[2] - 0.25).abs() < 1e-6);
        assert!(hit.dist_end < 0.0); // ends up penetrated
    }

    #[test]
    fn vertex_missing_triangle_is_none() {
        let x = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(3.0, 1.0, 3.0), // passes beside the triangle
        ];
        let d = [Vec3::default(), Vec3::default(), Vec3::default(), Vec3::new(0.0, -2.0, 0.0)];
        assert!(ccd_vertex_face(x, d, 1e-4).is_none());
    }

    #[test]
    fn edges_crossing() {
        let x = [
            Vec3::new(-1.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, -1.0),
            Vec3::new(0.0, 1.0, 1.0),
        ];
        let d = [
            Vec3::default(),
            Vec3::default(),
            Vec3::new(0.0, -2.0, 0.0),
            Vec3::new(0.0, -2.0, 0.0),
        ];
        let hit = ccd_edge_edge(x, d, 1e-4).expect("edges must collide");
        assert!((hit.t - 0.5).abs() < 1e-6);
        assert!((hit.alpha[0] - 0.5).abs() < 1e-6); // midpoint of edge 1
        assert!((hit.alpha[2] - 0.5).abs() < 1e-6); // midpoint of edge 2
    }

    #[test]
    fn proximity_tests_fire_inside_thickness() {
        let x = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.2, 0.005, 0.2),
        ];
        let hit = proximity_vertex_face(x, 0.01).expect("within thickness");
        assert!(hit.dist_end > 0.0 && hit.dist_end < 0.01);
        assert!(proximity_vertex_face(x, 0.001).is_none());
    }

    #[test]
    fn closest_point_triangle_regions() {
        let (a, b, c) =
            (Vec3::new(0.0, 0.0, 0.0), Vec3::new(1.0, 0.0, 0.0), Vec3::new(0.0, 1.0, 0.0));
        // Interior.
        let (a1, a2, a3) = closest_point_triangle(Vec3::new(0.25, 0.25, 5.0), a, b, c);
        assert!((a1 - 0.5).abs() < 1e-12 && (a2 - 0.25).abs() < 1e-12 && (a3 - 0.25).abs() < 1e-12);
        // Vertex region.
        let (a1, _, _) = closest_point_triangle(Vec3::new(-1.0, -1.0, 0.0), a, b, c);
        assert_eq!(a1, 1.0);
        // Edge region.
        let (a1, a2, a3) = closest_point_triangle(Vec3::new(0.5, -1.0, 0.0), a, b, c);
        assert!((a1 - 0.5).abs() < 1e-12 && (a2 - 0.5).abs() < 1e-12 && a3 == 0.0);
    }

    #[test]
    fn closest_segments_basic() {
        let (s, t) = closest_segment_segment(
            Vec3::new(-1.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, -1.0),
            Vec3::new(0.0, 1.0, 1.0),
        );
        assert!((s - 0.5).abs() < 1e-12);
        assert!((t - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ccd_agrees_with_dense_sampling() {
        quick("ccd-vs-sampling", 50, |g| {
            let x: Vec<Vec3> = (0..4).map(|_| Vec3::from_slice(&g.vec_normal(3))).collect();
            let d: Vec<Vec3> =
                (0..4).map(|_| Vec3::from_slice(&g.vec_normal(3)) * 0.8).collect();
            let x4 = [x[0], x[1], x[2], x[3]];
            let d4 = [d[0], d[1], d[2], d[3]];
            let hit = ccd_vertex_face(x4, d4, 1e-5);
            // Dense sampling of the vertex–plane gap.
            let mut min_gap = f64::MAX;
            for k in 0..=400 {
                let t = k as f64 / 400.0;
                let p: Vec<Vec3> = (0..4).map(|i| x4[i] + d4[i] * t).collect();
                let (b1, b2, b3) = closest_point_triangle(p[3], p[0], p[1], p[2]);
                let proj = p[0] * b1 + p[1] * b2 + p[2] * b3;
                min_gap = min_gap.min((p[3] - proj).norm());
            }
            if let Some(h) = hit {
                // At the reported time the gap must be tiny.
                let p: Vec<Vec3> = (0..4).map(|i| x4[i] + d4[i] * h.t).collect();
                let (b1, b2, b3) = closest_point_triangle(p[3], p[0], p[1], p[2]);
                let proj = p[0] * b1 + p[1] * b2 + p[2] * b3;
                assert!((p[3] - proj).norm() < 2e-3, "gap at hit = {}", (p[3] - proj).norm());
            } else {
                // No hit ⇒ sampled gap never went below ~thickness.
                assert!(min_gap > 1e-7, "sampling found contact (gap {min_gap}) but CCD missed");
            }
        });
    }
}
