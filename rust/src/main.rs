//! DiffSim CLI — the L3 leader entrypoint.
//!
//! ```text
//! diffsim run --scene scene.json [--steps N] [--pjrt] [--print-every K] [--trace out.jsonl]
//! diffsim experiment <id> [options] [--trace out.jsonl]
//! diffsim info                         # artifact + build info
//! ```

use anyhow::{Context, Result};
use diffsim::engine::scene::build_scene;
use diffsim::util::cli::Args;
use diffsim::util::json::Json;
use diffsim::util::memory;
use diffsim::util::timer::Timer;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("experiment") => diffsim::experiments::run_from_cli(&args),
        Some("info") => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "diffsim — scalable differentiable physics (ICML 2020 reproduction)\n\n\
         USAGE:\n  diffsim run --scene <file.json> [--steps N] [--pjrt] [--trace out.jsonl]\n  \
         diffsim experiment <id> [--sizes a,b,c] [--out file.json] [--trace out.jsonl]\n  \
         diffsim info\n\nEXPERIMENTS:\n{}",
        diffsim::experiments::registry_help()
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let scene_path = args.get("scene").context("--scene <file.json> required")?;
    let text = std::fs::read_to_string(scene_path)
        .with_context(|| format!("reading scene {scene_path}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("scene json: {e}"))?;
    let mut sim = build_scene(&j)?;
    if args.flag("pjrt") {
        let rt = diffsim::runtime::Runtime::load_default()?;
        sim.coordinator = Some(std::sync::Arc::new(diffsim::coordinator::Coordinator::new(
            std::sync::Arc::new(rt),
        )));
        sim.cfg.diff_mode = diffsim::engine::DiffMode::Pjrt;
    }
    let tracing = match args.get("trace") {
        Some(path) => {
            diffsim::obs::enable();
            let tr = diffsim::obs::Trace::to_file(path)
                .with_context(|| format!("creating trace file {path}"))?;
            sim.set_trace(Some(tr));
            println!("[tracing to {path}]");
            true
        }
        None => false,
    };
    let steps = args.usize_or("steps", 300);
    let print_every = args.usize_or("print-every", 50);
    let t = Timer::start();
    for s in 0..steps {
        sim.step();
        if print_every > 0 && (s + 1) % print_every == 0 {
            let st = &sim.last_stats;
            println!(
                "step {:5}  impacts {:5}  zones {:4}  maxdofs {:4}  ke {:.4}",
                s + 1,
                st.impacts,
                st.zones,
                st.max_zone_dofs,
                sim.sys.kinetic_energy()
            );
        }
    }
    println!(
        "done: {} steps in {:.2}s ({:.1} steps/s), peak rss {}",
        steps,
        t.seconds(),
        steps as f64 / t.seconds(),
        memory::fmt_bytes(memory::peak_rss_bytes())
    );
    if tracing {
        sim.set_trace(None); // drops the last handle → flush
        let st = &sim.last_stats;
        println!(
            "[trace] last step: cg_iters {} gn_iters {} passes {}",
            st.cg_iters, st.gn_iters, st.resolve_passes
        );
        diffsim::obs::disable();
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("diffsim {} ({} workers available)", env!("CARGO_PKG_VERSION"),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    match diffsim::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!("artifacts:");
            for name in rt.artifact_names() {
                let spec = rt.spec(&name).unwrap();
                println!("  {name}: inputs {:?}", spec.inputs);
            }
        }
        Err(e) => {
            println!("artifacts: unavailable ({e:#})");
            println!("run `make artifacts` first for the PJRT path");
        }
    }
    if std::path::Path::new("/proc/self/status").exists() {
        println!("rss now: {}", memory::fmt_bytes(memory::current_rss_bytes()));
    }
    Ok(())
}
